"""Quickstart: schedule an All-to-All with FLASH and inspect the Plan IR.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's 4x8 MI300X testbed model, generates a skewed MoE-style
traffic matrix, synthesizes the FLASH schedule through the Scheduler ->
Plan -> Executor pipeline (Birkhoff decomposition over the server-level
matrix), validates byte conservation, times every registered scheduler on
the generic alpha-beta executor, and demonstrates PlanCache reuse on
repeated traffic fingerprints plus the batched serving front door
(``simulate_many`` over a traffic trajectory with compiled execution).
"""

from repro.core import (
    ClusterSpec,
    PlanCache,
    available_schedulers,
    get_scheduler,
    moe_workload,
    simulate,
    simulate_many,
    t_optimal,
)


def main():
    cluster = ClusterSpec(n_servers=4, m_gpus=8,
                          b_intra=64e9, b_inter=12.5e9)
    w = moe_workload(cluster, tokens_per_gpu=8192, bytes_per_token=8192,
                     top_k=2, seed=0)
    print(f"cluster: {cluster.n_servers} servers x {cluster.m_gpus} GPUs, "
          f"intra {cluster.b_intra / 1e9:.0f} GB/s, "
          f"inter {cluster.b_inter / 1e9:.1f} GB/s")
    print(f"workload: {w.total_bytes / 1e6:.1f} MB total "
          f"(MoE top-2 gating, skewed)\n")

    plan = get_scheduler("flash").synthesize(w)
    plan.validate(w)  # byte conservation + permutation structure
    print(f"FLASH synthesized {plan.n_stages} inter-server stages "
          f"in {plan.synth_seconds * 1e6:.0f} us (plan validated):")
    for i, stage in enumerate(plan.stages):
        arrows = " ".join(f"{s}->{d}" for s, d in enumerate(stage.perm)
                          if d >= 0)
        print(f"  stage {i:2d}: {stage.size / 1e6:8.2f} MB/pair  [{arrows}]")

    print(f"\ntheoretical optimum (Thm 1): {t_optimal(w) * 1e3:.2f} ms")
    print(f"{'algorithm':14s} {'time ms':>9s} {'AlgoBW GB/s':>12s}")
    for name in available_schedulers():
        r = simulate(w, name)
        print(f"{name:14s} {r.completion_time * 1e3:9.2f} "
              f"{r.algbw_gbps():12.2f}")

    # Dynamic-MoE reuse: a repeated traffic fingerprint skips synthesis.
    cache = PlanCache()
    for _ in range(3):
        simulate(w, "flash", cache=cache)
    print(f"\nPlanCache over 3 identical iterations: "
          f"{cache.hits} hits / {cache.misses} miss "
          f"(hit rate {cache.hit_rate:.0%})")

    # Batched serving loop: a traffic trajectory through one call.  Cache
    # hits reuse the plan *and* its compiled ExecutableSchedule, so
    # repeated signatures cost one matrix reduction each.
    trajectory = [moe_workload(cluster, 8192, 8192, top_k=2, seed=s)
                  for s in (0, 1, 0, 1, 0)]
    hits0, misses0 = cache.hits, cache.misses
    results = simulate_many(trajectory, "flash", cache=cache)
    print(f"simulate_many over a {len(trajectory)}-step trajectory: "
          f"{cache.hits - hits0} hits / {cache.misses - misses0} misses, "
          f"mean AlgoBW {sum(r.algbw for r in results) / len(results) / 1e9:.2f} GB/s")

    # To share one scheduler (and its cache) across *concurrent* jobs,
    # run it as a daemon instead -- see examples/plan_server_demo.py and
    # DESIGN.md section 2 (PlanServer / PlanClient, warm repair with
    # background upgrades, drift prewarming, telemetry).
    print("\nnext: examples/plan_server_demo.py -- the plan-serving "
          "daemon (repro.serving)")


if __name__ == "__main__":
    main()
