"""End-to-end driver: train a MoE LM with FLASH expert dispatch on a
multi-device mesh (8 fake CPU devices stand in for 2 pods x 2 x 2).

    PYTHONPATH=src python examples/moe_train_flash.py --steps 60

Demonstrates the full stack: synthetic data pipeline -> MoE model with the
FLASH hierarchical All-to-All (EP over pod x data) -> AdamW -> fault-
tolerant Trainer (checkpoint/resume). Loss decreases; swap --a2a to compare
schedules (outputs are bit-identical -- only the collective schedule
changes).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.configs.registry import MoESpec
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.train import TrainOptions, make_train_step
from repro.models import build_model
from repro.optim import init_opt_state
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--a2a", default="flash",
                    choices=["flash", "direct", "hierarchical"])
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_flash")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        smoke_config("megatron-moe-32e"),
        moe=MoESpec(num_experts=4, top_k=2),  # 4 experts == pod*data shards
        a2a_impl=args.a2a)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"a2a_impl={args.a2a}")

    opts = TrainOptions(peak_lr=3e-3, warmup_steps=5,
                        total_steps=args.steps)
    step_fn, state_shape, state_sh, batch_sh_fn = make_train_step(
        cfg, mesh, opts)

    model = build_model(cfg)
    with jax.default_device(jax.devices()[0]):
        params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    state = jax.device_put(state, state_sh)

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch), cfg)

    def batches(step):
        host = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        sh = batch_sh_fn(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), host))
        return jax.device_put(host, sh)

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 3, 1), log_every=5),
        train_step=step_fn,
        init_state=lambda: state,
        batches=batches,
        state_shardings=state_sh,
    )
    result = trainer.run()
    print(f"done at step {result['stopped_at']}: "
          f"loss={result['metrics']['loss']:.4f} "
          f"(preempted={result['preempted']}, "
          f"stragglers={len(result['stragglers'])})")


if __name__ == "__main__":
    main()
