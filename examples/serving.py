"""Batched-request serving demo: prefill + decode with KV cache / SSM state.

    PYTHONPATH=src python examples/serving.py --arch qwen3-0.6b
    PYTHONPATH=src python examples/serving.py --arch xlstm-125m  # recurrent

Serves a batch of prompts with the reduced (smoke) config of any assigned
arch on CPU: prefill emits the decode cache, then tokens stream one step at
a time (greedy).  The same ``make_serve_step`` is what the dry-run lowers
for the decode_32k / long_500k shape cells on the production mesh.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import make_serve_step
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    total = args.prompt_len + args.gen_len

    batch = {"tokens": prompts}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.frontend_len, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_len, cfg.d_model)) * 0.02,
            jnp.float32)

    t0 = time.perf_counter()
    if cfg.encdec:
        from repro.models.encdec import encdec_init_cache
        cache = encdec_init_cache(cfg, args.batch, total,
                                  frames=batch["frames"], params=params)
        toks = prompts[:, 0]
        start = 0
    else:
        from repro.models.transformer import lm_prefill
        logits, cache = lm_prefill(cfg, params, prompts,
                                   batch if cfg.frontend else None,
                                   cache_len=total)
        toks = jnp.argmax(logits, -1)
        start = args.prompt_len
    t_prefill = time.perf_counter() - t0

    step = make_serve_step(cfg, mesh=None)
    out = [toks]
    t0 = time.perf_counter()
    for t in range(start, total - 1):
        logits, cache = step(params, cache, toks, jnp.int32(t))
        toks = jnp.argmax(logits, -1)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0

    gen = np.stack([np.asarray(t) for t in out], 1)
    n_steps = max(gen.shape[1] - 1, 1)
    print(f"arch={cfg.name}: prefill {args.prompt_len} tok in "
          f"{t_prefill * 1e3:.0f} ms; decoded {n_steps} steps x "
          f"batch {args.batch} at "
          f"{args.batch * n_steps / t_decode:.1f} tok/s")
    print("sample:", gen[0, :16])


if __name__ == "__main__":
    main()
