"""Elastic restart: train on one mesh, checkpoint, resume on a DIFFERENT
mesh (the 1000+-node failure/resize story at demo scale).

    PYTHONPATH=src python examples/elastic_restart.py
    PYTHONPATH=src python examples/elastic_restart.py --fault-only

Phase 1 trains on a (2,2,2) pod x data x model mesh and checkpoints.
Phase 2 restores the same (host-gathered, mesh-independent) checkpoint onto
a (4,2) data x model single-pod mesh -- as after losing a pod -- and
continues; the loss trajectory continues from where phase 1 stopped.
Also demonstrates int8 error-feedback gradient compression over the pod
axis (--compress).

Phase 3 (``--fault-only`` runs it alone, without jax) is the scheduler
side of the same elasticity story: a plan-serving daemon survives an
injected mid-job NIC failure.  A FabricMonitor feeds the fail/recover
events into the PlanServer, which re-repairs its warm plan families
against the degraded fabric instead of evicting them; every request in
the event window is answered (zero rejections), with completion bounded
by a small factor of what cold synthesis on the degraded fabric would
give.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse


def run_fault_phase():
    """Phase 3: the plan server rides out a NIC failure (no jax needed)."""
    import numpy as np

    from repro.core import ClusterSpec, Topology, execute_plan, get_scheduler
    from repro.core.traffic import Workload, moe_workload
    from repro.serving import FabricMonitor, PlanClient, PlanServer

    spec = ClusterSpec(n_servers=4, m_gpus=2)
    topo = Topology.homogeneous(4, 2)
    mon = FabricMonitor(topo)

    def drifting(step, scale=0.02):
        base = moe_workload(spec, 512, 64, top_k=2, seed=0)
        rng = np.random.default_rng(step)
        m = base.matrix * (1.0 + scale * rng.standard_normal(
            base.matrix.shape))
        m = np.maximum(m, 0.0)
        np.fill_diagonal(m, 0.0)
        return Workload(spec, m, topo)  # clients keep the ORIGINAL fabric

    print("phase 3: plan server vs mid-job NIC failure")
    worst_ratio = 0.0
    with PlanServer(workers=2) as srv:
        srv.attach_monitor(mon)
        cli = PlanClient(srv, algorithm="flash_ca", timeout=30.0)
        for step in range(4):                      # healthy warmup
            cli.get_plan(drifting(step))
        srv.drain()

        ev = mon.inject("fail", server=0, nic=0)   # the fault
        degraded = mon.current()
        print(f"  injected: {ev.describe()}")
        cold = get_scheduler("flash_ca")
        for step in range(4, 8):                   # event window
            w = drifting(step)
            answer = cli.get_plan(w)               # stale topo: re-homed
            w_deg = Workload(spec, w.matrix, degraded)
            t_served = execute_plan(answer.plan, w_deg).completion_time
            t_cold = execute_plan(cold.synthesize(w_deg),
                                  w_deg).completion_time
            worst_ratio = max(worst_ratio, t_served / t_cold)
        srv.drain()

        mon.inject("recover", server=0, nic=0)     # the heal
        assert mon.current() == topo, "recovery must restore the fabric"
        for step in range(8, 10):
            cli.get_plan(drifting(step))
        srv.drain()

        c = srv.telemetry_snapshot()["counters"]
        print(f"  event-window worst served/cold ratio: {worst_ratio:.3f}")
        print(f"  counters: rerepaired={c.get('rerepaired', 0)} "
              f"stale_topology={c.get('stale_topology', 0)} "
              f"rejected={c.get('rejected', 0)} shed={c.get('shed', 0)} "
              f"errors={c.get('errors', 0)}")
        assert c.get("rejected", 0) == 0 and c.get("shed", 0) == 0
        assert c.get("errors", 0) == 0
        assert cli.counters["inline"] == 0, "daemon must answer everything"
        assert worst_ratio <= 2.0, "slowdown must stay bounded"
    print("fault survival OK: degraded, never stalled")
    return worst_ratio


def run_phase(cfg, mesh, steps, ckpt_dir, data, grad_compression=False):
    # jax and the training stack are imported lazily so --fault-only
    # exercises the scheduler path on boxes without an accelerator stack.
    import jax
    import jax.numpy as jnp

    from repro.launch.train import TrainOptions, make_train_step
    from repro.models import build_model
    from repro.optim import init_opt_state
    from repro.runtime import Trainer, TrainerConfig

    opts = TrainOptions(peak_lr=3e-3, warmup_steps=4, total_steps=steps,
                        grad_compression=grad_compression)
    step_fn, _, state_sh, batch_sh_fn = make_train_step(cfg, mesh, opts)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = jax.device_put(
        {"params": params, "opt": init_opt_state(params),
         "step": jnp.zeros((), jnp.int32)}, state_sh)

    def batches(step):
        host = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        sh = batch_sh_fn(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), host))
        return jax.device_put(host, sh)

    trainer = Trainer(
        TrainerConfig(total_steps=steps, ckpt_dir=ckpt_dir, ckpt_every=10,
                      log_every=5),
        step_fn, lambda: state, batches, state_shardings=state_sh)
    return trainer.run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default="/tmp/repro_elastic")
    ap.add_argument("--compress", action="store_true",
                    help="int8 EF gradient sync over the pod axis (phase 1)")
    ap.add_argument("--fault-only", action="store_true",
                    help="run only phase 3 (plan-server fault survival; "
                         "no jax required)")
    args = ap.parse_args()
    if args.fault_only:
        run_fault_phase()
        return

    import shutil
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    from repro.checkpoint import latest_step
    from repro.configs import smoke_config
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import make_mesh

    cfg = smoke_config("qwen3-0.6b")
    data = SyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8), cfg)

    mesh1 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print("phase 1: multi-pod mesh", mesh1.devices.shape,
          "compress:", args.compress)
    r1 = run_phase(cfg, mesh1, 20, args.ckpt_dir, data,
                   grad_compression=args.compress)
    print(f"  stopped at {r1['stopped_at']}, "
          f"loss={r1['metrics']['loss']:.4f}")
    assert latest_step(args.ckpt_dir) == 20

    mesh2 = make_mesh((4, 2), ("data", "model"))
    print("phase 2: resumed on single-pod mesh", mesh2.devices.shape,
          "(elastic reshard)")
    r2 = run_phase(cfg, mesh2, 40, args.ckpt_dir, data)
    print(f"  stopped at {r2['stopped_at']}, "
          f"loss={r2['metrics']['loss']:.4f}")
    assert r2["stopped_at"] == 40
    assert r2["metrics"]["loss"] < r1["metrics"]["loss"] * 1.2
    print("elastic restart OK: training continued across mesh resize")

    run_fault_phase()


if __name__ == "__main__":
    main()
