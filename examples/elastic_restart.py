"""Elastic restart: train on one mesh, checkpoint, resume on a DIFFERENT
mesh (the 1000+-node failure/resize story at demo scale).

    PYTHONPATH=src python examples/elastic_restart.py

Phase 1 trains on a (2,2,2) pod x data x model mesh and checkpoints.
Phase 2 restores the same (host-gathered, mesh-independent) checkpoint onto
a (4,2) data x model single-pod mesh -- as after losing a pod -- and
continues; the loss trajectory continues from where phase 1 stopped.
Also demonstrates int8 error-feedback gradient compression over the pod
axis (--compress).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step
from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.shardings import batch_shardings
from repro.launch.train import TrainOptions, make_train_step
from repro.models import build_model
from repro.optim import init_opt_state
from repro.runtime import Trainer, TrainerConfig


def run_phase(cfg, mesh, steps, ckpt_dir, data, grad_compression=False):
    opts = TrainOptions(peak_lr=3e-3, warmup_steps=4, total_steps=steps,
                        grad_compression=grad_compression)
    step_fn, _, state_sh, batch_sh_fn = make_train_step(cfg, mesh, opts)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = jax.device_put(
        {"params": params, "opt": init_opt_state(params),
         "step": jnp.zeros((), jnp.int32)}, state_sh)

    def batches(step):
        host = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        sh = batch_sh_fn(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), host))
        return jax.device_put(host, sh)

    trainer = Trainer(
        TrainerConfig(total_steps=steps, ckpt_dir=ckpt_dir, ckpt_every=10,
                      log_every=5),
        step_fn, lambda: state, batches, state_shardings=state_sh)
    return trainer.run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default="/tmp/repro_elastic")
    ap.add_argument("--compress", action="store_true",
                    help="int8 EF gradient sync over the pod axis (phase 1)")
    args = ap.parse_args()
    import shutil
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = smoke_config("qwen3-0.6b")
    data = SyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8), cfg)

    mesh1 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print("phase 1: multi-pod mesh", mesh1.devices.shape,
          "compress:", args.compress)
    r1 = run_phase(cfg, mesh1, 20, args.ckpt_dir, data,
                   grad_compression=args.compress)
    print(f"  stopped at {r1['stopped_at']}, "
          f"loss={r1['metrics']['loss']:.4f}")
    assert latest_step(args.ckpt_dir) == 20

    mesh2 = make_mesh((4, 2), ("data", "model"))
    print("phase 2: resumed on single-pod mesh", mesh2.devices.shape,
          "(elastic reshard)")
    r2 = run_phase(cfg, mesh2, 40, args.ckpt_dir, data)
    print(f"  stopped at {r2['stopped_at']}, "
          f"loss={r2['metrics']['loss']:.4f}")
    assert r2["stopped_at"] == 40
    assert r2["metrics"]["loss"] < r1["metrics"]["loss"] * 1.2
    print("elastic restart OK: training continued across mesh resize")


if __name__ == "__main__":
    main()
