"""Plan-serving daemon demo: concurrent MoE jobs sharing one scheduler.

    PYTHONPATH=src python examples/plan_server_demo.py

Three "jobs" (client threads) replay a drifting MoE dispatch trajectory
against one ``PlanServer`` (see DESIGN.md section 2).  The demo shows the
full serving story on the paper's 4x8 testbed fabric:

  * exact repeats answered from cache on the synchronous fast path,
  * drifted signatures answered immediately via warm repair, then
    upgraded to exact plans by the background synthesizer,
  * the drift predictor prewarming the next step of the trajectory,
  * the telemetry export (counters, per-tier latency percentiles,
    synthesis histogram, queue depth) that a fleet dashboard would scrape.
"""

import json
import threading

import numpy as np

from repro.core import ClusterSpec, moe_workload
from repro.core.traffic import Workload
from repro.serving import PlanClient, PlanServer, Tier


def drifting_trajectory(cluster, steps=24, seed=0):
    """30% exact repeats, ~3% entry drift otherwise (dynamic MoE gating)."""
    rng = np.random.default_rng(seed)
    mats = [moe_workload(cluster, 8192, 4096, top_k=2, seed=seed).matrix]
    for _ in range(1, steps):
        if rng.random() < 0.3 and len(mats) > 1:
            mats.append(mats[int(rng.integers(len(mats)))])
            continue
        nxt = mats[-1].copy()
        sel = rng.random(nxt.shape) < 0.03
        nxt[sel] *= rng.uniform(0.8, 1.2, size=int(sel.sum()))
        np.fill_diagonal(nxt, 0.0)
        mats.append(nxt)
    return [Workload(cluster, m) for m in mats]


def main():
    cluster = ClusterSpec(n_servers=4, m_gpus=8,
                          b_intra=64e9, b_inter=12.5e9)
    traj = drifting_trajectory(cluster)

    with PlanServer(workers=2, prewarm=True) as server:
        clients = [PlanClient(server, algorithm="flash",
                              tier=Tier.INTERACTIVE)
                   for _ in range(3)]

        def job(client, name):
            for w in traj:
                answer = client.get_plan(w)
                if answer.source != "hit":
                    print(f"  [{name}] {answer.source:4s} "
                          f"{answer.latency_s * 1e3:6.2f} ms  "
                          f"exact={answer.exact}")

        print("serving 3 concurrent jobs x "
              f"{len(traj)} steps (misses shown):")
        threads = [threading.Thread(target=job, args=(c, f"job{i}"))
                   for i, c in enumerate(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        server.drain(30.0)  # let upgrades + prewarms settle
        snap = server.telemetry_snapshot()

    counters = snap["counters"]
    lat = snap["latency"]["INTERACTIVE"]
    print(f"\nrequests={counters['requests']} "
          f"hits={counters.get('hits', 0)} "
          f"warm={counters.get('warm', 0)} "
          f"cold={counters.get('cold', 0)} "
          f"upgrades={counters.get('upgrades', 0)} "
          f"prewarmed={counters.get('prewarmed', 0)}")
    print(f"latency p50={lat['p50_us']:.0f}us "
          f"p99={lat['p99_us'] / 1e3:.1f}ms")
    print("\nfull telemetry snapshot:")
    print(json.dumps(snap, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
