"""CI perf-budget guard over the BENCH_*.json trajectory.

Reads a ``benchmarks.run --json`` snapshot and fails on performance
regressions in the guarded series.  Three kinds of budget:

  * **Absolute synthesis budgets** (``BUDGETS``): the flash
    schedule-synthesis rows must stay under generous absolute ceilings
    (several times the observed laptop-class times, so CI variance never
    flakes) -- an accidental return to the interpreted per-stage
    decomposer (the seed is ~30x over the n=32 budget, minutes over the
    n=256 one) fails loudly.

  * **Ratio budgets** (``RATIO_BUDGETS``): the ``synth.hetero{n}`` rows
    (fig_hetero) guard the *relative* cost of capacity-aware synthesis --
    flash_ca must stay within 2x of blind flash on the same degraded
    fabric (observed ~1.3x; the time-domain decomposition shares the
    blind engines' matching machinery, so a larger ratio means an
    accidental extra pass crept in).

  * **Executor budgets** (``EXEC_BUDGETS`` / ``EXEC_SPEEDUP_FLOORS``):
    the ``exec.*`` rows (fig_dynamic) guard compiled plan execution.
    Each series records a baseline (generous multiples of the observed
    times); compiled execution regressing past ``1.5x`` its baseline
    fails -- that is the margin between "CI box is slow" and "someone
    reintroduced per-stage Python on the serving hot path".  The
    ``exec.cached{n}`` row additionally enforces the issue-5 acceptance
    bar: compiled re-execution of a cached plan must stay >= 10x faster
    than the interpreted oracle (observed ~1000x).

  * **Incremental-synthesis guards** (``SYNTH_AMORTIZED_*``): the
    ``dynamic.synth_amortized`` row (fig_dynamic) guards trajectory-fused
    warm synthesis.  The issue-7 acceptance bars: amortized per-step
    synthesis within 10x of compiled execution of the cached plan
    (observed ~7-15x on shared runners, with contended-run outliers, so
    the CI ceiling is a generous backstop), and the incremental engine
    at least 2x faster
    than per-miss one-shot repair (observed ~100-200x; a drop toward 1x
    means the stateful delta path silently fell back to cold
    decomposition).

  * **Serving guards** (``SERVE_*``): the ``serve.*`` rows (fig_serving)
    guard the plan-serving daemon under closed-loop concurrent load.
    The issue-6 acceptance bar: p50 plan-request latency within 10x of
    compiled execution of a cached plan (observed ~4x), a cache hit-rate
    floor of 0.5 on the repeat-heavy trajectory (observed ~0.94), at
    least one background upgrade applied, plan-for-plan parity between
    post-drain served plans and from-scratch synthesis, and a generous
    absolute p99 ceiling (a whole synthesis in the tail is expected; a
    deadlocked or serialized daemon is not).

  * **Fault guards** (``FAULT_*``): the ``fault.*`` rows (fig_fault)
    guard the fabric-event pipeline.  The issue-8 acceptance bars:
    every plan served inside a NIC-failure event window completes within
    ``FAULT_RECOVERY_RATIO_MAX`` of a cold synthesis on the degraded
    fabric (observed ~1.06: topology-change repair re-water-fills the
    old structure against the new pair capacities), zero stalls
    (rejected/shed/errors/inline fallbacks) across the whole run, and at
    least one family actually re-repaired (a zero means the event walk
    silently stopped finding families and every answer went cold).

Usage:  python -m benchmarks.check_synth_budget BENCH_ci.json
"""

from __future__ import annotations

import argparse
import json
import sys

# series name (emitted by fig17_overhead) -> budget in microseconds
BUDGETS = {
    "synth.servers32": 1_000_000.0,    # observed ~65ms; reference ~225ms+
    "synth.servers256": 30_000_000.0,  # observed ~4s; reference ~minutes
}

# series name (emitted by fig_hetero) -> max us_per_call / derived[blind_us]
RATIO_BUDGETS = {
    "synth.hetero16": 2.0,  # observed ~1.3x
    "synth.hetero32": 2.0,  # observed ~1.3x
}

# series name (emitted by fig_dynamic) -> recorded baseline in microseconds.
# A row regressing past EXEC_REGRESSION_FACTOR x its baseline fails CI.
EXEC_BUDGETS = {
    "exec.cached32": 200.0,     # observed ~17us (955-stage FLASH plan)
    "exec.batch32": 400.0,      # observed ~36us/matrix
    "exec.compile32": 60_000.0,  # observed ~8ms, paid once per plan
}
EXEC_REGRESSION_FACTOR = 1.5

# series name -> min derived[speedup] vs the interpreted oracle.
EXEC_SPEEDUP_FLOORS = {
    "exec.cached32": 10.0,  # issue-5 acceptance bar; observed ~1000x
}

# Incremental trajectory synthesis (fig_dynamic) acceptance bars.
SYNTH_AMORTIZED_MAX_RATIO = 35.0  # nominal issue-7 bar: 10x exec.cached32.
                                  # Observed 7-15x, but both the numerator
                                  # and the ~20us denominator ride a
                                  # single-shot chain on a shared runner
                                  # (one contended run measured 31x), so
                                  # the ceiling is a backstop like the
                                  # other exec guards: one-shot repair
                                  # lands ~2000x and a return to per-stage
                                  # Python in the delta path ~60x -- both
                                  # still fail loudly.
SYNTH_SPEEDUP_FLOOR = 2.0         # issue-7 bar: incremental >= 2x one-shot
                                  # repair; observed ~100-200x.

# Plan-serving daemon (fig_serving) acceptance bars.
SERVE_P50_MAX_RATIO = 10.0    # issue-6 bar: p50 / exec_us; observed ~4x
SERVE_P99_CEILING_US = 500_000.0  # tail = one synthesis; observed ~15ms
SERVE_HIT_RATE_FLOOR = 0.5    # repeat-heavy trajectory; observed ~0.94
SERVE_UPGRADES_FLOOR = 1      # background upgrades must actually land

# Fabric-event fault tolerance (fig_fault) acceptance bars.
FAULT_RECOVERY_RATIO_MAX = 2.0  # issue-8 bar: served vs cold on the
                                # degraded fabric; observed ~1.06
FAULT_REREPAIRED_FLOOR = 1      # the event walk must re-repair something

# Plan-exec device loop (fig14) acceptance bars.  Both rows are
# CPU-interpret proxies (fake devices, XLA:CPU-emulated collectives, the
# jnp pack path), so the ceilings are wide regression backstops: the
# correctness gate is the parity flag, the numbers catch a plan lowering
# that silently explodes into per-pair sends.
E2E_PLAN_VS_DIRECT_MAX = 20.0  # measured plan/direct wall-clock ratio;
                               # observed ~1.6 on fake CPU devices
E2E_SIM_PRED_ERR_MAX = 10.0    # |measured-predicted|/predicted against
                               # the simulator's flash/fanout ratio;
                               # observed ~0.45 (no real DCN on CI)


def check(path: str) -> int:
    with open(path) as f:
        snapshot = json.load(f)
    records = {r["name"]: r for r in snapshot["rows"]}
    status = 0
    for name, budget in sorted(BUDGETS.items()):
        rec = records.get(name)
        if rec is None:
            print(f"FAIL {name}: missing from {path} (benchmark renamed or "
                  "skipped?)")
            status = 1
            continue
        us = float(rec["us_per_call"])
        if us > budget:
            print(f"FAIL {name}: {us / 1e6:.2f}s exceeds the "
                  f"{budget / 1e6:.2f}s budget")
            status = 1
        else:
            print(f"ok   {name}: {us / 1e6:.3f}s <= {budget / 1e6:.2f}s")
    for name, max_ratio in sorted(RATIO_BUDGETS.items()):
        rec = records.get(name)
        blind_us = (rec or {}).get("derived", {}).get("blind_us")
        if rec is None or blind_us is None:
            print(f"FAIL {name}: missing from {path} (or no blind_us "
                  "baseline; benchmark renamed or skipped?)")
            status = 1
            continue
        ratio = float(rec["us_per_call"]) / float(blind_us)
        if ratio > max_ratio:
            print(f"FAIL {name}: capacity-aware synthesis is {ratio:.2f}x "
                  f"blind (> {max_ratio:.1f}x budget)")
            status = 1
        else:
            print(f"ok   {name}: capacity-aware/blind = {ratio:.2f}x "
                  f"<= {max_ratio:.1f}x")
    for name, baseline in sorted(EXEC_BUDGETS.items()):
        rec = records.get(name)
        if rec is None:
            print(f"FAIL {name}: missing from {path} (benchmark renamed or "
                  "skipped?)")
            status = 1
            continue
        us = float(rec["us_per_call"])
        ceiling = EXEC_REGRESSION_FACTOR * baseline
        if us > ceiling:
            print(f"FAIL {name}: {us:.1f}us regresses "
                  f"{us / baseline:.2f}x past the {baseline:.0f}us baseline "
                  f"(> {EXEC_REGRESSION_FACTOR:.1f}x)")
            status = 1
        else:
            print(f"ok   {name}: {us:.1f}us <= {ceiling:.0f}us "
                  f"({EXEC_REGRESSION_FACTOR:.1f}x of baseline)")
    for name, floor in sorted(EXEC_SPEEDUP_FLOORS.items()):
        rec = records.get(name)
        speedup = (rec or {}).get("derived", {}).get("speedup", "")
        speedup = speedup.rstrip("x") if speedup else None
        if rec is None or not speedup:
            print(f"FAIL {name}: missing from {path} (or no speedup "
                  "column; benchmark renamed or skipped?)")
            status = 1
            continue
        ratio = float(speedup)
        if ratio < floor:
            print(f"FAIL {name}: compiled execution only {ratio:.1f}x the "
                  f"interpreted oracle (< {floor:.0f}x floor)")
            status = 1
        else:
            print(f"ok   {name}: compiled/interpreted = {ratio:.0f}x "
                  f">= {floor:.0f}x")
    status |= _check_synth_amortized(records)
    status |= _check_serving(records)
    status |= _check_fault(records)
    status |= _check_e2e(records)
    return status


def _check_synth_amortized(records) -> int:
    """The dynamic.synth_amortized row: incremental trajectory synthesis."""
    status = 0
    rec = records.get("dynamic.synth_amortized")
    derived = (rec or {}).get("derived", {})
    ratio = derived.get("ratio", "").rstrip("x")
    speedup = derived.get("speedup", "").rstrip("x")
    if rec is None or not ratio or not speedup:
        print("FAIL dynamic.synth_amortized: missing (or no ratio/speedup "
              "columns; benchmark renamed or skipped?)")
        return 1
    if float(ratio) > SYNTH_AMORTIZED_MAX_RATIO:
        print(f"FAIL dynamic.synth_amortized: {float(ratio):.2f}x compiled "
              f"execution (> {SYNTH_AMORTIZED_MAX_RATIO:.0f}x budget)")
        status = 1
    else:
        print(f"ok   dynamic.synth_amortized: {float(ratio):.2f}x compiled "
              f"execution <= {SYNTH_AMORTIZED_MAX_RATIO:.0f}x")
    if float(speedup) < SYNTH_SPEEDUP_FLOOR:
        print(f"FAIL dynamic.synth_amortized: incremental only "
              f"{float(speedup):.1f}x one-shot repair "
              f"(< {SYNTH_SPEEDUP_FLOOR:.0f}x floor)")
        status = 1
    else:
        print(f"ok   dynamic.synth_amortized: incremental/one-shot = "
              f"{float(speedup):.0f}x >= {SYNTH_SPEEDUP_FLOOR:.0f}x")
    return status


def _check_serving(records) -> int:
    """The fig_serving rows: daemon latency, hit rate, upgrades, parity."""
    status = 0
    p50 = records.get("serve.p50")
    ratio = (p50 or {}).get("derived", {}).get("ratio", "").rstrip("x")
    if p50 is None or not ratio:
        print("FAIL serve.p50: missing (benchmark renamed or skipped?)")
        status = 1
    elif float(ratio) > SERVE_P50_MAX_RATIO:
        print(f"FAIL serve.p50: {float(ratio):.2f}x compiled execution "
              f"(> {SERVE_P50_MAX_RATIO:.0f}x budget)")
        status = 1
    else:
        print(f"ok   serve.p50: {float(ratio):.2f}x compiled execution "
              f"<= {SERVE_P50_MAX_RATIO:.0f}x")
    p99 = records.get("serve.p99")
    if p99 is None:
        print("FAIL serve.p99: missing (benchmark renamed or skipped?)")
        status = 1
    elif float(p99["us_per_call"]) > SERVE_P99_CEILING_US:
        print(f"FAIL serve.p99: {float(p99['us_per_call']) / 1e3:.1f}ms "
              f"exceeds the {SERVE_P99_CEILING_US / 1e3:.0f}ms ceiling")
        status = 1
    else:
        print(f"ok   serve.p99: {float(p99['us_per_call']) / 1e3:.1f}ms "
              f"<= {SERVE_P99_CEILING_US / 1e3:.0f}ms")
    hit = records.get("serve.hit_rate")
    if hit is None:
        print("FAIL serve.hit_rate: missing (benchmark renamed or "
              "skipped?)")
        status = 1
    elif float(hit["us_per_call"]) < SERVE_HIT_RATE_FLOOR:
        print(f"FAIL serve.hit_rate: {float(hit['us_per_call']):.2f} "
              f"below the {SERVE_HIT_RATE_FLOOR:.2f} floor")
        status = 1
    else:
        print(f"ok   serve.hit_rate: {float(hit['us_per_call']):.2f} "
              f">= {SERVE_HIT_RATE_FLOOR:.2f}")
    up = records.get("serve.upgrades")
    parity = (up or {}).get("derived", {}).get("parity")
    if up is None:
        print("FAIL serve.upgrades: missing (benchmark renamed or "
              "skipped?)")
        status = 1
    else:
        if float(up["us_per_call"]) < SERVE_UPGRADES_FLOOR:
            print(f"FAIL serve.upgrades: {up['us_per_call']} background "
                  f"upgrades (< {SERVE_UPGRADES_FLOOR} floor)")
            status = 1
        else:
            print(f"ok   serve.upgrades: {float(up['us_per_call']):.0f} "
                  f">= {SERVE_UPGRADES_FLOOR}")
        if parity != "ok":
            print(f"FAIL serve.upgrades: post-drain plan parity is "
                  f"{parity!r} (served plans must match from-scratch "
                  "synthesis)")
            status = 1
        else:
            print("ok   serve.upgrades: post-drain plan parity holds")
    return status


def _check_fault(records) -> int:
    """The fig_fault rows: bounded slowdown, zero stalls, live re-repair."""
    status = 0
    ratio = records.get("fault.recovery_ratio")
    if ratio is None:
        print("FAIL fault.recovery_ratio: missing (benchmark renamed or "
              "skipped?)")
        status = 1
    else:
        value = float(ratio["us_per_call"])
        if value > FAULT_RECOVERY_RATIO_MAX:
            print(f"FAIL fault.recovery_ratio: {value:.2f}x cold synthesis "
                  f"on the degraded fabric "
                  f"(> {FAULT_RECOVERY_RATIO_MAX:.1f}x budget)")
            status = 1
        else:
            print(f"ok   fault.recovery_ratio: {value:.2f}x "
                  f"<= {FAULT_RECOVERY_RATIO_MAX:.1f}x")
        rerepaired = ratio.get("derived", {}).get("rerepaired")
        if rerepaired is None or int(rerepaired) < FAULT_REREPAIRED_FLOOR:
            print(f"FAIL fault.recovery_ratio: rerepaired="
                  f"{rerepaired!r} (< {FAULT_REREPAIRED_FLOOR} floor; the "
                  "event walk found no families to repair)")
            status = 1
        else:
            print(f"ok   fault.recovery_ratio: rerepaired={rerepaired} "
                  f">= {FAULT_REREPAIRED_FLOOR}")
    stalls = records.get("fault.stalls")
    if stalls is None:
        print("FAIL fault.stalls: missing (benchmark renamed or skipped?)")
        status = 1
    elif float(stalls["us_per_call"]) != 0:
        print(f"FAIL fault.stalls: {stalls['us_per_call']} requests "
              f"stalled/rejected during the fault run "
              f"({stalls['derived_raw']})")
        status = 1
    else:
        print("ok   fault.stalls: 0 across the event window")
    return status


def _check_e2e(records) -> int:
    """The e2e.* rows (fig14): the plan-exec measured-vs-simulated loop."""
    status = 0
    ratio = records.get("e2e.plan_vs_direct")
    parity = (ratio or {}).get("derived", {}).get("parity")
    if ratio is None:
        print("FAIL e2e.plan_vs_direct: missing (benchmark renamed or "
              "skipped?)")
        status = 1
    else:
        if parity != "ok":
            print(f"FAIL e2e.plan_vs_direct: device parity is {parity!r} "
                  "(impl=\"plan\" must stay bit-identical to direct)")
            status = 1
        else:
            print("ok   e2e.plan_vs_direct: device bit-parity holds")
        value = float(ratio["us_per_call"])
        if value > E2E_PLAN_VS_DIRECT_MAX:
            print(f"FAIL e2e.plan_vs_direct: measured {value:.2f}x direct "
                  f"(> {E2E_PLAN_VS_DIRECT_MAX:.0f}x backstop)")
            status = 1
        else:
            print(f"ok   e2e.plan_vs_direct: {value:.2f}x "
                  f"<= {E2E_PLAN_VS_DIRECT_MAX:.0f}x")
    err = records.get("e2e.sim_pred_err")
    if err is None:
        print("FAIL e2e.sim_pred_err: missing (benchmark renamed or "
              "skipped?)")
        status = 1
    else:
        value = float(err["us_per_call"])
        if value > E2E_SIM_PRED_ERR_MAX:
            print(f"FAIL e2e.sim_pred_err: prediction error {value:.2f} "
                  f"(> {E2E_SIM_PRED_ERR_MAX:.0f} backstop)")
            status = 1
        else:
            print(f"ok   e2e.sim_pred_err: {value:.2f} "
                  f"<= {E2E_SIM_PRED_ERR_MAX:.0f} "
                  f"({err['derived_raw']})")
    return status


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("path", help="BENCH_*.json snapshot to check")
    args = parser.parse_args(argv)
    sys.exit(check(args.path))


if __name__ == "__main__":
    main()
