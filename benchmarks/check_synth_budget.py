"""CI synthesis-time regression guard.

Reads a ``benchmarks.run --json`` snapshot and fails if the flash
schedule-synthesis rows exceed generous absolute budgets.  The budgets are
deliberately loose (several times the observed times on a laptop-class CPU)
so CI variance never flakes, while an accidental return to interpreted
per-stage Python -- the seed's O(n^2)-adjacency-rebuild decomposer is ~30x
over the n=32 budget and minutes over the n=256 one -- fails loudly.

The ``synth.hetero{n}`` rows (emitted by fig_hetero) additionally guard the
*relative* cost of capacity-aware synthesis: flash_ca must stay within 2x
of blind flash synthesis on the same degraded-NIC fabric (observed ~1.3x;
the time-domain decomposition shares the blind engines' matching machinery,
so a larger ratio means an accidental extra pass crept in).

Usage:  python -m benchmarks.check_synth_budget BENCH_ci.json
"""

from __future__ import annotations

import argparse
import json
import sys

# series name (emitted by fig17_overhead) -> budget in microseconds
BUDGETS = {
    "synth.servers32": 1_000_000.0,    # observed ~65ms; reference ~225ms+
    "synth.servers256": 30_000_000.0,  # observed ~4s; reference ~minutes
}

# series name (emitted by fig_hetero) -> max us_per_call / derived[blind_us]
RATIO_BUDGETS = {
    "synth.hetero16": 2.0,  # observed ~1.3x
    "synth.hetero32": 2.0,  # observed ~1.3x
}


def check(path: str) -> int:
    with open(path) as f:
        snapshot = json.load(f)
    records = {r["name"]: r for r in snapshot["rows"]}
    status = 0
    for name, budget in sorted(BUDGETS.items()):
        rec = records.get(name)
        if rec is None:
            print(f"FAIL {name}: missing from {path} (benchmark renamed or "
                  "skipped?)")
            status = 1
            continue
        us = float(rec["us_per_call"])
        if us > budget:
            print(f"FAIL {name}: {us / 1e6:.2f}s exceeds the "
                  f"{budget / 1e6:.2f}s budget")
            status = 1
        else:
            print(f"ok   {name}: {us / 1e6:.3f}s <= {budget / 1e6:.2f}s")
    for name, max_ratio in sorted(RATIO_BUDGETS.items()):
        rec = records.get(name)
        blind_us = (rec or {}).get("derived", {}).get("blind_us")
        if rec is None or blind_us is None:
            print(f"FAIL {name}: missing from {path} (or no blind_us "
                  "baseline; benchmark renamed or skipped?)")
            status = 1
            continue
        ratio = float(rec["us_per_call"]) / float(blind_us)
        if ratio > max_ratio:
            print(f"FAIL {name}: capacity-aware synthesis is {ratio:.2f}x "
                  f"blind (> {max_ratio:.1f}x budget)")
            status = 1
        else:
            print(f"ok   {name}: capacity-aware/blind = {ratio:.2f}x "
                  f"<= {max_ratio:.1f}x")
    return status


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("path", help="BENCH_*.json snapshot to check")
    args = parser.parse_args(argv)
    sys.exit(check(args.path))


if __name__ == "__main__":
    main()
