"""CI synthesis-time regression guard.

Reads a ``benchmarks.run --json`` snapshot and fails if the flash
schedule-synthesis rows exceed generous absolute budgets.  The budgets are
deliberately loose (several times the observed times on a laptop-class CPU)
so CI variance never flakes, while an accidental return to interpreted
per-stage Python -- the seed's O(n^2)-adjacency-rebuild decomposer is ~30x
over the n=32 budget and minutes over the n=256 one -- fails loudly.

Usage:  python -m benchmarks.check_synth_budget BENCH_ci.json
"""

from __future__ import annotations

import argparse
import json
import sys

# series name (emitted by fig17_overhead) -> budget in microseconds
BUDGETS = {
    "synth.servers32": 1_000_000.0,    # observed ~65ms; reference ~225ms+
    "synth.servers256": 30_000_000.0,  # observed ~4s; reference ~minutes
}


def check(path: str) -> int:
    with open(path) as f:
        snapshot = json.load(f)
    rows = {r["name"]: float(r["us_per_call"]) for r in snapshot["rows"]}
    status = 0
    for name, budget in sorted(BUDGETS.items()):
        us = rows.get(name)
        if us is None:
            print(f"FAIL {name}: missing from {path} (benchmark renamed or "
                  "skipped?)")
            status = 1
        elif us > budget:
            print(f"FAIL {name}: {us / 1e6:.2f}s exceeds the "
                  f"{budget / 1e6:.2f}s budget")
            status = 1
        else:
            print(f"ok   {name}: {us / 1e6:.3f}s <= {budget / 1e6:.2f}s")
    return status


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("path", help="BENCH_*.json snapshot to check")
    args = parser.parse_args(argv)
    sys.exit(check(args.path))


if __name__ == "__main__":
    main()
