"""Paper Fig 13: AlgoBW and phase breakdown across Zipf skew factors."""

from __future__ import annotations

from repro.core import ClusterSpec, simulate, skewed_workload

from .common import TESTBED, Csv

SKEWS = [0.8, 1.0, 1.2, 1.5, 2.0]


def run(csv: Csv):
    cluster = ClusterSpec(**TESTBED)
    for s in SKEWS:
        w = skewed_workload(cluster, 16 << 20, zipf_s=s, seed=0)
        flash = simulate(w, "flash")
        fan = simulate(w, "fanout")
        spread = simulate(w, "spreadout")
        bd = flash.breakdown
        total = flash.completion_time
        derived = (
            f"algbw_gbps={flash.algbw_gbps():.2f}"
            f"|vs_fanout={flash.algbw / fan.algbw:.1f}x"
            f"|vs_spreadout={flash.algbw / spread.algbw:.2f}x"
            f"|head_pct={100 * bd['head'] / total:.1f}"
            f"|inter_pct={100 * bd['inter'] / total:.1f}"
            f"|tail_pct={100 * bd['tail'] / total:.1f}")
        csv.emit(f"fig13.zipf{s}", total * 1e6, derived)


if __name__ == "__main__":  # CI smoke entry point
    print("name,us_per_call,derived")
    run(Csv())
