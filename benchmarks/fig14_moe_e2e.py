"""Paper Fig 14: MoE end-to-end training speedup, FLASH vs RCCL-fanout.

Step-time model: per-iteration All-to-All times come from the alpha-beta
simulator on MoE-gating traffic (2 dispatch + 2 combine per MoE layer, fwd
+ bwd); compute time per layer is modeled at 40% MFU on MI300X bf16
(1.3 PFLOP/s peak).  Varies (a) expert/server count at fixed top-k, (b)
top-k at fixed 4 servers -- the two sweeps of the figure.

Measured-vs-simulated column (the plan-exec loop): a subprocess with fake
CPU devices runs the *device* exchange both ways -- ``impl="plan"``
(comm.plan_exec, the synthesized schedule lowered into shard_map) against
``direct_all_to_all`` -- on the same MoE matrix, checks bit parity, and
emits

  * ``e2e.plan_vs_direct``: measured wall-clock ratio plan/direct (with
    ``parity=ok`` as the correctness gate), and
  * ``e2e.sim_pred_err``: |measured - predicted| / predicted, where the
    prediction is the simulator's flash/fanout completion ratio on the
    identical workload -- the tracked simulator-prediction-error number.

Both are CPU-interpret proxies (XLA:CPU emulates the collectives; there
is no real DCN), so the CI ceilings in check_synth_budget.py are generous
regression backstops, not fidelity claims.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.core import ClusterSpec, moe_workload, simulate

from .common import TESTBED, Csv

D_MODEL, D_FF, N_MOE_LAYERS = 4096, 28672, 12
TOKENS_PER_GPU = 8192
BYTES_PER_TOKEN = D_MODEL * 2
MI300X_FLOPS = 1.3e15 * 0.4

# Device-probe scale: small enough for CI smoke (fake CPU devices,
# interpret-free jnp path), big enough that the exchange dominates noise.
PROBE_PODS, PROBE_GPUS = 2, 2
PROBE_ROWS, PROBE_D = 64, 128

_PROBE_CODE = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.comm import direct_all_to_all, plan_all_to_all, lower_plan
from repro.core.schedulers import get_scheduler
from repro.core.traffic import ClusterSpec, moe_workload
from repro.launch.mesh import make_mesh

pods, gpp, rows, dmodel = {pods}, {gpp}, {rows}, {d}
mesh = make_mesh((pods, gpp), ("pod", "data"))
n = pods * gpp
w = moe_workload(ClusterSpec(pods, gpp), tokens_per_gpu=2048,
                 bytes_per_token=2, seed=0)
plan = get_scheduler("flash").synthesize(w)
sched = lower_plan(plan)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(n * n, rows, dmodel)).astype(np.float32))
spec = P(("pod", "data"))

# use_kernel=False: the jnp gather/scatter path is bit-identical to the
# pallas pair but stable to time on CPU (interpret-mode pallas would
# measure the emulator, not the schedule).
f_plan = jax.jit(jax.shard_map(
    partial(plan_all_to_all, slow_axis="pod", fast_axes=("data",),
            plan=plan, use_kernel=False),
    mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False))
f_dir = jax.jit(jax.shard_map(
    partial(direct_all_to_all, slow_axis="pod", fast_axes=("data",)),
    mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False))

parity = bool(jnp.array_equal(f_plan(x), f_dir(x)))

def best_of(f, repeats=30):
    f(x).block_until_ready()  # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        times.append(time.perf_counter() - t0)
    return min(times)

print(json.dumps({{
    "plan_s": best_of(f_plan),
    "direct_s": best_of(f_dir),
    "parity": parity,
    "n_stages": sched.n_stages,
    "n_plan_stages": sched.n_plan_stages,
}}))
"""


def _measure_device_probe():
    """Run the plan-vs-direct device exchange in a fresh fake-device
    process; returns the probe's measurement dict."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{PROBE_PODS * PROBE_GPUS}")
    env["TF_CPP_MIN_LOG_LEVEL"] = "3"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _PROBE_CODE.format(pods=PROBE_PODS, gpp=PROBE_GPUS,
                              rows=PROBE_ROWS, d=PROBE_D)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"device probe failed (rc={proc.returncode}):\n"
            f"{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _step_time(cluster, algo: str, top_k: int, seed=0) -> float:
    w = moe_workload(cluster, TOKENS_PER_GPU, BYTES_PER_TOKEN,
                     top_k=top_k, seed=seed)
    a2a = simulate(w, algo).completion_time
    # expert FFN flops per GPU per layer (fwd 2x matmul, bwd 2x fwd)
    tokens = TOKENS_PER_GPU * top_k
    flops = 2 * tokens * D_MODEL * D_FF * 3 * 3
    compute = flops / MI300X_FLOPS
    # attention + the dense transformer layers interleaved with MoE layers
    # (paper Fig 2: half the stack is dense) -- roughly 2x the expert flops
    dense = 2 * compute
    # 4 All-to-Alls per MoE layer (dispatch+combine, fwd+bwd)
    return N_MOE_LAYERS * (compute + dense + 4 * a2a)


def run(csv: Csv):
    base = dict(TESTBED)
    for n_servers in (1, 2, 4):
        cluster = ClusterSpec(**{**base, "n_servers": n_servers})
        flash = _step_time(cluster, "flash", top_k=2)
        fanout = _step_time(cluster, "fanout", top_k=2)
        plan_t = _step_time(cluster, "flash", top_k=2)  # plan == flash sim
        csv.emit(f"fig14.experts{n_servers * 8}", flash * 1e6,
                 f"speedup_vs_fanout={fanout / flash:.2f}x"
                 f"|plan_us={plan_t * 1e6:.1f}"
                 f"|tokens_per_s={TOKENS_PER_GPU / flash:.0f}")
    cluster = ClusterSpec(**base)
    for k in (1, 2, 4):
        flash = _step_time(cluster, "flash", top_k=k)
        fanout = _step_time(cluster, "fanout", top_k=k)
        csv.emit(f"fig14.top{k}", flash * 1e6,
                 f"speedup_vs_fanout={fanout / flash:.2f}x")

    # -- measured vs simulated: the plan-exec device loop ------------------
    probe = _measure_device_probe()
    measured = probe["plan_s"] / probe["direct_s"]
    w = moe_workload(ClusterSpec(PROBE_PODS, PROBE_GPUS),
                     tokens_per_gpu=2048, bytes_per_token=2, seed=0)
    predicted = (simulate(w, "flash").completion_time
                 / simulate(w, "fanout").completion_time)
    pred_err = abs(measured - predicted) / predicted
    csv.emit("e2e.plan_vs_direct", measured,
             f"parity={'ok' if probe['parity'] else 'MISMATCH'}"
             f"|stages={probe['n_stages']}"
             f"|plan_stages={probe['n_plan_stages']}"
             f"|plan_us={probe['plan_s'] * 1e6:.1f}"
             f"|direct_us={probe['direct_s'] * 1e6:.1f}")
    csv.emit("e2e.sim_pred_err", pred_err,
             f"measured={measured:.3f}|predicted={predicted:.3f}")
