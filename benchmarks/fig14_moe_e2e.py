"""Paper Fig 14: MoE end-to-end training speedup, FLASH vs RCCL-fanout.

Step-time model: per-iteration All-to-All times come from the alpha-beta
simulator on MoE-gating traffic (2 dispatch + 2 combine per MoE layer, fwd
+ bwd); compute time per layer is modeled at 40% MFU on MI300X bf16
(1.3 PFLOP/s peak).  Varies (a) expert/server count at fixed top-k, (b)
top-k at fixed 4 servers -- the two sweeps of the figure.
"""

from __future__ import annotations

from repro.core import ClusterSpec, moe_workload, simulate

from .common import TESTBED, Csv

D_MODEL, D_FF, N_MOE_LAYERS = 4096, 28672, 12
TOKENS_PER_GPU = 8192
BYTES_PER_TOKEN = D_MODEL * 2
MI300X_FLOPS = 1.3e15 * 0.4


def _step_time(cluster, algo: str, top_k: int, seed=0) -> float:
    w = moe_workload(cluster, TOKENS_PER_GPU, BYTES_PER_TOKEN,
                     top_k=top_k, seed=seed)
    a2a = simulate(w, algo).completion_time
    # expert FFN flops per GPU per layer (fwd 2x matmul, bwd 2x fwd)
    tokens = TOKENS_PER_GPU * top_k
    flops = 2 * tokens * D_MODEL * D_FF * 3 * 3
    compute = flops / MI300X_FLOPS
    # attention + the dense transformer layers interleaved with MoE layers
    # (paper Fig 2: half the stack is dense) -- roughly 2x the expert flops
    dense = 2 * compute
    # 4 All-to-Alls per MoE layer (dispatch+combine, fwd+bwd)
    return N_MOE_LAYERS * (compute + dense + 4 * a2a)


def run(csv: Csv):
    base = dict(TESTBED)
    for n_servers in (1, 2, 4):
        cluster = ClusterSpec(**{**base, "n_servers": n_servers})
        flash = _step_time(cluster, "flash", top_k=2)
        fanout = _step_time(cluster, "fanout", top_k=2)
        csv.emit(f"fig14.experts{n_servers * 8}", flash * 1e6,
                 f"speedup_vs_fanout={fanout / flash:.2f}x"
                 f"|tokens_per_s={TOKENS_PER_GPU / flash:.0f}")
    cluster = ClusterSpec(**base)
    for k in (1, 2, 4):
        flash = _step_time(cluster, "flash", top_k=k)
        fanout = _step_time(cluster, "fanout", top_k=k)
        csv.emit(f"fig14.top{k}", flash * 1e6,
                 f"speedup_vs_fanout={fanout / flash:.2f}x")
