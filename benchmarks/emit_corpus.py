"""Emit the golden plan corpus the CI analysis gate verifies.

    PYTHONPATH=src python -m benchmarks.emit_corpus [--out plan_corpus]

Synthesizes every registered scheduler against the fixed workload
battery in ``repro.analysis.corpus`` and writes one JSON file of plans
per workload.  ``python -m repro.analysis --planlint --corpus <dir>``
then proves every emitted plan structurally sound (incast-free, slots
feasible, stage order ascending, fingerprint round-trip stable).
"""

from __future__ import annotations

import argparse

from repro.analysis.corpus import emit_corpus


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="plan_corpus",
                    help="output directory (default: plan_corpus)")
    args = ap.parse_args()
    written = emit_corpus(args.out)
    for path in written:
        print(path)
    print(f"{len(written)} corpus file(s) written to {args.out}")


if __name__ == "__main__":
    main()
