"""Shared benchmark utilities: CSV emission in `name,us_per_call,derived`
plus a machine-readable JSON export for the perf trajectory."""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

BENCH_SCHEMA_VERSION = 1


class Csv:
    def __init__(self):
        self.rows: List[str] = []
        self.records: List[Dict] = []

    def emit(self, name: str, us_per_call: float, derived: str = ""):
        line = f"{name},{us_per_call:.3f},{derived}"
        self.rows.append(line)
        self.records.append({
            "name": name,
            "us_per_call": float(us_per_call),
            "derived": _parse_derived(derived),
            "derived_raw": derived,
        })
        print(line)

    def to_json(self) -> Dict:
        """Machine-readable snapshot (BENCH_*.json): schema-versioned so
        successive CI runs accumulate a comparable perf trajectory."""
        return {
            "schema": BENCH_SCHEMA_VERSION,
            "generated_unix": time.time(),
            "rows": self.records,
        }

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")


def _parse_derived(derived: str) -> Dict[str, str]:
    """Split the `k1=v1|k2=v2` derived column into a dict (best effort)."""
    out: Dict[str, str] = {}
    for part in derived.split("|"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def time_us(fn: Callable, repeats: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


# paper's 4-node MI300X testbed (section 6, Fig 11)
TESTBED = dict(n_servers=4, m_gpus=8, b_intra=64e9, b_inter=12.5e9,
               alpha=10e-6)
