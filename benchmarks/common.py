"""Shared benchmark utilities: CSV emission in `name,us_per_call,derived`."""

from __future__ import annotations

import time
from typing import Callable, List


class Csv:
    def __init__(self):
        self.rows: List[str] = []

    def emit(self, name: str, us_per_call: float, derived: str = ""):
        line = f"{name},{us_per_call:.3f},{derived}"
        self.rows.append(line)
        print(line)


def time_us(fn: Callable, repeats: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


# paper's 4-node MI300X testbed (section 6, Fig 11)
TESTBED = dict(n_servers=4, m_gpus=8, b_intra=64e9, b_inter=12.5e9,
               alpha=10e-6)
