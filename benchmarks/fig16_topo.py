"""Paper Fig 16: intra-server topology sweep + intra/inter bandwidth-ratio
sweep (4 servers x 8 GPUs, random workload)."""

from __future__ import annotations

from repro.core import ClusterSpec, random_workload, simulate

from .common import Csv

TOPOLOGIES = {
    "switch": 900e9 / 8,      # H100 NVSwitch per-GPU port share
    "full_mesh": 64e9,        # MI300X xGMI per link
    "ring": 100e9,            # MI250X-ish
    "hybrid_cube": 25e9,      # V100 DGX-1
}

RATIOS = [(64e9, 12.5e9, "mi300x_100g"),
          (112e9, 12.5e9, "b200ish_100g"),
          (112e9, 50e9, "b200ish_400g"),
          (900e9 / 8, 50e9, "h100_400g")]


def run(csv: Csv):
    for topo, b1 in TOPOLOGIES.items():
        cluster = ClusterSpec(4, 8, b_intra=b1, b_inter=12.5e9,
                              alpha=10e-6, intra_topology=topo)
        w = random_workload(cluster, 16 << 20, seed=0)
        flash = simulate(w, "flash")
        opt = simulate(w, "optimal")
        csv.emit(f"fig16.topo.{topo}", flash.completion_time * 1e6,
                 f"opt_frac={flash.algbw / opt.algbw:.3f}")
    for b1, b2, name in RATIOS:
        cluster = ClusterSpec(4, 8, b_intra=b1, b_inter=b2, alpha=10e-6,
                              intra_topology="full_mesh")
        w = random_workload(cluster, 16 << 20, seed=0)
        flash = simulate(w, "flash")
        opt = simulate(w, "optimal")
        csv.emit(f"fig16.bw.{name}", flash.completion_time * 1e6,
                 f"ratio={b1 / b2:.1f}"
                 f"|opt_frac={flash.algbw / opt.algbw:.3f}")


if __name__ == "__main__":
    run(Csv())
