"""Paper Fig 17: (a) schedule-synthesis time vs cluster size; (b) memory
footprint slope vs workload bytes; plus the beyond-paper PlanCache row
(dynamic-MoE re-synthesis skipped on repeated traffic fingerprints)."""

from __future__ import annotations

import numpy as np

from repro.core import (
    ClusterSpec,
    PlanCache,
    get_scheduler,
    moe_workload,
    random_workload,
    simulate,
)

from .common import Csv, time_us


def run(csv: Csv):
    flash = get_scheduler("flash")
    # (a) synthesis wall-time: paper reports ~15-32us at small scale,
    # <1ms for <10 servers, <0.25s for <50 servers (O(n^4.5-5) in servers)
    for n in (3, 4, 8, 16, 32, 50):
        cluster = ClusterSpec(n_servers=n, m_gpus=8)
        w = random_workload(cluster, 4 << 20, seed=0)
        us = time_us(lambda: flash.synthesize(w), repeats=3)
        plan = flash.synthesize(w)
        csv.emit(f"fig17a.synth.servers{n}", us,
                 f"n_stages={plan.n_stages}")
    # (a') PlanCache: iterations whose MoE gating signature repeats skip
    # synthesis entirely -- cached lookup vs fresh synthesis wall time.
    cluster = ClusterSpec(n_servers=8, m_gpus=8)
    w = moe_workload(cluster, 8192, 4096, top_k=2, seed=0)
    cache = PlanCache()
    simulate(w, "flash", cache=cache)  # warm: 1 miss
    us_cached = time_us(lambda: simulate(w, "flash", cache=cache), repeats=5)
    us_fresh = time_us(lambda: simulate(w, "flash"), repeats=5)
    csv.emit("fig17a.plan_cache", us_cached,
             f"fresh_us={us_fresh:.1f}"
             f"|speedup={us_fresh / max(us_cached, 1e-9):.1f}x"
             f"|hits={cache.hits}|misses={cache.misses}")
    # (b) memory slope: baseline 2.0x, FLASH ~2.6x
    cluster = ClusterSpec(n_servers=4, m_gpus=8)
    sizes = [4 << 20, 16 << 20, 64 << 20]
    slopes = []
    for s in sizes:
        w = random_workload(cluster, s, seed=1)
        r = simulate(w, "flash")
        slopes.append(r.memory_bytes / w.total_bytes)
    base_w = random_workload(cluster, 16 << 20, seed=1)
    base = simulate(base_w, "spreadout")
    csv.emit("fig17b.memory", 0.0,
             f"flash_slope={np.mean(slopes):.2f}"
             f"|baseline_slope={base.memory_bytes / base_w.total_bytes:.2f}"
             f"|paper_claim=2.6_vs_2.0")
