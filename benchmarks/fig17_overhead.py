"""Paper Fig 17: (a) schedule-synthesis time vs cluster size; (b) memory
footprint slope vs workload bytes; plus the beyond-paper PlanCache row
(dynamic-MoE re-synthesis skipped on repeated traffic fingerprints) and the
warm-started near-miss repair row.

The synthesis sweep reports the incremental engine (``fig17a.synth.*`` /
``synth.*``) against the pre-rewrite reference decomposer (``ref_us``) up to
50 servers, and extends to 128/256/512 servers where the reference is
minutes-slow and only the new engine is timed.  The ``synth.servers{n}``
alias series feeds the CI regression guard (check_synth_budget.py).
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    ClusterSpec,
    PlanCache,
    get_scheduler,
    moe_workload,
    random_workload,
    simulate,
)
from repro.core.birkhoff import birkhoff_decompose
from repro.core.traffic import Workload

from .common import Csv, time_us

# (servers, timing repeats, time the reference decomposer too?)
SYNTH_SWEEP = (
    (3, 3, True),
    (4, 3, True),
    (8, 3, True),
    (16, 3, True),
    (32, 3, True),
    (50, 3, True),
    (128, 1, False),
    (256, 1, False),
    (512, 1, False),
)


def run(csv: Csv):
    flash = get_scheduler("flash")
    # (a) synthesis wall-time: paper reports ~15-32us at small scale, <1ms
    # for <10 servers, <0.25s for <50 servers.  The incremental engine is
    # exact (bit-identical stages) through 32 servers and switches to the
    # repair policy beyond; the reference column is the seed's interpreted
    # decomposer.
    for n, repeats, with_ref in SYNTH_SWEEP:
        cluster = ClusterSpec(n_servers=n, m_gpus=8)
        w = random_workload(cluster, 4 << 20, seed=0)
        timed = {}  # keep the last synthesized plan: n=512 costs ~40s/run

        def synth(w=w, timed=timed):
            timed["plan"] = flash.synthesize(w)

        us = time_us(synth, repeats=repeats,
                     warmup=1 if repeats > 1 else 0)
        plan = timed["plan"]
        derived = ""
        if with_ref:
            # engine-vs-engine column: decompose only, so the ratio is not
            # diluted by the (shared) load-balance/fingerprint overhead
            t_server = w.server_matrix()
            new_us = time_us(lambda: birkhoff_decompose(t_server),
                             repeats=repeats, warmup=0)
            ref_us = time_us(
                lambda: birkhoff_decompose(t_server, reference=True),
                repeats=1, warmup=0)
            derived = (f"engine_us={new_us:.1f}|ref_us={ref_us:.1f}"
                       f"|speedup={ref_us / new_us:.1f}x|")
        csv.emit(f"fig17a.synth.servers{n}", us,
                 derived + f"n_stages={plan.n_stages}")
        # stable alias series consumed by the CI synthesis budget guard
        csv.emit(f"synth.servers{n}", us)
    # (a') PlanCache: iterations whose MoE gating signature repeats skip
    # synthesis entirely -- cached lookup vs fresh synthesis wall time.
    cluster = ClusterSpec(n_servers=8, m_gpus=8)
    w = moe_workload(cluster, 8192, 4096, top_k=2, seed=0)
    cache = PlanCache()
    simulate(w, "flash", cache=cache)  # warm: 1 miss
    us_cached = time_us(lambda: simulate(w, "flash", cache=cache), repeats=5)
    us_fresh = time_us(lambda: simulate(w, "flash"), repeats=5)
    csv.emit("fig17a.plan_cache", us_cached,
             f"fresh_us={us_fresh:.1f}"
             f"|speedup={us_fresh / max(us_cached, 1e-9):.1f}x"
             f"|hits={cache.hits}|misses={cache.misses}")
    # (a'') warm-started near-miss repair: a small MoE routing drift costs
    # a slot-refill pass seeded with the cached plan's permutations, not a
    # cold synthesis (PlanCache(warm_start=True) path).
    cluster = ClusterSpec(n_servers=32, m_gpus=8)
    w1 = moe_workload(cluster, 8192, 4096, top_k=2, seed=0)
    rng = np.random.default_rng(7)
    m2 = w1.matrix.copy()
    drift = rng.random(m2.shape) < 0.02
    m2[drift] *= rng.uniform(0.8, 1.2, size=int(drift.sum()))
    np.fill_diagonal(m2, 0.0)
    w2 = Workload(cluster, m2)
    prev = flash.synthesize(w1)
    us_warm = time_us(lambda: flash.repair_plan(prev, w2), repeats=3)
    us_cold = time_us(lambda: flash.synthesize(w2), repeats=3)
    warm_t = simulate(w2, "flash", plan=flash.repair_plan(prev, w2))
    cold_t = simulate(w2, "flash", plan=flash.synthesize(w2))
    csv.emit("fig17a.warm_resynthesis", us_warm,
             f"cold_us={us_cold:.1f}"
             f"|speedup={us_cold / max(us_warm, 1e-9):.1f}x"
             f"|quality_vs_cold="
             f"{warm_t.completion_time / cold_t.completion_time:.3f}")
    # (b) memory slope: baseline 2.0x, FLASH ~2.6x
    cluster = ClusterSpec(n_servers=4, m_gpus=8)
    sizes = [4 << 20, 16 << 20, 64 << 20]
    slopes = []
    for s in sizes:
        w = random_workload(cluster, s, seed=1)
        r = simulate(w, "flash")
        slopes.append(r.memory_bytes / w.total_bytes)
    base_w = random_workload(cluster, 16 << 20, seed=1)
    base = simulate(base_w, "spreadout")
    csv.emit("fig17b.memory", 0.0,
             f"flash_slope={np.mean(slopes):.2f}"
             f"|baseline_slope={base.memory_bytes / base_w.total_bytes:.2f}"
             f"|paper_claim=2.6_vs_2.0")
