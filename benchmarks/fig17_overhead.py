"""Paper Fig 17: (a) schedule-synthesis time vs cluster size; (b) memory
footprint slope vs workload bytes."""

from __future__ import annotations

import numpy as np

from repro.core import ClusterSpec, flash_schedule, random_workload, simulate

from .common import Csv, time_us


def run(csv: Csv):
    # (a) synthesis wall-time: paper reports ~15-32us at small scale,
    # <1ms for <10 servers, <0.25s for <50 servers (O(n^4.5-5) in servers)
    for n in (3, 4, 8, 16, 32, 50):
        cluster = ClusterSpec(n_servers=n, m_gpus=8)
        w = random_workload(cluster, 4 << 20, seed=0)
        us = time_us(lambda: flash_schedule(w), repeats=3)
        plan = flash_schedule(w)
        csv.emit(f"fig17a.synth.servers{n}", us,
                 f"n_stages={plan.n_stages}")
    # (b) memory slope: baseline 2.0x, FLASH ~2.6x
    cluster = ClusterSpec(n_servers=4, m_gpus=8)
    sizes = [4 << 20, 16 << 20, 64 << 20]
    slopes = []
    for s in sizes:
        w = random_workload(cluster, s, seed=1)
        r = simulate(w, "flash")
        slopes.append(r.memory_bytes / w.total_bytes)
    base_w = random_workload(cluster, 16 << 20, seed=1)
    base = simulate(base_w, "spreadout")
    csv.emit("fig17b.memory", 0.0,
             f"flash_slope={np.mean(slopes):.2f}"
             f"|baseline_slope={base.memory_bytes / base_w.total_bytes:.2f}"
             f"|paper_claim=2.6_vs_2.0")
