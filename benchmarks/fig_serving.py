"""Closed-loop load on the plan-serving daemon (repro.serving).

Issue-6 acceptance scenario: several concurrent MoE jobs share one
``PlanServer`` over the drifting-gating trajectory of fig_dynamic (30%
signature repeats, ~2% entry drift between steps).  Four client threads
replay the trajectory in closed loop (next request only after the last
answer) for several rounds, so the steady state is what serving actually
looks like: mostly exact cache hits, a trickle of warm repairs on drift
steps, and the daemon's background synthesizer upgrading those to exact
plans behind the traffic.  Series:

  serve.p50      median INTERACTIVE plan-request latency (us) across every
                 client request.  The derived ``ratio`` column divides by
                 the compiled execution time of a cached plan on the same
                 fabric -- the issue-6 bar is ratio <= 10x.
  serve.p99      tail latency (us): the occasional cold/warm synthesis a
                 closed-loop client absorbs.
  serve.hit_rate fraction of requests answered from cache (value column is
                 the fraction itself, not a latency).  Floor-guarded in
                 check_synth_budget.py: the trajectory repeats 30% of its
                 signatures and each is visited by 4 clients x 3 rounds,
                 so a healthy daemon sits far above 0.5.
  serve.upgrades background exact-synthesis upgrades applied (value column
                 is the count).  The derived ``parity`` field re-requests
                 distinct signatures after ``drain()`` and compares each
                 served plan -- phase for phase, via ``to_dict`` -- against
                 a from-scratch exact synthesis of the same workload:
                 post-drain, every upgraded entry must be
                 indistinguishable from the one-shot path.

The scale (8 servers x 8 GPUs) keeps the fingerprint hash -- the
irreducible cost of the fast path -- at tens of microseconds so the p50
measures the daemon, not blake2b over a half-megabyte matrix.
"""

from __future__ import annotations

import threading
import time

from repro.core import ClusterSpec, execute_plan, get_scheduler
from repro.serving import PlanClient, PlanServer, Tier, TieredQueue

from .common import Csv, time_us
from .fig_dynamic import _drift_trajectory

_N, _M = 8, 8
_TRAJ_STEPS = 48
_CLIENTS = 4
_ROUNDS = 3
_PARITY_CHECKS = 10


def _client_loop(client: PlanClient, traj, rounds: int, errors: list):
    try:
        for _ in range(rounds):
            for w in traj:
                client.get_plan(w)
    except Exception as exc:  # surfaced in the main thread
        errors.append(exc)


def run(csv: Csv):
    cluster = ClusterSpec(n_servers=_N, m_gpus=_M)
    traj = _drift_trajectory(cluster, _TRAJ_STEPS, seed=11)

    # The closed-loop benchmark must measure the daemon, never shed: a
    # deep queue, no staleness horizon, no synthesis budget.
    queue = TieredQueue(max_depth=4096, stale_after=None)
    server = PlanServer(workers=2, queue=queue, prewarm=True)
    with server:
        clients = [PlanClient(server, algorithm="flash",
                              tier=Tier.INTERACTIVE, timeout=120.0,
                              inline_fallback=False)
                   for _ in range(_CLIENTS)]
        errors: list = []
        threads = [threading.Thread(target=_client_loop,
                                    args=(c, traj, _ROUNDS, errors))
                   for c in clients]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        if errors:
            raise errors[0]
        drained = server.drain(60.0)
        snap = server.telemetry_snapshot()

        # Post-drain parity: a served (hit) plan for each of the first
        # distinct signatures must match a from-scratch exact synthesis.
        parity = "ok"
        seen = set()
        scheduler = get_scheduler("flash")
        for w in traj:
            sig = w.matrix.tobytes()
            if sig in seen:
                continue
            seen.add(sig)
            served = server.request(w, "flash").plan
            fresh = scheduler.synthesize(w)
            a, b = served.to_dict(), fresh.to_dict()
            a.pop("synth_seconds"), b.pop("synth_seconds")
            a.pop("fingerprint"), b.pop("fingerprint")
            if a != b:
                parity = "MISMATCH"
                break
            if len(seen) >= _PARITY_CHECKS:
                break

    counters = snap["counters"]
    lat = snap["latency"]["INTERACTIVE"]
    requests = counters.get("requests", 0)
    hits = counters.get("hits", 0)
    hit_rate = hits / max(requests, 1)

    # The issue-6 latency bar compares against compiled execution of a
    # cached plan for the same fabric (the serving hot path's other half).
    plan = scheduler.synthesize(traj[0])
    plan.compile()
    exec_us = time_us(lambda: execute_plan(plan, traj[0]), repeats=30)

    csv.emit("serve.p50", lat["p50_us"],
             f"exec_us={exec_us:.1f}"
             f"|ratio={lat['p50_us'] / max(exec_us, 1e-9):.2f}x"
             f"|clients={_CLIENTS}|requests={requests}"
             f"|wall_s={wall_s:.2f}")
    csv.emit("serve.p99", lat["p99_us"],
             f"p90_us={lat['p90_us']:.1f}|max_us={lat['max_us']:.1f}")
    csv.emit("serve.hit_rate", hit_rate,
             f"hits={hits}|warm={counters.get('warm', 0)}"
             f"|cold={counters.get('cold', 0)}"
             f"|coalesced={counters.get('coalesced', 0)}")
    csv.emit("serve.upgrades", counters.get("upgrades", 0),
             f"parity={parity}|drained={drained}"
             f"|prewarmed={counters.get('prewarmed', 0)}"
             f"|prewarm_hits={counters.get('prewarm_hits', 0)}")


if __name__ == "__main__":
    run(Csv())
