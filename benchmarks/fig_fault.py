"""Fault injection against the plan-serving daemon (repro.serving.events).

Issue-8 acceptance scenario: a NIC fails in the middle of a drifting-MoE
serving run and the daemon must *degrade*, never stall.  One client
replays the fig_dynamic drift trajectory in three acts over an 8x8
fabric: a healthy warmup, an event window opened by ``fail nic 0.0``
(every request still carrying the pre-event Topology, so the server's
re-homing and family re-repair both run on the hot path), and a recovery
tail after the inverse ``recover`` event.  Series:

  fault.recovery_ratio  worst served/cold completion ratio inside the
                 event window: each served plan is executed on the
                 degraded fabric and compared against a from-scratch
                 cold synthesis for the same traffic on that fabric.
                 The issue-8 bar is <= 2x (observed ~1.0: topology-change
                 repair re-water-fills the old structure against the new
                 pair capacities and lands within a percent of cold).
                 Derived columns carry the re-repair counters and the
                 wall time of applying the event (the family walk).
  fault.stalls   rejected + shed + errors + client inline fallbacks
                 across the whole run (value column is the count).  The
                 issue-8 bar is exactly 0: a fabric event must never
                 surface to clients as anything but a answered request.

Guarded in check_synth_budget.py (FAULT_*).
"""

from __future__ import annotations

import time

from repro.core import ClusterSpec, execute_plan, get_scheduler
from repro.core.traffic import Workload
from repro.serving import FabricMonitor, PlanClient, PlanServer, TieredQueue

from .common import Csv
from .fig_dynamic import _drift_trajectory

_N, _M = 8, 8
_TRAJ_STEPS = 24
_ALGO = "flash_ca"


def run(csv: Csv):
    cluster = ClusterSpec(n_servers=_N, m_gpus=_M)
    topo0 = _drift_trajectory(cluster, 1, seed=11)[0].topo
    mon = FabricMonitor(topo0)
    # Clients keep the ORIGINAL fabric throughout: the server must re-home.
    traj = [Workload(cluster, w.matrix, topo0)
            for w in _drift_trajectory(cluster, _TRAJ_STEPS, seed=11)]
    third = _TRAJ_STEPS // 3

    queue = TieredQueue(max_depth=4096, stale_after=None)
    cold_memo = {}
    scheduler = get_scheduler(_ALGO)

    def cold_time(w):
        sig = w.matrix.tobytes()
        if sig not in cold_memo:
            cold_memo[sig] = execute_plan(scheduler.synthesize(w),
                                          w).completion_time
        return cold_memo[sig]

    worst_ratio = 0.0
    with PlanServer(workers=2, queue=queue) as server:
        server.attach_monitor(mon)
        client = PlanClient(server, algorithm=_ALGO, timeout=120.0)

        for w in traj[:third]:                       # act 1: healthy
            client.get_plan(w)
        server.drain(60.0)

        t0 = time.perf_counter()
        mon.inject("fail", server=0, nic=0)          # act 2: the fault
        event_apply_us = (time.perf_counter() - t0) * 1e6
        degraded = mon.current()
        for w in traj[third:2 * third]:              # event window
            answer = client.get_plan(w)
            w_deg = Workload(cluster, w.matrix, degraded)
            served = execute_plan(answer.plan, w_deg).completion_time
            worst_ratio = max(worst_ratio, served / cold_time(w_deg))
        server.drain(60.0)

        mon.inject("recover", server=0, nic=0)       # act 3: the heal
        assert mon.current() == topo0
        for w in traj[2 * third:]:
            client.get_plan(w)
        drained = server.drain(60.0)
        snap = server.telemetry_snapshot()

    c = snap["counters"]
    stalls = (c.get("rejected", 0) + c.get("shed", 0) + c.get("errors", 0)
              + client.counters["inline"])
    csv.emit("fault.recovery_ratio", worst_ratio,
             f"rerepaired={c.get('rerepaired', 0)}"
             f"|rerepair_cold={c.get('rerepair_cold', 0)}"
             f"|stale_topology={c.get('stale_topology', 0)}"
             f"|event_apply_us={event_apply_us:.1f}"
             f"|fabric_events={c.get('fabric_events', 0)}")
    csv.emit("fault.stalls", stalls,
             f"rejected={c.get('rejected', 0)}|shed={c.get('shed', 0)}"
             f"|errors={c.get('errors', 0)}"
             f"|inline={client.counters['inline']}"
             f"|requests={c.get('requests', 0)}"
             f"|worker_deaths={c.get('worker_deaths', 0)}"
             f"|drained={drained}")


if __name__ == "__main__":
    csv = Csv()
    run(csv)
