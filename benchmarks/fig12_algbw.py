"""Paper Fig 12: AlgoBW vs per-GPU transfer size under balanced / random /
skewed workloads, FLASH vs all baselines, on the 4x8 MI300X testbed model."""

from __future__ import annotations

from repro.core import (
    ClusterSpec,
    available_schedulers,
    balanced_workload,
    random_workload,
    simulate,
    skewed_workload,
)

from .common import TESTBED, Csv

SIZES = [1 << 20, 16 << 20, 130 << 20, 512 << 20]  # bytes per GPU pair-sum


def _workload(kind: str, cluster, total_per_gpu: float, seed=0):
    per_pair = total_per_gpu / (cluster.n_gpus - 1)
    if kind == "balanced":
        return balanced_workload(cluster, per_pair)
    if kind == "random":
        return random_workload(cluster, per_pair, seed=seed)
    return skewed_workload(cluster, per_pair, zipf_s=1.2, seed=seed)


def run(csv: Csv):
    cluster = ClusterSpec(**TESTBED)
    for kind in ("balanced", "random", "skewed"):
        for size in SIZES:
            w = _workload(kind, cluster, size)
            results = {a: simulate(w, a) for a in available_schedulers()}
            flash = results["flash"]
            derived = (
                f"algbw_gbps={flash.algbw_gbps():.2f}"
                f"|opt_frac={flash.algbw / results['optimal'].algbw:.3f}"
                f"|vs_fanout={flash.algbw / results['fanout'].algbw:.1f}x"
                f"|vs_spreadout="
                f"{flash.algbw / results['spreadout'].algbw:.2f}x"
                f"|vs_hier="
                f"{flash.algbw / results['hierarchical'].algbw:.2f}x")
            csv.emit(f"fig12.{kind}.{size >> 20}MB",
                     flash.completion_time * 1e6, derived)
