"""Heterogeneous-fabric sweep: topology-aware vs topology-blind FLASH.

Scenarios a two-scalar ClusterSpec cannot represent (degraded links, mixed
NIC generations, oversubscribed scale-out tiers), timed by the link-level
executor against a first-class ``Topology``:

  * degraded-NIC sweep -- one NIC at 50/25/10% of nominal; the blind
    uniform T/m split strands a full share on the slow rail while the aware
    schedule rebalances shares to rail capacity;
  * failed-NIC -- the aware schedule routes around the dead rail (finite
    time), the blind one never finishes;
  * mixed NIC speeds (rail imbalance) -- each server half 400G, half 100G
    rails; the aware schedule loads rails proportionally to capacity;
  * mixed server generations -- 100G servers next to 400G servers (cross
    pairs are endpoint-capped, so aware == blind: the honest null case);
  * scale-out oversubscription -- 1:1 to 4:1 spine.

"aware" synthesizes FLASH against the real fabric; "blind" executes the
homogeneous-fabric FLASH plan on that same fabric (the
``execute_plan(topology=...)`` override).  Speedup = blind / aware.

The ``hetero.synth.*`` rows compare capacity-aware *synthesis* (flash_ca:
time-domain Birkhoff, per-pair slots) against capacity-blind synthesis
(flash: byte-domain stages, capacity-proportional rail shares only), both
executed link-level on the real fabric, under capacity-matched traffic --
the serving regime where a load balancer keeps slow servers lightly
loaded, and where blind equal-byte slots park fast pairs behind slow
stragglers.  The ``synth.hetero{n}`` rows time capacity-aware vs blind
synthesis on degraded-NIC fabrics and feed the CI guard
(benchmarks/check_synth_budget.py): an aware slowdown > 2x over blind
fails CI.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    Topology,
    capacity_matched_workload,
    get_scheduler,
    random_workload,
    simulate,
)

from .common import Csv, TESTBED, time_us

_N, _M = TESTBED["n_servers"], TESTBED["m_gpus"]
_MEAN = 16 << 20


def _homo() -> Topology:
    return Topology.homogeneous(
        _N, _M, b_intra=TESTBED["b_intra"], b_inter=TESTBED["b_inter"],
        alpha=TESTBED["alpha"])


def _aware_vs_blind(csv: Csv, name: str, topo: Topology) -> None:
    """Emit aware/blind/optimal completion for one heterogeneous fabric."""
    w = random_workload(topo, _MEAN, seed=0)
    aware = simulate(w, "flash")
    opt = simulate(w, "optimal")
    # Blind: the FLASH plan synthesized for the *homogeneous* fabric,
    # executed on the real one.
    w_homo = random_workload(_homo(), _MEAN, seed=0)
    blind_plan = get_scheduler("flash").synthesize(w_homo)
    blind = simulate(w, "flash", plan=blind_plan, topology=topo)
    speedup = blind.completion_time / aware.completion_time
    speedup_s = "inf" if np.isinf(speedup) else f"{speedup:.3f}"
    csv.emit(f"hetero.{name}", aware.completion_time * 1e6,
             f"blind_us={blind.completion_time * 1e6:.3f}"
             f"|speedup={speedup_s}"
             f"|opt_frac={aware.algbw / opt.algbw:.3f}")


def _synth_aware_vs_blind(csv: Csv, name: str, topo: Topology) -> None:
    """Capacity-aware synthesis (flash_ca) vs capacity-blind synthesis
    (flash), both executed link-level on the real fabric."""
    w = capacity_matched_workload(topo, _MEAN, seed=0)
    aware = simulate(w, "flash_ca")
    blind = simulate(w, "flash")
    opt = simulate(w, "optimal")
    csv.emit(f"hetero.synth.{name}", aware.completion_time * 1e6,
             f"blind_us={blind.completion_time * 1e6:.3f}"
             f"|speedup={blind.completion_time / aware.completion_time:.3f}"
             f"|opt_frac={aware.algbw / opt.algbw:.3f}")


def _synth_time_series(csv: Csv) -> None:
    """``synth.hetero{n}``: capacity-aware vs blind synthesis wall time and
    plan quality on degraded-NIC fabrics (CI ratio guard input)."""
    for n in (16, 32):
        topo = Topology.homogeneous(
            n, _M, b_intra=TESTBED["b_intra"], b_inter=TESTBED["b_inter"],
            alpha=TESTBED["alpha"]).degrade_server(n // 2, 0.25)
        w = capacity_matched_workload(topo, 4 << 20, seed=1)
        aware_s, blind_s = get_scheduler("flash_ca"), get_scheduler("flash")
        aware_us = time_us(lambda: aware_s.synthesize(w), repeats=3)
        blind_us = time_us(lambda: blind_s.synthesize(w), repeats=3)
        quality = (simulate(w, "flash").completion_time
                   / simulate(w, "flash_ca").completion_time)
        csv.emit(f"synth.hetero{n}", aware_us,
                 f"blind_us={blind_us:.1f}"
                 f"|synth_ratio={aware_us / blind_us:.2f}"
                 f"|plan_speedup={quality:.3f}")


def run(csv: Csv):
    homo = _homo()
    for factor in (0.5, 0.25, 0.1):
        _aware_vs_blind(csv, f"degraded_nic_{factor:g}",
                        homo.degrade_nic(2, 3, factor))
    _aware_vs_blind(csv, "failed_nic", homo.fail_nic(1, 0))
    # Rail imbalance: every server has 4 fast (400G) and 4 slow (100G)
    # rails -- the regime where RailS-style capacity-proportional loading
    # differentiates itself from the uniform T/m split.
    rails = homo.with_nic_bw(
        np.tile([50e9] * (_M // 2) + [12.5e9] * (_M - _M // 2), (_N, 1)))
    _aware_vs_blind(csv, "mixed_rails_400g_100g", rails)
    # Mixed server generations: cross pairs are capped by the slower
    # endpoint NIC on every rail, so uniform shares are already optimal and
    # aware == blind (the null case that keeps the model honest).
    mixed = homo.with_server_nic_speeds([12.5e9, 12.5e9, 50e9, 50e9])
    _aware_vs_blind(csv, "mixed_servers_100g_400g", mixed)
    # Scale-out oversubscription: the spine term binds beyond 1:1.
    for factor in (1.0, 2.0, 4.0):
        topo = homo.with_oversubscription(factor)
        w = random_workload(topo, _MEAN, seed=0)
        flash = simulate(w, "flash")
        opt = simulate(w, "optimal")
        csv.emit(f"hetero.oversub_{factor:g}", flash.completion_time * 1e6,
                 f"opt_frac={flash.algbw / opt.algbw:.3f}")
    # Capacity-aware synthesis vs blind synthesis (both link-level on the
    # real fabric): a server with every NIC degraded, and mixed
    # 400G/100G server generations, under capacity-matched traffic.
    _synth_aware_vs_blind(csv, "degraded_nic_server_0.25",
                          homo.degrade_server(2, 0.25))
    _synth_aware_vs_blind(
        csv, "mixed_servers_400g_100g",
        homo.with_server_nic_speeds([12.5e9, 12.5e9, 50e9, 50e9]))
    _synth_time_series(csv)


if __name__ == "__main__":
    run(Csv())
