"""Heterogeneous-fabric sweep: topology-aware vs topology-blind FLASH.

Scenarios a two-scalar ClusterSpec cannot represent (degraded links, mixed
NIC generations, oversubscribed scale-out tiers), timed by the link-level
executor against a first-class ``Topology``:

  * degraded-NIC sweep -- one NIC at 50/25/10% of nominal; the blind
    uniform T/m split strands a full share on the slow rail while the aware
    schedule rebalances shares to rail capacity;
  * failed-NIC -- the aware schedule routes around the dead rail (finite
    time), the blind one never finishes;
  * mixed NIC speeds (rail imbalance) -- each server half 400G, half 100G
    rails; the aware schedule loads rails proportionally to capacity;
  * mixed server generations -- 100G servers next to 400G servers (cross
    pairs are endpoint-capped, so aware == blind: the honest null case);
  * scale-out oversubscription -- 1:1 to 4:1 spine.

"aware" synthesizes FLASH against the real fabric; "blind" executes the
homogeneous-fabric FLASH plan on that same fabric (the
``execute_plan(topology=...)`` override).  Speedup = blind / aware.
"""

from __future__ import annotations

import numpy as np

from repro.core import Topology, get_scheduler, random_workload, simulate

from .common import Csv, TESTBED

_N, _M = TESTBED["n_servers"], TESTBED["m_gpus"]
_MEAN = 16 << 20


def _homo() -> Topology:
    return Topology.homogeneous(
        _N, _M, b_intra=TESTBED["b_intra"], b_inter=TESTBED["b_inter"],
        alpha=TESTBED["alpha"])


def _aware_vs_blind(csv: Csv, name: str, topo: Topology) -> None:
    """Emit aware/blind/optimal completion for one heterogeneous fabric."""
    w = random_workload(topo, _MEAN, seed=0)
    aware = simulate(w, "flash")
    opt = simulate(w, "optimal")
    # Blind: the FLASH plan synthesized for the *homogeneous* fabric,
    # executed on the real one.
    w_homo = random_workload(_homo(), _MEAN, seed=0)
    blind_plan = get_scheduler("flash").synthesize(w_homo)
    blind = simulate(w, "flash", plan=blind_plan, topology=topo)
    speedup = blind.completion_time / aware.completion_time
    speedup_s = "inf" if np.isinf(speedup) else f"{speedup:.3f}"
    csv.emit(f"hetero.{name}", aware.completion_time * 1e6,
             f"blind_us={blind.completion_time * 1e6:.3f}"
             f"|speedup={speedup_s}"
             f"|opt_frac={aware.algbw / opt.algbw:.3f}")


def run(csv: Csv):
    homo = _homo()
    for factor in (0.5, 0.25, 0.1):
        _aware_vs_blind(csv, f"degraded_nic_{factor:g}",
                        homo.degrade_nic(2, 3, factor))
    _aware_vs_blind(csv, "failed_nic", homo.fail_nic(1, 0))
    # Rail imbalance: every server has 4 fast (400G) and 4 slow (100G)
    # rails -- the regime where RailS-style capacity-proportional loading
    # differentiates itself from the uniform T/m split.
    rails = homo.with_nic_bw(
        np.tile([50e9] * (_M // 2) + [12.5e9] * (_M - _M // 2), (_N, 1)))
    _aware_vs_blind(csv, "mixed_rails_400g_100g", rails)
    # Mixed server generations: cross pairs are capped by the slower
    # endpoint NIC on every rail, so uniform shares are already optimal and
    # aware == blind (the null case that keeps the model honest).
    mixed = homo.with_server_nic_speeds([12.5e9, 12.5e9, 50e9, 50e9])
    _aware_vs_blind(csv, "mixed_servers_100g_400g", mixed)
    # Scale-out oversubscription: the spine term binds beyond 1:1.
    for factor in (1.0, 2.0, 4.0):
        topo = homo.with_oversubscription(factor)
        w = random_workload(topo, _MEAN, seed=0)
        flash = simulate(w, "flash")
        opt = simulate(w, "optimal")
        csv.emit(f"hetero.oversub_{factor:g}", flash.completion_time * 1e6,
                 f"opt_frac={flash.algbw / opt.algbw:.3f}")


if __name__ == "__main__":
    run(Csv())
