"""Aggregate the dry-run sweep JSONs into the roofline table (section g).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits one
CSV row per (arch x shape x mesh) cell plus a markdown table on request
(consumed by EXPERIMENTS.md).
"""

from __future__ import annotations

import glob
import json
import os

from .common import Csv

RESULTS_DIR = os.environ.get(
    "REPRO_DRYRUN_RESULTS",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "results", "dryrun"))


def load_cells(results_dir: str = RESULTS_DIR):
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(csv: Csv):
    cells = load_cells()
    if not cells:
        csv.emit("roofline.no_results", 0.0,
                 f"run scripts/run_dryrun_sweep.sh first ({RESULTS_DIR})")
        return
    n_ok = n_skip = n_fail = 0
    for c in cells:
        tag = f"roofline.{c['arch']}.{c['shape']}.{c['mesh']}"
        if c["status"] == "skipped":
            n_skip += 1
            csv.emit(tag, 0.0, "skipped:" + c["reason"][:60])
            continue
        if c["status"] != "ok":
            n_fail += 1
            csv.emit(tag, 0.0, "FAILED:" + c.get("error", "?")[:80])
            continue
        n_ok += 1
        r = c["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        csv.emit(
            tag, bound * 1e6,
            f"compute_s={r['compute_s']:.4g}|memory_s={r['memory_s']:.4g}"
            f"|collective_s={r['collective_s']:.4g}"
            f"|dominant={r['dominant']}"
            f"|roofline_frac={r['roofline_fraction']:.3f}"
            f"|useful_flops={c.get('useful_flop_ratio') or 0:.3f}")
    csv.emit("roofline.summary", 0.0,
             f"ok={n_ok}|skipped={n_skip}|failed={n_fail}")


def markdown_table(results_dir: str = RESULTS_DIR) -> str:
    cells = load_cells(results_dir)
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s "
        "| dominant | roofline frac | useful flops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c["status"] == "skipped":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | -- | -- | -- "
                f"| skipped | -- | -- |")
            continue
        if c["status"] != "ok":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | -- | -- | -- "
                f"| FAILED | -- | -- |")
            continue
        r = c["roofline"]
        u = c.get("useful_flop_ratio")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} | {u:.3f} |" if u else
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} | -- |")
    return "\n".join(lines)
