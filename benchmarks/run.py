"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes a machine-readable
``BENCH_*.json`` snapshot (``--json PATH``, default ``BENCH_latest.json``)
so successive runs accumulate a perf trajectory.  Modules:
  fig12  AlgoBW vs transfer size (balanced/random/skewed) vs 4 baselines
  fig13  skew sweep + FLASH phase breakdown
  fig14  MoE end-to-end training speedup (EP degree, top-k)
  fig15  scale sweep (servers, GPUs/server)
  fig16  intra-server topology + bandwidth-ratio sweep
  fig17  scheduler synthesis time + memory overhead slope
  hetero heterogeneous fabrics: degraded/failed/mixed NICs, oversubscription
  dynamic  drifting-MoE serving loop: cache + warm start + compiled executor
  serving  closed-loop concurrent load on the plan-serving daemon
  fault    mid-run NIC failure: fabric events, re-repair, bounded slowdown
  roofline  per-(arch x shape x mesh) terms from the dry-run sweep
"""

from __future__ import annotations

import argparse

from . import (
    fig12_algbw,
    fig13_skew,
    fig14_moe_e2e,
    fig15_scale,
    fig16_topo,
    fig17_overhead,
    fig_dynamic,
    fig_fault,
    fig_hetero,
    fig_serving,
    roofline_table,
)
from .common import Csv


MODULES = (fig12_algbw, fig13_skew, fig14_moe_e2e, fig15_scale,
           fig16_topo, fig17_overhead, fig_hetero, fig_dynamic,
           fig_serving, fig_fault, roofline_table)


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--json", default="BENCH_latest.json", metavar="PATH",
        help="write a machine-readable snapshot here ('' to disable)")
    parser.add_argument(
        "--only", default="", metavar="SUBSTR",
        help="run only modules whose name contains SUBSTR "
             "(e.g. 'fig17' for the synthesis/overhead rows)")
    args = parser.parse_args(argv)

    mods = [m for m in MODULES if args.only in m.__name__]
    if not mods:
        names = ", ".join(m.__name__.rsplit(".", 1)[-1] for m in MODULES)
        parser.error(f"--only {args.only!r} matches none of: {names}")
    csv = Csv()
    print("name,us_per_call,derived")
    for mod in mods:
        mod.run(csv)
    if args.json:
        csv.write_json(args.json)
        print(f"# wrote {len(csv.records)} rows to {args.json}")


if __name__ == "__main__":
    main()
