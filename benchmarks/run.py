"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:
  fig12  AlgoBW vs transfer size (balanced/random/skewed) vs 4 baselines
  fig13  skew sweep + FLASH phase breakdown
  fig14  MoE end-to-end training speedup (EP degree, top-k)
  fig15  scale sweep (servers, GPUs/server)
  fig16  intra-server topology + bandwidth-ratio sweep
  fig17  scheduler synthesis time + memory overhead slope
  roofline  per-(arch x shape x mesh) terms from the dry-run sweep
"""

from __future__ import annotations

from . import (
    fig12_algbw,
    fig13_skew,
    fig14_moe_e2e,
    fig15_scale,
    fig16_topo,
    fig17_overhead,
    roofline_table,
)
from .common import Csv


def main() -> None:
    csv = Csv()
    print("name,us_per_call,derived")
    for mod in (fig12_algbw, fig13_skew, fig14_moe_e2e, fig15_scale,
                fig16_topo, fig17_overhead, roofline_table):
        mod.run(csv)


if __name__ == "__main__":
    main()
