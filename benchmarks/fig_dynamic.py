"""Dynamic-MoE serving trajectory: compiled execution end to end.

The paper's serving story is traffic that shifts every few hundred
milliseconds; PR 3/4 made *synthesis* microsecond-scale, and the compiled
executor removes the remaining per-iteration executor overhead.  Series:

  exec.cached{n}     compiled re-execution of a cached n-server FLASH plan
                     (`execute_plan` on a plan whose ExecutableSchedule is
                     memoized) vs the interpreted per-phase walk
                     (`reference=True`).  The derived ``speedup`` column is
                     the issue-5 acceptance bar (>= 10x) and feeds the CI
                     perf-budget guard (benchmarks/check_synth_budget.py).
  exec.compile{n}    one-shot `compile_plan` cost -- the price of the first
                     execution, amortized away by the memo slot.
  exec.batch{n}      per-matrix cost of `ExecutableSchedule.execute_batch`
                     on a (B, N, N) drift stack vs a loop of compiled
                     `execute_plan` calls.
  dynamic.trajectory end-to-end serving loop over a drifting-MoE
                     trajectory with repeated gating signatures:
                     `PlanCache(warm_start=True)` -> `simulate_many`
                     (cache hit -> compiled execute; near miss -> warm
                     repair; cold otherwise), reported as us/iteration.
  dynamic.synth_amortized
                     amortized per-step synthesis over the drift
                     trajectory via `synthesize_trajectory` with the
                     incremental DecompositionState engine, excluding the
                     step-0 cold bootstrap (paid once per family, not per
                     step).  Derived columns: one-shot repair baseline
                     (`RepairConfig(incremental=False)`) and the ratio vs
                     compiled execution -- the issue-7 acceptance bars
                     (amortized <= 10x exec.cached32, incremental >= 2x
                     one-shot) enforced by check_synth_budget.py.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    ClusterSpec,
    PlanCache,
    RepairConfig,
    compile_plan,
    execute_plan,
    get_scheduler,
    moe_workload,
    simulate_many,
)
from repro.core.traffic import Workload

from .common import Csv, time_us

_N, _M = 32, 8  # the issue-5 bar is a cached 32-server FLASH plan
_TRAJ_STEPS = 48
_REPEAT_P = 0.3  # fraction of iterations whose gating signature repeats
_DRIFT_P = 0.02  # entry-level drift probability between iterations


def _drift_trajectory(cluster, steps, seed=0):
    """Drifting-MoE gating: each iteration either replays a recent
    signature (PlanCache exact hit) or perturbs ~2% of the entries by
    +-20% (near miss -> warm repair)."""
    rng = np.random.default_rng(seed)
    base = moe_workload(cluster, 4096, 2048, top_k=2, seed=seed)
    mats = [base.matrix]
    for _ in range(1, steps):
        if rng.random() < _REPEAT_P and len(mats) > 1:
            mats.append(mats[int(rng.integers(len(mats)))])
            continue
        nxt = mats[-1].copy()
        drift = rng.random(nxt.shape) < _DRIFT_P
        nxt[drift] *= rng.uniform(0.8, 1.2, size=int(drift.sum()))
        np.fill_diagonal(nxt, 0.0)
        mats.append(nxt)
    return [Workload(cluster, mat) for mat in mats]


def _amortized_synth_us(scheduler, traj, config, passes=5):
    """Mean synthesis seconds per trajectory step past the step-0 cold
    bootstrap (the one full decomposition every family pays regardless of
    engine).  Repeated signatures resolve from the trajectory memo and
    cost zero synthesis -- exactly the serving cache's behavior.  The
    chain is single-shot per pass, so the best of ``passes`` runs is the
    low-noise estimate (the analogue of time_us's hot-loop averaging)."""
    best = None
    for _ in range(max(passes, 1)):
        plans = scheduler.synthesize_trajectory(traj, config=config)
        seen = {id(plans[0])}
        total = 0.0
        for p in plans[1:]:
            if id(p) not in seen:
                seen.add(id(p))
                total += p.synth_seconds
        us = total * 1e6 / max(len(traj) - 1, 1)
        best = us if best is None else min(best, us)
    return best


def run(csv: Csv):
    cluster = ClusterSpec(n_servers=_N, m_gpus=_M)
    w = moe_workload(cluster, 8192, 4096, top_k=2, seed=0)
    plan = get_scheduler("flash").synthesize(w)

    # Compiled re-execution of a cached plan: the serving-loop hot path
    # (PlanCache hit -> plan with its ExecutableSchedule attached).
    plan.compile()  # attach the memoized schedule up front
    compiled_us = time_us(lambda: execute_plan(plan, w), repeats=30)
    interp_us = time_us(lambda: execute_plan(plan, w, reference=True),
                        repeats=3)
    csv.emit(f"exec.cached{_N}", compiled_us,
             f"interp_us={interp_us:.1f}"
             f"|speedup={interp_us / max(compiled_us, 1e-9):.1f}x"
             f"|n_stages={plan.n_stages}")

    # One-shot compilation cost (the first execution's overhead).
    compile_us = time_us(lambda: compile_plan(plan), repeats=3)
    csv.emit(f"exec.compile{_N}", compile_us,
             f"interp_exec_us={interp_us:.1f}"
             f"|vs_one_interp={interp_us / max(compile_us, 1e-9):.2f}x")

    # Batched accounting of a (B, N, N) drift stack against one schedule.
    traj_b = _drift_trajectory(cluster, 32, seed=3)
    stack = np.stack([t.matrix for t in traj_b])
    sched = plan.compile()
    batch_us = time_us(lambda: sched.execute_batch(stack), repeats=5)
    loop_us = time_us(lambda: [execute_plan(plan, t) for t in traj_b],
                      repeats=5)
    csv.emit(f"exec.batch{_N}", batch_us / len(traj_b),
             f"loop_us_per_matrix={loop_us / len(traj_b):.2f}"
             f"|batch={len(traj_b)}")

    # End-to-end serving loop: drifting trajectory through cache + warm
    # start + compiled execution.
    traj = _drift_trajectory(cluster, _TRAJ_STEPS, seed=7)
    cache = PlanCache(warm_start=True)
    t0 = time.perf_counter()
    results = simulate_many(traj, "flash", cache=cache)
    total_us = (time.perf_counter() - t0) * 1e6
    algbw = np.mean([r.algbw for r in results]) / 1e9
    csv.emit("dynamic.trajectory", total_us / len(traj),
             f"steps={len(traj)}|hits={cache.hits}|misses={cache.misses}"
             f"|warm_hits={cache.warm_hits}"
             f"|mean_algbw_gbps={algbw:.2f}")

    # Amortized per-step synthesis: incremental delta-decomposition vs the
    # legacy one-shot repair loop, both fused over the same trajectory.
    # One warmup pass (house style: time_us warms once) keeps allocator
    # and code-path effects out of the single-shot chain measurement.
    traj_s = _drift_trajectory(cluster, _TRAJ_STEPS, seed=11)
    sched_flash = get_scheduler("flash")
    _amortized_synth_us(sched_flash, traj_s, RepairConfig())
    inc_us = _amortized_synth_us(sched_flash, traj_s, RepairConfig())
    one_us = _amortized_synth_us(sched_flash, traj_s,
                                 RepairConfig(incremental=False))
    csv.emit("dynamic.synth_amortized", inc_us,
             f"oneshot_us={one_us:.1f}"
             f"|speedup={one_us / max(inc_us, 1e-9):.1f}x"
             f"|exec_us={compiled_us:.2f}"
             f"|ratio={inc_us / max(compiled_us, 1e-9):.2f}x"
             f"|steps={len(traj_s)}")


if __name__ == "__main__":
    run(Csv())
