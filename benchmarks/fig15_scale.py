"""Paper Fig 15: scaling servers (8 GPUs each) and GPUs-per-server (8
servers), 100 Gbps RoCE + 900 GB/s NVSwitch-class intra fabric; plus the
old-vs-new synthesis-time curve over the same server sweep."""

from __future__ import annotations

from repro.core import ClusterSpec, random_workload, simulate
from repro.core.birkhoff import birkhoff_decompose

from .common import Csv, time_us

HW = dict(b_intra=900e9 / 8, b_inter=12.5e9, alpha=10e-6,
          intra_topology="switch")


def run(csv: Csv):
    for n in (2, 4, 8, 16, 32):
        cluster = ClusterSpec(n_servers=n, m_gpus=8, **HW)
        w = random_workload(cluster, 16 << 20, seed=0)
        flash = simulate(w, "flash")
        opt = simulate(w, "optimal")
        mpi = simulate(w, "spreadout")
        csv.emit(f"fig15.servers{n}", flash.completion_time * 1e6,
                 f"algbw_gbps={flash.algbw_gbps():.2f}"
                 f"|opt_frac={flash.algbw / opt.algbw:.3f}"
                 f"|vs_mpi={flash.algbw / mpi.algbw:.2f}x")
        # synthesis engine trajectory on the same sweep: incremental
        # (bit-identical at these sizes) vs the seed's reference decomposer
        t_server = w.server_matrix()
        new_us = time_us(lambda: birkhoff_decompose(t_server), repeats=3)
        ref_us = time_us(lambda: birkhoff_decompose(t_server,
                                                    reference=True),
                         repeats=1, warmup=0)
        csv.emit(f"fig15.synth.servers{n}", new_us,
                 f"ref_us={ref_us:.1f}|speedup={ref_us / new_us:.1f}x")
    for m in (2, 4, 8, 16):
        cluster = ClusterSpec(n_servers=8, m_gpus=m, **HW)
        w = random_workload(cluster, 16 << 20, seed=1)
        flash = simulate(w, "flash")
        opt = simulate(w, "optimal")
        gap = 1 - flash.algbw / opt.algbw
        csv.emit(f"fig15.gpus{m}", flash.completion_time * 1e6,
                 f"algbw_gbps={flash.algbw_gbps():.2f}"
                 f"|gap_pct={100 * gap:.1f}")
