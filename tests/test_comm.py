"""Multi-device collective tests (subprocess with 8 fake CPU devices)."""



def test_all_to_all_impl_equivalence(subproc):
    """flash == hierarchical == direct == mathematical reference."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comm import direct_all_to_all, flash_all_to_all, \\
    hierarchical_all_to_all
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
C, D, n_shards = 3, 5, 4
rng = np.random.default_rng(0)
x = rng.normal(size=(2 * 2 * n_shards, C, D)).astype(np.float32)
spec = P(("pod", "data"))
outs = {}
for name, fn in [("direct", direct_all_to_all),
                 ("flash", flash_all_to_all),
                 ("hier", hierarchical_all_to_all)]:
    f = jax.shard_map(partial(fn, slow_axis="pod", fast_axes=("data",)),
                      mesh=mesh, in_specs=spec, out_specs=spec)
    outs[name] = np.asarray(jax.jit(f)(x))
ref = np.swapaxes(x.reshape(n_shards, n_shards, C, D), 0, 1) \\
    .reshape(2 * 2 * n_shards, C, D)
assert np.array_equal(outs["direct"], ref), "direct != ref"
assert np.array_equal(outs["flash"], ref), "flash != ref"
assert np.array_equal(outs["hier"], ref), "hier != ref"
print("EQUIV_OK")
""")
    assert "EQUIV_OK" in out


def test_rotation_all_to_all(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comm import rotation_all_to_all
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("pod", "model"))
rng = np.random.default_rng(1)
x = rng.normal(size=(16, 6)).astype(np.float32)  # 4 shards x 4 rows
f = jax.shard_map(partial(rotation_all_to_all, axis="pod"),
                  mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
out = np.asarray(jax.jit(f)(x))
ref = np.swapaxes(x.reshape(4, 4, 1, 6), 0, 1).reshape(16, 6)
assert np.array_equal(out, ref)
print("ROT_OK")
""")
    assert "ROT_OK" in out


def test_ef_compressed_psum(subproc):
    """int8 EF sum: ~1e-2 one-shot error; error feedback kills bias."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comm import ef_compressed_psum
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
g = rng.normal(size=(2, 64, 32)).astype(np.float32)

def sync(gl, err):
    total, new_err = ef_compressed_psum(gl[0], "pod", err[0])
    return total[None], new_err[None]

f = jax.jit(jax.shard_map(
    sync, mesh=mesh, in_specs=(P("pod"), P("pod")),
    out_specs=(P("pod"), P("pod"))))
true = g.sum(0)
err = np.zeros_like(g)
tot, err = f(g, err)
rel = np.abs(np.asarray(tot)[0] - true).max() / np.abs(true).max()
assert rel < 0.05, rel
# repeated steps with same grad: error feedback => mean approaches truth
acc = np.zeros_like(true)
err = np.zeros_like(g)
for i in range(16):
    tot, err = f(g, err)
    acc += np.asarray(tot)[0]
rel_mean = np.abs(acc / 16 - true).max() / np.abs(true).max()
assert rel_mean < 0.012, rel_mean
print("EF_OK")
""")
    assert "EF_OK" in out
