"""Multi-device collective tests (subprocess with 8 fake CPU devices)."""



def test_all_to_all_impl_equivalence(subproc):
    """flash == hierarchical == direct == mathematical reference."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comm import direct_all_to_all, flash_all_to_all, \\
    hierarchical_all_to_all
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
C, D, n_shards = 3, 5, 4
rng = np.random.default_rng(0)
x = rng.normal(size=(2 * 2 * n_shards, C, D)).astype(np.float32)
spec = P(("pod", "data"))
outs = {}
for name, fn in [("direct", direct_all_to_all),
                 ("flash", flash_all_to_all),
                 ("hier", hierarchical_all_to_all)]:
    f = jax.shard_map(partial(fn, slow_axis="pod", fast_axes=("data",)),
                      mesh=mesh, in_specs=spec, out_specs=spec)
    outs[name] = np.asarray(jax.jit(f)(x))
ref = np.swapaxes(x.reshape(n_shards, n_shards, C, D), 0, 1) \\
    .reshape(2 * 2 * n_shards, C, D)
assert np.array_equal(outs["direct"], ref), "direct != ref"
assert np.array_equal(outs["flash"], ref), "flash != ref"
assert np.array_equal(outs["hier"], ref), "hier != ref"
print("EQUIV_OK")
""")
    assert "EQUIV_OK" in out


def test_rotation_all_to_all(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comm import rotation_all_to_all
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("pod", "model"))
rng = np.random.default_rng(1)
x = rng.normal(size=(16, 6)).astype(np.float32)  # 4 shards x 4 rows
f = jax.shard_map(partial(rotation_all_to_all, axis="pod"),
                  mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
out = np.asarray(jax.jit(f)(x))
ref = np.swapaxes(x.reshape(4, 4, 1, 6), 0, 1).reshape(16, 6)
assert np.array_equal(out, ref)
print("ROT_OK")
""")
    assert "ROT_OK" in out


def test_ef_compressed_psum(subproc):
    """int8 EF sum: ~1e-2 one-shot error; error feedback kills bias."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comm import ef_compressed_psum
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
g = rng.normal(size=(2, 64, 32)).astype(np.float32)

def sync(gl, err):
    total, new_err = ef_compressed_psum(gl[0], "pod", err[0])
    return total[None], new_err[None]

f = jax.jit(jax.shard_map(
    sync, mesh=mesh, in_specs=(P("pod"), P("pod")),
    out_specs=(P("pod"), P("pod"))))
true = g.sum(0)
err = np.zeros_like(g)
tot, err = f(g, err)
rel = np.abs(np.asarray(tot)[0] - true).max() / np.abs(true).max()
assert rel < 0.05, rel
# repeated steps with same grad: error feedback => mean approaches truth
acc = np.zeros_like(true)
err = np.zeros_like(g)
for i in range(16):
    tot, err = f(g, err)
    acc += np.asarray(tot)[0]
rel_mean = np.abs(acc / 16 - true).max() / np.abs(true).max()
assert rel_mean < 0.012, rel_mean
print("EF_OK")
""")
    assert "EF_OK" in out


def test_plan_all_to_all_bit_identity(subproc):
    """impl="plan" == direct on every routed-token exchange: 2-pod and
    4-pod meshes, moe/skewed/random matrices, pallas-kernel and jnp
    paths (the tentpole acceptance golden)."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comm import direct_all_to_all, plan_all_to_all
from repro.core.schedulers import get_scheduler
from repro.core.traffic import ClusterSpec, Workload, moe_workload, \\
    skewed_workload
from repro.launch.mesh import make_mesh

def rand_w(n_servers, m_gpus, seed):
    n = n_servers * m_gpus
    rng = np.random.default_rng(seed)
    mat = rng.integers(1, 50, size=(n, n)).astype(float)
    np.fill_diagonal(mat, 0)
    return Workload(ClusterSpec(n_servers, m_gpus), mat)

cases = [
    (2, 4, moe_workload(ClusterSpec(2, 4), 256, 2, seed=0), "flash"),
    (2, 4, skewed_workload(ClusterSpec(2, 4), 1e6, seed=1), "flash"),
    (4, 2, moe_workload(ClusterSpec(4, 2), 256, 2, seed=2), "flash"),
    (4, 2, rand_w(4, 2, 3), "fanout"),
]
rng = np.random.default_rng(42)
for pods, gpp, w, algo in cases:
    mesh = make_mesh((pods, gpp), ("pod", "data"))
    plan = get_scheduler(algo).synthesize(w)
    n = pods * gpp
    x = jnp.asarray(rng.normal(size=(n * n, 3, 8)).astype(np.float32))
    spec = P(("pod", "data"))
    for use_kernel in (True, False):
        f_plan = jax.shard_map(
            partial(plan_all_to_all, slow_axis="pod", fast_axes=("data",),
                    plan=plan, use_kernel=use_kernel),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
        f_dir = jax.shard_map(
            partial(direct_all_to_all, slow_axis="pod",
                    fast_axes=("data",)),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
        a = np.asarray(jax.jit(f_plan)(x))
        b = np.asarray(jax.jit(f_dir)(x))
        assert np.array_equal(a, b), \\
            f"plan != direct: pods={pods} {algo} kernel={use_kernel}"
print("PLAN_GOLDEN_OK")
""")
    assert "PLAN_GOLDEN_OK" in out


def test_plan_all_to_all_slow_only(subproc):
    """Slow-axis-only EP (no fast axes): the plan path replaces the
    rotation schedule and still matches it bit for bit."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comm import plan_all_to_all, rotation_all_to_all
from repro.core.schedulers import get_scheduler
from repro.core.traffic import ClusterSpec, moe_workload
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("pod", "model"))
w = moe_workload(ClusterSpec(4, 1), 256, 2, seed=5)
plan = get_scheduler("flash").synthesize(w)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
f_plan = jax.shard_map(
    partial(plan_all_to_all, slow_axis="pod", fast_axes=(), plan=plan),
    mesh=mesh, in_specs=P("pod"), out_specs=P("pod"), check_vma=False)
f_rot = jax.shard_map(
    partial(rotation_all_to_all, axis="pod"),
    mesh=mesh, in_specs=P("pod"), out_specs=P("pod"), check_vma=False)
a = np.asarray(jax.jit(f_plan)(x))
b = np.asarray(jax.jit(f_rot)(x))
assert np.array_equal(a, b)
print("PLAN_SLOW_ONLY_OK")
""")
    assert "PLAN_SLOW_ONLY_OK" in out


def test_resolve_auto_prefers_plan():
    """impl="auto" resolution across homo/hetero topologies with and
    without a plan: a supplied plan wins everywhere; otherwise the fabric
    decides (flash on hetero, direct on homo/unknown)."""
    from repro.comm.all_to_all import (
        direct_all_to_all,
        flash_all_to_all,
        resolve_all_to_all,
    )
    from repro.comm.plan_exec import plan_all_to_all
    from repro.core.schedulers import get_scheduler
    from repro.core.topology import Topology

    w = _mk_workload(4, 2)
    plan = get_scheduler("flash").synthesize(w)
    homo = Topology.from_cluster(w.cluster)
    het = homo.degrade_nic(0, 0, 0.5)
    for topo in (None, homo, het):
        got = resolve_all_to_all(slow_axis="pod", ep_axes=("pod", "data"),
                                 impl="auto", topology=topo, plan=plan)
        assert got.func is plan_all_to_all
        assert got.keywords["plan"] is plan
    assert resolve_all_to_all(
        slow_axis="pod", ep_axes=("pod", "data"), impl="auto",
        topology=het).func is flash_all_to_all
    assert resolve_all_to_all(
        slow_axis="pod", ep_axes=("pod", "data"), impl="auto",
        topology=homo).func is direct_all_to_all
    # slow-only EP: plan replaces the rotation schedule
    rot = resolve_all_to_all(slow_axis="pod", ep_axes=("pod",),
                             impl="auto", plan=plan)
    assert rot.func is plan_all_to_all
    assert rot.keywords["fast_axes"] == ()


def test_resolve_plan_impl_requires_plan():
    import pytest

    from repro.comm.all_to_all import resolve_all_to_all

    with pytest.raises(ValueError, match="needs a synthesized plan"):
        resolve_all_to_all(slow_axis="pod", ep_axes=("pod", "data"),
                           impl="plan")


def test_resolve_dist_context_plan_path():
    """The DistContext attribute path threads .plan through to the
    closed-over impl (what models/moe.py relies on)."""
    from repro.comm.all_to_all import resolve_all_to_all
    from repro.comm.plan_exec import plan_all_to_all
    from repro.core.schedulers import get_scheduler

    plan = get_scheduler("flash").synthesize(_mk_workload(2, 4))

    class _Dist:
        slow_axis = "pod"
        ep_axes = ("pod", "data")
        a2a_impl = "auto"
        topology = None
        plan_attr = None

    _Dist.plan = plan
    got = resolve_all_to_all(_Dist())
    assert got.func is plan_all_to_all
    assert got.keywords["plan"] is plan


def _mk_workload(n_servers, m_gpus, seed=0):
    import numpy as np

    from repro.core.traffic import ClusterSpec, Workload

    n = n_servers * m_gpus
    rng = np.random.default_rng(seed)
    mat = rng.integers(1, 50, size=(n, n)).astype(float)
    np.fill_diagonal(mat, 0)
    return Workload(ClusterSpec(n_servers, m_gpus), mat)
