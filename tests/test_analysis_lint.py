"""Static analysis passes (issue 9): each AST rule fires on a seeded
violation, suppression works, the repo tree is clean, and the plan
verifier catches injected structural defects."""

import dataclasses
import json
import subprocess
import sys

import numpy as np

from repro.analysis import astlint, planlint
from repro.analysis.corpus import emit_corpus
from repro.core.plan import PermutationBlock, PermutationStage
from repro.core.schedulers import get_scheduler
from repro.core.traffic import ClusterSpec, balanced_workload

SRC_ROOT = "src"


def _rules(findings):
    return [f.rule for f in findings]


# -- LCK001 ---------------------------------------------------------------

def test_lck001_raw_lock():
    src = "import threading\nlock = threading.Lock()\n"
    assert _rules(astlint.lint_source(src)) == ["LCK001"]


def test_lck001_raw_rlock_and_condition():
    src = ("import threading\n"
           "a = threading.RLock()\n"
           "b = threading.Condition()\n")
    assert _rules(astlint.lint_source(src)) == ["LCK001", "LCK001"]


def test_lck001_bare_import_form():
    src = "from threading import Lock\nlock = Lock()\n"
    assert _rules(astlint.lint_source(src)) == ["LCK001"]


def test_lck001_event_not_flagged():
    src = "import threading\nev = threading.Event()\n"
    assert astlint.lint_source(src) == []


def test_lck001_noqa_suppression():
    src = "import threading\nlock = threading.Lock()  # noqa: LCK001\n"
    assert astlint.lint_source(src) == []
    src2 = "import threading\nlock = threading.Lock()  # noqa\n"
    assert astlint.lint_source(src2) == []


def test_factory_call_not_flagged():
    src = ("from repro.analysis.locks import make_lock\n"
           "lock = make_lock('X._lock')\n")
    assert astlint.lint_source(src) == []


# -- LCK002 ---------------------------------------------------------------

_SPEC = {"Telemetry": ("_lock", frozenset({"_counters", "_count"}))}


def _lck002(src):
    return astlint.lint_source(src, guard_specs=_SPEC,
                               check_lck001=False)


def test_lck002_unlocked_write_flagged():
    src = ("class Telemetry:\n"
           "    def bump(self):\n"
           "        self._counters['x'] = 1\n")
    assert _rules(_lck002(src)) == ["LCK002"]


def test_lck002_locked_write_clean():
    src = ("class Telemetry:\n"
           "    def bump(self):\n"
           "        with self._lock:\n"
           "            self._counters['x'] = 1\n")
    assert _lck002(src) == []


def test_lck002_init_exempt():
    src = ("class Telemetry:\n"
           "    def __init__(self):\n"
           "        self._counters = {}\n")
    assert _lck002(src) == []


def test_lck002_locked_suffix_exempt():
    src = ("class Telemetry:\n"
           "    def _bump_locked(self):\n"
           "        self._counters['x'] = 1\n")
    assert _lck002(src) == []


def test_lck002_mutator_call_flagged():
    src = ("class Telemetry:\n"
           "    def bump(self):\n"
           "        self._counters.update(a=1)\n")
    assert _rules(_lck002(src)) == ["LCK002"]


def test_lck002_augassign_flagged():
    src = ("class Telemetry:\n"
           "    def bump(self):\n"
           "        self._count += 1\n")
    assert _rules(_lck002(src)) == ["LCK002"]


def test_lck002_delete_flagged():
    src = ("class Telemetry:\n"
           "    def drop(self):\n"
           "        del self._counters['x']\n")
    assert _rules(_lck002(src)) == ["LCK002"]


def test_lck002_unregistered_attr_clean():
    src = ("class Telemetry:\n"
           "    def bump(self):\n"
           "        self._other = 1\n")
    assert _lck002(src) == []


def test_lck002_unregistered_class_clean():
    src = ("class Whatever:\n"
           "    def bump(self):\n"
           "        self._counters['x'] = 1\n")
    assert _lck002(src) == []


# -- EXC001 ---------------------------------------------------------------

def test_exc001_swallow_flagged():
    src = ("try:\n    pass\nexcept Exception:\n    pass\n")
    assert _rules(astlint.lint_source(src)) == ["EXC001"]


def test_exc001_bare_except_flagged():
    src = ("try:\n    pass\nexcept:\n    x = 1\n")
    assert _rules(astlint.lint_source(src)) == ["EXC001"]


def test_exc001_reraise_clean():
    src = ("try:\n    pass\nexcept BaseException:\n    raise\n")
    assert astlint.lint_source(src) == []


def test_exc001_telemetry_count_clean():
    src = ("try:\n    pass\nexcept Exception:\n"
           "    tel.count('errors')\n")
    assert astlint.lint_source(src) == []


def test_exc001_capture_clean():
    src = ("err = None\ntry:\n    pass\nexcept BaseException as e:\n"
           "    err = e\n")
    assert astlint.lint_source(src) == []


def test_exc001_narrow_except_clean():
    src = ("try:\n    pass\nexcept ValueError:\n    pass\n")
    assert astlint.lint_source(src) == []


# -- DET001 ---------------------------------------------------------------

def test_det001_wall_clock_flagged():
    src = "import time\nt = time.time()\n"
    fs = astlint.lint_source(src, check_det001=True, check_lck001=False)
    assert _rules(fs) == ["DET001"]


def test_det001_unseeded_np_random_flagged():
    src = "import numpy as np\nx = np.random.rand(3)\n"
    fs = astlint.lint_source(src, check_det001=True, check_lck001=False)
    assert _rules(fs) == ["DET001"]


def test_det001_seeded_rng_and_perf_counter_clean():
    src = ("import time\nimport numpy as np\n"
           "rng = np.random.default_rng(0)\n"
           "t = time.perf_counter()\nm = time.monotonic()\n")
    assert astlint.lint_source(src, check_det001=True,
                               check_lck001=False) == []


def test_det001_off_outside_core():
    src = "import time\nt = time.time()\n"
    assert astlint.lint_source(src, check_det001=False) == []


# -- the repo itself is clean --------------------------------------------

def test_repo_tree_clean():
    findings = astlint.lint_tree(SRC_ROOT)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_comm_in_scope_with_det001():
    """comm/ rides the DET001 determinism contract: the plan lowering
    (comm/plan_exec.py) bakes plans into traced programs, so wall-clock /
    unseeded-random use there is as replay-hostile as in core/."""
    import os

    path = os.path.join(SRC_ROOT, "repro", "comm", "plan_exec.py")
    # in scope and clean as shipped
    assert astlint.lint_file(path, SRC_ROOT) == []
    # DET001 actually armed for a comm module path
    dirty = "import time\nt = time.time()\n"
    findings = astlint.lint_source(
        dirty, path=path, module="repro.comm.plan_exec",
        check_det001=True)
    assert [f.rule for f in findings] == ["DET001"]
    # models/ (for example) stays out of scope
    other = os.path.join(SRC_ROOT, "repro", "models", "moe.py")
    assert astlint.lint_file(other, SRC_ROOT) == []


# -- planlint -------------------------------------------------------------

C = ClusterSpec(4, 2)


def _plan():
    return get_scheduler("flash").synthesize(balanced_workload(C, 1e6))


def _codes(issues):
    return [i["code"] for i in issues]


def test_planlint_clean_plan():
    assert planlint.check_plan(_plan()) == []


def test_planlint_all_schedulers_clean():
    w = balanced_workload(C, 1e6)
    from repro.core.schedulers import SCHEDULERS
    for name in sorted(SCHEDULERS):
        plan = get_scheduler(name).synthesize(w)
        issues = planlint.check_plan(plan, source=name)
        assert issues == [], issues


def test_planlint_injected_incast():
    plan = _plan()
    bad_stage = PermutationStage(perm=(1, 0, 0, -1), size=10.0,
                                 sent=(10.0, 10.0, 10.0, 0.0))
    bad = dataclasses.replace(plan, phases=plan.phases + (bad_stage,))
    issues = planlint.check_plan(bad)
    assert "PLAN-STRUCT" in _codes(issues)
    assert any("incast" in i["message"] for i in issues)


def test_planlint_injected_self_traffic():
    plan = _plan()
    bad_stage = PermutationStage(perm=(0, 2, 1, -1), size=10.0,
                                 sent=(10.0, 10.0, 10.0, 0.0))
    bad = dataclasses.replace(plan, phases=plan.phases + (bad_stage,))
    issues = planlint.check_plan(bad)
    assert any("self-traffic" in i["message"] for i in issues)


def test_planlint_injected_slot_overflow():
    plan = _plan()
    bad_stage = PermutationStage(perm=(1, 2, 3, 0), size=5.0,
                                 sent=(10.0, 1.0, 1.0, 1.0))
    bad = dataclasses.replace(plan, phases=plan.phases + (bad_stage,))
    issues = planlint.check_plan(bad)
    assert any("exceeds slot size" in i["message"] for i in issues)


def test_planlint_descending_stage_order():
    plan = _plan()
    s1 = PermutationStage(perm=(1, 2, 3, 0), size=100.0, sent=(100.0,) * 4)
    s2 = PermutationStage(perm=(2, 3, 0, 1), size=10.0, sent=(10.0,) * 4)
    bad = dataclasses.replace(plan, phases=(s1, s2))
    issues = planlint.check_plan(bad)
    assert "PLAN-ORDER" in _codes(issues)


def test_planlint_block_exempt_from_order():
    """Repair blocks keep stored order by design: no PLAN-ORDER issue."""
    plan = _plan()
    block = PermutationBlock(
        perms=np.array([[1, 2, 3, 0], [2, 3, 0, 1]]),
        sizes=np.array([100.0, 10.0]),
        sent=np.array([[100.0] * 4, [10.0] * 4]))
    bad = dataclasses.replace(plan, phases=(block,))
    assert "PLAN-ORDER" not in _codes(planlint.check_plan(bad))


def test_planlint_shape_mismatch():
    plan = _plan()
    short = PermutationStage(perm=(1, 0), size=1.0, sent=(1.0, 1.0))
    bad = dataclasses.replace(plan, phases=plan.phases + (short,))
    issues = planlint.check_plan(bad)
    assert "PLAN-SHAPE" in _codes(issues)


def test_planlint_file_roundtrip(tmp_path):
    plan = _plan()
    path = tmp_path / "plan.json"
    path.write_text(json.dumps(plan.to_dict()))
    assert planlint.check_file(str(path)) == []


def test_planlint_file_with_bad_plan(tmp_path):
    plan = _plan()
    bad_stage = PermutationStage(perm=(1, 0, 0, -1), size=10.0,
                                 sent=(10.0, 10.0, 10.0, 0.0))
    bad = dataclasses.replace(plan, phases=plan.phases + (bad_stage,))
    path = tmp_path / "plans.json"
    path.write_text(json.dumps([plan.to_dict(), bad.to_dict()]))
    issues = planlint.check_file(str(path))
    assert issues and all("[1]" in i["source"] for i in issues)


def test_planlint_unreadable_file(tmp_path):
    path = tmp_path / "garbage.json"
    path.write_text("{not json")
    issues = planlint.check_file(str(path))
    assert _codes(issues) == ["PLAN-IO"]


def test_audit_cache_clean_and_family_mismatch():
    from repro.core.plan import PlanCache, plan_family_key

    cache = PlanCache(capacity=8)
    plan = _plan()
    cache.insert("k1", plan)
    rep = planlint.audit_cache(cache)
    assert rep["clean"] and rep["plans"] == 1

    # Corrupt the family index: point a foreign family key at the plan.
    with cache._lock:
        cache._family["deadbeef" * 4] = "k1"
        cache._family_count["deadbeef" * 4] = 1
    rep = planlint.audit_cache(cache)
    assert not rep["clean"]
    assert any(i["code"] == "CACHE-FAMILY" for i in rep["issues"])
    assert plan_family_key(plan) != "deadbeef" * 4


# -- corpus + CLI gate ----------------------------------------------------

def test_corpus_emission_and_check(tmp_path):
    out = tmp_path / "corpus"
    written = emit_corpus(str(out), algorithms=["flash", "fanout"])
    assert len(written) == 5
    result = planlint.check_paths([str(out)])
    assert result["clean"], result["issues"]
    assert result["plans"] == 10  # 5 workloads x 2 algorithms


def test_cli_gate_exits_zero_on_clean_corpus(tmp_path):
    out = tmp_path / "corpus"
    emit_corpus(str(out), algorithms=["flash"])
    report = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--planlint",
         "--corpus", str(out), "--json", str(report)],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(report.read_text())
    assert data["clean"] is True
    assert data["passes"]["planlint"]["plans"] == 5


def test_cli_gate_fails_on_injected_incast(tmp_path):
    plan = _plan()
    bad_stage = PermutationStage(perm=(1, 0, 0, -1), size=10.0,
                                 sent=(10.0, 10.0, 10.0, 0.0))
    bad = dataclasses.replace(plan, phases=plan.phases + (bad_stage,))
    out = tmp_path / "corpus"
    out.mkdir()
    (out / "bad.json").write_text(json.dumps([bad.to_dict()]))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--planlint",
         "--corpus", str(out)],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1
    assert "incast" in proc.stdout


def test_cli_astlint_exits_zero():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--astlint"],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
