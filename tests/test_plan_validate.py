"""Exhaustive Plan.validate coverage (issue 9): every
``PlanValidationError`` branch -- stage and block structural checks,
slot-vs-rail feasibility, byte conservation, serialization -- fires on a
targeted corruption and stays silent on the intact plan."""

import dataclasses

import numpy as np
import pytest

from repro.core.plan import (
    PermutationBlock,
    PermutationStage,
    Plan,
    PlanValidationError,
    uniform_nic_shares,
)
from repro.core.schedulers import get_scheduler
from repro.core.topology import Topology
from repro.core.traffic import ClusterSpec, Workload, balanced_workload

C = ClusterSpec(n_servers=4, m_gpus=2)
W = balanced_workload(C, 1e6)


def _plan(algo="flash", w=W):
    return get_scheduler(algo).synthesize(w)


def _with_phases(plan, phases):
    return dataclasses.replace(plan, phases=tuple(phases))


def _stage(**kw):
    defaults = dict(perm=(1, 2, 3, 0), size=10.0, sent=(10.0,) * 4)
    defaults.update(kw)
    return PermutationStage(**defaults)


def _block(**kw):
    defaults = dict(
        perms=np.array([[1, 2, 3, 0], [3, 0, 1, 2]]),
        sizes=np.array([10.0, 10.0]),
        sent=np.full((2, 4), 10.0))
    defaults.update(kw)
    return PermutationBlock(**defaults)


def _expect(plan, match, w=W):
    with pytest.raises(PlanValidationError, match=match):
        plan.validate(w)


def test_valid_plan_passes():
    _plan().validate(W)


def test_validate_structure_is_workload_free():
    """The extracted entry point needs no workload at all."""
    _plan().validate_structure()
    bad = _with_phases(_plan(), [_stage(perm=(1, 0, 0, -1),
                                        sent=(10.0, 10.0, 10.0, 0.0))])
    with pytest.raises(PlanValidationError, match="incast"):
        bad.validate_structure()


# -- workload-dependent branches ------------------------------------------

def test_cluster_mismatch():
    other = balanced_workload(ClusterSpec(8, 2), 1e6)
    _expect(_plan(), "plan targets", w=other)


def test_topology_fingerprint_mismatch():
    degraded = Topology.from_cluster(C).degrade_nic(0, 0, 0.5, "both")
    stale = Workload(C, W.matrix, degraded)
    _expect(_plan(), "different topology", w=stale)


def test_inter_bytes_not_conserved():
    plan = _plan()
    extra = _stage(size=1e6, sent=(1e6,) * 4)
    _expect(_with_phases(plan, plan.phases + (extra,)),
            "inter-server bytes not conserved")


def test_intra_bytes_not_conserved():
    plan = _plan()
    dropped = [p for p in plan.phases
               if p.payload(C)[1] == 0.0]
    assert len(dropped) < len(plan.phases), "plan must carry intra bytes"
    _expect(_with_phases(plan, dropped),
            "intra-server bytes not conserved")


# -- PermutationStage branches --------------------------------------------

def test_stage_incast():
    _expect(_with_phases(_plan(), [_stage(perm=(1, 0, 0, -1),
                                          sent=(10.0, 10.0, 10.0, 0.0))]),
            "incast")


def test_stage_self_traffic():
    _expect(_with_phases(_plan(), [_stage(perm=(0, 2, 1, -1),
                                          sent=(10.0,) * 3 + (0.0,))]),
            "self-traffic")


def test_stage_negative_size():
    _expect(_with_phases(_plan(), [_stage(size=-1.0)]),
            "payload exceeds slot size")


def test_stage_payload_exceeds_size():
    _expect(_with_phases(_plan(), [_stage(sent=(20.0, 1.0, 1.0, 1.0))]),
            "payload exceeds slot size")


def test_stage_slots_length_mismatch():
    _expect(_with_phases(_plan(), [_stage(slots=(10.0, 10.0))]),
            "slot sizes")


def test_stage_slot_exceeds_size():
    _expect(_with_phases(_plan(), [_stage(slots=(20.0,) + (10.0,) * 3,
                                          sent=(1.0,) * 4)]),
            "slot exceeds the stage size")


def test_stage_payload_exceeds_slot():
    _expect(_with_phases(_plan(), [_stage(slots=(5.0,) + (10.0,) * 3,
                                          sent=(8.0, 1.0, 1.0, 1.0))]),
            "exceeds its per-sender slot")


# -- PermutationBlock branches --------------------------------------------

def test_block_shape_disagreement():
    _expect(_with_phases(_plan(), [_block(sizes=np.array([10.0]))]),
            "arrays disagree")


def test_block_dst_out_of_range():
    _expect(_with_phases(
        _plan(), [_block(perms=np.array([[1, 2, 3, 9], [3, 0, 1, 2]]))]),
        "destination out of range")


def test_block_incast():
    _expect(_with_phases(
        _plan(), [_block(perms=np.array([[1, 1, 3, -1], [3, 0, 1, 2]]))]),
        "incast")


def test_block_self_traffic():
    _expect(_with_phases(
        _plan(), [_block(perms=np.array([[0, 2, 3, 1], [3, 0, 1, 2]]))]),
        "self-traffic")


def test_block_payload_exceeds_size():
    _expect(_with_phases(
        _plan(), [_block(sent=np.full((2, 4), 20.0))]),
        "payload exceeds slot size")


def test_block_slots_shape_mismatch():
    _expect(_with_phases(
        _plan(), [_block(slots=np.full((1, 4), 10.0))]),
        "slot sizes")


def test_block_slot_exceeds_size():
    _expect(_with_phases(
        _plan(), [_block(slots=np.full((2, 4), 20.0),
                         sent=np.full((2, 4), 1.0))]),
        "slot exceeds the stage size")


def test_block_payload_exceeds_slot():
    _expect(_with_phases(
        _plan(), [_block(slots=np.full((2, 4), 5.0),
                         sent=np.full((2, 4), 8.0),
                         sizes=np.array([10.0, 10.0]))]),
        "exceeds its per-sender slot")


# -- slot-vs-rail feasibility ---------------------------------------------

def _ca_setup():
    """A capacity-aware plan on a degraded fabric."""
    topo = Topology.from_cluster(C).degrade_nic(1, 0, 0.25, "both")
    w = Workload(C, W.matrix, topo)
    return get_scheduler("flash_ca").synthesize(w), w


def test_capacity_aware_valid():
    plan, w = _ca_setup()
    assert plan.capacity_aware
    plan.validate(w)


def test_stage_slot_vs_rail_infeasible():
    plan, w = _ca_setup()
    # Grafting uniform shares onto the degraded fabric's slots makes a
    # rail of the degraded pair need longer than the stage window.
    bad = dataclasses.replace(
        plan, nic_shares=uniform_nic_shares(C.n_servers, C.m_gpus))
    with pytest.raises(PlanValidationError, match="slot-vs-rail"):
        bad.validate(w)


def test_block_slot_vs_rail_infeasible():
    plan, w = _ca_setup()
    stages = [p for p in plan.phases if isinstance(p, PermutationStage)]
    assert stages, "capacity-aware cold plan emits PermutationStages"
    rest = [p for p in plan.phases
            if not isinstance(p, PermutationStage)]
    block = PermutationBlock(
        perms=np.array([s.perm for s in stages]),
        sizes=np.array([s.size for s in stages]),
        sent=np.array([s.sent for s in stages]),
        slots=np.array([s.slots if s.slots is not None
                        else (s.size,) * C.n_servers for s in stages]))
    as_block = dataclasses.replace(
        plan, phases=tuple(rest) + (block,),
        nic_shares=uniform_nic_shares(C.n_servers, C.m_gpus))
    with pytest.raises(PlanValidationError, match="slot-vs-rail"):
        as_block.validate(w)


# -- serialization --------------------------------------------------------

def test_unknown_phase_kind():
    d = _plan().to_dict()
    d["phases"][0]["kind"] = "warp_drive"
    with pytest.raises(PlanValidationError, match="unknown phase kind"):
        Plan.from_dict(d)


def test_roundtrip_still_validates():
    plan = _plan()
    Plan.from_dict(plan.to_dict()).validate(W)
