"""Per-arch smoke tests (reduced configs, 1 CPU device): one forward/train
step asserting output shapes + no NaNs, one decode step, and decode==forward
consistency for a representative subset."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, smoke_config
from repro.models import build_model

B, S = 2, 16


def _batch(cfg, key=0):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (B, cfg.frontend_len, cfg.d_model)) * 0.1
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 2),
            (B, cfg.encoder_len, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    grads = jax.grad(lambda p: m.loss(p, _batch(cfg))[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm), f"{arch}: bad grads"


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step_smoke(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(B, 32)
    logits, cache2 = jax.jit(
        lambda p, c, t, pos: m.decode_step(p, c, t, pos))(
        params, cache, jnp.ones((B,), jnp.int32), jnp.int32(3))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode NaN"


@pytest.mark.parametrize("arch", [
    "llama3.2-1b", "qwen3-0.6b", "mixtral-8x7b", "dbrx-132b",
    "xlstm-125m", "hymba-1.5b", "granite-3-2b", "mistral-large-123b",
    "megatron-moe-32e"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode chain reproduces the training forward."""
    cfg = dataclasses.replace(smoke_config(arch), compute_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    from repro.models.transformer import lm_forward
    logits_fwd, _ = lm_forward(cfg, params, toks, {"tokens": toks})
    cache = m.init_cache(B, S)
    scale = float(jnp.abs(logits_fwd).max()) + 1e-9
    step = jax.jit(lambda p, c, t, pos: m.decode_step(p, c, t, pos))
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
        err = float(jnp.abs(lg - logits_fwd[:, t]).max()) / scale
        assert err < 1e-5, (arch, t, err)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b",
                                  "xlstm-125m", "hymba-1.5b"])
def test_prefill_then_decode(arch):
    cfg = dataclasses.replace(smoke_config(arch), compute_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    from repro.models.transformer import lm_forward, lm_prefill
    logits_fwd, _ = lm_forward(cfg, params, toks, {"tokens": toks})
    scale = float(jnp.abs(logits_fwd).max()) + 1e-9
    half = S // 2
    lg, cache = lm_prefill(cfg, params, toks[:, :half], cache_len=S)
    assert float(jnp.abs(lg - logits_fwd[:, half - 1]).max()) / scale < 1e-5
    for t in range(half, S):
        lg, cache = m.decode_step(params, cache, toks[:, t], jnp.int32(t))
        err = float(jnp.abs(lg - logits_fwd[:, t]).max()) / scale
        assert err < 1e-5, (arch, t, err)


def test_sliding_window_masks_history():
    """A windowed arch must ignore tokens beyond the window."""
    cfg = dataclasses.replace(smoke_config("mixtral-8x7b"),
                              compute_dtype="float32", swa_window=4,
                              n_layers=1, moe=None, family="dense")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    from repro.models.transformer import lm_forward
    base, _ = lm_forward(cfg, params, toks, None)
    # perturb a token far outside the window of the last position
    toks2 = toks.at[0, 1].set((toks[0, 1] + 7) % cfg.vocab)
    pert, _ = lm_forward(cfg, params, toks2, None)
    # last position only sees tokens 8..11: unchanged
    assert float(jnp.abs(base[0, -1] - pert[0, -1]).max()) < 1e-5
    # position 2 sees token 1: changed
    assert float(jnp.abs(base[0, 2] - pert[0, 2]).max()) > 1e-6


def test_vlm_patch_prefix_used():
    cfg = dataclasses.replace(smoke_config("internvl2-1b"),
                              compute_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = _batch(cfg)
    l1, _ = m.loss(params, b)
    b2 = dict(b)
    b2["patch_embeds"] = b["patch_embeds"] + 1.0
    l2, _ = m.loss(params, b2)
    assert abs(float(l1) - float(l2)) > 1e-6, "patch embeds ignored"
