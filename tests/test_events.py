"""Fault-tolerant elasticity tests (issue 8): fabric events as a
versioned stream, PlanServer topology swap + family re-repair, request
re-homing, worker death/respawn with conserved accounting, and the
client's retry/backoff/deadline ladder with inline fallback.
"""

import pytest

from repro.core import (
    ClusterSpec,
    Topology,
    execute_plan,
    get_scheduler,
    moe_workload,
)
from repro.core.traffic import Workload
from repro.serving import (
    AdmissionError,
    FabricEvent,
    FabricMonitor,
    PlanClient,
    PlanServer,
    ServerClosed,
)

C = ClusterSpec(n_servers=4, m_gpus=2)
T = Topology.homogeneous(4, 2)


def _w(topo, scale=1.0, seed=0):
    base = moe_workload(C, 512, 64, top_k=2, seed=seed)
    return Workload(C, base.matrix * scale, topo)


# -- FabricEvent -----------------------------------------------------------

def test_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FabricEvent(kind="explode", server=0)
    with pytest.raises(ValueError, match="direction"):
        FabricEvent(kind="fail", server=0, direction="sideways")
    with pytest.raises(ValueError, match="factor"):
        FabricEvent(kind="degrade", server=0, factor=1.5)


def test_event_apply_matches_scenario_constructors():
    assert FabricEvent(kind="fail", server=0, nic=1).apply(T) \
        == T.fail_nic(0, 1)
    assert FabricEvent(kind="degrade", server=2, nic=0, factor=0.5,
                       direction="down").apply(T) \
        == T.degrade_nic(2, 0, 0.5, direction="down")
    assert FabricEvent(kind="degrade", server=1, factor=0.25).apply(T) \
        == T.degrade_server(1, 0.25)
    assert FabricEvent(kind="fail", server=3).apply(T) == T.fail_server(3)
    hurt = T.fail_nic(0, 1)
    assert FabricEvent(kind="recover", server=0, nic=1).apply(hurt) == T
    assert FabricEvent(kind="recover", server=0).apply(hurt) == T


def test_event_describe_and_dict():
    ev = FabricEvent(kind="degrade", server=1, nic=0, factor=0.5,
                     direction="up", version=3)
    s = ev.describe()
    assert "v3" in s and "degrade" in s and "1.0" in s and "up" in s
    d = ev.to_dict()
    assert d["kind"] == "degrade" and d["version"] == 3


# -- FabricMonitor ---------------------------------------------------------

def test_monitor_versions_and_history():
    mon = FabricMonitor(T)
    assert mon.version == 0 and mon.current() is T
    e1 = mon.inject("fail", server=0, nic=0)
    e2 = mon.inject("degrade", server=1, nic=1, factor=0.5)
    assert (e1.version, e2.version) == (1, 2)
    assert mon.version == 2
    assert mon.current() == T.fail_nic(0, 0).degrade_nic(1, 1, 0.5)
    assert [e.version for e in mon.history()] == [1, 2]


def test_monitor_notifies_in_version_order():
    mon = FabricMonitor(T)
    seen = []
    mon.subscribe(lambda ev, topo: seen.append((ev.version,
                                                topo.fingerprint())))
    mon.inject("fail", server=0, nic=0)
    mon.inject("recover", server=0, nic=0)
    assert [v for v, _ in seen] == [1, 2]
    assert seen[1][1] == T.fingerprint()


# -- PlanServer: event handling -------------------------------------------

def test_apply_event_requires_topology():
    srv = PlanServer()
    with pytest.raises(ValueError, match="active topology"):
        srv.apply_fabric_event(FabricEvent(kind="fail", server=0, nic=0,
                                           version=1))


def test_apply_event_drops_stale_versions():
    with PlanServer(topology=T) as srv:
        ev = FabricEvent(kind="fail", server=0, nic=0, version=1)
        srv.apply_fabric_event(ev)
        snap = srv.telemetry_snapshot()
        assert snap["counters"]["fabric_events"] == 1
        assert snap["fabric"]["version"] == 1
        # A re-delivered (or reordered) duplicate must not re-fail a NIC
        # that later events may have recovered.
        srv.apply_fabric_event(ev)
        snap = srv.telemetry_snapshot()
        assert snap["counters"]["fabric_events"] == 1
        assert snap["counters"]["fabric_events_stale"] == 1


def test_server_survives_nic_failure_with_rerepair_and_rehoming():
    """The tentpole scenario: a NIC dies mid-stream; the server swaps
    fabrics, re-repairs the warm family in the background, re-homes
    stale-topology requests, and never stalls or rejects."""
    mon = FabricMonitor(T)
    with PlanServer(workers=2) as srv:
        srv.attach_monitor(mon)
        cli = PlanClient(srv, algorithm="flash", timeout=30.0)
        for i in range(3):
            cli.get_plan(_w(T, 1.0 + 0.01 * i))
        assert srv.drain()

        mon.inject("fail", server=0, nic=0)
        degraded = mon.current()
        assert degraded == T.fail_nic(0, 0)

        # Clients still hold the pre-event Topology: re-homed, answered.
        for i in range(3):
            a = cli.get_plan(_w(T, 1.0 + 0.01 * i))
            assert a.plan.topo.fingerprint() == degraded.fingerprint()
            a.plan.validate(_w(degraded, 1.0 + 0.01 * i))
        assert srv.drain()

        mon.inject("recover", server=0, nic=0)
        assert mon.current() == T
        assert srv.drain()
        a = cli.get_plan(_w(T, 1.04))
        assert a.plan.topo.fingerprint() == T.fingerprint()

        c = srv.telemetry_snapshot()["counters"]
        assert c["fabric_events"] == 2
        assert c.get("stale_topology", 0) >= 3
        assert c.get("rerepaired", 0) + c.get("rerepair_cold", 0) >= 1
        assert c.get("errors", 0) == 0
        assert c.get("rejected", 0) == 0 and c.get("shed", 0) == 0
        assert cli.counters["inline"] == 0  # daemon answered everything


def test_rerepaired_plan_quality_is_bounded():
    """A re-repaired plan on the degraded fabric stays within a small
    factor of cold synthesis on that fabric (degraded, not broken)."""
    mon = FabricMonitor(T)
    with PlanServer(workers=1) as srv:
        srv.attach_monitor(mon)
        cli = PlanClient(srv, algorithm="flash", timeout=30.0)
        cli.get_plan(_w(T, 1.0))
        assert srv.drain()
        mon.inject("degrade", server=0, nic=0, factor=0.25)
        degraded = mon.current()
        assert srv.drain()
        w = _w(degraded, 1.0)
        served = cli.get_plan(w).plan
        cold = get_scheduler("flash").synthesize(w)
        t_served = execute_plan(served, w).completion_time
        t_cold = execute_plan(cold, w).completion_time
        assert t_served <= 2.0 * t_cold


# -- worker death and respawn ---------------------------------------------

def test_worker_death_fails_ticket_and_respawns():
    """Satellite 1: a worker killed by a BaseException mid-request fails
    the ticket (client unblocks), counts the death, respawns in place,
    and accounting stays conserved.  workers=1 makes the respawned slot
    the only one able to serve the follow-up request."""
    with PlanServer(workers=1) as srv:
        orig = srv._synthesize_best
        mark = {"armed": True}

        def boom(req):
            if mark["armed"]:
                mark["armed"] = False
                raise SystemExit("injected worker crash")
            return orig(req)

        srv._synthesize_best = boom
        with pytest.raises(SystemExit):
            srv.request(_w(T), timeout=10.0)
        # The same (respawned) worker slot must serve this one.
        a = srv.request(_w(T, 1.01), timeout=10.0)
        assert a.source == "cold"
        c = srv.telemetry_snapshot()["counters"]
        assert c["worker_deaths"] == 1
        assert c["errors"] == 1
        # Conservation: every request has exactly one outcome.
        outcomes = sum(c.get(k, 0) for k in
                       ("hits", "warm", "cold", "rejected", "shed",
                        "errors"))
        assert c["requests"] == outcomes == 2


def test_worker_death_between_requests_respawns_silently():
    """A BaseException outside any request (queue.get, housekeeping)
    respawns the worker without failing anything."""
    with PlanServer(workers=1) as srv:
        srv.request(_w(T), timeout=10.0)  # make sure the loop is alive
        dead_sweep = {"armed": True}
        orig_sweep = srv.ttl.sweep

        def bad_sweep(cache, limit=None):
            if dead_sweep["armed"]:
                dead_sweep["armed"] = False
                raise SystemExit("injected idle crash")
            return orig_sweep(cache, limit=limit)

        srv.ttl.sweep = bad_sweep
        # Wait until the idle housekeeping path trips and the worker
        # respawns, then prove the slot still serves.
        deadline = 5.0
        import time as _time
        t0 = _time.monotonic()
        while (srv.telemetry.get("worker_deaths") < 1
               and _time.monotonic() - t0 < deadline):
            _time.sleep(0.01)
        assert srv.telemetry.get("worker_deaths") == 1
        a = srv.request(_w(T, 1.02), timeout=10.0)
        assert a.plan is not None
        assert srv.telemetry.get("errors") == 0


# -- client retry / backoff / deadline ------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.sleeps.append(s)
        self.t += s


class StubServer:
    """Minimal PlanServer stand-in: scripted failures, then an answer."""

    def __init__(self, failures=(), answer="answer", clock=None,
                 advance=0.0):
        self.failures = list(failures)
        self.answer = answer
        self.timeouts = []
        self.clock = clock
        self.advance = advance

    def request(self, w, algorithm, tier, timeout=None):
        self.timeouts.append(timeout)
        if self.clock is not None:
            self.clock.t += self.advance  # simulated time spent waiting
        if self.failures:
            raise self.failures.pop(0)
        return self.answer


def _stub_answer():
    import dataclasses as _dc

    @_dc.dataclass
    class A:
        source: str = "hit"
        plan: object = None
    return A()


def test_client_retries_with_exponential_backoff():
    clk = FakeClock()
    srv = StubServer(failures=[AdmissionError("full"),
                               AdmissionError("full")],
                     answer=_stub_answer())
    cli = PlanClient(srv, max_retries=3, backoff_base=0.1, backoff_cap=1.0,
                     clock=clk, sleep=clk.sleep)
    a = cli.get_plan(_w(T))
    assert a.source == "hit"
    assert cli.counters["retries"] == 2
    assert clk.sleeps == pytest.approx([0.1, 0.2])


def test_client_backoff_is_capped():
    clk = FakeClock()
    srv = StubServer(failures=[TimeoutError()] * 3, answer=_stub_answer())
    cli = PlanClient(srv, max_retries=5, backoff_base=1.0, backoff_cap=1.5,
                     clock=clk, sleep=clk.sleep)
    cli.get_plan(_w(T))
    assert clk.sleeps == pytest.approx([1.0, 1.5, 1.5])


def test_client_falls_back_inline_after_retries(monkeypatch):
    clk = FakeClock()
    srv = StubServer(failures=[AdmissionError("full")] * 10)
    cli = PlanClient(srv, algorithm="flash", max_retries=1,
                     backoff_base=0.1, clock=clk, sleep=clk.sleep)
    a = cli.get_plan(_w(T))
    assert a.source == "inline"
    assert cli.counters["inline"] == 1
    assert cli.counters["retries"] == 1
    assert len(srv.timeouts) == 2  # initial + one retry


def test_client_server_closed_is_terminal():
    clk = FakeClock()
    srv = StubServer(failures=[ServerClosed("stopped")] * 2)
    cli = PlanClient(srv, algorithm="flash", max_retries=5,
                     clock=clk, sleep=clk.sleep)
    a = cli.get_plan(_w(T))
    assert a.source == "inline"
    assert cli.counters["retries"] == 0
    assert len(srv.timeouts) == 1  # no retry against a stopped server
    assert clk.sleeps == []


def test_client_deadline_trims_attempts_and_sleeps():
    clk = FakeClock()
    srv = StubServer(failures=[TimeoutError()] * 10, clock=clk,
                     advance=6.0)
    cli = PlanClient(srv, algorithm="flash", timeout=60.0, max_retries=10,
                     backoff_base=0.0, deadline=10.0,
                     clock=clk, sleep=clk.sleep)
    a = cli.get_plan(_w(T))
    assert a.source == "inline"
    # First attempt gets min(timeout, deadline)=10; 6s pass; the second
    # attempt is trimmed to the remaining 4; then the budget is spent.
    assert srv.timeouts == pytest.approx([10.0, 4.0])


def test_client_without_fallback_raises():
    clk = FakeClock()
    srv = StubServer(failures=[AdmissionError("full")] * 3)
    cli = PlanClient(srv, inline_fallback=False, max_retries=1,
                     backoff_base=0.0, clock=clk, sleep=clk.sleep)
    with pytest.raises(AdmissionError):
        cli.get_plan(_w(T))


def test_client_fallback_parity_with_inline_synthesis():
    """A fallback answer is a real plan: same completion time as calling
    the scheduler inline."""
    srv = StubServer(failures=[AdmissionError("full")] * 10)
    cli = PlanClient(srv, algorithm="flash", max_retries=0)
    w = _w(T)
    a = cli.get_plan(w)
    assert a.source == "inline" and a.exact
    direct = get_scheduler("flash").synthesize(w)
    assert execute_plan(a.plan, w).completion_time == pytest.approx(
        execute_plan(direct, w).completion_time)
