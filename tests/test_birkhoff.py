"""Property tests for the Birkhoff-von Neumann scheduler (paper section 4.2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dev dep: skip property-based tests
    from _hypothesis_fallback import given, settings, st

from repro.core.birkhoff import (
    birkhoff_decompose,
    hopcroft_karp,
    max_line_sum,
    pad_to_doubly_balanced,
)


def _matrices(max_n=8, max_v=1000.0):
    return st.integers(2, max_n).flatmap(
        lambda n: st.lists(
            st.lists(st.floats(0, max_v, allow_nan=False), min_size=n,
                     max_size=n),
            min_size=n, max_size=n,
        ).map(lambda rows: _zero_diag(np.array(rows))))


def _zero_diag(t):
    np.fill_diagonal(t, 0.0)
    return t


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_padding_balances_lines(t):
    pad = pad_to_doubly_balanced(t)
    m = t + pad
    target = max_line_sum(t)
    assert pad.min() >= 0
    if target > 0:
        np.testing.assert_allclose(m.sum(axis=0), target, rtol=1e-6)
        np.testing.assert_allclose(m.sum(axis=1), target, rtol=1e-6)


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_decomposition_reconstructs_exactly(t):
    n = t.shape[0]
    stages = birkhoff_decompose(t)
    recon = sum((s.as_matrix(n) for s in stages), np.zeros_like(t))
    np.testing.assert_allclose(recon, t, atol=1e-6 * max(t.max(), 1.0))


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_makespan_is_optimal(t):
    """Sum of stage sizes equals the Theorem-1 lower bound numerator."""
    stages = birkhoff_decompose(t)
    makespan = sum(s.size for s in stages)
    assert makespan <= max_line_sum(t) * (1 + 1e-9)
    if t.sum() > 0:
        # and it can never beat the bound either
        assert makespan >= max_line_sum(t) * (1 - 1e-9)


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_stage_count_bound(t):
    """Classic Birkhoff bound: at most n^2 - 2n + 2 stages."""
    n = t.shape[0]
    stages = birkhoff_decompose(t)
    assert len(stages) <= n * n - 2 * n + 2


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_stages_incast_free(t):
    """Each stage is (a partial) permutation: one sender per receiver."""
    for s in birkhoff_decompose(t):
        dsts = [j for j in s.perm if j >= 0]
        assert len(dsts) == len(set(dsts))
        assert s.size > 0
        for i, j in enumerate(s.perm):
            assert j != i  # no self-traffic


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_stages_ascending(t):
    sizes = [s.size for s in birkhoff_decompose(t, sort_ascending=True)]
    assert sizes == sorted(sizes)


def test_hopcroft_karp_perfect_matching():
    # bipartite 4x4 with a known perfect matching
    adj = [[0, 1], [1], [2, 3], [3]]
    match = hopcroft_karp(adj, 4)
    assert sorted(match) == [0, 1, 2, 3]


def test_hopcroft_karp_partial():
    adj = [[0], [0], [1]]
    match = hopcroft_karp(adj, 2)
    assert sum(1 for m in match if m >= 0) == 2


def test_rejects_nonzero_diagonal():
    t = np.ones((3, 3))
    with pytest.raises(ValueError):
        birkhoff_decompose(t)


def test_empty_and_zero():
    assert birkhoff_decompose(np.zeros((4, 4))) == []


# -- engine identity and repair-policy properties (PR 3) -------------------


@settings(max_examples=40, deadline=None)
@given(_matrices())
def test_incremental_engine_identical_to_reference(t):
    """The exact engine's stage lists are bit-identical to the golden
    reference (same perms, sizes and sent tuples, in the same order)."""
    fast = birkhoff_decompose(t.copy(), policy="exact")
    ref = birkhoff_decompose(t.copy(), reference=True)
    assert fast == ref


@settings(max_examples=40, deadline=None)
@given(_matrices())
def test_repair_policy_conserves_bytes_on_support(t):
    """Repair-policy stages conserve bytes exactly on the support of T and
    never exceed the n^2 - 2n + 2 stage bound (issue satellite)."""
    n = t.shape[0]
    stages = birkhoff_decompose(t.copy(), policy="repair")
    recon = sum((s.as_matrix(n) for s in stages), np.zeros_like(t))
    np.testing.assert_allclose(recon, t, atol=1e-6 * max(t.max(), 1.0))
    # no traffic invented outside the support
    assert np.all(recon[t == 0] <= 1e-6 * max(t.max(), 1.0))
    assert len(stages) <= n * n - 2 * n + 2
    for s in stages:
        dsts = [j for j in s.perm if j >= 0]
        assert len(dsts) == len(set(dsts))
        assert all(i != j for i, j in enumerate(s.perm))


def test_repair_policy_preserves_makespan_optimality():
    rng = np.random.default_rng(3)
    t = rng.uniform(0, 1e6, (12, 12))
    np.fill_diagonal(t, 0.0)
    stages = birkhoff_decompose(t.copy(), policy="repair")
    makespan = sum(s.size for s in stages)
    assert abs(makespan - max_line_sum(t)) <= 1e-9 * max_line_sum(t)


def test_auto_policy_matches_exact_below_threshold():
    from repro.core.birkhoff import AUTO_EXACT_MAX_N

    rng = np.random.default_rng(4)
    n = min(8, AUTO_EXACT_MAX_N)
    t = rng.uniform(0, 100, (n, n))
    np.fill_diagonal(t, 0.0)
    assert birkhoff_decompose(t.copy()) == \
        birkhoff_decompose(t.copy(), policy="exact")


def test_unknown_policy_raises():
    t = np.array([[0.0, 1.0], [1.0, 0.0]])
    with pytest.raises(ValueError, match="unknown policy"):
        birkhoff_decompose(t, policy="bogus")


# -- Stage satellite: vectorized as_matrix + shape validation --------------


def test_stage_as_matrix_matches_per_entry_reference():
    from repro.core.birkhoff import Stage

    s = Stage(perm=(2, -1, 0, 1), size=8.0, sent=(5.0, 0.0, 8.0, 2.5))
    got = s.as_matrix(4)
    ref = np.zeros((4, 4))
    for i, j in enumerate(s.perm):
        if j >= 0:
            ref[i, j] = s.sent[i]
    np.testing.assert_array_equal(got, ref)
    assert s.active == 3
    assert s.real_bytes == 15.5


def test_stage_rejects_mismatched_perm_sent_lengths():
    from repro.core.birkhoff import Stage

    with pytest.raises(ValueError, match="slots"):
        Stage(perm=(1, 0), size=4.0, sent=(4.0,))


# -- padding satellite: already-balanced and all-zero matrices -------------


def test_padding_of_already_balanced_matrix_is_zero():
    # circulant: every row and column already sums to the same value
    t = np.array([[0.0, 3.0, 5.0],
                  [5.0, 0.0, 3.0],
                  [3.0, 5.0, 0.0]])
    pad = pad_to_doubly_balanced(t)
    np.testing.assert_array_equal(pad, np.zeros_like(t))


def test_padding_of_all_zero_matrix_is_zero():
    t = np.zeros((4, 4))
    pad = pad_to_doubly_balanced(t)
    np.testing.assert_array_equal(pad, np.zeros_like(t))
    assert birkhoff_decompose(t) == []


# -- _greedy_drain satellite: the float-erosion fallback -------------------


def test_greedy_drain_routes_remaining_entries():
    from repro.core.birkhoff import _greedy_drain

    real = np.array([[0.0, 7.0, 0.0],
                     [0.0, 0.0, 3.0],
                     [0.5, 0.0, 0.0]])
    stages = []
    _greedy_drain(real, stages, eps=1e-9)
    assert len(stages) == 3  # one stage per surviving entry
    np.testing.assert_array_equal(real, np.zeros_like(real))
    total = sum(s.real_bytes for s in stages)
    assert total == 7.0 + 3.0 + 0.5
    for s in stages:
        assert s.active == 1
        assert s.size == s.real_bytes  # single-flow stages


def test_greedy_drain_ignores_subthreshold_residue():
    from repro.core.birkhoff import _greedy_drain

    real = np.array([[0.0, 1e-15], [2.0, 0.0]])
    stages = []
    _greedy_drain(real, stages, eps=1e-9)
    assert len(stages) == 1
    assert stages[0].perm == (-1, 0)
    assert real[0, 1] == 1e-15  # below eps: left in place, not routed


def test_decompose_falls_back_to_drain_when_matching_erodes(monkeypatch):
    """Simulate float erosion: if the matching ends imperfect, the engine
    must still route all genuine bytes via the greedy-drain fallback."""
    import repro.core.birkhoff as B

    def no_augment(adj, match_l, match_r):
        return None  # leave the greedy matching unrepaired

    monkeypatch.setattr(B, "_augment_phases", no_augment)
    rng = np.random.default_rng(5)
    n = 6
    # sparse support: the first-fit greedy is imperfect on some stage
    t = rng.uniform(0, 100, (n, n)) * (rng.random((n, n)) < 0.5)
    np.fill_diagonal(t, 0.0)
    stages = B.birkhoff_decompose(t.copy(), policy="exact")
    recon = sum((s.as_matrix(n) for s in stages), np.zeros_like(t))
    np.testing.assert_allclose(recon, t, atol=1e-6 * max(t.max(), 1.0))
