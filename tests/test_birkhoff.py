"""Property tests for the Birkhoff-von Neumann scheduler (paper section 4.2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dev dep: skip property-based tests
    from _hypothesis_fallback import given, settings, st

from repro.core.birkhoff import (
    birkhoff_decompose,
    hopcroft_karp,
    max_line_sum,
    pad_to_doubly_balanced,
)


def _matrices(max_n=8, max_v=1000.0):
    return st.integers(2, max_n).flatmap(
        lambda n: st.lists(
            st.lists(st.floats(0, max_v, allow_nan=False), min_size=n,
                     max_size=n),
            min_size=n, max_size=n,
        ).map(lambda rows: _zero_diag(np.array(rows))))


def _zero_diag(t):
    np.fill_diagonal(t, 0.0)
    return t


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_padding_balances_lines(t):
    pad = pad_to_doubly_balanced(t)
    m = t + pad
    target = max_line_sum(t)
    assert pad.min() >= 0
    if target > 0:
        np.testing.assert_allclose(m.sum(axis=0), target, rtol=1e-6)
        np.testing.assert_allclose(m.sum(axis=1), target, rtol=1e-6)


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_decomposition_reconstructs_exactly(t):
    n = t.shape[0]
    stages = birkhoff_decompose(t)
    recon = sum((s.as_matrix(n) for s in stages), np.zeros_like(t))
    np.testing.assert_allclose(recon, t, atol=1e-6 * max(t.max(), 1.0))


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_makespan_is_optimal(t):
    """Sum of stage sizes equals the Theorem-1 lower bound numerator."""
    stages = birkhoff_decompose(t)
    makespan = sum(s.size for s in stages)
    assert makespan <= max_line_sum(t) * (1 + 1e-9)
    if t.sum() > 0:
        # and it can never beat the bound either
        assert makespan >= max_line_sum(t) * (1 - 1e-9)


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_stage_count_bound(t):
    """Classic Birkhoff bound: at most n^2 - 2n + 2 stages."""
    n = t.shape[0]
    stages = birkhoff_decompose(t)
    assert len(stages) <= n * n - 2 * n + 2


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_stages_incast_free(t):
    """Each stage is (a partial) permutation: one sender per receiver."""
    for s in birkhoff_decompose(t):
        dsts = [j for j in s.perm if j >= 0]
        assert len(dsts) == len(set(dsts))
        assert s.size > 0
        for i, j in enumerate(s.perm):
            assert j != i  # no self-traffic


@settings(max_examples=60, deadline=None)
@given(_matrices())
def test_stages_ascending(t):
    sizes = [s.size for s in birkhoff_decompose(t, sort_ascending=True)]
    assert sizes == sorted(sizes)


def test_hopcroft_karp_perfect_matching():
    # bipartite 4x4 with a known perfect matching
    adj = [[0, 1], [1], [2, 3], [3]]
    match = hopcroft_karp(adj, 4)
    assert sorted(match) == [0, 1, 2, 3]


def test_hopcroft_karp_partial():
    adj = [[0], [0], [1]]
    match = hopcroft_karp(adj, 2)
    assert sum(1 for m in match if m >= 0) == 2


def test_rejects_nonzero_diagonal():
    t = np.ones((3, 3))
    with pytest.raises(ValueError):
        birkhoff_decompose(t)


def test_empty_and_zero():
    assert birkhoff_decompose(np.zeros((4, 4))) == []
