"""Shared test utilities.

Multi-device tests run in SUBPROCESSES with their own XLA_FLAGS so the main
pytest process keeps the default single CPU device (per the assignment:
smoke tests must see 1 device).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run python code in a fresh process with n fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["TF_CPP_MIN_LOG_LEVEL"] = "3"
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"stdout:\n{proc.stdout[-4000:]}\nstderr:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_subprocess
