"""Warm-started re-synthesis tests: FlashScheduler.repair_plan seeds a new
plan with a previous plan's permutations, and PlanCache's opt-in near-miss
path routes exact-fingerprint misses through it (issue 3 tentpole, part 3).
"""

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    PlanCache,
    Topology,
    cluster_family_key,
    get_scheduler,
    moe_workload,
    simulate,
    synthesis_time,
    traffic_fingerprint,
)
from repro.core.traffic import Workload

C = ClusterSpec(n_servers=8, m_gpus=8)


def _near_miss(w, seed=7, frac=0.02, jitter=0.2):
    """Perturb a small fraction of pairs by a small factor (MoE drift)."""
    rng = np.random.default_rng(seed)
    m = w.matrix.copy()
    sel = rng.random(m.shape) < frac
    m[sel] *= rng.uniform(1 - jitter, 1 + jitter, size=int(sel.sum()))
    np.fill_diagonal(m, 0.0)
    return Workload(w.cluster, m, w.topology)


def test_repair_plan_conserves_bytes_and_validates():
    flash = get_scheduler("flash")
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=0)
    w2 = _near_miss(w1)
    prev = flash.synthesize(w1)
    warm = flash.repair_plan(prev, w2)
    warm.validate(w2)  # byte conservation + incast-free + topology match
    assert warm.algorithm == "flash"
    assert warm.synth_seconds > 0
    r = simulate(w2, "flash", plan=warm)
    assert np.isfinite(r.completion_time) and r.completion_time > 0


def test_repair_plan_quality_close_to_cold_on_near_miss():
    flash = get_scheduler("flash")
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=1)
    w2 = _near_miss(w1, seed=11)
    warm = flash.repair_plan(flash.synthesize(w1), w2)
    cold = flash.synthesize(w2)
    t_warm = simulate(w2, "flash", plan=warm).completion_time
    t_cold = simulate(w2, "flash", plan=cold).completion_time
    # a small drift must not cost more than a modest quality factor
    assert t_warm <= 1.5 * t_cold


def test_repair_plan_falls_back_to_cold_on_large_shift():
    """A 100x traffic surge is no near-miss (the old slots hold a sliver of
    it): repair_plan must return a cold-quality plan, not a patched one."""
    flash = get_scheduler("flash")
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=2)
    w2 = Workload(C, w1.matrix * 100.0)
    warm = flash.repair_plan(flash.synthesize(w1), w2)
    cold = flash.synthesize(w2)
    assert warm.n_stages == cold.n_stages
    assert [p.to_dict() for p in warm.phases] == \
        [p.to_dict() for p in cold.phases]
    warm.validate(w2)


def test_repair_plan_rejects_mismatched_fabric():
    flash = get_scheduler("flash")
    prev = flash.synthesize(moe_workload(C, 8192, 4096, top_k=2, seed=0))
    other = ClusterSpec(n_servers=4, m_gpus=8)
    with pytest.raises(ValueError, match="warm-start"):
        flash.repair_plan(prev, moe_workload(other, 8192, 4096, seed=0))
    degraded = Topology.from_cluster(C).degrade_nic(0, 0, factor=0.25)
    w_deg = moe_workload(degraded, 8192, 4096, top_k=2, seed=0)
    with pytest.raises(ValueError, match="warm-start"):
        flash.repair_plan(prev, w_deg)


def test_plan_cache_warm_start_repairs_on_near_miss():
    cache = PlanCache(warm_start=True)
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=4)
    w2 = _near_miss(w1, seed=13)
    simulate(w1, "flash", cache=cache)
    assert (cache.hits, cache.misses, cache.warm_hits) == (0, 1, 0)
    simulate(w2, "flash", cache=cache)
    assert (cache.hits, cache.misses, cache.warm_hits) == (0, 2, 1)
    # the repaired plan is cached under the exact fingerprint: replay hits
    simulate(w2, "flash", cache=cache)
    assert (cache.hits, cache.misses, cache.warm_hits) == (1, 2, 1)


def test_plan_cache_warm_start_off_by_default():
    cache = PlanCache()
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=5)
    simulate(w1, "flash", cache=cache)
    simulate(w2 := _near_miss(w1, seed=17), "flash", cache=cache)
    assert cache.warm_hits == 0 and cache.misses == 2
    # same family, different exact fingerprints
    assert cluster_family_key(w1, "flash") == cluster_family_key(w2, "flash")
    assert traffic_fingerprint(w1, "flash") != traffic_fingerprint(w2, "flash")


def test_plan_cache_warm_start_ignores_other_algorithms():
    """Schedulers without repair_plan keep cold-synthesizing."""
    cache = PlanCache(warm_start=True)
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=6)
    simulate(w1, "spreadout", cache=cache)
    simulate(_near_miss(w1, seed=19), "spreadout", cache=cache)
    assert cache.warm_hits == 0 and cache.misses == 2


def test_plan_cache_clear_resets_warm_state():
    cache = PlanCache(warm_start=True)
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=8)
    simulate(w1, "flash", cache=cache)
    cache.clear()
    assert (cache.hits, cache.misses, cache.warm_hits) == (0, 0, 0)
    # family index cleared too: the next miss cold-synthesizes
    simulate(_near_miss(w1, seed=23), "flash", cache=cache)
    assert cache.warm_hits == 0


# -- family-index hygiene under LRU eviction (issue 4 satellite) -----------


def test_plan_cache_family_index_stays_bounded_past_capacity():
    """Filling past ``capacity`` with same-family traffic must not grow the
    family index (the pre-fix cache leaked one entry per family forever and
    could point at evicted keys), and warm repair must still fire from the
    surviving plans afterwards."""
    cache = PlanCache(capacity=3, warm_start=True)
    ws = [moe_workload(C, 8192, 4096, top_k=2, seed=s) for s in range(8)]
    for w in ws:
        simulate(w, "flash", cache=cache)
    assert len(cache) == 3
    assert len(cache._family) == 1
    # the family pointer references a live key, never an evicted one
    assert set(cache._family.values()) <= set(cache._store)
    # warm repair still fires: a near-miss of the most recent workload
    simulate(_near_miss(ws[-1], seed=31), "flash", cache=cache)
    assert cache.warm_hits >= 1


def test_plan_cache_family_index_pruned_across_many_families():
    """Distinct fabrics are distinct families: under eviction churn the
    family index must stay bounded by the store, not accumulate one stale
    entry per fabric ever seen (long-running serving leak)."""
    cache = PlanCache(capacity=4, warm_start=True)
    base = Topology.from_cluster(C)
    for i in range(12):
        topo = base.degrade_nic(i % C.n_servers, i % C.m_gpus,
                                0.9 - 0.05 * i)
        w = moe_workload(topo, 1024, 512, top_k=2, seed=i)
        simulate(w, "flash", cache=cache)
    assert len(cache) == 4
    assert len(cache._family) <= 4
    assert set(cache._family.values()) <= set(cache._store)
    assert len(cache._key_family) == len(cache._store)
    assert sum(cache._family_count.values()) == len(cache._store)


def test_plan_cache_family_repoints_to_surviving_plan_on_eviction():
    """When the family's latest plan is evicted but an older same-family
    plan survives (it was touched more recently), the family pointer must
    repoint to the survivor so warm starts keep seeding from it."""
    cache = PlanCache(capacity=2, warm_start=True)
    w_a = moe_workload(C, 8192, 4096, top_k=2, seed=40)
    w_b = Workload(C, w_a.matrix * 3.0)  # same family, no near-miss of A
    simulate(w_a, "flash", cache=cache)   # store A (family F -> A)
    simulate(w_b, "flash", cache=cache)   # store B (family F -> B)
    simulate(w_a, "flash", cache=cache)   # touch A: B is now LRU
    other = moe_workload(ClusterSpec(n_servers=4, m_gpus=8), 8192, 4096,
                         top_k=2, seed=41)
    simulate(other, "flash", cache=cache)  # store C: evicts B
    key_a = traffic_fingerprint(w_a, "flash")
    fam = cluster_family_key(w_a, "flash")
    assert cache._family[fam] == key_a
    # warm start now seeds from the survivor A
    simulate(_near_miss(w_a, seed=43), "flash", cache=cache)
    assert cache.warm_hits == 1


# -- synthesis_time argument validation (issue satellite) ------------------


def test_synthesis_time_accepts_shape_or_workload():
    assert synthesis_time(n_servers=3) > 0
    w = moe_workload(C, 1024, 512, top_k=2, seed=0)
    assert synthesis_time(workload=w) > 0
    # matching explicit shape is fine
    assert synthesis_time(n_servers=8, m_gpus=8, workload=w) > 0


def test_synthesis_time_rejects_conflicting_arguments():
    w = moe_workload(C, 1024, 512, top_k=2, seed=0)
    with pytest.raises(ValueError, match="conflicting"):
        synthesis_time(n_servers=4, workload=w)
    with pytest.raises(ValueError, match="conflicting"):
        synthesis_time(n_servers=8, m_gpus=4, workload=w)
    with pytest.raises(ValueError, match="n_servers"):
        synthesis_time()


def test_plan_cache_warm_start_survives_same_fabric_different_alpha():
    """Two ClusterSpecs can share a fabric fingerprint but differ in
    scalars repair cannot bridge (e.g. alpha): the cache must degrade to a
    cold synthesis, never raise out of a lookup (review regression)."""
    cache = PlanCache(warm_start=True)
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=9)
    c_alpha = ClusterSpec(n_servers=8, m_gpus=8, alpha=20e-6)
    w2 = moe_workload(c_alpha, 8192, 4096, top_k=2, seed=9)
    simulate(w1, "flash", cache=cache)
    simulate(w2, "flash", cache=cache)  # must not raise
    assert cache.warm_hits == 0 and cache.misses == 2


def test_plan_cache_warm_hits_not_counted_on_cold_fallback():
    """A large shift makes try_repair_plan bail: the plan served is cold
    and warm_hits must say so (review regression)."""
    cache = PlanCache(warm_start=True)
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=10)
    w2 = Workload(C, w1.matrix * 100.0)  # 100x surge: no near-miss
    simulate(w1, "flash", cache=cache)
    simulate(w2, "flash", cache=cache)
    assert cache.warm_hits == 0 and cache.misses == 2


def test_try_repair_plan_returns_none_on_large_shift():
    flash = get_scheduler("flash")
    w1 = moe_workload(C, 8192, 4096, top_k=2, seed=2)
    prev = flash.synthesize(w1)
    assert flash.try_repair_plan(prev, Workload(C, w1.matrix * 100.0)) is None
    near = flash.try_repair_plan(prev, _near_miss(w1, seed=29))
    assert near is not None
    near.validate(_near_miss(w1, seed=29))
