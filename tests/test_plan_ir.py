"""Plan IR tests: serialization round-trip, byte conservation, PlanCache
hit/miss behavior, and executor-vs-seed numeric parity on fixed seeds.

GOLDEN holds completion times recorded from the seed repo's per-algorithm
``simulate_*`` functions (pre-IR) on fixed-seed workloads; the unified
Scheduler -> Plan -> executor pipeline must reproduce them to <= 1e-9
relative error.
"""

import json

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    FlashPlan,
    Plan,
    PlanCache,
    PlanValidationError,
    available_schedulers,
    balanced_workload,
    flash_schedule,
    get_scheduler,
    moe_workload,
    random_workload,
    simulate,
    skewed_workload,
    traffic_fingerprint,
)
from repro.core.plan import PermutationStage
from repro.core.schedulers import hierarchical_nic_loads, spreadout_stages

ALGOS = ("optimal", "flash", "flash_ca", "spreadout", "fanout",
         "hierarchical")

CLUSTERS = {
    "c48": ClusterSpec(4, 8),
    "c48a0": ClusterSpec(4, 8, alpha=0.0),
    "c24ring": ClusterSpec(2, 4, intra_topology="ring"),
    "c82sw": ClusterSpec(8, 2, b_intra=900e9 / 8, intra_topology="switch"),
}


def _workload(cluster, kind):
    return {
        "balanced": lambda: balanced_workload(cluster, 4 << 20),
        "random": lambda: random_workload(cluster, 4 << 20, seed=1),
        "skewed": lambda: skewed_workload(cluster, 4 << 20, 1.2, seed=2),
        "moe": lambda: moe_workload(cluster, 8192, 4096, top_k=2, seed=3),
    }[kind]()


# Completion times recorded from the seed's per-algorithm simulators.
GOLDEN = {
    ("c48", "balanced", "optimal"): 0.00805306368,
    ("c48", "balanced", "flash"): 0.008167961965714284,
    ("c48", "balanced", "spreadout"): 0.010711873920000003,
    ("c48", "balanced", "fanout"): 0.5134249222399999,
    ("c48", "balanced", "hierarchical"): 0.008307758537142856,
    ("c48", "random", "optimal"): 0.008636259163108565,
    ("c48", "random", "flash"): 0.008854418181775264,
    ("c48", "random", "spreadout"): 0.02015652024223573,
    ("c48", "random", "fanout"): 0.45774545685473256,
    ("c48", "random", "hierarchical"): 0.010574012297143453,
    ("c48", "skewed", "optimal"): 0.014900139588591705,
    ("c48", "skewed", "flash"): 0.016956171172464302,
    ("c48", "skewed", "spreadout"): 0.2035175943392745,
    ("c48", "skewed", "fanout"): 0.10731641099166422,
    ("c48", "skewed", "hierarchical"): 0.08568716782783053,
    ("c48", "moe", "optimal"): 0.0059109376,
    ("c48", "moe", "flash"): 0.006041162742857143,
    ("c48", "moe", "spreadout"): 0.0165580128,
    ("c48", "moe", "fanout"): 0.9768271530234315,
    ("c48", "moe", "hierarchical"): 0.01383514816,
    ("c48a0", "balanced", "optimal"): 0.00805306368,
    ("c48a0", "balanced", "flash"): 0.008127961965714286,
    ("c48a0", "balanced", "spreadout"): 0.010401873920000002,
    ("c48a0", "balanced", "fanout"): 0.51341492224,
    ("c48a0", "balanced", "hierarchical"): 0.008277758537142856,
    ("c48a0", "random", "optimal"): 0.008636259163108565,
    ("c48a0", "random", "flash"): 0.008754418181775265,
    ("c48a0", "random", "spreadout"): 0.01984652024223573,
    ("c48a0", "random", "fanout"): 0.45773545685473255,
    ("c48a0", "random", "hierarchical"): 0.010544012297143452,
    ("c48a0", "skewed", "optimal"): 0.014900139588591705,
    ("c48a0", "skewed", "flash"): 0.016856171172464303,
    ("c48a0", "skewed", "spreadout"): 0.20320759433927443,
    ("c48a0", "skewed", "fanout"): 0.10730641099166423,
    ("c48a0", "skewed", "hierarchical"): 0.08565716782783053,
    ("c48a0", "moe", "optimal"): 0.0059109376,
    ("c48a0", "moe", "flash"): 0.005951162742857142,
    ("c48a0", "moe", "spreadout"): 0.0162480128,
    ("c48a0", "moe", "fanout"): 0.9768171530234315,
    ("c48a0", "moe", "hierarchical"): 0.013805148159999999,
    ("c24ring", "balanced", "optimal"): 0.00134217728,
    ("c24ring", "balanced", "flash"): 0.00149324928,
    ("c24ring", "balanced", "spreadout"): 0.0024188102400000003,
    ("c24ring", "balanced", "fanout"): 0.00135217728,
    ("c24ring", "balanced", "hierarchical"): 0.00148324928,
    ("c24ring", "random", "optimal"): 0.00180864482600501,
    ("c24ring", "random", "flash"): 0.002046083365372265,
    ("c24ring", "random", "spreadout"): 0.0042844768042680555,
    ("c24ring", "random", "fanout"): 0.0022314551772559953,
    ("c24ring", "random", "hierarchical"): 0.0024467507592457094,
    ("c24ring", "skewed", "optimal"): 0.002505884885756885,
    ("c24ring", "skewed", "flash"): 0.003211466762756689,
    ("c24ring", "skewed", "spreadout"): 0.008937634258458631,
    ("c24ring", "skewed", "fanout"): 0.008085733872401787,
    ("c24ring", "skewed", "hierarchical"): 0.007031137397242913,
    ("c24ring", "moe", "optimal"): 0.00311615488,
    ("c24ring", "moe", "flash"): 0.00345242688,
    ("c24ring", "moe", "spreadout"): 0.0100594528,
    ("c24ring", "moe", "fanout"): 0.06011224466897498,
    ("c24ring", "moe", "hierarchical"): 0.00772131712,
    ("c82sw", "balanced", "optimal"): 0.00469762048,
    ("c82sw", "balanced", "flash"): 0.004852185884444444,
    ("c82sw", "balanced", "spreadout"): 0.005183164800000002,
    ("c82sw", "balanced", "fanout"): 0.11586388544000001,
    ("c82sw", "balanced", "hierarchical"): 0.0052895783111111105,
    ("c82sw", "random", "optimal"): 0.00521498836065357,
    ("c82sw", "random", "flash"): 0.005845617481727996,
    ("c82sw", "random", "spreadout"): 0.009279495598075341,
    ("c82sw", "random", "fanout"): 0.12941442554699628,
    ("c82sw", "random", "hierarchical"): 0.006913316762040524,
    ("c82sw", "skewed", "optimal"): 0.013040125473442254,
    ("c82sw", "skewed", "flash"): 0.015174575670347303,
    ("c82sw", "skewed", "spreadout"): 0.04739191532143623,
    ("c82sw", "skewed", "fanout"): 0.029698676522073017,
    ("c82sw", "skewed", "hierarchical"): 0.025499356847760397,
    ("c82sw", "moe", "optimal"): 0.01041907712,
    ("c82sw", "moe", "flash"): 0.011099780195555558,
    ("c82sw", "moe", "spreadout"): 0.020198117760000002,
    ("c82sw", "moe", "fanout"): 0.8646337485726816,
    ("c82sw", "moe", "hierarchical"): 0.02092970830222222,
}


def test_registry_has_all_schedulers():
    """The paper's five algorithms plus the capacity-aware FLASH opt-in."""
    assert set(ALGOS) == set(available_schedulers())


def test_unknown_algorithm_raises():
    w = balanced_workload(CLUSTERS["c48"], 1 << 20)
    with pytest.raises(ValueError, match="unknown algorithm"):
        simulate(w, "no-such-algo")


@pytest.mark.parametrize("key", sorted(GOLDEN), ids=lambda k: "-".join(k))
def test_executor_matches_seed_numerics(key):
    cn, wn, algo = key
    w = _workload(CLUSTERS[cn], wn)
    got = simulate(w, algo).completion_time
    want = GOLDEN[key]
    assert abs(got - want) <= 1e-9 * want, (key, got, want)


@pytest.mark.parametrize("key", sorted(GOLDEN), ids=lambda k: "-".join(k))
def test_link_level_executor_matches_seed_on_homogeneous_topology(key):
    """The link-level executor on an explicit (homogeneous) Topology must
    reproduce the seed's scalar completion times to <= 1e-9 relative
    error, for every registered scheduler."""
    from repro.core import Topology

    cn, wn, algo = key
    w = _workload(Topology.from_cluster(CLUSTERS[cn]), wn)
    got = simulate(w, algo).completion_time
    want = GOLDEN[key]
    assert abs(got - want) <= 1e-9 * want, (key, got, want)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("kind", ("balanced", "random", "skewed", "moe"))
def test_plans_conserve_bytes(algo, kind):
    w = _workload(CLUSTERS["c48"], kind)
    get_scheduler(algo).synthesize(w).validate(w)


def test_validation_catches_lost_bytes():
    w = _workload(CLUSTERS["c48"], "random")
    plan = get_scheduler("flash").synthesize(w)
    # Halve one permutation stage's payload: conservation must fail.
    broken = []
    dropped = False
    for p in plan.phases:
        if not dropped and isinstance(p, PermutationStage):
            p = PermutationStage(perm=p.perm, size=p.size,
                                 sent=tuple(s / 2 for s in p.sent))
            dropped = True
        broken.append(p)
    bad = Plan(algorithm=plan.algorithm, cluster=plan.cluster,
               phases=tuple(broken), accounts_intra=plan.accounts_intra)
    with pytest.raises(PlanValidationError, match="not conserved"):
        bad.validate(w)


def test_validation_catches_incast():
    c = CLUSTERS["c48"]
    w = _workload(c, "random")
    stage = PermutationStage(perm=(1, 1, -1, -1), size=8.0,
                             sent=(8.0, 8.0, 0.0, 0.0))
    bad = Plan(algorithm="flash", cluster=c, phases=(stage,),
               accounts_intra=False)
    with pytest.raises(PlanValidationError, match="incast"):
        bad.validate(w)


@pytest.mark.parametrize("algo", ALGOS)
def test_plan_round_trips_through_json(algo):
    w = _workload(CLUSTERS["c48"], "skewed")
    plan = get_scheduler(algo).synthesize(w)
    wire = json.dumps(plan.to_dict())
    plan2 = Plan.from_dict(json.loads(wire))
    assert plan2.to_dict() == plan.to_dict()
    r1 = simulate(w, algo, plan=plan)
    r2 = simulate(w, algo, plan=plan2)
    assert r1.completion_time == r2.completion_time
    assert r1.breakdown == r2.breakdown
    assert r1.n_stages == r2.n_stages


@pytest.mark.parametrize("algo", ALGOS)
def test_breakdown_sums_to_completion(algo):
    """Unified-executor invariant: the breakdown is a full account."""
    w = _workload(CLUSTERS["c48"], "skewed")
    r = simulate(w, algo)
    assert np.isclose(sum(r.breakdown.values()), r.completion_time,
                      rtol=1e-12)


def test_plan_cache_hit_skips_synthesis():
    cache = PlanCache()
    c = CLUSTERS["c48"]
    w = moe_workload(c, 8192, 4096, top_k=2, seed=7)
    r1 = simulate(w, "flash", cache=cache)
    assert (cache.hits, cache.misses) == (0, 1)
    # Same traffic fingerprint next iteration: plan reused, not re-made.
    w_again = moe_workload(c, 8192, 4096, top_k=2, seed=7)
    r2 = simulate(w_again, "flash", cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)
    assert r2.completion_time == r1.completion_time
    key = traffic_fingerprint(w, "flash")
    assert cache.lookup(key) is cache.lookup(key)  # same Plan object
    # Shifted traffic: new fingerprint, fresh synthesis.
    w_shift = moe_workload(c, 8192, 4096, top_k=2, seed=8)
    simulate(w_shift, "flash", cache=cache)
    assert cache.misses == 2


def test_plan_cache_keyed_by_algorithm_and_cluster():
    cache = PlanCache()
    w = _workload(CLUSTERS["c48"], "random")
    simulate(w, "flash", cache=cache)
    simulate(w, "spreadout", cache=cache)  # same matrix, different algo
    assert cache.misses == 2 and cache.hits == 0
    w_ring = _workload(CLUSTERS["c24ring"], "random")
    simulate(w_ring, "flash", cache=cache)  # same seed, different cluster
    assert cache.misses == 3 and cache.hits == 0


def test_plan_cache_lru_eviction():
    cache = PlanCache(capacity=2)
    for seed in (0, 1, 2):
        simulate(random_workload(CLUSTERS["c48"], 1 << 20, seed=seed),
                 "flash", cache=cache)
    assert len(cache) == 2
    # seed=0 was evicted; re-simulating it is a miss again.
    simulate(random_workload(CLUSTERS["c48"], 1 << 20, seed=0),
             "flash", cache=cache)
    assert cache.misses == 4 and cache.hits == 0


def test_flash_schedule_shim_matches_plan():
    w = _workload(CLUSTERS["c48"], "skewed")
    legacy = flash_schedule(w)
    plan = get_scheduler("flash").synthesize(w)
    assert isinstance(legacy, FlashPlan)
    assert legacy.n_stages == plan.n_stages
    assert legacy.inter_bytes == pytest.approx(plan.inter_bytes, rel=1e-12)
    np.testing.assert_allclose(
        legacy.stage_sizes(),
        [p.size for p in plan.phases if isinstance(p, PermutationStage)])


def test_vectorized_spreadout_stages_matches_reference():
    w = _workload(CLUSTERS["c48"], "random")
    n_gpus = w.cluster.n_gpus
    got = spreadout_stages(w)
    assert len(got) == n_gpus - 1
    for k, sizes in enumerate(got, start=1):
        ref = np.array([w.matrix[g, (g + k) % n_gpus]
                        for g in range(n_gpus)])
        np.testing.assert_array_equal(sizes, ref)


def test_vectorized_hierarchical_loads_match_reference():
    w = _workload(CLUSTERS["c48"], "moe")
    c = w.cluster
    n, m = c.n_servers, c.m_gpus
    blk = w.matrix.reshape(n, m, n, m)
    send_ref = np.zeros((n, m))
    recv_ref = np.zeros((n, m))
    gather_ref = np.zeros((n, m))
    for a in range(n):
        for i in range(m):
            inter = blk[a, :, :, i].sum() - blk[a, :, a, i].sum()
            send_ref[a, i] = inter
            own = blk[a, i, :, i].sum() - blk[a, i, a, i]
            gather_ref[a, i] = inter - own
    for b in range(n):
        for i in range(m):
            recv_ref[b, i] = blk[:, :, b, i].sum() - blk[b, :, b, i].sum()
    send, recv, gather = hierarchical_nic_loads(w)
    np.testing.assert_allclose(send, send_ref, rtol=1e-12)
    np.testing.assert_allclose(recv, recv_ref, rtol=1e-12)
    np.testing.assert_allclose(gather, gather_ref, rtol=1e-12)
