"""Alpha-beta simulator vs the paper's analytic bounds and claims."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dev dep: skip property-based tests
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ClusterSpec,
    balanced_workload,
    gap_bound,
    moe_workload,
    random_workload,
    simulate,
    skewed_workload,
    t_flash_worst_case,
    t_optimal,
)
from repro.core.bounds import check_workload_assumption

# alpha = 0 so the analytic bounds (which exclude wakeup latency) apply.
C0 = ClusterSpec(n_servers=4, m_gpus=8, alpha=0.0)


def _workloads(cluster):
    return [
        balanced_workload(cluster, 4 << 20),
        random_workload(cluster, 4 << 20, seed=1),
        skewed_workload(cluster, 4 << 20, 1.2, seed=2),
        moe_workload(cluster, 8192, 4096, top_k=2, seed=3),
    ]


@pytest.mark.parametrize("idx", range(4))
def test_flash_between_optimal_and_worst_case(idx):
    w = _workloads(C0)[idx]
    r = simulate(w, "flash")
    assert r.completion_time >= t_optimal(w) * (1 - 1e-9)
    if check_workload_assumption(w):
        assert r.completion_time <= t_flash_worst_case(w) * (1 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(2, 8), st.integers(0, 10_000))
def test_gap_bound_theorem3(n, m, seed):
    cluster = ClusterSpec(n_servers=n, m_gpus=m, alpha=0.0)
    w = random_workload(cluster, 1 << 20, seed=seed)
    r = simulate(w, "flash")
    bound = gap_bound(cluster)
    assert r.completion_time <= t_optimal(w) * bound * (1 + 1e-6)


def test_flash_beats_spreadout_under_skew():
    w = skewed_workload(C0, 4 << 20, zipf_s=1.2, seed=0)
    flash = simulate(w, "flash")
    spread = simulate(w, "spreadout")
    assert flash.algbw > 2.0 * spread.algbw  # paper: 2.5-2.7x for skewed


def test_hierarchical_matches_flash_on_balanced():
    """Paper Fig 12a: MSCCL within 0.91-1.0x of FLASH on balanced."""
    w = balanced_workload(C0, 16 << 20)
    flash = simulate(w, "flash")
    hier = simulate(w, "hierarchical")
    assert hier.algbw >= 0.85 * flash.algbw


def test_hierarchical_loses_under_skew():
    w = skewed_workload(C0, 4 << 20, zipf_s=1.2, seed=0)
    assert simulate(w, "flash").algbw > 1.5 * simulate(w, "hierarchical").algbw


def test_fanout_incast_collapse():
    """Paper Fig 12a: RCCL collapses at large balanced transfers."""
    w_small = balanced_workload(C0, 64 << 10)
    w_large = balanced_workload(C0, 64 << 20)
    small = simulate(w_small, "fanout")
    large = simulate(w_large, "fanout")
    opt_large = simulate(w_large, "optimal")
    assert large.algbw < 0.05 * opt_large.algbw
    assert small.algbw / simulate(w_small, "optimal").algbw > \
        large.algbw / opt_large.algbw


def test_flash_near_optimal_on_balanced():
    """Paper: FLASH reaches 98% of optimal at large balanced transfers."""
    w = balanced_workload(ClusterSpec(4, 8, alpha=10e-6), 128 << 20)
    r = simulate(w, "flash")
    assert r.algbw >= 0.9 * simulate(w, "optimal").algbw


def test_breakdown_sums_to_total():
    w = skewed_workload(C0, 4 << 20, seed=5)
    r = simulate(w, "flash")
    assert np.isclose(sum(r.breakdown.values()), r.completion_time,
                      rtol=1e-9)


def test_bw_ratio_shrinks_gap():
    """Theorem 3 trend (paper Fig 16b): faster intra => closer to optimal."""
    gaps = []
    for b1 in (64e9, 256e9, 1024e9):
        c = ClusterSpec(4, 8, b_intra=b1, alpha=0.0)
        w = skewed_workload(c, 4 << 20, seed=7)
        gaps.append(simulate(w, "flash").completion_time / t_optimal(w))
    assert gaps[0] >= gaps[1] >= gaps[2]
    assert gaps[2] < 1.1


def test_synthesis_time_micro():
    """Paper Fig 17a: schedule synthesis in us-to-ms, not minutes."""
    from repro.core import synthesis_time
    t = synthesis_time(n_servers=4, m_gpus=8, seed=0)
    assert t < 0.05  # 50 ms worst case on a slow CI box; paper: ~15-32 us


def test_redistribute_charged_at_receiver_fabric_not_cluster_min():
    """Regression (issue 4 satellite): a stage's hidden redistribute rides
    the fabrics of the servers the stage actually touches.  The old model
    charged every stage at the cluster-wide slowest fabric
    (``intra_a2a_bw.min()``), overcharging fast servers on mixed fabrics.
    """
    from repro.core import PermutationStage, ServerFabric, Topology
    from repro.core.simulator import _permutation_times

    slow = ServerFabric(intra_topology="ring", b_intra=8e9, m_gpus=4)
    fast = ServerFabric(intra_topology="full_mesh", b_intra=64e9, m_gpus=4)
    topo = Topology(fabrics=(slow, fast, fast, fast),
                    nic_bw=np.full((4, 4), 12.5e9), alpha=0.0)
    m = 4
    shares = np.full((4, 4, m), 1.0 / m)
    pair_cap = m * 12.5e9  # all rails equal: min-endpoint sum
    a2a_slow = slow.a2a_bandwidth()   # ring, m=4: 2 * b_intra = 16e9
    a2a_fast = fast.a2a_bandwidth()   # full mesh: 3 * b_intra = 192e9
    assert a2a_slow == 16e9 and a2a_fast == 192e9

    def mk(perm, size):
        sent = tuple(float(size) if j >= 0 else 0.0 for j in perm)
        return PermutationStage(perm=perm, size=float(size), sent=sent)

    # Stage 1's receivers are all fast servers {1, 2, 3}; its redistribute
    # (100e6/4 bytes per GPU over 192e9) hides entirely under stage 2's
    # transfer.  The old cluster-min model charged it over server 0's ring
    # (16e9) and found a large un-hidden residual that does not exist.
    fast_only = [mk((-1, 2, 3, 1), 100e6), mk((-1, 2, 3, 1), 20e6)]
    out = _permutation_times(topo, fast_only, shares)
    t_next = 20e6 / pair_cap
    assert (100e6 / m) / a2a_fast < t_next  # genuinely hidden
    assert out["hidden_residual"] == 0.0
    old_residual = (100e6 / m) / a2a_slow - t_next
    assert old_residual > 0  # the two models provably diverge here

    # Control: when the slow server *is* a receiver, both models agree.
    touching = [mk((1, 0, -1, -1), 100e6), mk((1, 0, -1, -1), 20e6)]
    out2 = _permutation_times(topo, touching, shares)
    assert out2["hidden_residual"] == pytest.approx(old_residual, rel=1e-12)


def test_tail_redistribute_charged_at_last_stage_receivers():
    """The tail RedistributePhase is the last stage's redistribute: it
    rides that stage's receiver fabrics, not the cluster-wide slowest."""
    from repro.core import (PermutationStage, Plan, RedistributePhase,
                            ServerFabric, Topology, execute_plan)

    slow = ServerFabric(intra_topology="ring", b_intra=8e9, m_gpus=4)
    fast = ServerFabric(intra_topology="full_mesh", b_intra=64e9, m_gpus=4)
    topo = Topology(fabrics=(slow, fast, fast, fast),
                    nic_bw=np.full((4, 4), 12.5e9), alpha=0.0)
    w = balanced_workload(topo, 1 << 20)
    t_server = w.server_matrix()
    size = float(t_server[1, 2])
    # One stage among the fast servers only; the tail must ride their
    # full-mesh fabric (192e9), not server 0's ring (16e9).
    stage = PermutationStage(perm=(-1, 2, 3, 1), size=size,
                             sent=(0.0, size, size, size))
    tail_bytes = size / 4
    plan = Plan(algorithm="flash", cluster=topo.cluster_view(),
                phases=(stage,
                        RedistributePhase(bytes_per_gpu=tail_bytes,
                                          charge_alpha=False)),
                accounts_intra=False, topology=topo)
    r = execute_plan(plan, w)
    assert r.breakdown["tail"] == pytest.approx(
        tail_bytes / fast.a2a_bandwidth(), rel=1e-12)
    # Hierarchical-style plans (no permutation stages) keep the
    # conservative cluster-min charge.
    plan_no_perm = Plan(algorithm="hierarchical", cluster=topo.cluster_view(),
                        phases=(RedistributePhase(bytes_per_gpu=tail_bytes,
                                                  charge_alpha=False),),
                        accounts_intra=False, topology=topo)
    r2 = execute_plan(plan_no_perm, w)
    assert r2.breakdown["tail"] == pytest.approx(
        tail_bytes / slow.a2a_bandwidth(), rel=1e-12)


def test_redistribute_charge_mixed_fabric_end_to_end():
    """On a mixed intra-fabric cluster the per-receiver charge keeps FLASH
    executable and fully accounted (breakdown sums to completion)."""
    from repro.core import ServerFabric, Topology

    slow = ServerFabric(intra_topology="ring", b_intra=8e9, m_gpus=4)
    fast = ServerFabric(intra_topology="full_mesh", b_intra=64e9, m_gpus=4)
    topo = Topology(fabrics=(slow, fast, fast, fast),
                    nic_bw=np.full((4, 4), 12.5e9))
    w = random_workload(topo, 4 << 20, seed=0)
    r = simulate(w, "flash")
    assert np.isfinite(r.completion_time) and r.completion_time > 0
    assert np.isclose(sum(r.breakdown.values()), r.completion_time,
                      rtol=1e-9)


def test_memory_overhead_slope():
    """Paper Fig 17b: FLASH ~2.6x workload bytes vs baseline 2x."""
    w = random_workload(C0, 8 << 20, seed=3)
    flash = simulate(w, "flash")
    base = simulate(w, "spreadout")
    assert base.memory_bytes == pytest.approx(2.0 * w.total_bytes)
    assert 2.0 < flash.memory_bytes / w.total_bytes < 3.2
