"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dev dep: skip property-based tests
    from _hypothesis_fallback import given, settings, st

from repro.kernels.a2a_pack import a2a_pack_op, a2a_pack_ref, \
    a2a_unpack_op, a2a_unpack_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_op
from repro.kernels.grouped_matmul import grouped_matmul_op, grouped_matmul_ref


@pytest.mark.parametrize("b,h,kv,s,d,causal,window", [
    (2, 4, 2, 256, 64, True, None),
    (1, 4, 4, 256, 128, True, 64),
    (2, 2, 1, 512, 64, False, None),
    (1, 8, 2, 256, 128, True, 128),
    (1, 2, 2, 128, 128, True, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, kv, s, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("blocks", [(64, 64), (128, 256)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 64))
    out = flash_attention_op(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("e,c,d,f,masked", [
    (4, 128, 256, 128, False),
    (8, 256, 512, 256, True),
    (2, 128, 1024, 512, True),
    (1, 128, 128, 128, False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_matches_ref(e, c, d, f, masked, dtype):
    ks = jax.random.split(jax.random.PRNGKey(e + c), 3)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    w = jax.random.normal(ks[1], (e, d, f), dtype)
    counts = jax.random.randint(ks[2], (e,), 0, c + 1) if masked else None
    y = grouped_matmul_op(x, w, counts, interpret=True)
    ref = grouped_matmul_ref(x, w, counts)
    scale = float(jnp.abs(ref.astype(jnp.float32)).max()) + 1e-9
    err = float(jnp.abs(y.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max()) / scale
    assert err < (1e-5 if dtype == jnp.float32 else 2e-2), err


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_a2a_pack_property(n, m, seed):
    d = 128
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (m,), 0, n)
    y = a2a_pack_op(x, idx, interpret=True)
    assert jnp.array_equal(y, a2a_pack_ref(x, idx))


def test_a2a_pack_moe_layout():
    """Pack scattered token rows destination-contiguously (the paper's
    anti-fragmentation bundling): packed buffer equals sort-by-destination."""
    n, d, n_dst = 64, 128, 4
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (n, d))
    dst = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, n_dst)
    order = jnp.argsort(dst, stable=True)
    packed = a2a_pack_op(x, order.astype(jnp.int32), interpret=True)
    assert jnp.array_equal(packed, x[order])
    # destination-contiguity: dst of packed rows is non-decreasing
    assert bool(jnp.all(jnp.diff(dst[order]) >= 0))


@pytest.mark.parametrize("d", [5, 64, 130, 200, 256])
def test_a2a_pack_non_tile_lanes(d):
    """D need not divide the 128-lane tile: pad-and-slice inside the op."""
    n, m = 16, 9
    key = jax.random.PRNGKey(d)
    x = jax.random.normal(key, (n, d), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (m,), 0, n)
    y = a2a_pack_op(x, idx, interpret=True)
    assert jnp.array_equal(y, a2a_pack_ref(x, idx))


@pytest.mark.parametrize("block_rows", [1, 3, 8, 16, 24])
@pytest.mark.parametrize("d", [128, 72])
def test_a2a_pack_block_rows(block_rows, d):
    """Row blocks beyond 1: out block m = in block idx[m], any block size
    (8-row sublane tiling kicks in for multiples of 8)."""
    n_blocks, m = 6, 10
    key = jax.random.PRNGKey(block_rows * d)
    x = jax.random.normal(key, (n_blocks * block_rows, d), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (m,), 0, n_blocks)
    y = a2a_pack_op(x, idx, block_rows=block_rows, interpret=True)
    assert jnp.array_equal(y, a2a_pack_ref(x, idx, block_rows=block_rows))


@pytest.mark.parametrize("block_rows", [1, 8, 24])
@pytest.mark.parametrize("d", [128, 130])
def test_a2a_unpack_matches_ref(block_rows, d):
    """Inverse scatter: out block idx[m] <- in block m.  Blocks never
    named by idx are unspecified, so parity is checked on named blocks
    only (the plan-exec caller slices its trash block off the same way)."""
    n_out, m = 8, 5
    key = jax.random.PRNGKey(3 * block_rows + d)
    x = jax.random.normal(key, (m * block_rows, d), jnp.float32)
    perm = jax.random.permutation(jax.random.fold_in(key, 1), n_out)
    idx = perm[:m].astype(jnp.int32)
    y = a2a_unpack_op(x, idx, n_out_blocks=n_out, block_rows=block_rows,
                      interpret=True)
    ref = a2a_unpack_ref(x, idx, n_out_blocks=n_out, block_rows=block_rows)
    named = np.asarray(
        y.reshape(n_out, block_rows, d))[np.asarray(idx)]
    named_ref = np.asarray(
        ref.reshape(n_out, block_rows, d))[np.asarray(idx)]
    assert np.array_equal(named, named_ref)


@pytest.mark.parametrize("block_rows", [1, 4, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_a2a_pack_unpack_round_trip(block_rows, seed):
    """unpack(pack(x, perm), perm) == x for any permutation of blocks."""
    n_blocks, d = 7, 128
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n_blocks * block_rows, d), jnp.float32)
    perm = jax.random.permutation(
        jax.random.fold_in(key, 1), n_blocks).astype(jnp.int32)
    packed = a2a_pack_op(x, perm, block_rows=block_rows, interpret=True)
    back = a2a_unpack_op(packed, perm, n_out_blocks=n_blocks,
                         block_rows=block_rows, interpret=True)
    assert jnp.array_equal(back, x)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 12), st.integers(1, 180),
       st.integers(0, 2 ** 31 - 1))
def test_a2a_pack_unpack_round_trip_property(n_blocks, block_rows, d, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n_blocks * block_rows, d), jnp.float32)
    perm = jax.random.permutation(
        jax.random.fold_in(key, 1), n_blocks).astype(jnp.int32)
    packed = a2a_pack_op(x, perm, block_rows=block_rows, interpret=True)
    assert jnp.array_equal(
        packed, a2a_pack_ref(x, perm, block_rows=block_rows))
    back = a2a_unpack_op(packed, perm, n_out_blocks=n_blocks,
                         block_rows=block_rows, interpret=True)
    assert jnp.array_equal(back, x)
