"""No-op stand-ins for hypothesis so property tests *skip* (not error) when
the optional dev dependency is absent.

Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, st

``st`` accepts any chained strategy construction (``st.integers(...).flatmap
(...)`` etc.) lazily; ``given`` replaces the test with a skipped stub.
"""

import pytest


class _LazyStrategy:
    """Absorbs any attribute access / call chain without evaluating."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _LazyStrategy()


def settings(*args, **kwargs):
    def deco(fn):
        return fn

    return deco


def given(*args, **kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed "
                                 "(see requirements-dev.txt)")
        def _skipped():
            pass

        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped

    return deco
