"""Compiled plan execution (issue 5 tentpole).

Parity of the compiled executor (``Plan.compile`` -> ``ExecutableSchedule``)
with the interpreted oracle (``execute_plan(reference=True)``) across every
registered scheduler x heterogeneous topologies x skewed workloads;
``execute_batch`` / ``simulate_many`` equivalence with the one-at-a-time
pipeline on a drifting-MoE trajectory; the compiled-schedule memo slot; and
the issue's satellite regressions (cache seeding from a pre-synthesized
plan, memoized uniform rail shares, memoized per-stage ``live_slots``).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dev dep: skip property-based tests
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ClusterSpec,
    PermutationStage,
    Plan,
    PlanCache,
    RedistributePhase,
    ServerFabric,
    Topology,
    available_schedulers,
    compile_plan,
    execute_plan,
    get_scheduler,
    moe_workload,
    random_workload,
    simulate,
    simulate_many,
    skewed_workload,
    traffic_fingerprint,
    uniform_nic_shares,
)
from repro.core.birkhoff import live_slots
from repro.core.traffic import Workload

PARITY_RTOL = 1e-12


def _homo(n=4, m=4):
    return Topology.homogeneous(n, m, b_intra=64e9, b_inter=12.5e9)


def _topology(kind, n=4, m=4):
    h = _homo(n, m)
    return {
        "uniform": lambda: h,
        "degraded_nic": lambda: h.degrade_nic(n // 2, m - 1, 0.25),
        "failed_nic": lambda: h.fail_nic(1 % n, 0),
        "mixed_speeds": lambda: h.with_server_nic_speeds(
            [12.5e9] * (n // 2) + [50e9] * (n - n // 2)),
        "oversubscribed": lambda: h.with_oversubscription(2.0),
        "mixed_fabrics": lambda: Topology(
            fabrics=(ServerFabric("ring", 8e9, m),)
            + (ServerFabric("full_mesh", 64e9, m),) * (n - 1),
            nic_bw=np.full((n, m), 12.5e9)),
    }[kind]()


TOPO_KINDS = ("uniform", "degraded_nic", "failed_nic", "mixed_speeds",
              "oversubscribed", "mixed_fabrics")


def _workload(topo, kind, seed=2):
    return {
        "skewed": lambda: skewed_workload(topo, 4 << 20, 1.2, seed=seed),
        "moe": lambda: moe_workload(topo, 4096, 2048, top_k=2, seed=seed),
        "random": lambda: random_workload(topo, 4 << 20, seed=seed),
    }[kind]()


def _rel(a, b):
    if a == b:  # covers inf == inf and exact zeros
        return 0.0
    return abs(a - b) / max(abs(a), abs(b), 1e-30)


def _assert_parity(plan, w, topology=None):
    ref = execute_plan(plan, w, topology=topology, reference=True)
    got = execute_plan(plan, w, topology=topology)
    assert _rel(ref.completion_time, got.completion_time) <= PARITY_RTOL
    assert _rel(ref.algbw, got.algbw) <= PARITY_RTOL
    assert _rel(ref.memory_bytes, got.memory_bytes) <= PARITY_RTOL
    assert ref.n_stages == got.n_stages
    assert ref.algorithm == got.algorithm
    assert set(ref.breakdown) == set(got.breakdown)
    for k, v in ref.breakdown.items():
        assert _rel(v, got.breakdown[k]) <= PARITY_RTOL, (k, v,
                                                          got.breakdown[k])
    return ref, got


# -- compiled-vs-interpreted parity ---------------------------------------


@pytest.mark.parametrize("algo", sorted(available_schedulers()))
@pytest.mark.parametrize("topo_kind", TOPO_KINDS)
@pytest.mark.parametrize("wl_kind", ("skewed", "moe"))
def test_compiled_matches_interpreted(algo, topo_kind, wl_kind):
    """The acceptance bar: <= 1e-12 parity for every registered scheduler
    on heterogeneous fabrics under skewed traffic."""
    topo = _topology(topo_kind)
    w = _workload(topo, wl_kind)
    plan = get_scheduler(algo).synthesize(w)
    _assert_parity(plan, w)


def test_compiled_matches_interpreted_blind_on_degraded_fabric():
    """Topology-override execution (the fig_hetero blindness experiment),
    including the infinite-completion failed-NIC case."""
    for kind in ("degraded_nic", "failed_nic", "oversubscribed"):
        topo = _topology(kind)
        w = random_workload(topo, 4 << 20, seed=0)
        w_homo = random_workload(_homo(), 4 << 20, seed=0)
        blind = get_scheduler("flash").synthesize(w_homo)
        ref, got = _assert_parity(blind, w, topology=topo)
        if kind == "failed_nic":
            assert np.isinf(ref.completion_time)
            assert np.isinf(got.completion_time)


def test_compiled_matches_interpreted_padding_only_stage():
    """A stage whose matched entries were all padding (perm all -1) takes
    the legacy cluster-min redistribute fallback in both paths."""
    topo = _homo()
    w = random_workload(topo, 1 << 20, seed=3)
    size = 4.0e6
    phases = (
        PermutationStage(perm=(-1, -1, -1, -1), size=size,
                         sent=(0.0,) * 4),
        PermutationStage(perm=(1, 0, 3, 2), size=size,
                         sent=(size,) * 4),
        RedistributePhase(bytes_per_gpu=size / 4, charge_alpha=True),
    )
    plan = Plan(algorithm="flash", cluster=topo.cluster_view(),
                phases=phases, accounts_intra=False, topology=topo)
    _assert_parity(plan, w)


def test_compiled_matches_interpreted_zero_traffic():
    """An all-zero workload produces all-zero barrier stages: neither path
    may invent a breakdown key for them (key-set parity)."""
    c = ClusterSpec(2, 4)
    w = Workload(c, np.zeros((c.n_gpus, c.n_gpus)))
    for algo in available_schedulers():
        plan = get_scheduler(algo).synthesize(w)
        ref, got = _assert_parity(plan, w)
        assert ref.completion_time == got.completion_time


@pytest.mark.parametrize("seed", range(6))
def test_compiled_parity_seeded(seed):
    """Seeded fallback for the property test below: random shapes,
    scenarios and schedulers, always run."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 6))
    m = int(rng.integers(2, 5))
    topo = _topology(TOPO_KINDS[int(rng.integers(len(TOPO_KINDS)))], n, m)
    w = _workload(topo, ("skewed", "random", "moe")[seed % 3],
                  seed=int(rng.integers(10_000)))
    for algo in available_schedulers():
        plan = get_scheduler(algo).synthesize(w)
        _assert_parity(plan, w)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(2, 5), st.integers(0, 10_000),
       st.sampled_from(TOPO_KINDS),
       st.sampled_from(("skewed", "random", "moe")))
def test_compiled_parity_property(n, m, seed, topo_kind, wl_kind):
    topo = _topology(topo_kind, n, m)
    w = _workload(topo, wl_kind, seed=seed)
    for algo in available_schedulers():
        plan = get_scheduler(algo).synthesize(w)
        _assert_parity(plan, w)


# -- batched execution -----------------------------------------------------


def _drift_trajectory(topo, steps=6, seed=0):
    """A drifting-MoE trajectory: small multiplicative perturbations of a
    base gating matrix, with one exact repeat."""
    rng = np.random.default_rng(seed)
    base = moe_workload(topo, 4096, 2048, top_k=2, seed=seed)
    mats = [base.matrix]
    for _ in range(steps - 2):
        nxt = mats[-1].copy()
        drift = rng.random(nxt.shape) < 0.05
        nxt[drift] *= rng.uniform(0.8, 1.2, size=int(drift.sum()))
        np.fill_diagonal(nxt, 0.0)
        mats.append(nxt)
    mats.append(mats[0])  # repeated signature -> exact cache hit
    return [Workload(base.cluster, mat, base.topology) for mat in mats]


def test_execute_batch_matches_loop_of_execute_plan():
    topo = _topology("mixed_speeds")
    traj = _drift_trajectory(topo)
    plan = get_scheduler("flash").synthesize(traj[0])
    sched = plan.compile()
    want = [execute_plan(plan, w) for w in traj]
    # All three traffic forms: (B, N, N) stack, workloads, raw matrices.
    stack = np.stack([w.matrix for w in traj])
    for batch in (sched.execute_batch(stack), sched.execute_batch(traj),
                  sched.execute_batch([w.matrix for w in traj])):
        assert len(batch) == len(want)
        for got, ref in zip(batch, want):
            assert got.completion_time == ref.completion_time
            assert got.algbw == ref.algbw
            assert got.memory_bytes == ref.memory_bytes
            assert got.breakdown == ref.breakdown


def test_execute_batch_rejects_wrong_shapes():
    topo = _homo()
    w = random_workload(topo, 1 << 20, seed=0)
    sched = get_scheduler("flash").synthesize(w).compile()
    with pytest.raises(ValueError, match="traffic stack shape"):
        sched.execute_batch(np.zeros((2, 3, 3)))
    with pytest.raises(ValueError, match="traffic matrix shape"):
        sched.execute_batch([np.zeros((3, 3))])
    # A workload whose *cluster* shape mismatches is rejected even when
    # its GPU count (and so its matrix shape) coincides with the plan's.
    w_other = random_workload(_homo(2, 8), 1 << 20, seed=0)
    assert w_other.cluster.n_gpus == w.cluster.n_gpus
    with pytest.raises(ValueError, match="workload shape"):
        sched.execute_batch([w_other])


def test_simulate_many_matches_loop_of_simulate():
    """The batched front door is result-for-result the serving loop,
    including PlanCache hit/warm counters."""
    topo = _homo()
    traj = _drift_trajectory(topo, steps=7, seed=1)
    cache_a = PlanCache(warm_start=True)
    cache_b = PlanCache(warm_start=True)
    got = simulate_many(traj, "flash", cache=cache_a)
    want = [simulate(w, "flash", cache=cache_b) for w in traj]
    assert len(got) == len(want)
    for g, r in zip(got, want):
        assert g.completion_time == r.completion_time
        assert g.algbw == r.algbw
        assert g.breakdown == r.breakdown
    assert (cache_a.hits, cache_a.misses, cache_a.warm_hits) == \
        (cache_b.hits, cache_b.misses, cache_b.warm_hits)
    assert cache_a.hits >= 1  # the trajectory's exact repeat


def test_simulate_many_with_held_plan_and_override_topology():
    """One stale plan held across a trajectory (drift experiment) on an
    override fabric: equals the loop, batched through one schedule."""
    topo = _topology("degraded_nic")
    traj = _drift_trajectory(topo, steps=5, seed=2)
    w_homo = random_workload(_homo(), 4 << 20, seed=0)
    blind = get_scheduler("flash").synthesize(w_homo)
    got = simulate_many(traj, "flash", plan=blind, topology=topo)
    want = [simulate(w, "flash", plan=blind, topology=topo) for w in traj]
    for g, r in zip(got, want):
        assert g.completion_time == r.completion_time
        assert g.algbw == r.algbw


# -- the compiled-schedule cache slot --------------------------------------


def test_plan_compile_is_memoized_per_topology():
    topo = _homo()
    w = random_workload(topo, 1 << 20, seed=0)
    plan = get_scheduler("flash").synthesize(w)
    s1 = plan.compile()
    assert plan.compile() is s1  # same fingerprint -> same schedule
    other = _topology("degraded_nic")
    s2 = plan.compile(other)
    assert s2 is not s1  # new fabric -> recompiled
    assert plan.compile(other) is s2
    assert plan.compile() is s1  # both slots live side by side
    # compile_plan itself never memoizes (always-fresh building block).
    assert compile_plan(plan) is not s1


def test_compiled_result_breakdown_is_private_copy():
    topo = _homo()
    w = random_workload(topo, 1 << 20, seed=0)
    plan = get_scheduler("flash").synthesize(w)
    r1 = execute_plan(plan, w)
    r1.breakdown["inter"] = -1.0  # caller mutates its result...
    r2 = execute_plan(plan, w)
    assert r2.breakdown["inter"] > 0  # ...the compiled schedule is intact


def test_execute_rejects_mismatched_workload_shape():
    w4 = random_workload(_homo(4, 4), 1 << 20, seed=0)
    w2 = random_workload(_homo(2, 4), 1 << 20, seed=0)
    sched = get_scheduler("flash").synthesize(w4).compile()
    with pytest.raises(ValueError, match="workload shape"):
        sched.execute(w2)


# -- satellite regressions -------------------------------------------------


def test_simulate_seeds_cache_with_provided_plan():
    """Regression: ``simulate(w, algo, plan=..., cache=...)`` used to
    ignore the cache entirely; it now inserts the plan under its own
    traffic fingerprint so later replays hit."""
    cache = PlanCache()
    c = ClusterSpec(4, 4)
    w = moe_workload(c, 4096, 2048, top_k=2, seed=5)
    plan = get_scheduler("flash").synthesize(w)
    simulate(w, "flash", plan=plan, cache=cache)
    assert len(cache) == 1
    assert (cache.hits, cache.misses) == (0, 0)  # insert, not lookup
    r = simulate(w, "flash", cache=cache)
    assert (cache.hits, cache.misses) == (1, 0)  # later hits now fire
    assert r.completion_time == execute_plan(plan, w).completion_time
    assert cache.lookup(traffic_fingerprint(w, "flash")) is plan


def test_simulate_plan_insertion_does_not_poison_drift_experiments():
    """A stale plan deliberately executed against *new* traffic must be
    cached under the traffic it was synthesized for -- never under the
    drifted workload's fingerprint."""
    cache = PlanCache()
    c = ClusterSpec(4, 4)
    w0 = moe_workload(c, 4096, 2048, top_k=2, seed=0)
    w1 = moe_workload(c, 4096, 2048, top_k=2, seed=1)
    plan0 = get_scheduler("flash").synthesize(w0)
    simulate(w1, "flash", plan=plan0, cache=cache)  # drift execution
    # w1's own fingerprint must still miss (fresh synthesis)...
    assert cache.lookup(traffic_fingerprint(w1, "flash")) is None
    # ...while w0's traffic now hits plan0.
    assert cache.lookup(traffic_fingerprint(w0, "flash")) is plan0


def test_uniform_shares_memoized_and_frozen():
    """Regression: the executor allocated a fresh uniform (n, n, m) share
    array on every call for plans without explicit ``nic_shares``."""
    s1 = uniform_nic_shares(4, 8)
    assert uniform_nic_shares(4, 8) is s1
    assert not s1.flags.writeable
    np.testing.assert_allclose(s1, 1.0 / 8)
    assert uniform_nic_shares(4, 4) is not s1


def test_permutation_stage_live_is_memoized():
    """Regression: ``live_slots`` was recomputed up to three times per
    stage per execution (transfer, hidden redistribute, tail)."""
    stage = PermutationStage(perm=(1, 0, -1, 3), size=8.0,
                             sent=(8.0, 8.0, 0.0, 4.0))
    live = stage.live()
    assert stage.live() is live
    src, dst, slot = live
    ref_src, ref_dst, ref_slot = live_slots(stage.perm, stage.slots,
                                            stage.size)
    np.testing.assert_array_equal(src, ref_src)
    np.testing.assert_array_equal(dst, ref_dst)
    np.testing.assert_array_equal(slot, ref_slot)
    assert not src.flags.writeable


def test_simulate_reference_path_still_available():
    """The interpreted oracle stays reachable through the public
    pipeline, like ``birkhoff_decompose(reference=True)``."""
    w = random_workload(_homo(), 4 << 20, seed=9)
    r_ref = simulate(w, "spreadout", reference=True)
    r = simulate(w, "spreadout")
    assert _rel(r_ref.completion_time, r.completion_time) <= PARITY_RTOL
