"""Beyond-paper perf knobs: correctness under the hillclimb configurations."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import build_model


def test_quantized_dispatch_close_to_exact(subproc):
    """int8 DCN dispatch: outputs within quantization tolerance of exact."""
    out = subproc("""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.configs.registry import MoESpec
from repro.models.dist import DistContext
from repro.models.moe import init_moe, moe_apply
from repro.models.sharding import MeshRules, use_mesh_rules
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
dist = DistContext(mesh=mesh, dp_axes=("pod", "data"), slow_axis="pod",
                   ep_axes=("pod",), a2a_impl="flash")
base = dataclasses.replace(
    smoke_config("mixtral-8x7b"), compute_dtype="float32",
    moe=MoESpec(num_experts=2, top_k=2))
p = init_moe(jax.random.PRNGKey(0), base)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, base.d_model),
                      jnp.float32) * 0.3
xg = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
rules = MeshRules(mesh=mesh, batch=("pod", "data"))
outs = {}
for quant in (False, True):
    cfg = dataclasses.replace(base, quantized_dispatch=quant)
    with use_mesh_rules(rules):
        y, _ = jax.jit(lambda pp, xx: moe_apply(cfg, pp, xx, dist))(p, xg)
    outs[quant] = y
scale = float(jnp.abs(outs[False]).max()) + 1e-9
err = float(jnp.abs(outs[True] - outs[False]).max()) / scale
assert 0 < err < 0.05, err   # int8: ~1% expected, must not be exact-zero
print("QUANT_OK", err)
""")
    assert "QUANT_OK" in out


@pytest.mark.parametrize("knobs", [
    {"pure_dp": True},
    {"fsdp": True, "param_dtype": "bfloat16"},
    {"fsdp": True, "seq_shard_activations": True},
    {"remat_group": 2},
    {"microbatches": 2},
])
def test_knob_lowering_small_mesh(subproc, knobs):
    """Every perf knob lowers+compiles a train step on a small mesh."""
    out = subproc(f"""
import os
os.environ["TF_CPP_MIN_LOG_LEVEL"] = "3"
import dataclasses as dc, jax
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
import repro.launch.mesh as mesh_mod
mesh_mod.make_production_mesh = \\
    lambda multi_pod=False: make_mesh((2, 2, 4), ("pod", "data", "model"))
from repro.launch.dryrun import run_cell
import repro.configs.registry as reg

cfg = dc.replace(get_config("qwen3-0.6b"), n_layers=4, scan_layers=True,
                 d_model=256, d_ff=512, n_heads=8, n_kv_heads=4,
                 head_dim=32, vocab=3200, **{knobs!r})
reg._REGISTRY["qwen3-0.6b"] = lambda: cfg
import repro.launch.dryrun as dr
shape = dc.replace(SHAPES["train_4k"], global_batch=16, seq_len=256)
dr.SHAPES = dict(SHAPES); dr.SHAPES["train_4k"] = shape
res = run_cell("qwen3-0.6b", "train_4k", "multi")
assert res["status"] == "ok", res.get("error")
print("KNOB_OK")
""", n_devices=16, timeout=600)
    assert "KNOB_OK" in out


def test_microbatch_grads_match_full_batch():
    cfg = smoke_config("llama3.2-1b")
    from repro.launch.train import TrainOptions, make_train_step
    from repro.optim import init_opt_state
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    results = {}
    for mb in (1, 2, 4):
        step_fn, _, _, _ = make_train_step(
            cfg, None, TrainOptions(microbatches=mb, peak_lr=1e-3,
                                    warmup_steps=1, total_steps=10))
        s2, m = step_fn(jax.tree.map(lambda x: x, state), batch)
        results[mb] = s2["params"]
    for mb in (2, 4):
        diff = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(results[1]), jax.tree.leaves(results[mb])))
        assert diff < 1e-4, (mb, diff)
