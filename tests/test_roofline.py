"""HLO collective parser + roofline-term math against synthetic fixtures."""

import pytest

from repro.launch.roofline import (
    CollectiveStats,
    parse_collectives,
    roofline_terms,
)

# Synthetic optimized-HLO snippets in the forms XLA emits.
HLO_FIXTURE = """
HloModule jit_step

%add.clone_promoted (x: f32[], y: f32[]) -> f32[] {
}

ENTRY %main {
  %ar1 = f32[16,4096,1024]{2,1,0} all-reduce(%a), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add.clone_promoted
  %ag1 = bf16[2048,1024]{1,0} all-gather(%b), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %aa1 = bf16[32,128,64]{2,1,0} all-to-all(%c), channel_id=3, replica_groups=[16,32]<=[2,16,16]T(1,2,0)
  %cp1 = bf16[64,256]{1,0} collective-permute(%d), channel_id=4, source_target_pairs={{0,256},{256,0}}
  %rs1 = f32[8,16]{1,0} reduce-scatter(%e), channel_id=5, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
}
"""


def test_parse_counts_and_bytes():
    st = parse_collectives(HLO_FIXTURE, pod_size=256)
    assert st.count == 5
    # ar1: promoted f32 counted at bf16 width: 16*4096*1024*4/2 = 134217728
    assert st.by_op["all-reduce"] == pytest.approx(
        2 * 134217728 * 15 / 16)
    # ag1: 2048*1024*2 * 15/16
    assert st.by_op["all-gather"] == pytest.approx(
        2048 * 1024 * 2 * 15 / 16)


def test_iota_group_pod_crossing():
    """[16,32]<=[2,16,16]T(1,2,0): groups mix pod 0 and pod 1 devices."""
    st = parse_collectives(HLO_FIXTURE, pod_size=256)
    assert st.dcn_bytes > 0
    # the all-to-all (pod-crossing) + permute land in DCN
    expected_aa = 32 * 128 * 64 * 2 * 31 / 32
    expected_cp = 64 * 256 * 2
    assert st.dcn_bytes == pytest.approx(expected_aa + expected_cp)


def test_intra_pod_groups_stay_ici():
    st = parse_collectives(HLO_FIXTURE, pod_size=256)
    # ar1 and ag1 ([16,16]<=[256]: consecutive blocks of 16 within pod 0)
    assert st.ici_bytes == pytest.approx(
        2 * 134217728 * 15 / 16 + 2048 * 1024 * 2 * 15 / 16
        + 8 * 16 * 4 * 3)  # + rs1 (explicit small groups)


def test_explicit_group_list_parsing():
    st = parse_collectives(HLO_FIXTURE, pod_size=4)
    # with pod_size=4 the reduce-scatter groups {0..3},{4..7} stay intra
    hlo_rs = [l for l in HLO_FIXTURE.splitlines() if "reduce-scatter" in l]
    assert hlo_rs
    assert st.count == 5


def test_roofline_terms_dominant():
    coll = CollectiveStats(simple_bytes=1e9, wire_bytes=1e9, ici_bytes=5e8,
                           dcn_bytes=5e8, count=3)
    t = roofline_terms(flops_per_chip=1.97e14, bytes_per_chip=819e9,
                       coll=coll)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(5e8 / 50e9 + 5e8 / 25e9)
    assert t["dominant"] in ("compute", "memory")
    assert 0 < t["roofline_fraction"] <= 1.0


def test_promoted_reduction_halved():
    line_promoted = ("%ar = f32[1024]{0} all-reduce(%x), replica_groups="
                     "[4,4]<=[16], to_apply=%add.clone_promoted\n")
    line_plain = ("%ar = f32[1024]{0} all-reduce(%x), replica_groups="
                  "[4,4]<=[16], to_apply=%add\n")
    sp = parse_collectives(line_promoted, pod_size=256)
    pl = parse_collectives(line_plain, pod_size=256)
    assert sp.wire_bytes == pytest.approx(pl.wire_bytes / 2)
