"""Plan -> device lowering (comm.plan_exec.lower_plan): host-side checks.

The lowering is a pure host computation (tuples of ints, no shard_map), so
these run single-device; the on-device bit-identity goldens live in
tests/test_comm.py.
"""

import numpy as np
import pytest

from repro.comm.plan_exec import DeviceSchedule, is_lowered, lower_plan
from repro.core.schedulers import get_scheduler
from repro.core.topology import Topology
from repro.core.traffic import ClusterSpec, Workload, moe_workload, \
    skewed_workload


def _random_workload(n_servers, m_gpus, seed=0):
    n = n_servers * m_gpus
    rng = np.random.default_rng(seed)
    mat = rng.integers(1, 50, size=(n, n)).astype(float)
    np.fill_diagonal(mat, 0)
    return Workload(ClusterSpec(n_servers, m_gpus), mat)


def _coverage(sched: DeviceSchedule):
    pairs = [pair for stage in sched.pairs for pair in stage]
    return pairs, set(pairs)


@pytest.mark.parametrize("algo", ["flash", "fanout"])
@pytest.mark.parametrize("n_servers,m_gpus", [(2, 4), (4, 2), (4, 8)])
def test_lowering_covers_every_pair_once(algo, n_servers, m_gpus):
    """Each ordered (src, dst) pod pair appears in exactly one stage --
    the property that makes the device exchange exact on capacity-padded
    buffers -- and every stage is a partial permutation (incast-free)."""
    w = _random_workload(n_servers, m_gpus)
    sched = lower_plan(get_scheduler(algo).synthesize(w))
    pairs, distinct = _coverage(sched)
    want = {(s, d) for s in range(n_servers) for d in range(n_servers)
            if s != d}
    assert distinct == want
    assert len(pairs) == len(distinct), "a pair was scheduled twice"
    for stage in sched.pairs:
        srcs = [s for s, _ in stage]
        dsts = [d for _, d in stage]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts), "incast within a stage"


def test_stage_tables_match_pairs():
    w = moe_workload(ClusterSpec(4, 2), tokens_per_gpu=128,
                     bytes_per_token=2, seed=3)
    sched = lower_plan(get_scheduler("flash").synthesize(w))
    for k, stage in enumerate(sched.pairs):
        for s, d in stage:
            assert sched.dst_of[k][s] == d
            assert sched.src_of[k][d] == s
        live_src = {s for s, _ in stage}
        live_dst = {d for _, d in stage}
        for q in range(sched.n_pods):
            if q not in live_src:
                assert sched.dst_of[k][q] == -1
            if q not in live_dst:
                assert sched.src_of[k][q] == -1


def test_plan_stages_precede_fallback():
    """Bulk traffic moves in the plan's own stage order; only the
    zero-traffic remainder rides the appended rotations."""
    w = skewed_workload(ClusterSpec(4, 2), mean_size=1e6, seed=1)
    sched = lower_plan(get_scheduler("flash").synthesize(w))
    assert sched.n_stages == sched.n_plan_stages + sched.n_fallback_stages
    assert sched.n_plan_stages >= 1
    # flash covers the full support of a positive matrix; no fallback
    assert sched.n_fallback_stages == 0


def test_fanout_lowering_is_all_fallback():
    """FanOutBurst plans carry no static permutations -- the lowering is
    entirely the coverage-completion rotations, still exact."""
    w = _random_workload(4, 2)
    sched = lower_plan(get_scheduler("fanout").synthesize(w))
    assert sched.n_plan_stages == 0
    assert sched.n_fallback_stages == sched.n_stages == 3
    _, distinct = _coverage(sched)
    assert len(distinct) == 12


def test_memoized_per_pod_count_and_is_lowered():
    w = _random_workload(4, 2)
    plan = get_scheduler("flash").synthesize(w)
    assert not is_lowered(plan)
    s1 = lower_plan(plan)
    assert is_lowered(plan) and is_lowered(plan, n_pods=4)
    s2 = lower_plan(plan, n_pods=4)
    assert s1 is s2


def test_determinism_per_fingerprint():
    """Two independent synth runs of the same workload lower identically."""
    w = moe_workload(ClusterSpec(4, 2), tokens_per_gpu=256,
                     bytes_per_token=2, seed=9)
    a = lower_plan(get_scheduler("flash").synthesize(w))
    b = lower_plan(get_scheduler("flash").synthesize(w))
    assert a is not b
    assert a.pairs == b.pairs
    assert a.plan_fingerprint == b.plan_fingerprint


def test_pod_count_mismatch_raises():
    w = _random_workload(4, 2)
    plan = get_scheduler("flash").synthesize(w)
    with pytest.raises(ValueError, match="4 servers"):
        lower_plan(plan, n_pods=8)


def test_executable_schedule_accepted():
    """lower_plan accepts a compiled ExecutableSchedule and shares the
    memo slot with its plan (the serving handoff path)."""
    w = _random_workload(2, 4)
    plan = get_scheduler("flash").synthesize(w)
    sched = plan.compile()
    dev = lower_plan(sched)
    assert dev is lower_plan(plan)
    assert dev is sched.lower_device()
    assert dev.algorithm == "flash"


def test_capacity_aware_dedup():
    """Capacity-aware synthesis repeats pairs across stages (byte
    proportional); the lowering keeps only each pair's first occurrence."""
    topo = Topology.from_cluster(ClusterSpec(4, 2))
    topo = topo.degrade_nic(0, 0, factor=0.25)
    n = 8
    rng = np.random.default_rng(5)
    mat = rng.integers(1, 80, size=(n, n)).astype(float)
    np.fill_diagonal(mat, 0)
    w = Workload(ClusterSpec(4, 2), mat, topology=topo)
    sched = lower_plan(get_scheduler("flash_ca").synthesize(w))
    pairs, distinct = _coverage(sched)
    assert len(pairs) == len(distinct) == 12
