"""Trajectory-fused incremental synthesis tests (issue 7): the stateful
DecompositionState delta engine, FlashScheduler.synthesize_trajectory,
the plan-to-plan state handoff, the RepairConfig knobs, the serving
daemon's repair-residual telemetry, and client-side request coalescing.
"""

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    DecompositionState,
    PermutationBlock,
    RepairConfig,
    birkhoff_decompose,
    get_scheduler,
    moe_workload,
    simulate,
)
from repro.core.schedulers import _STATE_ATTR
from repro.core.traffic import Workload
from repro.serving import PlanClient, PlanServer

C = ClusterSpec(n_servers=8, m_gpus=4)


def _near_miss(w, seed=7, frac=0.05, jitter=0.2):
    rng = np.random.default_rng(seed)
    m = w.matrix.copy()
    sel = rng.random(m.shape) < frac
    m[sel] *= rng.uniform(1 - jitter, 1 + jitter, size=int(sel.sum()))
    np.fill_diagonal(m, 0.0)
    return Workload(w.cluster, m, w.topology)


def _drift_trajectory(cluster, steps, seed=0, repeat_p=0.25):
    """fig_dynamic's drifting-MoE mix: sparse perturbations with repeats."""
    rng = np.random.default_rng(seed)
    base = moe_workload(cluster, 1024, 256, top_k=2, seed=seed)
    mats = [base.matrix]
    for _ in range(1, steps):
        if rng.random() < repeat_p and len(mats) > 1:
            mats.append(mats[int(rng.integers(len(mats)))])
            continue
        nxt = mats[-1].copy()
        drift = rng.random(nxt.shape) < 0.03
        nxt[drift] *= rng.uniform(0.8, 1.2, size=int(drift.sum()))
        np.fill_diagonal(nxt, 0.0)
        mats.append(nxt)
    return [Workload(cluster, mat) for mat in mats]


def _server_matrix(n=8, seed=0):
    """A dense positive (n, n) inter-server matrix with zero diagonal."""
    rng = np.random.default_rng(seed)
    t = rng.uniform(1e6, 5e6, size=(n, n))
    np.fill_diagonal(t, 0.0)
    return t


def _block_matrix(block, n):
    """Reconstruct the (n, n) byte matrix a StageBlock delivers."""
    mat = np.zeros((n, n))
    for k in range(len(block)):
        perm = block.perms[k]
        live = np.flatnonzero(perm >= 0)
        np.add.at(mat, (live, perm[live]), block.sent[k][live])
    return mat


def _fresh_state(t, headroom=0.5):
    stages = birkhoff_decompose(t, sort_ascending=True, coalesce=True)
    return DecompositionState.from_stages(stages, t.shape[0],
                                          headroom=headroom)


# -- DecompositionState unit behavior ---------------------------------------

def test_state_zero_drift_reproduces_matrix():
    t = _server_matrix()
    state = _fresh_state(t)
    block, stats = state.update(t)
    assert stats["mode"] == "incremental"
    assert stats["residual_fraction"] == pytest.approx(0.0, abs=1e-9)
    np.testing.assert_allclose(_block_matrix(block, 8), t, rtol=1e-9)


def test_state_headroom_absorbs_growth_without_new_stages():
    t = _server_matrix()
    state = _fresh_state(t, headroom=0.5)
    n_before = state._perms2d.shape[0]
    grown = t * 1.3  # within the 1.5x per-pair fill capacity
    # Uniform growth piles entirely into the headroom (last) slots, which
    # stretches the window -- relax the quality audit to isolate the
    # structural claim: no residual, no new stages, bytes conserved.
    block, stats = state.update(grown, quality_ratchet=2.0)
    assert stats["residual_fraction"] == pytest.approx(0.0, abs=1e-9)
    assert state._perms2d.shape[0] == n_before  # no structural change
    np.testing.assert_allclose(_block_matrix(block, 8), grown, rtol=1e-9)


def test_state_residual_appends_stages_and_conserves():
    t = _server_matrix()
    t[0, 1] = 0.0  # a pair the stored structure has no slot for
    state = _fresh_state(t)
    n_before = state._perms2d.shape[0]
    shifted = t.copy()
    shifted[0, 1] = 2e6  # new support: must come from a fresh decomposition
    block, stats = state.update(shifted)
    assert stats["residual_fraction"] > 0.0
    assert state._perms2d.shape[0] > n_before
    np.testing.assert_allclose(_block_matrix(block, 8), shifted, rtol=1e-9)
    # The appended structure keeps serving: a second update of the same
    # matrix now refills entirely in place.
    block2, stats2 = state.update(shifted)
    assert stats2["residual_fraction"] == pytest.approx(0.0, abs=1e-9)
    np.testing.assert_allclose(_block_matrix(block2, 8), shifted, rtol=1e-9)


def test_state_quality_audit_reported():
    t = _server_matrix()
    state = _fresh_state(t)
    _, stats = state.update(t)
    assert stats["n_stages"] > 0
    # Window sum over the exact lower bound: >= 1 by construction, and a
    # zero-drift refill reproduces the cold decomposition's quality.
    assert 1.0 <= stats["quality"] <= 1.10


def test_state_quality_ratchet_trips_on_window_stretch():
    t = _server_matrix()
    state = _fresh_state(t)
    # Residual-free but window-stretching: uniform growth lands in the
    # last (headroom) slot of every pair, so the audit -- not the residual
    # check -- must catch the degradation.
    block, stats = state.update(t * 1.3)
    assert block is None
    assert stats["tripped"] == "quality"
    assert stats["residual_fraction"] == pytest.approx(0.0, abs=1e-9)
    assert state.invalid


def test_state_residual_ratchet_trips_and_invalidates():
    t = _server_matrix()
    state = _fresh_state(t)
    alien = np.zeros_like(t)
    alien[2, 5] = 1e9  # overwhelmingly outside the stored slot capacity
    alien[5, 2] = 1e9
    block, stats = state.update(alien)
    assert block is None
    assert stats["tripped"] == "residual"
    assert state.invalid
    with pytest.raises(RuntimeError):
        state.update(t)


# -- trajectory fusion ------------------------------------------------------

def test_trajectory_quality_within_bar_over_50_steps():
    """The issue-7 acceptance bar: across a 50+ step drift sequence every
    warm plan validates and completes within 1.15x of exact synthesis."""
    flash = get_scheduler("flash")
    traj = _drift_trajectory(C, 52, seed=3)
    plans = flash.synthesize_trajectory(traj)
    assert len(plans) == len(traj)
    for w, plan in zip(traj, plans):
        plan.validate(w)
        warm_t = simulate(w, "flash", plan=plan).completion_time
        cold_t = simulate(w, "flash",
                          plan=flash.synthesize(w)).completion_time
        assert warm_t <= 1.15 * cold_t


def test_trajectory_repeats_share_plan_objects():
    flash = get_scheduler("flash")
    base = moe_workload(C, 1024, 256, top_k=2, seed=5)
    drift = _near_miss(base, seed=6)
    traj = [base, drift, base, drift]
    plans = flash.synthesize_trajectory(traj)
    assert plans[0] is plans[2]
    assert plans[1] is plans[3]
    assert plans[0] is not plans[1]


def test_trajectory_state_handoff_is_exclusive():
    """The carried DecompositionState chains head-to-head: exactly one
    plan (the newest fresh one) holds it; ancestors were claimed."""
    flash = get_scheduler("flash")
    traj = _drift_trajectory(C, 12, seed=9, repeat_p=0.0)
    plans = flash.synthesize_trajectory(traj)
    holders = [p for p in {id(p): p for p in plans}.values()
               if _STATE_ATTR in p.__dict__]
    assert len(holders) == 1
    assert holders[0] is plans[-1]


def test_seed_repair_state_attach_and_claim():
    flash = get_scheduler("flash")
    w = moe_workload(C, 1024, 256, top_k=2, seed=1)
    plan = flash.synthesize(w)
    assert _STATE_ATTR not in plan.__dict__  # cold plans carry no state
    flash.seed_repair_state(plan, w)
    assert isinstance(plan.__dict__[_STATE_ATTR], DecompositionState)
    w2 = _near_miss(w, seed=2)
    stats = {}
    warm = flash.try_repair_plan(plan, w2, stats=stats)
    assert warm is not None and stats["mode"] == "incremental"
    assert _STATE_ATTR not in plan.__dict__  # claimed by the successor
    assert _STATE_ATTR in warm.__dict__
    warm.validate(w2)


# -- RepairConfig knobs -----------------------------------------------------

def test_repair_config_selects_engine():
    flash = get_scheduler("flash")
    w = moe_workload(C, 1024, 256, top_k=2, seed=4)
    w2 = _near_miss(w, seed=5)
    prev = flash.synthesize(w)
    s_inc, s_one = {}, {}
    inc = flash.try_repair_plan(prev, w2, config=RepairConfig(),
                                stats=s_inc)
    one = flash.try_repair_plan(flash.synthesize(w), w2,
                                config=RepairConfig(incremental=False),
                                stats=s_one)
    assert s_inc["mode"] == "incremental" and s_one["mode"] == "oneshot"
    for plan in (inc, one):
        assert plan is not None
        plan.validate(w2)


def test_repair_config_residual_threshold_is_honored():
    flash = get_scheduler("flash")
    w = moe_workload(C, 1024, 256, top_k=2, seed=4)
    w2 = _near_miss(w, seed=5)
    for incremental in (True, False):
        stats = {}
        cfg = RepairConfig(max_residual_fraction=-1.0,
                           incremental=incremental)
        assert flash.try_repair_plan(flash.synthesize(w), w2, config=cfg,
                                     stats=stats) is None
        assert stats["tripped"] == "residual"


def test_incremental_repair_emits_block_plan_roundtrip():
    flash = get_scheduler("flash")
    w = moe_workload(C, 1024, 256, top_k=2, seed=4)
    warm = flash.try_repair_plan(flash.synthesize(w),
                                 _near_miss(w, seed=5))
    blocks = [p for p in warm.phases if isinstance(p, PermutationBlock)]
    assert len(blocks) == 1
    b = blocks[0]
    b2 = PermutationBlock.from_dict(b.to_dict())
    np.testing.assert_array_equal(b2.perms, b.perms)
    np.testing.assert_allclose(b2.sizes, np.asarray(b.sizes).reshape(-1))
    np.testing.assert_allclose(b2.sent, b.sent)
    # Per-stage views agree with the stacked arrays.
    first = next(iter(b.iter_stages()))
    assert first.size == pytest.approx(float(b.sizes[0]))


# -- serving integration ----------------------------------------------------

def test_server_repair_config_knob_and_residual_telemetry():
    cfg = RepairConfig(headroom=0.25)
    with PlanServer(workers=1, prewarm=False, repair_config=cfg) as srv:
        assert srv.repair_config is cfg
        client = PlanClient(srv)
        w = moe_workload(C, 1024, 256, top_k=2, seed=0)
        client.get_plan(w)
        answer = client.get_plan(_near_miss(w, seed=3))
        assert answer.source in ("warm", "cold")
        snap = srv.telemetry.snapshot()
        assert snap["repair"]["count"] >= 1
        assert sum(snap["repair"]["hist"].values()) == \
            snap["repair"]["count"]


def test_client_simulate_many_coalesces_repeats():
    with PlanServer(workers=1, prewarm=False) as srv:
        client = PlanClient(srv)
        w1 = moe_workload(C, 1024, 256, top_k=2, seed=0)
        w2 = _near_miss(w1, seed=3)
        out = client.simulate_many([w1, w2, w1, w2, w1])
        assert len(out) == 5
        assert client.counters["requests"] == 2
        assert client.counters["coalesced"] == 3
        # Coalesced repeats still execute per-workload.
        assert all(np.isfinite(r.completion_time) for r in out)
