"""Fault-tolerant runtime: resume-after-stop, straggler watchdog, and an
end-to-end mini training run whose loss must decrease."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.train import TrainOptions, make_train_step
from repro.models import build_model
from repro.optim import init_opt_state
from repro.runtime import Trainer, TrainerConfig


def _setup(arch="qwen3-0.6b", steps=12, seq=32, batch=4):
    cfg = smoke_config(arch)
    opts = TrainOptions(peak_lr=5e-3, warmup_steps=2, total_steps=steps)
    step_fn, _, _, _ = make_train_step(cfg, mesh=None, options=opts)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state0 = {"params": params, "opt": init_opt_state(params),
              "step": jnp.zeros((), jnp.int32)}
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch), cfg)

    def batches(step):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    return step_fn, state0, batches


def test_loss_decreases(tmp_path):
    step_fn, state0, batches = _setup(steps=30)
    tcfg = TrainerConfig(total_steps=30, ckpt_dir=str(tmp_path),
                         ckpt_every=100, log_every=5)
    trainer = Trainer(tcfg, step_fn, lambda: state0, batches)
    result = trainer.run()
    # compare early vs late loss from the metrics log
    import json, os
    recs = [json.loads(l) for l in
            open(os.path.join(str(tmp_path), "metrics.jsonl"))]
    assert recs[-1]["loss"] < recs[0]["loss"] * 0.9, (
        recs[0]["loss"], recs[-1]["loss"])


def test_resume_continues_from_checkpoint(tmp_path):
    step_fn, state0, batches = _setup(steps=8)
    ckpt = str(tmp_path)
    t1 = Trainer(TrainerConfig(total_steps=4, ckpt_dir=ckpt, ckpt_every=2),
                 step_fn, lambda: state0, batches)
    r1 = t1.run()
    assert r1["stopped_at"] == 4
    # second trainer resumes at step 4, runs to 8
    seen = []

    def batches2(step):
        seen.append(step)
        return batches(step)

    t2 = Trainer(TrainerConfig(total_steps=8, ckpt_dir=ckpt, ckpt_every=2),
                 step_fn, lambda: state0, batches2)
    r2 = t2.run()
    assert r2["stopped_at"] == 8
    assert min(seen) == 4, f"resume did not skip completed steps: {seen}"
    assert int(r2["state"]["step"]) == 8


def test_resume_bitwise_identical(tmp_path):
    """restart mid-run == uninterrupted run (determinism contract)."""
    step_fn, state0, batches = _setup(steps=6)
    # uninterrupted
    ckpt_a = str(tmp_path / "a")
    ta = Trainer(TrainerConfig(total_steps=6, ckpt_dir=ckpt_a,
                               ckpt_every=100), step_fn, lambda: state0,
                 batches)
    ra = ta.run()
    # interrupted at 3 + resumed
    ckpt_b = str(tmp_path / "b")
    tb1 = Trainer(TrainerConfig(total_steps=3, ckpt_dir=ckpt_b,
                                ckpt_every=3), step_fn, lambda: state0,
                  batches)
    tb1.run()
    tb2 = Trainer(TrainerConfig(total_steps=6, ckpt_dir=ckpt_b,
                                ckpt_every=100), step_fn, lambda: state0,
                  batches)
    rb = tb2.run()
    wa = jax.tree.leaves(ra["state"]["params"])
    wb = jax.tree.leaves(rb["state"]["params"])
    for a, b in zip(wa, wb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_preemption_checkpoint(tmp_path):
    """SIGTERM-style preemption saves at the step boundary and reports."""
    step_fn, state0, batches = _setup(steps=20)
    trainer = Trainer(
        TrainerConfig(total_steps=20, ckpt_dir=str(tmp_path),
                      ckpt_every=1000),
        step_fn, lambda: state0, batches)

    orig = trainer.train_step

    def step_then_preempt(state, batch):
        out = orig(state, batch)
        if int(state["step"]) == 2:
            trainer._preempted = True  # simulate SIGTERM delivery
        return out

    trainer.train_step = step_then_preempt
    result = trainer.run()
    assert result["preempted"]
    assert result["stopped_at"] == 3
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 3


def test_straggler_watchdog():
    events = []
    trainer = Trainer(
        TrainerConfig(total_steps=1, ckpt_dir="/tmp/unused_watchdog"),
        train_step=None, init_state=None, batches=None,
        straggler_cb=lambda s, dt, med: events.append((s, dt, med)))
    for i in range(20):
        trainer._watch_straggler(i, 0.1)
    trainer._watch_straggler(20, 1.0)  # 10x median
    assert len(events) == 1 and events[0][0] == 20
