"""Structural dry-run on a small carved-out mesh (subprocess, 16 devices).

The full 512-device 40-cell sweep is the deliverable artifact (see
EXPERIMENTS.md); this test keeps the lowering path honest in CI time.
"""

import pytest


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-0.6b", "train_4k"),
    ("mixtral-8x7b", "train_4k"),
    ("xlstm-125m", "decode_32k"),
])
def test_small_mesh_lower_compile(subproc, arch, shape):
    out = subproc(f"""
import os
os.environ["TF_CPP_MIN_LOG_LEVEL"] = "3"
import dataclasses as dc
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
import repro.launch.mesh as mesh_mod
mesh_mod.make_production_mesh = \\
    lambda multi_pod=False: make_mesh((2, 2, 4), ("pod", "data", "model"))
from repro.launch.dryrun import run_cell
import repro.launch.dryrun as dr

cfg = get_config("{arch}")
import repro.configs.registry as reg
small = dc.replace(cfg, n_layers=2, scan_layers=False, d_model=256,
                   d_ff=512, n_heads=8, n_kv_heads=4, head_dim=32,
                   vocab=3200)
if small.moe:
    from repro.configs.registry import MoESpec
    small = dc.replace(small, moe=MoESpec(num_experts=4, top_k=2))
if small.block_pattern:
    small = dc.replace(small, block_pattern=("m", "s"))
reg._REGISTRY["{arch}"] = lambda: small

shape = dc.replace(SHAPES["{shape}"], global_batch=16,
                   seq_len=min(SHAPES["{shape}"].seq_len, 512))
dr.SHAPES = dict(SHAPES); dr.SHAPES["{shape}"] = shape

res = run_cell("{arch}", "{shape}", "multi")
assert res["status"] == "ok", res.get("error")
assert res["flops_per_chip"] > 0
assert res["collectives"]["count"] > 0
assert res["memory"]["temp_bytes"] is not None
print("CELL_OK", res["roofline"]["dominant"])
""", n_devices=16, timeout=600)
    assert "CELL_OK" in out
