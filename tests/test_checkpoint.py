"""Checkpoint substrate: atomicity, roundtrip, GC, resume semantics."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    available_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.ones((3,)), jnp.zeros((2, 2), jnp.bfloat16)],
    }


def test_roundtrip(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    save_checkpoint(root, 7, tree)
    restored, step = restore_checkpoint(root, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


import jax  # noqa: E402  (used in tree comparisons above)


def test_latest_and_gc(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(root, s, tree, keep_last=3)
    assert available_steps(root) == [3, 4, 5]
    assert latest_step(root) == 5


def test_torn_save_ignored(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    save_checkpoint(root, 1, tree)
    # simulate a torn save: directory without the sentinel
    torn = os.path.join(root, "step_000000002")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        f.write("{}")
    assert latest_step(root) == 1
    restored, step = restore_checkpoint(root, tree)
    assert step == 1


def test_shape_mismatch_rejected(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 1, _tree())
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        restore_checkpoint(root, bad)


def test_dtype_restored_via_target(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    save_checkpoint(root, 3, tree)
    restored, _ = restore_checkpoint(root, tree)
    assert restored["nested"][1].dtype == jnp.bfloat16


def test_failed_save_surfaces_original_error(tmp_path, monkeypatch):
    """A mid-save failure propagates the genuine exception (issue 9: no
    broad except swallowing context) and leaves no staging litter."""
    import repro.checkpoint.checkpoint as ckpt

    root = str(tmp_path)
    boom = RuntimeError("disk on fire")

    def exploding_savez(*a, **k):
        raise boom

    monkeypatch.setattr(ckpt.np, "savez", exploding_savez)
    with pytest.raises(RuntimeError) as excinfo:
        save_checkpoint(root, 1, _tree())
    assert excinfo.value is boom
    leftovers = [d for d in os.listdir(root) if d.startswith(".tmp_save_")]
    assert leftovers == []
    assert available_steps(root) == []


def test_keyboard_interrupt_propagates_and_cleans(tmp_path, monkeypatch):
    """KeyboardInterrupt mid-save must reach the caller (the old
    `except BaseException` re-raised it, but the committed-flag pattern
    must preserve that) while still removing the staging dir."""
    import repro.checkpoint.checkpoint as ckpt

    root = str(tmp_path)

    def interrupted_savez(*a, **k):
        raise KeyboardInterrupt

    monkeypatch.setattr(ckpt.np, "savez", interrupted_savez)
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(root, 1, _tree())
    leftovers = [d for d in os.listdir(root) if d.startswith(".tmp_save_")]
    assert leftovers == []


def test_successful_save_keeps_no_staging(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 3, _tree())
    assert available_steps(root) == [3]
    leftovers = [d for d in os.listdir(root) if d.startswith(".tmp_save_")]
    assert leftovers == []
