"""Checkpoint substrate: atomicity, roundtrip, GC, resume semantics."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    available_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.ones((3,)), jnp.zeros((2, 2), jnp.bfloat16)],
    }


def test_roundtrip(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    save_checkpoint(root, 7, tree)
    restored, step = restore_checkpoint(root, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


import jax  # noqa: E402  (used in tree comparisons above)


def test_latest_and_gc(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(root, s, tree, keep_last=3)
    assert available_steps(root) == [3, 4, 5]
    assert latest_step(root) == 5


def test_torn_save_ignored(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    save_checkpoint(root, 1, tree)
    # simulate a torn save: directory without the sentinel
    torn = os.path.join(root, "step_000000002")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        f.write("{}")
    assert latest_step(root) == 1
    restored, step = restore_checkpoint(root, tree)
    assert step == 1


def test_shape_mismatch_rejected(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 1, _tree())
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        restore_checkpoint(root, bad)


def test_dtype_restored_via_target(tmp_path):
    root = str(tmp_path)
    tree = _tree()
    save_checkpoint(root, 3, tree)
    restored, _ = restore_checkpoint(root, tree)
    assert restored["nested"][1].dtype == jnp.bfloat16
