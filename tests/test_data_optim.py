"""Data pipeline determinism + optimizer correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.optim import (
    AdamWConfig,
    adamw_update,
    cosine_schedule,
    global_norm,
    init_opt_state,
)


def test_data_deterministic():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=3)
    a = SyntheticLM(cfg).batch(5)
    b = SyntheticLM(cfg).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(SyntheticLM(cfg).batch(6)["tokens"],
                              a["tokens"])


def test_data_host_sharding_partitions_batch():
    full = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=8,
                                  seed=1)).batch(0)
    h0 = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=8,
                                seed=1, n_hosts=2, host_id=0)).batch(0)
    h1 = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=8,
                                seed=1, n_hosts=2, host_id=1)).batch(0)
    assert h0["tokens"].shape == (4, 16)
    assert h1["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    del full  # host batches are independent streams, not slices


def test_data_labels_are_shifted_tokens():
    b = SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=2,
                               seed=0)).batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_data_induction_structure():
    """Second half repeats the first half (learnable copy structure)."""
    b = SyntheticLM(DataConfig(vocab=1024, seq_len=32, global_batch=2,
                               seed=0)).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 16:], b["tokens"][:, :16])


def test_frontend_extras():
    mc = smoke_config("internvl2-1b")
    b = SyntheticLM(DataConfig(vocab=mc.vocab, seq_len=16, global_batch=2),
                    mc).batch(0)
    assert b["patch_embeds"].shape == (2, mc.frontend_len, mc.d_model)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=None)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
        params, state, _ = adamw_update(grads, state, params,
                                        jnp.asarray(0.05), cfg)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clip():
    params = {"x": jnp.zeros(4)}
    state = init_opt_state(params)
    grads = {"x": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw_update(grads, state, params, jnp.asarray(0.1),
                               AdamWConfig(clip_norm=1.0))
    assert float(gnorm) == pytest.approx(200.0)  # reported pre-clip


def test_weight_decay_decoupled():
    params = {"x": jnp.ones(()) * 10.0}
    state = init_opt_state(params)
    grads = {"x": jnp.zeros(())}
    new_params, _, _ = adamw_update(
        grads, state, params, jnp.asarray(0.1),
        AdamWConfig(weight_decay=0.1, clip_norm=None))
    assert float(new_params["x"]) == pytest.approx(10.0 - 0.1 * 0.1 * 10.0)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(5)) == pytest.approx(5e-4)


def test_global_norm():
    t = {"a": jnp.ones((2, 2)), "b": jnp.ones((5,))}
    assert float(global_norm(t)) == pytest.approx(3.0)
