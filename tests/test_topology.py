"""Topology layer tests: fabric model, link-level executor parity and
heterogeneous behavior, topology-keyed PlanCache, workload validation, and
vectorized generator equivalence."""

import json

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    Plan,
    PlanCache,
    PlanValidationError,
    ServerFabric,
    Topology,
    available_schedulers,
    balanced_workload,
    execute_plan,
    get_scheduler,
    moe_workload,
    random_workload,
    simulate,
    skewed_workload,
    traffic_fingerprint,
)
from repro.core.traffic import Workload


def _homo(n=4, m=8, **kw):
    return Topology.homogeneous(n, m, **kw)


# -- fabric model ----------------------------------------------------------

@pytest.mark.parametrize("spec", [
    ClusterSpec(4, 8),
    ClusterSpec(2, 4, intra_topology="ring"),
    ClusterSpec(8, 2, b_intra=900e9 / 8, intra_topology="switch"),
    ClusterSpec(4, 8, alpha=0.0, b_inter=50e9),
])
def test_from_cluster_round_trips(spec):
    topo = Topology.from_cluster(spec)
    assert topo.cluster_view() == spec
    assert spec.to_topology() == topo
    assert topo.is_homogeneous
    assert (topo.n_servers, topo.m_gpus, topo.n_gpus) == \
        (spec.n_servers, spec.m_gpus, spec.n_gpus)


def test_derived_capacities():
    topo = _homo(4, 8, b_inter=12.5e9)
    np.testing.assert_allclose(topo.send_caps, 8 * 12.5e9)
    assert topo.spine_bandwidth == pytest.approx(4 * 8 * 12.5e9)
    assert topo.with_oversubscription(4.0).spine_bandwidth == \
        pytest.approx(8 * 12.5e9)
    c = ClusterSpec(4, 8)
    np.testing.assert_allclose(Topology.from_cluster(c).intra_a2a_bw,
                               c.intra_a2a_bandwidth())
    np.testing.assert_allclose(Topology.from_cluster(c).intra_path_bw,
                               c.intra_path_bandwidth())


def test_scenario_constructors():
    topo = _homo()
    deg = topo.degrade_nic(2, 3, 0.25)
    assert deg.nic_bw[2, 3] == pytest.approx(0.25 * topo.nic_bw[2, 3])
    assert not deg.is_homogeneous
    dead = topo.fail_nic(1, 0)
    assert dead.nic_bw[1, 0] == 0.0
    mixed = topo.with_server_nic_speeds([1e9, 2e9, 3e9, 4e9])
    np.testing.assert_allclose(mixed.nic_bw[3], 4e9)
    assert not topo.with_oversubscription(2.0).is_homogeneous


def test_topology_validation():
    fab = ServerFabric()
    with pytest.raises(ValueError, match="at least one server"):
        Topology(fabrics=(), nic_bw=np.zeros((0, 8)))
    with pytest.raises(ValueError, match="nic_bw shape"):
        Topology(fabrics=(fab,) * 2, nic_bw=np.ones((2, 4)))
    with pytest.raises(ValueError, match="GPU counts"):
        Topology(fabrics=(fab, ServerFabric(m_gpus=4)),
                 nic_bw=np.ones((2, 8)))
    with pytest.raises(ValueError, match=">= 0"):
        Topology(fabrics=(fab,), nic_bw=-np.ones((1, 8)))
    with pytest.raises(ValueError, match="oversubscription"):
        _homo().with_oversubscription(0.5)
    with pytest.raises(ValueError, match="degrade factor"):
        _homo().degrade_nic(0, 0, 1.5)


def test_fingerprint_covers_every_resource():
    base = _homo()
    prints = {
        base.fingerprint(),
        base.degrade_nic(0, 0, 0.5).fingerprint(),
        base.with_oversubscription(2.0).fingerprint(),
        Topology(fabrics=(ServerFabric(intra_topology="ring"),) * 4,
                 nic_bw=base.nic_bw).fingerprint(),
        Topology(fabrics=base.fabrics, nic_bw=base.nic_bw,
                 alpha=0.0).fingerprint(),
    }
    assert len(prints) == 5
    # Content-equal topologies agree (fingerprint is deterministic).
    assert _homo().fingerprint() == base.fingerprint()
    assert _homo() == base


def test_nic_shares_properties():
    topo = _homo().degrade_nic(2, 3, 0.25).fail_nic(1, 0)
    shares = topo.nic_shares()
    assert shares.shape == (4, 4, 8)
    np.testing.assert_allclose(shares.sum(axis=-1), 1.0)
    # Failed rail carries nothing for any pair touching server 1.
    assert shares[1, 0, 0] == 0.0 and shares[0, 1, 0] == 0.0
    # Degraded rail carries a sub-uniform share.
    assert shares[2, 0, 3] < 1.0 / 8
    # Homogeneous: exactly uniform.
    np.testing.assert_array_equal(_homo().nic_shares(), 1.0 / 8)


def test_serialization_round_trip():
    topo = _homo().degrade_nic(0, 1, 0.3).with_oversubscription(2.0)
    wire = json.dumps(topo.to_dict())
    topo2 = Topology.from_dict(json.loads(wire))
    assert topo2 == topo
    assert topo2.fingerprint() == topo.fingerprint()
    assert Topology.from_dict(None) is None


# -- link-level executor: homogeneous parity -------------------------------

@pytest.mark.parametrize("algo", sorted(available_schedulers()))
@pytest.mark.parametrize("kind", ("balanced", "random", "skewed", "moe"))
def test_explicit_topology_matches_scalar_path(algo, kind):
    """Workloads on an explicit homogeneous Topology time identically to
    the ClusterSpec scalar path (<= 1e-9 relative error)."""
    spec = ClusterSpec(4, 8)
    make = {
        "balanced": lambda c: balanced_workload(c, 4 << 20),
        "random": lambda c: random_workload(c, 4 << 20, seed=1),
        "skewed": lambda c: skewed_workload(c, 4 << 20, 1.2, seed=2),
        "moe": lambda c: moe_workload(c, 8192, 4096, top_k=2, seed=3),
    }[kind]
    scalar = simulate(make(spec), algo).completion_time
    link = simulate(make(Topology.from_cluster(spec)), algo).completion_time
    assert abs(link - scalar) <= 1e-9 * scalar


def test_oversubscription_one_is_inert():
    topo = _homo()
    w = random_workload(topo, 8 << 20, seed=0)
    w_o = random_workload(topo.with_oversubscription(1.0), 8 << 20, seed=0)
    for algo in available_schedulers():
        assert simulate(w_o, algo).completion_time == \
            simulate(w, algo).completion_time


# -- heterogeneous behavior ------------------------------------------------

def _aware_and_blind(topo, algo="flash", mean=16 << 20):
    """(aware, blind) results: synthesized on ``topo`` vs synthesized on
    the homogeneous fabric and executed on ``topo``."""
    w = random_workload(topo, mean, seed=0)
    aware = simulate(w, algo)
    homo = _homo(topo.n_servers, topo.m_gpus)
    blind_plan = get_scheduler(algo).synthesize(
        random_workload(homo, mean, seed=0))
    blind = simulate(w, algo, plan=blind_plan, topology=topo)
    return aware, blind


def test_degraded_nic_aware_strictly_beats_blind():
    """Acceptance: topology-aware FLASH strictly beats the topology-blind
    schedule on a degraded-NIC scenario."""
    aware, blind = _aware_and_blind(_homo().degrade_nic(2, 3, 0.25))
    assert aware.completion_time < blind.completion_time
    assert blind.completion_time > 3.0 * aware.completion_time


def test_degradation_sweep_monotone():
    times = []
    for factor in (1.0, 0.5, 0.25, 0.1):
        topo = _homo().degrade_nic(2, 3, factor)
        times.append(simulate(random_workload(topo, 16 << 20, seed=0),
                              "flash").completion_time)
    assert times == sorted(times)
    # Aware degradation is graceful: 10x slower NIC costs < 15% end-to-end.
    assert times[-1] < 1.15 * times[0]


def test_failed_nic_aware_routes_around():
    aware, blind = _aware_and_blind(_homo().fail_nic(1, 0))
    assert np.isfinite(aware.completion_time)
    assert blind.completion_time == np.inf


def test_mixed_rail_speeds_aware_beats_blind():
    rails = _homo().with_nic_bw(
        np.tile([50e9] * 4 + [12.5e9] * 4, (4, 1)))
    aware, blind = _aware_and_blind(rails)
    assert blind.completion_time > 2.0 * aware.completion_time


def test_aware_flash_stays_near_optimal_on_degraded_fabric():
    topo = _homo().degrade_nic(2, 3, 0.25)
    w = random_workload(topo, 16 << 20, seed=0)
    assert simulate(w, "flash").algbw >= 0.9 * simulate(w, "optimal").algbw


def test_optimal_bound_sees_per_server_capacity():
    """A degraded server raises the bound; other servers' don't mask it."""
    w_h = random_workload(_homo(), 16 << 20, seed=0)
    slow = _homo().with_server_nic_speeds([12.5e9, 12.5e9, 12.5e9, 6.25e9])
    w_s = random_workload(slow, 16 << 20, seed=0)
    assert simulate(w_s, "optimal").completion_time > \
        simulate(w_h, "optimal").completion_time


def test_oversubscription_binds_every_scheduler():
    for algo in ("flash", "hierarchical", "spreadout", "optimal"):
        t1 = simulate(random_workload(_homo(), 16 << 20, seed=0),
                      algo).completion_time
        t4 = simulate(
            random_workload(_homo().with_oversubscription(4.0),
                            16 << 20, seed=0), algo).completion_time
        # Schedulers whose straggler term already dominates (spreadout)
        # feel the spine less; everyone must still slow down materially.
        assert t4 > 1.5 * t1, algo


def test_hierarchical_cannot_rebalance_degraded_rail():
    """The rail-aligned baseline is stuck with its max-loaded rail; FLASH
    rebalances around it (the paper's skew argument, now for topology)."""
    topo = _homo().degrade_nic(2, 3, 0.1)
    w = random_workload(topo, 16 << 20, seed=0)
    assert simulate(w, "flash").completion_time < \
        simulate(w, "hierarchical").completion_time


def test_optimal_completion_time_matches_simulate_on_hetero():
    from repro.core import optimal_completion_time

    for topo in (_homo(), _homo().degrade_nic(2, 3, 0.1),
                 _homo().with_oversubscription(4.0)):
        w = random_workload(topo, 16 << 20, seed=0)
        assert optimal_completion_time(w) == pytest.approx(
            simulate(w, "optimal").completion_time, rel=1e-12)


def test_topology_snapshots_caller_array():
    """nic_bw is copied and frozen: mutating the source array must not
    change the fingerprint that keys PlanCache entries."""
    arr = np.full((4, 8), 12.5e9)
    topo = _homo().with_nic_bw(arr)
    fp = topo.fingerprint()
    arr[0, 0] = 1.0
    assert topo.fingerprint() == fp
    with pytest.raises(ValueError, match="read-only"):
        topo.nic_bw[0, 0] = 1.0


def test_all_nics_down_yields_inf_not_crash():
    topo = _homo(2, 2).with_nic_bw(np.zeros((2, 2)))
    w = balanced_workload(topo, 1 << 20)
    for algo in available_schedulers():
        assert simulate(w, algo).completion_time == np.inf, algo


def test_homogeneous_flash_plan_omits_dense_shares():
    w = random_workload(_homo(), 1 << 20, seed=0)
    assert get_scheduler("flash").synthesize(w).nic_shares is None
    w_het = random_workload(_homo().degrade_nic(0, 0, 0.5), 1 << 20, seed=0)
    assert get_scheduler("flash").synthesize(w_het).nic_shares is not None


def test_execute_plan_topology_shape_mismatch():
    w = random_workload(_homo(), 1 << 20, seed=0)
    plan = get_scheduler("flash").synthesize(w)
    with pytest.raises(ValueError, match="shape"):
        execute_plan(plan, w, topology=_homo(2, 4))


# -- plans carry their topology --------------------------------------------

def test_plan_carries_topology_and_round_trips():
    topo = _homo().degrade_nic(0, 1, 0.5)
    w = random_workload(topo, 4 << 20, seed=3)
    plan = get_scheduler("flash").synthesize(w)
    assert plan.topology == topo
    assert plan.nic_shares is not None
    plan2 = Plan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert plan2.topology == topo
    r1 = execute_plan(plan, w)
    r2 = execute_plan(plan2, w)
    assert r1.completion_time == r2.completion_time
    assert r1.breakdown == r2.breakdown


def test_validate_rejects_plan_for_different_topology():
    w_h = random_workload(_homo(), 4 << 20, seed=3)
    plan = get_scheduler("flash").synthesize(w_h)
    plan.validate(w_h)  # own fabric: fine
    w_d = random_workload(_homo().degrade_nic(0, 0, 0.5), 4 << 20, seed=3)
    with pytest.raises(PlanValidationError, match="different topology"):
        plan.validate(w_d)


def test_simulate_rejects_stale_plan_without_override():
    """Replaying a plan after a fabric change must be loud: either
    re-synthesize, or opt in to blindness with an explicit topology=."""
    w_h = random_workload(_homo(), 4 << 20, seed=3)
    plan = get_scheduler("flash").synthesize(w_h)
    deg = _homo().degrade_nic(2, 3, 0.5)
    w_d = random_workload(deg, 4 << 20, seed=3)
    with pytest.raises(ValueError, match="different fabric"):
        simulate(w_d, "flash", plan=plan)
    # The explicit override is the sanctioned blindness experiment.
    blind = simulate(w_d, "flash", plan=plan, topology=deg)
    assert blind.completion_time > simulate(w_d, "flash").completion_time


# -- PlanCache: topology keying, LRU order, counters -----------------------

def test_plan_cache_misses_on_different_topology():
    """The same traffic matrix replayed on a different fabric must miss --
    a stale plan is never served."""
    cache = PlanCache()
    homo = _homo()
    deg = homo.degrade_nic(2, 3, 0.25)
    r_h = simulate(random_workload(homo, 4 << 20, seed=0), "flash",
                   cache=cache)
    assert (cache.hits, cache.misses) == (0, 1)
    r_d = simulate(random_workload(deg, 4 << 20, seed=0), "flash",
                   cache=cache)
    assert (cache.hits, cache.misses) == (0, 2)
    assert r_d.completion_time != r_h.completion_time
    # Replays on each fabric now hit, and serve the right plan.
    assert simulate(random_workload(deg, 4 << 20, seed=0), "flash",
                    cache=cache).completion_time == r_d.completion_time
    assert simulate(random_workload(homo, 4 << 20, seed=0), "flash",
                    cache=cache).completion_time == r_h.completion_time
    assert (cache.hits, cache.misses) == (2, 2)


def test_traffic_fingerprint_includes_topology():
    w_h = random_workload(_homo(), 1 << 20, seed=0)
    w_d = random_workload(_homo().degrade_nic(0, 0, 0.5), 1 << 20, seed=0)
    np.testing.assert_array_equal(w_h.matrix, w_d.matrix)
    assert traffic_fingerprint(w_h, "flash") != traffic_fingerprint(
        w_d, "flash")


def test_plan_cache_lru_eviction_order():
    """Eviction follows recency of *use*, not insertion order."""
    cache = PlanCache(capacity=2)
    ws = [random_workload(_homo(), 1 << 20, seed=s) for s in (0, 1, 2)]
    keys = [traffic_fingerprint(w, "flash") for w in ws]
    simulate(ws[0], "flash", cache=cache)          # store A
    simulate(ws[1], "flash", cache=cache)          # store B
    simulate(ws[0], "flash", cache=cache)          # touch A -> B is now LRU
    assert (cache.hits, cache.misses) == (1, 2)
    simulate(ws[2], "flash", cache=cache)          # store C -> evicts B
    assert len(cache) == 2
    assert cache.lookup(keys[0]) is not None       # A survived (was touched)
    assert cache.lookup(keys[1]) is None           # B evicted
    assert cache.lookup(keys[2]) is not None
    assert (cache.hits, cache.misses) == (3, 4)


def test_plan_cache_counters_reset_on_clear():
    cache = PlanCache()
    simulate(random_workload(_homo(), 1 << 20, seed=0), "flash", cache=cache)
    assert (cache.hits, cache.misses, len(cache)) == (0, 1, 1)
    assert cache.hit_rate == 0.0
    simulate(random_workload(_homo(), 1 << 20, seed=0), "flash", cache=cache)
    assert cache.hit_rate == pytest.approx(0.5)
    cache.clear()
    assert (cache.hits, cache.misses, len(cache)) == (0, 0, 0)


# -- workload validation ---------------------------------------------------

def test_workload_rejects_negative_entries():
    c = ClusterSpec(2, 2)
    m = np.ones((4, 4))
    np.fill_diagonal(m, 0.0)
    m[1, 2] = -5.0
    with pytest.raises(ValueError, match="negative"):
        Workload(c, m)


def test_workload_rejects_self_traffic():
    c = ClusterSpec(2, 2)
    m = np.ones((4, 4))
    np.fill_diagonal(m, 0.0)
    m[3, 3] = 7.0
    with pytest.raises(ValueError, match="diagonal"):
        Workload(c, m)


def test_workload_rejects_mismatched_topology():
    c = ClusterSpec(2, 2)
    m = np.zeros((4, 4))
    with pytest.raises(ValueError, match="topology shape"):
        Workload(c, m, topology=_homo(4, 8))


def test_workload_shape_check_still_first_class():
    with pytest.raises(ValueError, match="matrix shape"):
        Workload(ClusterSpec(2, 2), np.zeros((3, 3)))


# -- vectorized generators match the reference loops -----------------------

def _skewed_reference(cluster, mean_size, zipf_s, seed):
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    n_pairs = n * (n - 1)
    ranks = np.arange(1, n_pairs + 1, dtype=np.float64)
    sizes = ranks ** (-zipf_s)
    sizes *= (mean_size * n_pairs) / sizes.sum()
    rng.shuffle(sizes)
    w = np.zeros((n, n))
    idx = [(i, j) for i in range(n) for j in range(n) if i != j]
    for (i, j), v in zip(idx, sizes):
        w[i, j] = v
    return w


def _moe_reference(cluster, tokens, bpt, top_k, skew, seed, n_experts):
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    e = n_experts or n
    popularity = rng.dirichlet(np.full(e, skew))
    w = np.zeros((n, n))
    for src in range(n):
        counts = np.zeros(e)
        for _ in range(top_k):
            counts += rng.multinomial(tokens, popularity)
        for expert, c in enumerate(counts):
            dst = expert % n
            if dst != src and c > 0:
                w[src, dst] += c * bpt
    return w


@pytest.mark.parametrize("seed", (0, 3, 11))
def test_vectorized_skewed_matches_reference(seed):
    c = ClusterSpec(3, 4)
    got = skewed_workload(c, 4 << 20, 1.2, seed=seed).matrix
    np.testing.assert_array_equal(
        got, _skewed_reference(c, 4 << 20, 1.2, seed))


@pytest.mark.parametrize("seed", (0, 3, 11))
@pytest.mark.parametrize("n_experts", (None, 24))
def test_vectorized_moe_matches_reference(seed, n_experts):
    c = ClusterSpec(3, 4)
    got = moe_workload(c, 512, 4096, top_k=2, seed=seed,
                       n_experts=n_experts).matrix
    np.testing.assert_array_equal(
        got, _moe_reference(c, 512, 4096, 2, 0.6, seed, n_experts))


# -- comm-layer impl resolution --------------------------------------------

def test_resolve_all_to_all_auto_reads_topology():
    from repro.comm.all_to_all import (
        direct_all_to_all,
        flash_all_to_all,
        resolve_all_to_all,
    )

    het = _homo().degrade_nic(0, 0, 0.5)
    aware = resolve_all_to_all(slow_axis="pod", ep_axes=("pod", "data"),
                               impl="auto", topology=het)
    assert aware.func is flash_all_to_all
    uniform = resolve_all_to_all(slow_axis="pod", ep_axes=("pod", "data"),
                                 impl="auto", topology=_homo())
    assert uniform.func is direct_all_to_all
    no_info = resolve_all_to_all(slow_axis="pod", ep_axes=("pod", "data"),
                                 impl="auto")
    assert no_info.func is direct_all_to_all

    # The DistContext path threads its topology attribute through.
    class _Dist:
        slow_axis = "pod"
        ep_axes = ("pod", "data")
        a2a_impl = "auto"
        topology = het

    assert resolve_all_to_all(_Dist()).func is flash_all_to_all


# -- fabric elasticity: degrade / fail / recover ---------------------------
#
# PR 8's fabric-event pipeline leans on three topology-model guarantees:
# every scenario constructor changes the fingerprint (plans keyed on the
# old fabric can never be served as the new one), recovery is an exact
# inverse (nominal rates survive any chain of degradations and a JSON
# round trip), and a fully-dead server degrades the *numbers* (inf
# completion) but never the *machinery* (plans still validate).

def test_degrade_zero_equals_fail():
    t = _homo()
    assert t.degrade_nic(1, 3, 0.0) == t.fail_nic(1, 3)
    assert (t.degrade_nic(1, 3, 0.0).fingerprint()
            == t.fail_nic(1, 3).fingerprint())
    assert t.degrade_server(2, 0.0) == t.fail_server(2)


def test_every_scenario_constructor_changes_fingerprint():
    t = _homo()
    fp = t.fingerprint()
    variants = [
        t.degrade_nic(0, 0, 0.5),
        t.degrade_nic(0, 0, 0.5, direction="up"),
        t.degrade_nic(0, 0, 0.5, direction="down"),
        t.fail_nic(0, 0),
        t.degrade_server(1, 0.25),
        t.fail_server(1),
    ]
    fps = [v.fingerprint() for v in variants]
    assert all(f != fp for f in fps)
    # up-only and down-only degradations hit different planes: distinct.
    assert len(set(fps)) == len(fps)


def test_recover_nic_is_exact_inverse():
    t = _homo()
    assert t.fail_nic(0, 0).recover_nic(0, 0) == t
    assert t.fail_nic(0, 0).recover_nic(0, 0).fingerprint() == t.fingerprint()
    # Chained damage, server-wide recovery.
    hurt = t.fail_nic(0, 0).degrade_nic(0, 1, 0.5).degrade_server(
        0, 0.9, direction="down")
    assert hurt.recover_server(0) == t
    # Recovering an undamaged fabric is the identity (no nominal baseline).
    assert t.recover_nic(2, 1) is t


def test_asymmetric_direction_forks_planes():
    t = _homo()
    up = t.degrade_nic(2, 0, 0.25, direction="up")
    # Send plane degraded, receive plane untouched.
    assert up.nic_tx[2, 0] == pytest.approx(0.25 * t.nic_bw[2, 0])
    assert up.nic_rx[2, 0] == pytest.approx(t.nic_bw[2, 0])
    assert not up.is_symmetric
    down = t.degrade_nic(2, 0, 0.25, direction="down")
    assert down.nic_tx[2, 0] == pytest.approx(t.nic_bw[2, 0])
    assert down.nic_rx[2, 0] == pytest.approx(0.25 * t.nic_bw[2, 0])
    # pair_capacity is limited by min(tx[src], rx[dst]) per rail.
    assert up.pair_capacity()[2, 0] < t.pair_capacity()[2, 0]
    assert up.pair_capacity()[0, 2] == pytest.approx(
        t.pair_capacity()[0, 2])
    # Symmetric fabrics share the plane array (zero-cost accessors).
    assert t.nic_tx is t.nic_rx
    # Recovery collapses the fork back to a symmetric fabric.
    assert up.recover_nic(2, 0) == t
    assert up.recover_nic(2, 0).is_symmetric


def test_degraded_topology_json_round_trip_preserves_recovery():
    t = _homo()
    hurt = t.fail_nic(0, 1).degrade_nic(1, 0, 0.5, direction="down")
    back = Topology.from_dict(json.loads(json.dumps(hurt.to_dict())))
    assert back == hurt
    assert back.fingerprint() == hurt.fingerprint()
    # The nominal baseline survives serde: recovery still works.
    assert back.recover_server(0).recover_server(1) == t


def test_spine_bandwidth_uses_slower_plane():
    t = _homo()
    down = t.degrade_server(0, 0.5, direction="down")
    assert down.spine_bandwidth == pytest.approx(
        min(down.nic_tx.sum(), down.nic_rx.sum()) / down.oversubscription)
    assert down.spine_bandwidth < t.spine_bandwidth


def test_dead_server_inf_completion_but_plans_validate():
    t = _homo(4, 2)
    dead = t
    for g in range(t.m_gpus):
        dead = dead.fail_nic(1, g)
    assert np.all(dead.nic_bw[1] == 0.0)
    base = balanced_workload(ClusterSpec(4, 2), 1 << 20)
    w = Workload(base.cluster, base.matrix, dead)
    from repro.core import optimal_completion_time
    assert optimal_completion_time(w) == np.inf
    for algo in available_schedulers():
        plan = get_scheduler(algo).synthesize(w)
        plan.validate(w)  # machinery intact: no exception
        assert execute_plan(plan, w).completion_time == np.inf, algo
    # Recovery brings completion back to finite.
    healed = Workload(w.cluster, w.matrix, dead.recover_server(1))
    assert np.isfinite(simulate(healed, "flash").completion_time)
