"""Distributed MoE island == single-device reference (the oracle check)."""


def test_moe_island_matches_local(subproc):
    """EP over (pod, data) with the flash 3-phase schedule vs dist=None."""
    out = subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.models.dist import DistContext
from repro.models.moe import init_moe, moe_apply
from repro.launch.mesh import make_mesh

cfg = dataclasses.replace(
    smoke_config("megatron-moe-32e"), compute_dtype="float32")
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
# E=4 experts == pod*data: full flash path engaged
dist = DistContext(mesh=mesh, dp_axes=("pod", "data"), slow_axis="pod",
                   ep_axes=("pod", "data"), a2a_impl="flash")
key = jax.random.PRNGKey(0)
p = init_moe(key, cfg)
B, S = 8, 16
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                      jnp.float32) * 0.3

y_ref, aux_ref = moe_apply(cfg, p, x, None)
xg = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
y_dist, aux_dist = jax.jit(
    lambda pp, xx: moe_apply(cfg, pp, xx, dist))(p, xg)
err = float(jnp.abs(y_dist - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9))
aux_err = abs(float(aux_dist) - float(aux_ref))
# NOTE: distributed capacity is per-shard, local is global: with
# capacity_factor 2.0 and uniform-ish routing both keep all tokens.
# The aux load-balance loss is a mean of per-shard statistics whose
# product is nonlinear => small covariance gap vs the global statistic.
assert err < 1e-4, f"y mismatch {err}"
assert aux_err < 0.05, f"aux mismatch {aux_err}"
print("MOE_FLASH_OK", err)

for impl in ("direct", "hierarchical"):
    d2 = dataclasses.replace(dist, a2a_impl=impl)
    y2, _ = jax.jit(lambda pp, xx: moe_apply(cfg, pp, xx, d2))(p, xg)
    e2 = float(jnp.abs(y2 - y_dist).max())
    assert e2 < 1e-5, (impl, e2)
print("MOE_IMPLS_OK")
""")
    assert "MOE_FLASH_OK" in out and "MOE_IMPLS_OK" in out


def test_moe_pod_only_ep(subproc):
    """Mixtral-style EP over the slow axis only (split-island form)."""
    out = subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.configs.registry import MoESpec
from repro.models.dist import DistContext
from repro.models.moe import init_moe, moe_apply
from repro.models.sharding import MeshRules, use_mesh_rules
from repro.launch.mesh import make_mesh

cfg = dataclasses.replace(
    smoke_config("mixtral-8x7b"), compute_dtype="float32",
    moe=MoESpec(num_experts=2, top_k=2))
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
dist = DistContext(mesh=mesh, dp_axes=("pod", "data"), slow_axis="pod",
                   ep_axes=("pod",), a2a_impl="flash")
p = init_moe(jax.random.PRNGKey(0), cfg)
B, S = 8, 16
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                      jnp.float32) * 0.3
y_ref, _ = moe_apply(cfg, p, x, None)
xg = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
rules = MeshRules(mesh=mesh, batch=("pod", "data"))
with use_mesh_rules(rules):
    y_dist, _ = jax.jit(lambda pp, xx: moe_apply(cfg, pp, xx, dist))(p, xg)
err = float(jnp.abs(y_dist - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9))
assert err < 1e-4, err
print("POD_EP_OK", err)
""")
    assert "POD_EP_OK" in out
