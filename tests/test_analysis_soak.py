"""Serving soak under full lock analysis (issue 9): tracked locks,
guard instrumentation and fabric events all on at once -- the lock-order
graph must stay acyclic, no forbidden operation may run under a lock,
no guarded attribute may be written unlocked, and the live-cache audit
must come back clean.  Latency budget guards prove the analysis-off
fast path is untouched."""

import threading

import numpy as np
import pytest

from repro.analysis import guards, locks
from repro.core.traffic import ClusterSpec, Workload, moe_workload
from repro.serving import PlanClient, PlanServer, TieredQueue

C = ClusterSpec(n_servers=4, m_gpus=2)


def _w(seed=0):
    return moe_workload(C, 512, 64, top_k=2, seed=seed)


@pytest.fixture
def analysis_on():
    """Everything armed: tracked locks + dynamic guard checking."""
    locks.reset()
    locks.enable()
    guards.install()
    yield
    guards.uninstall()
    guards.reset_violations()
    locks.reset()
    locks.disable()


def _drifting_trajectory(n=20, seed=0):
    rng = np.random.default_rng(seed)
    mats = [_w(seed=1).matrix]
    for _ in range(n - 1):
        if rng.random() < 0.4 and len(mats) > 1:
            mats.append(mats[int(rng.integers(len(mats)))])
        else:
            nxt = mats[-1].copy()
            sel = rng.random(nxt.shape) < 0.05
            nxt[sel] *= rng.uniform(0.8, 1.2, size=int(sel.sum()))
            np.fill_diagonal(nxt, 0.0)
            mats.append(nxt)
    return [Workload(C, m) for m in mats]


def test_soak_under_lock_analysis(analysis_on):
    """The PR-6 serving invariants, now machine-checked end to end."""
    traj = _drifting_trajectory()
    queue = TieredQueue(max_depth=1024, stale_after=None)
    n_clients = 4
    with PlanServer(workers=3, queue=queue, prewarm=True) as srv:
        clients = [PlanClient(srv, timeout=60.0, inline_fallback=False)
                   for _ in range(n_clients)]
        errors = []

        def loop(client):
            try:
                for w in traj:
                    answer = client.get_plan(w)
                    assert answer.plan.algorithm == "flash"
            except Exception as exc:  # pragma: no cover - failure path
                errors.append(exc)

        threads = [threading.Thread(target=loop, args=(c,))
                   for c in clients]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120.0)
        assert not any(t.is_alive() for t in threads), "soak deadlocked"
        assert not errors
        assert srv.drain(60.0)

        # The live-cache audit runs clean on the serving daemon itself.
        audit = srv.audit()
        assert audit["clean"], audit["issues"]
        assert audit["plans"] >= 1
        assert srv.telemetry.get("audits") == 1

    # Lock-order graph: populated, acyclic, and synthesis never ran
    # under a serving lock.
    edges = locks.lock_order_edges()
    assert edges, "soak must exercise nested lock acquisitions"
    locks.assert_acyclic()
    locks.assert_clean()
    assert guards.guard_violations() == []


def test_soak_with_fabric_event_under_analysis(analysis_on):
    """A mid-soak fabric event (degrade + recover) exercises the
    FabricMonitor -> server/cache/telemetry edges; still acyclic."""
    from repro.serving import FabricMonitor

    from repro.core.topology import Topology

    monitor = FabricMonitor(Topology.from_cluster(C))
    with PlanServer(workers=2, prewarm=False).attach_monitor(
            monitor) as srv:
        client = PlanClient(srv, timeout=60.0, inline_fallback=False)
        for i, w in enumerate(_drifting_trajectory(n=8, seed=3)):
            if i == 4:
                monitor.inject("degrade", 1, 0, factor=0.5)
            client.get_plan(Workload(C, w.matrix, monitor.current()))
        assert srv.drain(60.0)
        audit = srv.audit()
        assert audit["clean"], audit["issues"]

    locks.assert_acyclic()
    locks.assert_clean()
    assert guards.guard_violations() == []
    edges = set(locks.lock_order_edges())
    # The monitor notifies the server under its own lock: that edge is
    # the one a reversed acquisition elsewhere would close into a cycle,
    # so pin it down explicitly.
    assert any(src == "FabricMonitor._lock" for src, _ in edges)


def test_server_lock_is_leaf():
    """No lock is ever acquired while PlanServer._lock is held -- the
    fast path's critical sections stay self-contained."""
    locks.reset()
    locks.enable()
    try:
        with PlanServer(workers=2, prewarm=True) as srv:
            client = PlanClient(srv, timeout=60.0)
            for w in _drifting_trajectory(n=6, seed=5):
                client.get_plan(w)
            assert srv.drain(60.0)
        outgoing = [e for e in locks.lock_order_edges()
                    if e[0] == "PlanServer._lock"]
        assert outgoing == [], outgoing
    finally:
        locks.reset()
        locks.disable()


def test_analysis_off_by_default_in_serving():
    """With analysis off (the default), serving uses plain primitives --
    the zero-overhead contract."""
    assert not locks.enabled()
    srv = PlanServer(workers=1, prewarm=False)
    assert not isinstance(srv._lock, locks.TrackedLock)
    assert not isinstance(srv.cache._lock, locks.TrackedRLock)
