"""Sharding-rule unit tests (pure metadata, no devices needed... almost)."""

def test_param_specs_on_small_mesh(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.configs import smoke_config, get_config
from repro.launch.mesh import make_mesh
from repro.launch.shardings import param_shardings, cache_shardings
from repro.models import build_model

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

# full-size config shapes via eval_shape (no allocation)
cfg = get_config("mixtral-8x7b")
m = build_model(cfg)
shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
sh = param_shardings(cfg, mesh, shapes)

flat = jax.tree_util.tree_flatten_with_path(sh)[0]
by_name = {}
for path, s in flat:
    name = [str(p.key) for p in path if hasattr(p, "key")][-1]
    by_name.setdefault(name, s.spec)

assert by_name["embed"] == jax.sharding.PartitionSpec("model", None)
assert by_name["wo"][-2:] == ("model", None)
# mixtral experts on this small mesh: E=8 divides pod*data=4 -> 2-axis EP
ep_entry = by_name["w_gate"][-3]
assert "pod" in (ep_entry if isinstance(ep_entry, tuple) else (ep_entry,)), \
    by_name["w_gate"]
assert by_name["router"][-1] is None

# odd-vocab arch falls back to replicated vocab dim
cfg2 = get_config("internvl2-1b")   # vocab 151655 (odd)
m2 = build_model(cfg2)
shapes2 = jax.eval_shape(m2.init, jax.random.PRNGKey(0))
sh2 = param_shardings(cfg2, mesh, shapes2)
flat2 = jax.tree_util.tree_flatten_with_path(sh2)[0]
embed_spec = [s.spec for p, s in flat2
              if [str(q.key) for q in p if hasattr(q, "key")][-1] == "embed"]
assert all(sp[0] is None for sp in embed_spec), embed_spec

# caches: dh over model, batch over dp
cache = jax.eval_shape(lambda: m.init_cache(16, 64))
csh = cache_shardings(cfg, mesh, cache)
leaf = jax.tree.leaves(csh)[0]
assert leaf.spec[-1] == "model"
print("SHARDINGS_OK")
""")
    assert "SHARDINGS_OK" in out


def test_batch_sharding_scalar_and_batch1(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.launch.shardings import batch_shardings

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
batch = {"tokens": jax.ShapeDtypeStruct((16, 32), jnp.int32),
         "pos": jax.ShapeDtypeStruct((), jnp.int32),
         "one": jax.ShapeDtypeStruct((1, 32), jnp.int32)}
sh = batch_shardings(mesh, batch)
assert sh["tokens"].spec[0] == ("pod", "data")
assert sh["pos"].spec == jax.sharding.PartitionSpec()
assert sh["one"].spec[0] is None  # batch=1 cannot shard 4 ways
print("BATCH_OK")
""")
    assert "BATCH_OK" in out


def test_ep_axis_selection():
    from repro.configs import get_config
    from repro.models.dist import choose_ep_axes

    class FakeMesh:
        def __init__(self, shape, names):
            self.axis_names = names
            import numpy as _np
            self.devices = _np.zeros(shape)

    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert choose_ep_axes(get_config("megatron-moe-32e"), mesh) == \
        ("pod", "data")
    assert choose_ep_axes(get_config("dbrx-132b"), mesh) == ("data",)
    assert choose_ep_axes(get_config("mixtral-8x7b"), mesh) == ("pod",)
    assert choose_ep_axes(get_config("llama3.2-1b"), mesh) is None
    single = FakeMesh((16, 16), ("data", "model"))
    assert choose_ep_axes(get_config("dbrx-132b"), single) == ("data",)
    assert choose_ep_axes(get_config("mixtral-8x7b"), single) is None
