"""Lock tracking and guarded-state analysis (issue 9): tracked factories,
the lock-order graph, cycle detection, forbidden-while-held contracts,
and dynamic guarded-attribute checking."""

import threading

import pytest

from repro.analysis import guards, locks
from repro.analysis.locks import (
    TrackedLock,
    TrackedRLock,
    make_condition,
    make_lock,
    make_rlock,
)


@pytest.fixture(autouse=True)
def _clean_analysis():
    locks.reset()
    locks.disable()
    yield
    locks.reset()
    locks.disable()


# -- factories ------------------------------------------------------------

def test_factories_passthrough_when_disabled():
    assert not locks.enabled()
    lk = make_lock("X._lock")
    assert not isinstance(lk, TrackedLock)
    # Plain primitive: behaves like threading.Lock.
    with lk:
        pass
    rlk = make_rlock("X._rlock")
    assert not isinstance(rlk, TrackedRLock)
    with rlk:
        with rlk:
            pass


def test_factories_tracked_when_enabled():
    locks.enable()
    lk = make_lock("X._lock")
    assert isinstance(lk, TrackedLock)
    assert lk.name == "X._lock"
    rlk = make_rlock("X._rlock")
    assert isinstance(rlk, TrackedRLock)


def test_tracked_lock_held_by_current_thread():
    locks.enable()
    lk = make_lock("X._lock")
    assert not lk.held_by_current_thread()
    with lk:
        assert lk.held_by_current_thread()
    assert not lk.held_by_current_thread()


def test_tracked_rlock_reentrant():
    locks.enable()
    rlk = make_rlock("X._rlock")
    with rlk:
        with rlk:
            assert rlk.held_by_current_thread()
        assert rlk.held_by_current_thread()
    assert not rlk.held_by_current_thread()


def test_condition_over_tracked_lock():
    locks.enable()
    lk = make_lock("X._lock")
    cond = make_condition("X._cond", lk)
    hit = []

    def waiter():
        with cond:
            cond.wait(timeout=5.0)
            hit.append(True)

    t = threading.Thread(target=waiter)
    t.start()
    # Let the waiter block, then wake it.
    import time
    time.sleep(0.05)
    with cond:
        cond.notify()
    t.join(timeout=5.0)
    assert hit == [True]
    assert not lk.held_by_current_thread()


def test_condition_over_tracked_rlock():
    locks.enable()
    rlk = make_rlock("X._rlock")
    cond = make_condition("X._cond", rlk)
    with cond:
        cond.notify_all()
    assert not rlk.held_by_current_thread()


# -- lock-order graph -----------------------------------------------------

def test_edges_recorded_for_nested_acquisition():
    locks.enable()
    a = make_lock("A")
    b = make_lock("B")
    with a:
        with b:
            pass
    assert ("A", "B") in locks.lock_order_edges()
    assert ("B", "A") not in locks.lock_order_edges()
    assert locks.find_cycles() == []
    locks.assert_acyclic()


def test_no_edge_without_nesting():
    locks.enable()
    a = make_lock("A")
    b = make_lock("B")
    with a:
        pass
    with b:
        pass
    assert locks.lock_order_edges() == {}


def test_reentrant_rlock_records_no_self_edge():
    locks.enable()
    r = make_rlock("R")
    with r:
        with r:
            pass
    assert ("R", "R") not in locks.lock_order_edges()


def test_cycle_detected():
    locks.enable()
    a = make_lock("A")
    b = make_lock("B")
    # Thread 1 order A->B; thread 2 order B->A (sequentially, so no
    # actual deadlock -- the graph still records the hazard).
    with a:
        with b:
            pass

    def reversed_order():
        with b:
            with a:
                pass

    t = threading.Thread(target=reversed_order)
    t.start()
    t.join()
    cycles = locks.find_cycles()
    assert cycles, "A->B->A cycle must be reported"
    witness = cycles[0]
    assert witness[0] == witness[-1]
    assert set(witness) >= {"A", "B"}
    with pytest.raises(AssertionError):
        locks.assert_acyclic()


def test_three_lock_cycle():
    locks.enable()
    a, b, c = make_lock("A"), make_lock("B"), make_lock("C")
    for first, second in ((a, b), (b, c), (c, a)):
        with first:
            with second:
                pass
    assert locks.find_cycles()


# -- forbidden-while-held contracts ---------------------------------------

def test_check_forbidden_records_violation():
    locks.enable()
    cache_lock = make_lock("PlanCache._lock")
    with cache_lock:
        locks.check_forbidden("birkhoff_decompose")
    vs = locks.violations()
    assert len(vs) == 1
    assert vs[0].kind == "forbidden_call"
    assert vs[0].lock == "PlanCache._lock"
    assert vs[0].operation == "birkhoff_decompose"
    with pytest.raises(AssertionError):
        locks.assert_clean()


def test_check_forbidden_clean_outside_lock():
    locks.enable()
    make_lock("PlanCache._lock")  # constructed but not held
    locks.check_forbidden("birkhoff_decompose")
    assert locks.violations() == []
    locks.assert_clean()


def test_check_forbidden_ignores_unlisted_locks():
    locks.enable()
    lk = make_lock("Harmless._lock")
    with lk:
        locks.check_forbidden("synthesize")
    assert locks.violations() == []


def test_check_forbidden_noop_when_disabled():
    lk = make_lock("PlanCache._lock")
    with lk:
        locks.check_forbidden("synthesize")
    assert locks.violations() == []


def test_real_decompose_under_cache_lock_is_flagged():
    """The instrumented entry point itself fires the contract."""
    import numpy as np

    from repro.core.birkhoff import birkhoff_decompose
    from repro.core.plan import PlanCache

    locks.enable()
    cache = PlanCache(capacity=4)
    t = np.array([[0.0, 1.0], [1.0, 0.0]])
    with cache._lock:
        birkhoff_decompose(t)
    assert any(v.lock == "PlanCache._lock" for v in locks.violations())


def test_report_schema():
    locks.enable()
    a = make_lock("A")
    with a:
        pass
    rep = locks.report()
    assert rep["enabled"] is True
    assert "edges" in rep and "cycles" in rep and "violations" in rep


# -- guarded-state registry -----------------------------------------------

def test_registry_covers_serving_classes():
    classes = {(s.module, s.cls_name) for s in guards.REGISTRY}
    assert ("repro.serving.server", "PlanServer") in classes
    assert ("repro.core.plan", "PlanCache") in classes
    assert ("repro.serving.queue", "TieredQueue") in classes
    assert ("repro.serving.telemetry", "Telemetry") in classes


def test_guard_violation_on_unlocked_write():
    from repro.serving.telemetry import Telemetry

    locks.enable()
    guards.install()
    try:
        tel = Telemetry()
        tel.count("ok")  # locked write: clean
        assert guards.guard_violations() == []
        # Unlocked write to a registered attribute from outside.
        tel._counters = {}
        vs = guards.guard_violations()
        assert len(vs) == 1
        assert vs[0].cls_name == "Telemetry"
        assert vs[0].attr == "_counters"
    finally:
        guards.uninstall()
        guards.reset_violations()


def test_guard_init_writes_exempt():
    from repro.serving.telemetry import Telemetry

    locks.enable()
    guards.install()
    try:
        Telemetry()  # constructor writes all registered attrs, unlocked
        assert guards.guard_violations() == []
    finally:
        guards.uninstall()
        guards.reset_violations()


def test_guard_normal_serving_flow_clean():
    from repro.serving.queue import PlanRequest, TieredQueue

    from repro.core.traffic import ClusterSpec, balanced_workload

    locks.enable()
    guards.install()
    try:
        q = TieredQueue(max_depth=8)
        w = balanced_workload(ClusterSpec(2, 2), 1e3)
        q.put(PlanRequest(workload=w, algorithm="flash"))
        assert q.get(timeout=1.0) is not None
        q.close()
        assert guards.guard_violations() == []
    finally:
        guards.uninstall()
        guards.reset_violations()


def test_guard_uninstall_restores():
    from repro.serving.telemetry import Telemetry

    locks.enable()
    guards.install()
    guards.uninstall()
    guards.reset_violations()
    tel = Telemetry()
    tel._counters = {"raw": 1}  # no longer instrumented
    assert guards.guard_violations() == []
