"""Plan-serving daemon tests (issue 6): thread-safe PlanCache, tiered
queue admission control, TTL eviction, background upgrades, drift
prewarming, bounded synthesis, client fallback, and a multi-threaded
soak over drifting traffic with conserved request accounting.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    PlanCache,
    get_scheduler,
    moe_workload,
    simulate,
    traffic_fingerprint,
)
from repro.core.birkhoff import AUTO_EXACT_MAX_N
from repro.core.traffic import Workload
from repro.serving import (
    AdmissionError,
    DriftPredictor,
    LatencyReservoir,
    PlanClient,
    PlanRequest,
    PlanServer,
    PlanTicket,
    ServerClosed,
    Telemetry,
    Tier,
    TieredQueue,
    TTLPolicy,
)

C = ClusterSpec(n_servers=4, m_gpus=2)


def _w(seed=0, cluster=C):
    return moe_workload(cluster, 512, 64, top_k=2, seed=seed)


def _near_miss(w, seed=7, frac=0.05, jitter=0.2):
    rng = np.random.default_rng(seed)
    m = w.matrix.copy()
    sel = rng.random(m.shape) < frac
    m[sel] *= rng.uniform(1 - jitter, 1 + jitter, size=int(sel.sum()))
    np.fill_diagonal(m, 0.0)
    return Workload(w.cluster, m, w.topology)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# -- thread-safe PlanCache ---------------------------------------------------

def test_plan_cache_concurrent_get_or_synthesize_is_canonical():
    """N threads racing the same workloads: counters conserve, and every
    fingerprint resolves to exactly one canonical Plan object."""
    cache = PlanCache(capacity=64, warm_start=True)
    flash = get_scheduler("flash")
    workloads = [_w(seed=s) for s in range(4)]
    per_thread = 12
    n_threads = 6
    results = [[] for _ in range(n_threads)]

    def worker(i):
        for j in range(per_thread):
            w = workloads[(i + j) % len(workloads)]
            results[i].append(cache.get_or_synthesize(flash, w))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    assert not any(t.is_alive() for t in threads)
    assert cache.hits + cache.misses == n_threads * per_thread
    for w in workloads:
        key = traffic_fingerprint(w, "flash")
        canonical = cache.lookup(key)
        assert canonical is not None
        ids = {id(p) for i in range(n_threads)
               for j, p in enumerate(results[i])
               if workloads[(i + j) % len(workloads)] is w}
        assert ids == {id(canonical)}


def test_plan_cache_stats_snapshot():
    cache = PlanCache(capacity=8)
    flash = get_scheduler("flash")
    w = _w()
    cache.get_or_synthesize(flash, w)
    cache.get_or_synthesize(flash, w)
    stats = cache.stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    assert stats["size"] == 1 and stats["capacity"] == 8
    assert stats["hit_rate"] == pytest.approx(0.5)


def test_plan_cache_evict_and_peek():
    cache = PlanCache(capacity=8, warm_start=True)
    flash = get_scheduler("flash")
    w = _w()
    plan = cache.get_or_synthesize(flash, w)
    key = traffic_fingerprint(w, "flash")
    assert cache.peek(key) is plan
    assert cache.evict(key)
    assert not cache.evict(key)  # already gone
    assert cache.peek(key) is None
    assert len(cache) == 0


def test_explicit_evict_repoints_family_index():
    """Regression pin (issue 8, satellite 2): evicting the family's
    canonical key via ``evict()`` must keep the family index in lockstep
    exactly like LRU eviction does -- repoint at the MRU survivor, or
    drop the family with its last member.  A stale family -> evicted-key
    pointer would silently turn every warm start cold."""
    from repro.core import cluster_family_key

    cache = PlanCache(capacity=8, warm_start=True)
    flash = get_scheduler("flash")
    w1, w2 = _w(seed=1), _near_miss(_w(seed=1), seed=8)
    p1 = cache.get_or_synthesize(flash, w1)
    p2 = cache.get_or_synthesize(flash, w2)
    family = cluster_family_key(w1, "flash")
    assert cluster_family_key(w2, "flash") == family  # same family
    assert cache.peek_family(family) is p2  # canonical = latest insert

    # Evict the canonical key: the index must repoint at the survivor.
    assert cache.evict(traffic_fingerprint(w2, "flash"))
    assert cache.peek_family(family) is p1

    # A warm-repair attempt from the repointed head still works.
    w3 = _near_miss(_w(seed=1), seed=9)
    repaired = flash.try_repair_plan(cache.peek_family(family), w3)
    assert repaired is not None
    repaired.validate(w3)

    # Evicting the last member drops the family entirely.
    assert cache.evict(traffic_fingerprint(w1, "flash"))
    assert cache.peek_family(family) is None
    assert cache.family_heads() == []


def test_family_heads_lists_one_head_per_family():
    cache = PlanCache(capacity=8, warm_start=True)
    flash = get_scheduler("flash")
    from repro.core import cluster_family_key

    w_a = _w(seed=1)
    w_b = _w(seed=2, cluster=ClusterSpec(n_servers=2, m_gpus=4))
    p_a = cache.get_or_synthesize(flash, w_a)
    p_b = cache.get_or_synthesize(flash, w_b)
    heads = dict(cache.family_heads())
    assert heads == {cluster_family_key(w_a, "flash"): p_a,
                     cluster_family_key(w_b, "flash"): p_b}


# -- tiered queue ------------------------------------------------------------

def _req(tier=Tier.INTERACTIVE, kind="plan", key="k"):
    return PlanRequest(workload=_w(), algorithm="flash", tier=tier,
                       kind=kind, key=key, ticket=PlanTicket())


def test_queue_orders_by_tier_then_fifo():
    q = TieredQueue(max_depth=16, stale_after=None)
    r_bg = _req(Tier.BACKGROUND)
    r_b1, r_b2 = _req(Tier.BATCH), _req(Tier.BATCH)
    r_i = _req(Tier.INTERACTIVE)
    for r in (r_bg, r_b1, r_b2, r_i):
        q.put(r)
    assert [q.get(0.1) for _ in range(4)] == [r_i, r_b1, r_b2, r_bg]
    assert q.get(0.01) is None


def test_queue_rejects_when_full_of_equal_priority_work():
    q = TieredQueue(max_depth=2, stale_after=None)
    q.put(_req()), q.put(_req())
    victim = _req()
    with pytest.raises(AdmissionError):
        q.put(victim)
    assert victim.ticket.done()
    with pytest.raises(AdmissionError):
        victim.ticket.result(0.1)


def test_queue_preempts_newest_lower_priority_request():
    q = TieredQueue(max_depth=2, stale_after=None)
    bg_old, bg_new = _req(Tier.BACKGROUND), _req(Tier.BACKGROUND)
    q.put(bg_old), q.put(bg_new)
    hi = _req(Tier.INTERACTIVE)
    q.put(hi)  # admitted by shedding bg_new (newest lower-priority)
    assert bg_new.ticket.done() and not bg_old.ticket.done()
    assert q.get(0.1) is hi
    assert q.get(0.1) is bg_old


def test_queue_sheds_stale_requests_instead_of_serving_them():
    clock = FakeClock()
    q = TieredQueue(max_depth=8, stale_after={Tier.INTERACTIVE: 1.0},
                    clock=clock)
    stale = _req(Tier.INTERACTIVE)
    q.put(stale)
    clock.advance(5.0)
    fresh = _req(Tier.INTERACTIVE)
    q.put(fresh)
    assert q.get(0.0) is fresh  # stale one shed on the way out
    assert stale.ticket.done()
    with pytest.raises(AdmissionError):
        stale.ticket.result(0.1)


def test_queue_close_fails_all_waiters():
    q = TieredQueue(max_depth=8, stale_after=None)
    r = _req()
    q.put(r)
    q.close()
    with pytest.raises(ServerClosed):
        r.ticket.result(0.1)
    with pytest.raises(ServerClosed):
        q.put(_req())
    assert q.get(0.01) is None  # closed + drained, no blocking


def test_ticket_timeout():
    with pytest.raises(TimeoutError):
        PlanTicket().result(0.01)


# -- TTL policy --------------------------------------------------------------

def test_ttl_policy_expires_and_sweeps():
    clock = FakeClock()
    ttl = TTLPolicy(ttl_seconds=10.0, clock=clock)
    cache = PlanCache(capacity=8)
    flash = get_scheduler("flash")
    w = _w()
    plan = flash.synthesize(w)
    key = traffic_fingerprint(w, "flash")
    cache.insert(key, plan)
    ttl.note_insert(key)
    assert not ttl.expired(key)
    clock.advance(11.0)
    assert ttl.expired(key)
    assert ttl.sweep(cache) == [key]
    assert cache.peek(key) is None
    assert ttl.sweep(cache) == []  # forgotten after the sweep


def test_server_serves_expired_hit_as_miss():
    clock = FakeClock()
    ttl = TTLPolicy(ttl_seconds=5.0, clock=clock)
    with PlanServer(workers=1, ttl=ttl, prewarm=False) as srv:
        w = _w()
        first = srv.request(w)
        assert first.source == "cold"
        assert srv.request(w).source == "hit"
        clock.advance(6.0)
        again = srv.request(w)
        assert again.source == "cold"  # expired entry evicted, re-made
        assert again.plan is not first.plan
        assert srv.telemetry.get("expired") >= 1  # fast path or idle sweep


# -- background upgrades -----------------------------------------------------

def test_warm_answer_is_upgraded_to_exact_in_background():
    with PlanServer(workers=1, prewarm=False) as srv:
        w0 = _w(seed=0)
        w1 = _near_miss(w0)
        assert srv.request(w0).source == "cold"
        warm = srv.request(w1)
        assert warm.source == "warm" and not warm.exact
        assert srv.drain(20.0)
        after = srv.request(w1)
        assert after.source == "hit" and after.exact
        assert after.plan is not warm.plan
        # The upgraded entry is indistinguishable from one-shot synthesis.
        fresh = get_scheduler("flash").synthesize(w1)
        a, b = after.plan.to_dict(), fresh.to_dict()
        for d in (a, b):
            d.pop("synth_seconds"), d.pop("fingerprint")
        assert a == b
        assert srv.telemetry.get("upgrades") == 1
        assert srv.telemetry.get("warm") == 1


def test_inexact_hit_reschedules_upgrade():
    """If an upgrade was shed, a later hit on the still-inexact entry
    queues a new one rather than serving degraded plans forever."""
    with PlanServer(workers=1, prewarm=False) as srv:
        w0 = _w(seed=0)
        w1 = _near_miss(w0)
        srv.request(w0)
        assert srv.request(w1).source == "warm"
        assert srv.drain(20.0)
        upgrades0 = srv.telemetry.get("upgrades")
        assert upgrades0 == 1
        # Model a shed upgrade: the entry is marked inexact again with no
        # background job queued for it.
        key = traffic_fingerprint(w1, "flash")
        with srv._lock:
            srv._inexact.add(key)
        hit = srv.request(w1)
        assert hit.source == "hit" and not hit.exact
        assert srv.drain(20.0)
        assert srv.telemetry.get("upgrades") == upgrades0 + 1
        assert srv.request(w1).exact


# -- drift prewarming --------------------------------------------------------

def _linear_trajectory(steps, cluster=C, seed=0):
    """Arithmetic progression of matrices: the predictor's linear
    extrapolation is exact on it."""
    base = _w(seed=seed, cluster=cluster)
    delta = np.ones_like(base.matrix) * 8.0
    np.fill_diagonal(delta, 0.0)
    return [Workload(cluster, base.matrix + k * delta, base.topology)
            for k in range(steps)]


def test_drift_predictor_linear_extrapolation():
    traj = _linear_trajectory(3)
    pred = DriftPredictor()
    pred.observe(traj[0], "flash")
    assert pred.predict(traj[0], "flash") == []  # one sample: no signal
    pred.observe(traj[1], "flash")
    out = pred.predict(traj[1], "flash")
    assert len(out) == 1
    np.testing.assert_allclose(out[0].matrix, traj[2].matrix)


def test_drift_predictor_ignores_exact_repeats():
    w = _w()
    pred = DriftPredictor()
    pred.observe(w, "flash")
    pred.observe(Workload(w.cluster, w.matrix.copy(), w.topology), "flash")
    assert pred.predict(w, "flash") == []


def test_drift_predictor_bounds_families():
    pred = DriftPredictor(max_families=2)
    for n in (2, 4, 8):
        cl = ClusterSpec(n_servers=n, m_gpus=2)
        pred.observe(_w(cluster=cl), "flash")
    assert pred.families() == 2


def test_server_prewarms_predicted_next_step():
    traj = _linear_trajectory(3)
    with PlanServer(workers=1, prewarm=True) as srv:
        assert srv.request(traj[0]).source == "cold"
        assert srv.request(traj[1]).source in ("warm", "cold")
        assert srv.drain(20.0)
        assert srv.telemetry.get("prewarmed") >= 1
        hit = srv.request(traj[2])
        assert hit.source == "hit"  # synthesized before it was asked for
        assert srv.telemetry.get("prewarm_hits") == 1


# -- bounded synthesis -------------------------------------------------------

def test_synthesize_bounded_unbudgeted_is_exact():
    flash = get_scheduler("flash")
    w = _w()
    plan, exact = flash.synthesize_bounded(w)
    assert exact
    plan.validate(w)  # raises on an invalid plan


def test_synthesize_bounded_degrades_under_tiny_budget():
    flash = get_scheduler("flash")
    w = _w(seed=3)
    flash.synthesize_bounded(w)  # seed the EWMA latency model
    w2 = _near_miss(w)
    plan, exact = flash.synthesize_bounded(w2, 1e-12)
    assert not exact  # repair-policy decomposition at n <= AUTO_EXACT_MAX_N
    assert w.cluster.n_servers <= AUTO_EXACT_MAX_N
    plan.validate(w2)  # degraded, but still a correct schedule


def test_baseline_scheduler_bounded_is_always_exact():
    hier = get_scheduler("hierarchical")
    plan, exact = hier.synthesize_bounded(_w(), 1e-12)
    assert exact  # baselines have no degraded mode
    assert plan.algorithm == "hierarchical"


# -- client ------------------------------------------------------------------

def test_client_simulate_matches_inline_path():
    w = _w(seed=5)
    with PlanServer(workers=1, prewarm=False) as srv:
        client = PlanClient(srv)
        got = client.simulate(w)
    want = simulate(w, "flash")
    assert got.completion_time == pytest.approx(want.completion_time)
    assert got.algbw == pytest.approx(want.algbw)


def test_client_falls_back_inline_when_daemon_unavailable():
    srv = PlanServer(workers=1)
    srv.start()
    srv.stop()
    client = PlanClient(srv)
    answer = client.get_plan(_w())
    assert answer.source == "inline"
    assert client.counters["inline"] == 1
    strict = PlanClient(srv, inline_fallback=False)
    with pytest.raises(ServerClosed):
        strict.get_plan(_w())


def test_submit_before_start_raises():
    with pytest.raises(ServerClosed):
        PlanServer(workers=1).submit(_w())


# -- telemetry ---------------------------------------------------------------

def test_latency_reservoir_ring_and_percentiles():
    res = LatencyReservoir(capacity=4)
    for v in (1.0, 2.0, 3.0, 4.0):
        res.add(v)
    assert res.percentile(50) == pytest.approx(2.5)
    res.add(100.0)  # evicts the oldest sample (ring)
    assert res.count == 5
    assert res.percentile(100) == pytest.approx(100.0)
    assert res.summary_us()["max_us"] == pytest.approx(100.0 * 1e6)


def test_telemetry_snapshot_is_json_serializable():
    tele = Telemetry()
    tele.count("requests", 3)
    tele.observe_latency("INTERACTIVE", 1e-4)
    tele.observe_synthesis(2e-3)
    tele.observe_queue_depth(5)
    snap = json.loads(tele.to_json())
    assert snap["counters"]["requests"] == 3
    assert snap["latency"]["INTERACTIVE"]["count"] == 1
    assert snap["synthesis"]["count"] == 1
    assert sum(snap["synthesis"]["hist"].values()) == 1
    assert snap["queue"]["peak_depth"] == 5


# -- the soak ----------------------------------------------------------------

def test_soak_concurrent_clients_on_drifting_traffic():
    """N client threads replaying a drifting trajectory against one
    daemon: no deadlock, every request accounted for exactly once, and
    the repeat-heavy traffic keeps the cache hot."""
    rng = np.random.default_rng(0)
    base = _w(seed=1)
    mats = [base.matrix]
    for _ in range(29):
        if rng.random() < 0.4 and len(mats) > 1:
            mats.append(mats[int(rng.integers(len(mats)))])
        else:
            nxt = mats[-1].copy()
            sel = rng.random(nxt.shape) < 0.05
            nxt[sel] *= rng.uniform(0.8, 1.2, size=int(sel.sum()))
            np.fill_diagonal(nxt, 0.0)
            mats.append(nxt)
    traj = [Workload(C, m) for m in mats]

    queue = TieredQueue(max_depth=1024, stale_after=None)
    n_clients = 6
    with PlanServer(workers=3, queue=queue, prewarm=True) as srv:
        clients = [PlanClient(srv, timeout=60.0, inline_fallback=False)
                   for _ in range(n_clients)]
        errors = []

        def loop(client):
            try:
                for w in traj:
                    answer = client.get_plan(w)
                    assert answer.plan.algorithm == "flash"
            except Exception as exc:  # pragma: no cover - failure path
                errors.append(exc)

        threads = [threading.Thread(target=loop, args=(c,))
                   for c in clients]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120.0)
        assert not any(t.is_alive() for t in threads), "soak deadlocked"
        assert not errors
        assert srv.drain(60.0)
        snap = srv.telemetry_snapshot()

    counters = snap["counters"]
    total = n_clients * len(traj)
    assert counters["requests"] == total
    accounted = (counters.get("hits", 0) + counters.get("warm", 0)
                 + counters.get("cold", 0) + counters.get("rejected", 0)
                 + counters.get("shed", 0) + counters.get("errors", 0))
    assert accounted == total
    # 40% repeats visited by 6 clients: well over half must be hits.
    assert counters.get("hits", 0) / total >= 0.5
    # The snapshot round-trips through JSON (the export contract).
    json.dumps(snap)


def test_server_accounts_rejected_requests():
    queue = TieredQueue(max_depth=1, stale_after=None)
    srv = PlanServer(workers=1, queue=queue, prewarm=False)
    # Not started: workers never drain, so the queue fills synchronously.
    srv._running = True
    try:
        srv.submit(_w(seed=0))
        with pytest.raises(AdmissionError):
            srv.submit(_w(seed=99))
        assert srv.telemetry.get("rejected") == 1
        assert srv.telemetry.get("requests") == 2
    finally:
        srv._running = False
        srv.queue.close()


def test_request_latency_origin_always_stamped():
    """Every PlanRequest carries a t_start from construction (issue 9):
    the telemetry path reads it unconditionally instead of silently
    substituting 'now' (which recorded ~0s latencies for requests that
    ever missed the stamp)."""
    w = _w()
    req = PlanRequest(workload=w, algorithm="flash")
    assert req.t_start > 0.0
    assert req.t_start <= time.perf_counter()


def test_missing_latency_origin_fails_loudly():
    """A request stripped of its t_start must blow up in telemetry, not
    record a fake latency."""
    with PlanServer(workers=1, prewarm=False) as srv:
        w = _w()
        ticket = srv.submit(w)
        assert ticket.result(timeout=30.0).plan is not None
        req = PlanRequest(workload=w, algorithm="flash")
        del req.t_start
        plan = srv.cache.lookup(traffic_fingerprint(w, "flash"))
        with pytest.raises(AttributeError):
            srv._answer(req, plan, "hits", exact=True)


def test_submitted_latency_measured_from_submit():
    with PlanServer(workers=1, prewarm=False) as srv:
        srv.submit(_w()).result(timeout=30.0)
        snap = srv.telemetry_snapshot()
    lat = snap["latency"]
    assert lat, "telemetry must record a latency sample"
    tier = next(iter(lat.values()))
    assert tier["count"] >= 1
    assert tier["max_us"] < 60e6  # a genuine measurement, not garbage
