"""Capacity-aware Birkhoff synthesis (issue 4 tentpole).

Invariants of ``birkhoff_decompose(..., capacity_aware=True)`` plans on
heterogeneous fabrics (byte conservation, stage bound, slot-vs-rail
feasibility, ascending durations), the bit-identity of the capacity-blind
path, the ``flash_ca`` scheduler end to end (speedups over blind synthesis
on degraded/mixed fabrics, validation, serialization, warm repair), and
the Plan-level feasibility check.
"""

import dataclasses
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dev dep: skip property-based tests
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    PlanCache,
    PlanValidationError,
    Topology,
    birkhoff_decompose,
    capacity_matched_workload,
    get_scheduler,
    max_line_sum,
    random_workload,
    simulate,
    stage_duration,
)
from repro.core.plan import PermutationStage
from repro.core.traffic import Workload


def _homo(n=4, m=8):
    return Topology.homogeneous(n, m, b_intra=64e9, b_inter=12.5e9)


def _mixed_servers(n=4, m=8):
    """Half the servers on 100G NICs, half on 400G."""
    speeds = [12.5e9] * (n // 2) + [50e9] * (n - n // 2)
    return _homo(n, m).with_server_nic_speeds(speeds)


def _hetero_topo(n, scenario):
    return {
        "degraded_server": lambda: _homo(n, 4).degrade_server(n // 2, 0.25),
        "mixed_servers": lambda: _mixed_servers(n, 4),
        "degraded_nic": lambda: _homo(n, 4).degrade_nic(0, 1, 0.1),
        "failed_nic": lambda: _homo(n, 4).fail_nic(n - 1, 0),
    }[scenario]()


def _matrices(max_n=6, max_v=1000.0):
    return st.integers(2, max_n).flatmap(
        lambda n: st.lists(
            st.lists(st.floats(0, max_v, allow_nan=False), min_size=n,
                     max_size=n),
            min_size=n, max_size=n,
        ).map(lambda rows: _zero_diag(np.array(rows))))


def _zero_diag(t):
    np.fill_diagonal(t, 0.0)
    return t


# -- decomposition invariants ----------------------------------------------


def _check_aware_invariants(t, topo):
    """Aware stages conserve bytes on the support, keep the classic
    n^2 - 2n + 2 stage bound, stay incast-free, and never give a pair a
    slot its rails cannot drain inside the stage window."""
    n = t.shape[0]
    stages = birkhoff_decompose(t.copy(), topology=topo, capacity_aware=True)
    recon = sum((s.as_matrix(n) for s in stages), np.zeros_like(t))
    np.testing.assert_allclose(recon, t, atol=1e-6 * max(t.max(), 1.0))
    assert np.all(recon[t == 0] <= 1e-6 * max(t.max(), 1.0))
    assert len(stages) <= n * n - 2 * n + 2
    caps = topo.pair_capacity()
    shares = topo.nic_shares()
    durations = []
    for s in stages:
        dsts = [j for j in s.perm if j >= 0]
        assert len(dsts) == len(set(dsts))
        assert all(i != j for i, j in enumerate(s.perm))
        dur = stage_duration(s, caps)
        durations.append(dur)
        for i, j in enumerate(s.perm):
            if j < 0:
                continue
            slot = s.slots[i] if s.slots is not None else s.size
            assert s.sent[i] <= slot * (1 + 1e-9)
            assert slot <= s.size * (1 + 1e-9)
            # the pair's slot fits its capacity inside the stage window ...
            if caps[i, j] > 0:
                assert slot <= dur * caps[i, j] * (1 + 1e-9)
            # ... and rail by rail, no rail needs longer than the window
            rail_caps = np.minimum(topo.nic_bw[i], topo.nic_bw[j])
            rail_bytes = slot * shares[i, j]
            live_rails = rail_caps > 0
            assert np.all(rail_bytes[~live_rails] == 0.0)
            assert np.all(rail_bytes[live_rails]
                          <= dur * rail_caps[live_rails] * (1 + 1e-9))
    assert durations == sorted(durations)


@pytest.mark.parametrize("scenario", ("degraded_server", "mixed_servers",
                                      "degraded_nic", "failed_nic"))
@pytest.mark.parametrize("seed", (0, 1, 2))
def test_capacity_aware_invariants_seeded(scenario, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 9))
    t = rng.uniform(0, 1000.0, (n, n)) * (rng.random((n, n)) < 0.8)
    np.fill_diagonal(t, 0.0)
    _check_aware_invariants(t, _hetero_topo(n, scenario))


@settings(max_examples=25, deadline=None)
@given(_matrices())
def test_capacity_aware_invariants_property(t):
    _check_aware_invariants(t, _hetero_topo(t.shape[0], "mixed_servers"))


@pytest.mark.parametrize("seed", (0, 1, 2, 3))
def test_capacity_aware_sum_of_durations_is_optimal(seed):
    """The schedule's total transfer time equals the time-domain max line
    sum -- the serialization lower bound for incast-free permutation
    schedules on the heterogeneous fabric."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 9))
    t = rng.uniform(0, 1e6, (n, n))
    np.fill_diagonal(t, 0.0)
    topo = _mixed_servers(n, 4)
    caps = topo.pair_capacity()
    stages = birkhoff_decompose(t.copy(), topology=topo, capacity_aware=True)
    tau = np.divide(t, caps, out=np.zeros_like(t), where=caps > 0)
    total = sum(stage_duration(s, caps) for s in stages)
    assert total <= max_line_sum(tau) * (1 + 1e-6)


def _check_blind_path_ignores_topology(t):
    """capacity_aware=False must stay bit-identical to the PR 3 engines no
    matter what topology rides along (golden acceptance criterion)."""
    topo = _homo(t.shape[0], 4).degrade_server(0, 0.25)
    base = birkhoff_decompose(t.copy())
    with_topo = birkhoff_decompose(t.copy(), topology=topo,
                                   capacity_aware=False)
    ref = birkhoff_decompose(t.copy(), reference=True)
    assert base == with_topo == ref


@pytest.mark.parametrize("seed", (0, 1, 2, 3, 4))
def test_capacity_blind_path_ignores_topology_seeded(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 9))
    t = rng.uniform(0, 1000.0, (n, n)) * (rng.random((n, n)) < 0.7)
    np.fill_diagonal(t, 0.0)
    _check_blind_path_ignores_topology(t)


@settings(max_examples=25, deadline=None)
@given(_matrices())
def test_capacity_blind_path_ignores_topology_property(t):
    _check_blind_path_ignores_topology(t)


def test_capacity_aware_uniform_fabric_degenerates_to_blind():
    """On uniform pair capacities the time and byte domains coincide, so
    the aware decomposition is the blind one, bit for bit (no slots)."""
    rng = np.random.default_rng(3)
    t = rng.uniform(0, 1e6, (8, 8))
    np.fill_diagonal(t, 0.0)
    aware = birkhoff_decompose(t.copy(), topology=_homo(8),
                               capacity_aware=True)
    blind = birkhoff_decompose(t.copy())
    assert aware == blind
    assert all(s.slots is None for s in aware)


def test_capacity_aware_single_server_degenerates():
    """n=1 has no server pairs at all: aware must take the blind path
    (which returns no inter stages), not crash on the empty off-diagonal
    (review regression)."""
    from repro.core import ClusterSpec

    assert birkhoff_decompose(np.zeros((1, 1)), topology=_homo(1),
                              capacity_aware=True) == []
    w = random_workload(ClusterSpec(1, 8), 1 << 20, seed=0)
    r = simulate(w, "flash_ca")
    assert r.completion_time == simulate(w, "flash").completion_time


def test_capacity_aware_argument_validation():
    t = np.array([[0.0, 1.0], [1.0, 0.0]])
    with pytest.raises(ValueError, match="requires topology"):
        birkhoff_decompose(t, capacity_aware=True)
    with pytest.raises(ValueError, match="capacity-blind"):
        birkhoff_decompose(t, topology=_homo(2), capacity_aware=True,
                           reference=True)
    with pytest.raises(ValueError, match="servers"):
        birkhoff_decompose(t, topology=_homo(4), capacity_aware=True)


def test_repair_policy_capacity_aware_conserves_bytes():
    """The repair engine (n > AUTO_EXACT_MAX_N path, forced here) honors
    the same aware invariants as the exact engine."""
    rng = np.random.default_rng(5)
    n = 10
    t = rng.uniform(0, 1e6, (n, n)) * (rng.random((n, n)) < 0.6)
    np.fill_diagonal(t, 0.0)
    topo = _mixed_servers(n, 4)
    stages = birkhoff_decompose(t.copy(), topology=topo, capacity_aware=True,
                                policy="repair")
    recon = sum((s.as_matrix(n) for s in stages), np.zeros_like(t))
    np.testing.assert_allclose(recon, t, atol=1e-6 * max(t.max(), 1.0))
    assert len(stages) <= n * n - 2 * n + 2


# -- flash_ca end to end ---------------------------------------------------


def test_flash_ca_matches_flash_on_homogeneous_fabric():
    w = random_workload(_homo(), 4 << 20, seed=0)
    aware = get_scheduler("flash_ca").synthesize(w)
    blind = get_scheduler("flash").synthesize(w)
    assert aware.capacity_aware and not blind.capacity_aware
    assert [p.to_dict() for p in aware.phases] == \
        [p.to_dict() for p in blind.phases]
    assert simulate(w, "flash_ca").completion_time == \
        simulate(w, "flash").completion_time


@pytest.mark.parametrize("make_topo", (
    pytest.param(lambda: _homo().degrade_server(2, 0.25),
                 id="degraded_nic_server"),
    pytest.param(lambda: _mixed_servers(), id="mixed_servers_400g_100g"),
))
def test_flash_ca_beats_blind_synthesis_on_hetero(make_topo):
    """Acceptance: capacity-aware FLASH plans execute >= 1.2x faster than
    capacity-blind plans under the link-level executor on degraded-NIC and
    mixed 400G/100G fabrics (capacity-matched traffic)."""
    topo = make_topo()
    w = capacity_matched_workload(topo, 16 << 20, seed=0)
    blind = simulate(w, "flash")
    aware = simulate(w, "flash_ca")
    assert blind.completion_time >= 1.2 * aware.completion_time
    # and the aware schedule stays near the Theorem 1 bound
    assert aware.algbw >= 0.9 * simulate(w, "optimal").algbw


def test_flash_ca_plan_validates_and_round_trips():
    topo = _mixed_servers()
    w = capacity_matched_workload(topo, 16 << 20, seed=1)
    plan = get_scheduler("flash_ca").synthesize(w)
    plan.validate(w)  # conservation + incast + slot-vs-rail feasibility
    assert plan.capacity_aware
    perm_stages = [p for p in plan.phases if isinstance(p, PermutationStage)]
    assert perm_stages and all(p.slots is not None for p in perm_stages)
    plan2 = type(plan).from_dict(json.loads(json.dumps(plan.to_dict())))
    assert plan2.to_dict() == plan.to_dict()
    r1 = simulate(w, "flash_ca", plan=plan)
    r2 = simulate(w, "flash_ca", plan=plan2)
    assert r1.completion_time == r2.completion_time


def test_validate_rejects_payload_beyond_slot():
    topo = _mixed_servers()
    w = capacity_matched_workload(topo, 16 << 20, seed=1)
    plan = get_scheduler("flash_ca").synthesize(w)
    phases = []
    broken = False
    for p in plan.phases:
        if not broken and isinstance(p, PermutationStage) \
                and p.slots is not None and max(p.sent) > 0:
            i = int(np.argmax(p.sent))
            slots = list(p.slots)
            slots[i] = p.sent[i] / 2  # payload no longer fits its slot
            p = dataclasses.replace(p, slots=tuple(slots))
            broken = True
        phases.append(p)
    assert broken
    bad = dataclasses.replace(plan, phases=tuple(phases))
    with pytest.raises(PlanValidationError, match="slot"):
        bad.validate(w)


def test_validate_rejects_blind_shares_on_aware_plan():
    """The slot-vs-rail feasibility check: uniform rail shares grafted onto
    a capacity-aware plan over-run the stage window on the degraded rail."""
    topo = _homo().degrade_nic(2, 3, 0.05)
    w = capacity_matched_workload(topo, 16 << 20, seed=2)
    plan = get_scheduler("flash_ca").synthesize(w)
    plan.validate(w)
    m = topo.m_gpus
    uniform = np.full((topo.n_servers, topo.n_servers, m), 1.0 / m)
    bad = dataclasses.replace(plan, nic_shares=uniform)
    with pytest.raises(PlanValidationError, match="slot-vs-rail"):
        bad.validate(w)


def test_feasibility_check_not_vacuous_when_stage_touches_failed_pair():
    """A fully-failed pair (zero pair capacity) makes the stage window
    infinite; the slot-vs-rail check must still catch bad shares on the
    stage's *healthy* pairs instead of letting the infinity vouch for
    them (review regression)."""
    from repro.core import Plan, ServerFabric

    nic = np.array([[0.0, 1.0], [1.0, 0.0],
                    [0.2, 1.0], [1.0, 1.0]]) * 12.5e9
    topo = Topology(fabrics=(ServerFabric(m_gpus=2),) * 4, nic_bw=nic)
    caps = topo.pair_capacity()
    assert caps[0, 1] == 0.0 and caps[2, 3] > 0  # failed + degraded pairs
    window = 0.01
    slots = tuple(window * max(caps[i, j], 1e8)
                  for i, j in enumerate((1, 0, 3, 2)))
    stage = PermutationStage(perm=(1, 0, 3, 2), size=max(slots),
                             sent=slots, slots=slots)
    mk = lambda shares: Plan(  # noqa: E731
        algorithm="flash_ca", cluster=topo.cluster_view(), phases=(stage,),
        topology=topo, nic_shares=shares, capacity_aware=True)
    mk(topo.nic_shares())._check_slot_rail_feasibility(1e-6)  # consistent
    with pytest.raises(PlanValidationError, match="slot-vs-rail"):
        # Uniform shares over-run the degraded rail of the healthy (2, 3)
        # pair; pre-fix, the failed (0, 1) pair's infinite window hid it.
        mk(np.full((4, 4, 2), 0.5))._check_slot_rail_feasibility(1e-6)


def test_flash_ca_warm_repair_on_near_miss():
    flash_ca = get_scheduler("flash_ca")
    topo = _mixed_servers()
    w1 = capacity_matched_workload(topo, 16 << 20, seed=3)
    rng = np.random.default_rng(11)
    m2 = w1.matrix.copy()
    drift = rng.random(m2.shape) < 0.02
    m2[drift] *= rng.uniform(0.8, 1.2, size=int(drift.sum()))
    np.fill_diagonal(m2, 0.0)
    w2 = Workload(w1.cluster, m2, w1.topology)
    warm = flash_ca.repair_plan(flash_ca.synthesize(w1), w2)
    warm.validate(w2)
    assert warm.capacity_aware
    cold = flash_ca.synthesize(w2)
    t_warm = simulate(w2, "flash_ca", plan=warm).completion_time
    t_cold = simulate(w2, "flash_ca", plan=cold).completion_time
    assert t_warm <= 1.5 * t_cold


def test_plan_cache_warm_start_works_for_flash_ca():
    cache = PlanCache(warm_start=True)
    topo = _mixed_servers()
    w1 = capacity_matched_workload(topo, 16 << 20, seed=4)
    rng = np.random.default_rng(13)
    m2 = w1.matrix.copy()
    drift = rng.random(m2.shape) < 0.02
    m2[drift] *= rng.uniform(0.9, 1.1, size=int(drift.sum()))
    np.fill_diagonal(m2, 0.0)
    simulate(w1, "flash_ca", cache=cache)
    simulate(Workload(w1.cluster, m2, w1.topology), "flash_ca", cache=cache)
    assert (cache.misses, cache.warm_hits) == (2, 1)


def test_flash_ca_routes_around_failed_rail():
    topo = _homo().fail_nic(1, 0)
    w = random_workload(topo, 4 << 20, seed=0)
    r = simulate(w, "flash_ca")
    assert np.isfinite(r.completion_time)
    get_scheduler("flash_ca").synthesize(w).validate(w)
