"""Back-compat shims for older jax releases (no new dependencies).

The repo targets the modern jax API (``jax.shard_map``, ``lax.axis_size``,
``AxisType``-typed meshes).  Older runtimes (e.g. 0.4.x) lack these names;
this module installs equivalent aliases *only where missing*, so on a
current jax it is a no-op.  Imported for effect by ``repro.comm``,
``repro.models`` and ``repro.launch.mesh`` before any shimmed name is used.

Shims:
  * ``lax.axis_size(name)``    -> ``lax.psum(1, name)`` (static for a
                                  static operand, so python-level stage
                                  loops keep working).
  * ``jax.shard_map(...)``     -> ``jax.experimental.shard_map.shard_map``
                                  with the keyword translation
                                  ``axis_names={...}`` (manual axes) ->
                                  ``auto=frozenset(rest)`` and
                                  ``check_vma`` -> ``check_rep``.
"""

from __future__ import annotations

import jax
from jax import lax


def _axis_size(name) -> int:
    # psum of a static scalar is evaluated statically by jax, yielding a
    # concrete int usable in python control flow inside shard_map.
    return lax.psum(1, name)


def _shard_map_compat(f, mesh=None, in_specs=None, out_specs=None,
                      axis_names=None, check_vma=None, **kwargs):
    # Partial-manual lowering (`auto=...`) CHECK-crashes the SPMD
    # partitioner in old XLA builds, so axes outside `axis_names` are made
    # manual too instead of staying automatic.  That is semantically
    # equivalent whenever the in/out specs never reference those axes
    # (true for every call site in this repo: values are replicated over
    # them inside the manual region), at the cost of losing GSPMD
    # propagation for them inside the region.
    from jax.experimental.shard_map import shard_map as _shard_map

    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def install() -> None:
    if not hasattr(lax, "axis_size"):
        lax.axis_size = _axis_size
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover - pallas not bundled
        return
    # pltpu.TPUCompilerParams was renamed to pltpu.CompilerParams; the
    # accepted kwargs (dimension_semantics, ...) are unchanged.
    if not hasattr(pltpu, "CompilerParams") and \
            hasattr(pltpu, "TPUCompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


install()
