"""LR schedules: linear warmup + cosine decay (the MoE-training default)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "constant_schedule"]


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    floor_ratio: float = 0.1):
    floor = peak_lr * floor_ratio

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = floor + 0.5 * (peak_lr - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def constant_schedule(lr_value: float):
    return lambda step: jnp.asarray(lr_value, jnp.float32)
