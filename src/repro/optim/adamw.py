"""AdamW with decoupled weight decay + global-norm clipping, pure pytrees.

No optax dependency: the optimizer is part of the substrate the assignment
asks us to build.  State layout mirrors params (m, v same sharding as the
parameter they track, so TP-sharded weights get TP-sharded moments for
free under GSPMD).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(
    grads,
    state: OptState,
    params,
    lr: jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(m=new_m, v=new_v, count=count), gnorm
