from .adamw import AdamWConfig, OptState, adamw_update, global_norm, \
    init_opt_state
from .schedule import constant_schedule, cosine_schedule

__all__ = [
    "AdamWConfig", "OptState", "adamw_update", "global_norm",
    "init_opt_state", "constant_schedule", "cosine_schedule",
]
