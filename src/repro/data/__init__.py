from .synthetic import DataConfig, SyntheticLM

__all__ = ["DataConfig", "SyntheticLM"]
