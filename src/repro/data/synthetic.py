"""Deterministic synthetic LM data pipeline.

Structured so a model can actually learn from it (loss decreases in the
end-to-end examples): each sequence is Zipf-distributed tokens with an
induction pattern -- the second half repeats the first half -- so copying
heads reduce loss quickly.  Determinism contract: batch(step, host) depends
only on (seed, step, host), giving bit-identical restarts after preemption
and host-local sharding without a distributed filesystem.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.registry import ModelConfig

__all__ = ["DataConfig", "SyntheticLM"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Host-sharded deterministic batch stream."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.model_cfg = model_cfg
        self._host_batch = cfg.global_batch // cfg.n_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id]))
        b, s = self._host_batch, c.seq_len
        half = s // 2
        ranks = rng.zipf(c.zipf_a, size=(b, half + 1)).astype(np.int64)
        toks = np.minimum(ranks, c.vocab - 1).astype(np.int32)
        seq = np.concatenate([toks[:, :half], toks[:, :s - half]], axis=1)
        labels = np.concatenate(
            [seq[:, 1:], toks[:, s - half:s - half + 1]], axis=1)
        out = {"tokens": seq, "labels": labels.astype(np.int32)}
        mc = self.model_cfg
        if mc is not None and mc.frontend == "vision_stub":
            out["patch_embeds"] = rng.standard_normal(
                (b, mc.frontend_len, mc.d_model)).astype(np.float32) * 0.02
        if mc is not None and mc.frontend == "audio_stub":
            out["frames"] = rng.standard_normal(
                (b, mc.encoder_len, mc.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
