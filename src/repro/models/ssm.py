"""Recurrent blocks: xLSTM's mLSTM/sLSTM and Hymba's Mamba (selective SSM).

Training uses a ``lax.scan`` over time (sequential form).  A chunkwise-
parallel form would be faster wall-clock on TPU but has identical FLOP
structure; the dry-run/roofline numbers are unaffected (noted in DESIGN.md).
Decode reuses the same step functions with a carried state -- O(1) memory
per token, which is what makes xlstm/hymba ``long_500k``-capable.

All states are stabilized with the max-trick (m state) as in the xLSTM
paper, computed in f32.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.registry import ModelConfig
from .layers import dense_init

__all__ = [
    "init_mlstm", "mlstm_apply", "mlstm_decode", "mlstm_zero_state",
    "init_slstm", "slstm_apply", "slstm_decode", "slstm_zero_state",
    "init_mamba", "mamba_apply", "mamba_decode", "mamba_zero_state",
]


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, parallelizable linear-attention-like recurrence)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wif": dense_init(ks[3], d, 2 * h, dtype),
        "wz": dense_init(ks[4], d, d, dtype),
        "wo": dense_init(ks[5], d, d, dtype),
    }


def mlstm_zero_state(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_step(state, inputs):
    """inputs: q,k,v [B,H,Dh]; i_t,f_t [B,H]. All f32."""
    q, k, v, it, ft = inputs
    c, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    c = f_p[..., None, None] * c + i_p[..., None, None] \
        * k[..., :, None] * v[..., None, :]
    n = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h_out = num / den[..., None]
    return {"C": c, "n": n, "m": m_new}, h_out


def _mlstm_inputs(cfg: ModelConfig, p: dict, x: jax.Array):
    b, t, d = x.shape
    h = cfg.n_heads
    dh = d // h
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, t, h, dh).astype(jnp.float32)
    k = (x @ p["wk"].astype(dt)).reshape(b, t, h, dh).astype(
        jnp.float32) / jnp.sqrt(float(dh))
    v = (x @ p["wv"].astype(dt)).reshape(b, t, h, dh).astype(jnp.float32)
    gf = (x @ p["wif"].astype(dt)).astype(jnp.float32).reshape(b, t, 2, h)
    it, ft = gf[:, :, 0], gf[:, :, 1]
    return q, k, v, it, ft


def mlstm_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                return_state: bool = False):
    """x: [B, T, d] -> [B, T, d] (optionally also the final state)."""
    b, t, d = x.shape
    q, k, v, it, ft = _mlstm_inputs(cfg, p, x)
    state = mlstm_zero_state(cfg, b)
    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, it, ft))  # time-major
    final, hs = jax.lax.scan(_mlstm_step, state, xs)
    hs = hs.swapaxes(0, 1).reshape(b, t, d).astype(x.dtype)
    z = jax.nn.silu(x @ p["wz"].astype(x.dtype))
    out = (hs * z) @ p["wo"].astype(x.dtype)
    return (out, final) if return_state else out


def mlstm_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 state: dict) -> Tuple[jax.Array, dict]:
    """x: [B, 1, d]; one recurrent step."""
    q, k, v, it, ft = _mlstm_inputs(cfg, p, x)
    state, h = _mlstm_step(
        state, (q[:, 0], k[:, 0], v[:, 0], it[:, 0], ft[:, 0]))
    b, d = x.shape[0], x.shape[-1]
    h = h.reshape(b, 1, d).astype(x.dtype)
    z = jax.nn.silu(x @ p["wz"].astype(x.dtype))
    return (h * z) @ p["wo"].astype(x.dtype), state


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, strictly sequential, recurrent gate inputs)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w": dense_init(ks[0], d, 4 * d, dtype),    # z, i, f, o pre-acts
        "r": dense_init(ks[1], d, 4 * d, dtype),    # recurrent weights
        "wo": dense_init(ks[2], d, d, dtype),
    }


def slstm_zero_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }


def _slstm_step(p, state, wx_t):
    """wx_t: [B, 4d] precomputed input contribution."""
    d = state["c"].shape[-1]
    pre = wx_t + state["h"] @ p["r"].astype(jnp.float32)
    z, it, ft, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    m_new = jnp.maximum(ft + state["m"], it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + state["m"] - m_new)
    c = f_p * state["c"] + i_p * z
    n = f_p * state["n"] + i_p
    h = o * c / jnp.maximum(n, 1.0)
    del d
    return {"c": c, "n": n, "h": h, "m": m_new}, h


def slstm_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                return_state: bool = False):
    b, t, d = x.shape
    wx = (x @ p["w"].astype(x.dtype)).astype(jnp.float32)  # [B,T,4d]
    state = slstm_zero_state(cfg, b)
    final, hs = jax.lax.scan(lambda s, w_t: _slstm_step(p, s, w_t),
                             state, wx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)
    out = hs @ p["wo"].astype(x.dtype)
    return (out, final) if return_state else out


def slstm_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 state: dict) -> Tuple[jax.Array, dict]:
    wx = (x[:, 0] @ p["w"].astype(x.dtype)).astype(jnp.float32)
    state, h = _slstm_step(p, state, wx)
    out = (h[:, None].astype(x.dtype)) @ p["wo"].astype(x.dtype)
    return out, state


# ---------------------------------------------------------------------------
# Mamba head (Hymba's parallel-SSM path), Mamba-1 selective scan
# ---------------------------------------------------------------------------

_CONV_K = 4


def _dt_rank(d_in: int) -> int:
    return max(8, d_in // 16)


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    d_in = d
    n = cfg.ssm_state or 16
    r = _dt_rank(d_in)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (_CONV_K, d_in)) * 0.2).astype(
            dtype),
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d_in, 1))),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "wb": dense_init(ks[2], d_in, n, dtype),
        "wc": dense_init(ks[3], d_in, n, dtype),
        "w_dt": dense_init(ks[4], d_in, r, dtype),
        "w_dt2": dense_init(ks[5], r, d_in, dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[6], d_in, d, dtype),
    }


def mamba_zero_state(cfg: ModelConfig, batch: int) -> dict:
    d_in = cfg.d_model
    n = cfg.ssm_state or 16
    return {
        "h": jnp.zeros((batch, d_in, n), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, d_in), jnp.float32),
    }


def _mamba_scan_inputs(cfg, p, xt):
    """xt: [B, T, d_in] post-conv. Returns dt, b_t, c_t (f32)."""
    dt32 = xt.astype(jnp.float32)
    dt = jax.nn.softplus(
        dt32 @ p["w_dt"].astype(jnp.float32) @ p["w_dt2"].astype(jnp.float32)
        + p["dt_bias"])                                  # [B,T,d_in]
    b_t = dt32 @ p["wb"].astype(jnp.float32)             # [B,T,N]
    c_t = dt32 @ p["wc"].astype(jnp.float32)             # [B,T,N]
    return dt, b_t, c_t


def _mamba_step(a, d_skip, h, xt_t, dt_t, b_t, c_t):
    """One selective-scan step; all f32.
    h [B,d_in,N], xt_t [B,d_in], dt_t [B,d_in], b_t/c_t [B,N]."""
    da = jnp.exp(dt_t[..., None] * a)                    # [B,d_in,N]
    h = da * h + (dt_t * xt_t)[..., None] * b_t[:, None, :]
    y = (h * c_t[:, None, :]).sum(-1) + d_skip * xt_t
    return h, y


def _causal_depthwise_conv(x, w):
    """x: [B, T, C]; w: [K, C]; left-padded causal depthwise conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1]] * w[j]
    return out


def mamba_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                return_state: bool = False):
    b, t, d = x.shape
    xz = x @ p["in_proj"].astype(x.dtype)
    xt_pre, z = jnp.split(xz, 2, axis=-1)
    xt = jax.nn.silu(
        _causal_depthwise_conv(xt_pre, p["conv_w"].astype(x.dtype)))
    dt, b_t, c_t = _mamba_scan_inputs(cfg, p, xt)
    a = -jnp.exp(p["a_log"])                             # [d_in, N]
    xt32 = xt.astype(jnp.float32)

    def step(h, ins):
        xt_t, dt_t, bb, cc = ins
        return _mamba_step(a, p["d_skip"], h, xt_t, dt_t, bb, cc)

    h0 = jnp.zeros((b, d, cfg.ssm_state or 16), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (xt32.swapaxes(0, 1), dt.swapaxes(0, 1),
         b_t.swapaxes(0, 1), c_t.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if not return_state:
        return out
    state = {"h": h_final,
             "conv": xt_pre[:, t - (_CONV_K - 1):].astype(jnp.float32)}
    return out, state


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 state: dict) -> Tuple[jax.Array, dict]:
    b = x.shape[0]
    xz = x[:, 0] @ p["in_proj"].astype(x.dtype)
    xt_new, z = jnp.split(xz, 2, axis=-1)
    # conv over the carried window [B, K-1, d_in] + new input
    win = jnp.concatenate(
        [state["conv"], xt_new[:, None].astype(jnp.float32)], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    xt = jax.nn.silu((win * w[None]).sum(axis=1))        # [B, d_in]
    dt, b_t, c_t = _mamba_scan_inputs(cfg, p, xt[:, None])
    a = -jnp.exp(p["a_log"])
    h, y = _mamba_step(a, p["d_skip"], state["h"], xt,
                       dt[:, 0], b_t[:, 0], c_t[:, 0])
    y = (y.astype(x.dtype) * jax.nn.silu(z))[:, None]
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"h": h, "conv": win[:, 1:]}
