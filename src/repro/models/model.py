"""Unified model API: ``build_model(cfg)`` -> init / loss / prefill / decode.

This is the surface the launcher, dry-run, trainer, and server consume;
every assigned architecture is reachable through it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.registry import ModelConfig
from . import encdec, transformer

__all__ = ["Model", "build_model", "input_specs"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[..., Any]          # (params, batch, dist) -> (loss, metrics)
    prefill: Callable[..., Any]       # (params, batch, dist) -> (logits, cache)
    init_cache: Callable[..., Any]    # (batch, seq_len) -> cache
    decode_step: Callable[..., Any]   # (params, cache, tokens, pos, dist)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.encdec:
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            loss=lambda params, batch, dist=None: encdec.encdec_loss(
                cfg, params, batch, dist),
            prefill=lambda params, batch, dist=None: encdec.encdec_forward(
                cfg, params, batch["tokens"], batch, dist),
            init_cache=lambda batch, seq_len: encdec.encdec_init_cache(
                cfg, batch, seq_len),
            decode_step=lambda params, cache, tokens, pos, dist=None:
                encdec.encdec_decode_step(cfg, params, cache, tokens, pos,
                                          dist),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        loss=lambda params, batch, dist=None: transformer.lm_loss(
            cfg, params, batch, dist),
        prefill=lambda params, batch, dist=None: transformer.lm_prefill(
            cfg, params, batch["tokens"], batch, dist),
        init_cache=lambda batch, seq_len: transformer.init_decode_cache(
            cfg, batch, seq_len),
        decode_step=lambda params, cache, tokens, pos, dist=None:
            transformer.lm_decode_step(cfg, params, cache, tokens, pos, dist),
    )


def input_specs(cfg: ModelConfig, kind: str, seq_len: int,
                global_batch: int) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    Weak-type-correct, shardable, no device allocation -- the dry-run
    lowers against these.  ``decode`` kinds return the *step* inputs
    (tokens + pos); the cache is built separately via ``Model.init_cache``.
    """
    f32 = jnp.dtype(cfg.compute_dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    b, s = global_batch, seq_len
    if kind in ("train", "prefill"):
        batch = {
            "tokens": sds((b, s), i32),
            "labels": sds((b, s), i32),
        }
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = sds((b, cfg.frontend_len, cfg.d_model),
                                        f32)
        if cfg.frontend == "audio_stub":
            batch["frames"] = sds((b, cfg.encoder_len, cfg.d_model), f32)
        return batch
    if kind == "decode":
        return {"tokens": sds((b,), i32),
                "pos": sds((), i32)}
    raise ValueError(f"unknown shape kind {kind!r}")
