"""Mixture-of-Experts layer with expert-parallel dispatch over FLASH.

The MoE block is where the paper's All-to-All appears in a real model: top-k
routing produces a token->expert traffic matrix that changes every step
(paper Fig 4), and dispatch/combine are All-to-All collectives over the EP
mesh axes.  When the EP axes include the slow ``pod`` axis, dispatch crosses
DCN and the configured ``a2a_impl`` (flash | direct | hierarchical | plan)
decides the schedule -- the jit-integrated analogue of swapping RCCL's fanout for
FLASH in Megatron-LM (paper section 5).  Implementation selection happens
in ``comm.all_to_all.resolve_all_to_all`` (one registry for model code,
launch/ and benchmarks), never inline here.

Static-shape contract: capacity-factor padding (standard TPU MoE practice)
bounds every (source shard, expert) chunk at C tokens; overflow tokens are
dropped (contribute zero), underflow is zero-padded.  This padding is what
makes the *post-load-balance* traffic matrix uniform, which in turn is why
the balanced Birkhoff schedule inside ``flash_all_to_all`` is exact (see
DESIGN.md section 3).

The single-device path (``dist=None``) runs the same sort-dispatch math with
G=1 and no collectives; it is the correctness oracle for the island.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..comm.all_to_all import resolve_all_to_all
from ..configs.registry import ModelConfig
from .dist import DistContext
from .layers import dense_init


__all__ = ["init_moe", "moe_apply"]


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(
            jax.random.split(k, e))
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": stack(ks[1], d, f),
        "w_up": stack(ks[2], d, f),
        "w_down": stack(ks[3], f, d),
    }


def _capacity(cfg: ModelConfig, n_tokens: int, n_experts: int) -> int:
    c = int(cfg.moe.capacity_factor * n_tokens * cfg.moe.top_k
            // n_experts) + 1
    # pad to the 128-lane register tile (TPU adaptation of the paper's
    # cache-line alignment, implementation note (3) in section 5)
    return max(8, -(-c // 8) * 8) if n_tokens < 1024 else -(-c // 128) * 128


def _route(cfg: ModelConfig, router_w, x_flat):
    """Top-k routing. Returns (gates [T,k], eids [T,k], aux_loss scalar)."""
    e = cfg.moe.num_experts
    logits = (x_flat.astype(jnp.float32) @ router_w)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, cfg.moe.top_k)         # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (fraction * mean prob).
    onehot = jax.nn.one_hot(eids[:, 0], e, dtype=jnp.float32)
    frac = onehot.mean(0)
    aux = e * jnp.sum(frac * probs.mean(0))
    return gates.astype(x_flat.dtype), eids, aux


def _dispatch(x_flat, eids, capacity: int, n_experts: int):
    """Sort-based dispatch into a [E * C, d] buffer.

    Returns (buffer, slot [T*k], keep [T*k], order [T*k]) where ``slot`` is
    each (token, choice)'s position in the buffer (only valid where keep).
    """
    t, k = eids.shape
    flat_eid = eids.reshape(-1)                                # [T*k]
    order = jnp.argsort(flat_eid, stable=True)
    sorted_eid = flat_eid[order]
    first = jnp.searchsorted(sorted_eid, sorted_eid, side="left")
    pos_in_e = jnp.arange(t * k) - first
    keep_sorted = pos_in_e < capacity
    slot_sorted = sorted_eid * capacity + pos_in_e
    tokens_sorted = x_flat[order // k]
    buf = jnp.zeros((n_experts * capacity, x_flat.shape[-1]), x_flat.dtype)
    safe_slot = jnp.where(keep_sorted, slot_sorted, n_experts * capacity)
    buf = buf.at[safe_slot].set(tokens_sorted, mode="drop")
    # map back to unsorted (token, choice) order
    slot = jnp.zeros((t * k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    keep = jnp.zeros((t * k,), bool).at[order].set(keep_sorted)
    return buf, slot, keep


def _combine(y_buf, slot, keep, gates, t: int, k: int):
    """Gather expert outputs back to (token, choice), weight, and sum."""
    y = y_buf[slot] * keep[:, None]
    y = y.reshape(t, k, -1)
    return (y * gates[..., None]).sum(axis=1)


def _expert_ffn(cfg: ModelConfig, w_gate, w_up, w_down, tokens):
    """tokens: [E_loc, C_tot, d] -> [E_loc, C_tot, d] (grouped SwiGLU).

    No sharding constraints in here: with_sharding_constraint on values
    that vary over manual axes is rejected inside a partial-manual
    shard_map; the expert-ff ("model") sharding of ``h`` propagates from
    the weights instead.
    """
    dt = tokens.dtype
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", tokens, w_gate.astype(dt))) \
        * jnp.einsum("ecd,edf->ecf", tokens, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))


def _moe_island(cfg: ModelConfig, dist: DistContext, x, router_w,
                w_gate, w_up, w_down):
    """Runs on each (pod, data) shard with ``model`` still auto-sharded.

    x: [B_loc, S, d].  Expert stacks arrive E-sharded over the EP axes:
    [E_loc, d, f].
    """
    b, s, d = x.shape
    e = cfg.moe.num_experts
    g = dist.ep_size
    e_loc = e // g
    x_flat = x.reshape(b * s, d)
    t = b * s
    gates, eids, aux = _route(cfg, router_w, x_flat)
    cap = _capacity(cfg, t, e)
    buf, slot, keep = _dispatch(x_flat, eids, cap, e)
    buf = buf.reshape(g, e_loc * cap, d)

    if g > 1:
        a2a = resolve_all_to_all(dist)
        recv = a2a(buf)                                     # [G, E_loc*C, d]
    else:
        recv = buf

    # [G, E_loc, C, d] -> [E_loc, G*C, d]: my experts, everyone's tokens.
    tokens = recv.reshape(g, e_loc, cap, d).transpose(1, 0, 2, 3) \
        .reshape(e_loc, g * cap, d)
    y = _expert_ffn(cfg, w_gate, w_up, w_down, tokens)
    y = y.reshape(e_loc, g, cap, d).transpose(1, 0, 2, 3) \
        .reshape(g, e_loc * cap, d)
    y = a2a(y) if g > 1 else y                              # return trip
    out = _combine(y.reshape(e * cap, d), slot, keep, gates, t,
                   cfg.moe.top_k)
    # Aux loss averaged over all manual shards so every shard returns the
    # same replicated scalar.
    aux = jax.lax.pmean(aux, dist.dp_axes)
    return out.reshape(b, s, d), aux


def _dp_size(dist: DistContext) -> int:
    shape = dict(zip(dist.mesh.axis_names, dist.mesh.devices.shape))
    n = 1
    for a in dist.dp_axes:
        n *= shape[a]
    return n


def _moe_pod_ep(cfg: ModelConfig, dist: DistContext, p: dict, x: jax.Array):
    """Split-island MoE: EP over the slow axis only (mixtral: 8e over
    pod=2), or no EP at all (p_pods=1: experts replicated, TP over model --
    mixtral on the single-pod mesh where 16 does not divide 8 experts).

    Expert weights must NOT enter the manual region: a bf16 weight
    replicated over a manual axis makes XLA:CPU's promoted-reduction pass
    emit an invalid 'copy' binary op during SPMD partitioning (CHECK-crash).
    Structure: island1 (route+dispatch+DCN rotation a2a) -> auto-world
    grouped FFN with experts sharded over 'pod' by plain constraints ->
    island2 (return a2a + combine).  Also the cleaner layout: GSPMD keeps
    full freedom over the FFN while the FLASH rotation schedule stays
    explicit.
    """
    mesh, dp, slow = dist.mesh, dist.dp_axes, dist.slow_axis
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep_axis = dist.ep_axes[0] if dist.ep_axes else None
    p_pods = shape[ep_axis] if ep_axis else 1
    exchange_slow = ep_axis is not None and ep_axis == slow
    n_shards = 1
    for a in dp:
        n_shards *= shape[a]
    e = cfg.moe.num_experts
    e_loc = e // p_pods
    b, s, d = x.shape
    t_loc = (b * s) // n_shards
    cap = _capacity(cfg, t_loc, e)
    k = cfg.moe.top_k

    def _exchange(buf):
        """a2a over the EP axis: FLASH rotations on the slow (DCN) axis,
        flat all_to_all on a fast (ICI) axis; optionally int8-quantized.

        Beyond-paper (DeepSeek-V3-style low-precision dispatch): tokens are
        activations entering an expert FFN; per-row int8 with an f32 scale
        halves DCN bytes at ~0.4% RMS payload error.  The paper's own
        principle -- spend fast-tier resources to shrink slow-tier bytes.
        """
        a2a = resolve_all_to_all(
            slow_axis=ep_axis if exchange_slow else None,
            ep_axes=(ep_axis,), impl=dist.a2a_impl,
            plan=dist.plan if exchange_slow else None)

        if not (cfg.quantized_dispatch and exchange_slow):
            return a2a(buf)
        scale = jnp.maximum(jnp.max(jnp.abs(buf), axis=-1, keepdims=True),
                            1e-6) / 127.0
        q = jnp.clip(jnp.round(buf / scale), -127, 127).astype(jnp.int8)
        q = a2a(q)
        s = a2a(scale.astype(jnp.float32))
        return (q.astype(buf.dtype) * s.astype(buf.dtype))

    def island1(xl, router_w):
        bl, sl, _ = xl.shape
        x_flat = xl.reshape(bl * sl, d)
        gates, eids, aux = _route(cfg, router_w, x_flat)
        buf, slot, keep = _dispatch(x_flat, eids, cap, e)
        buf = buf.reshape(p_pods, e_loc * cap, d)
        recv = _exchange(buf) if p_pods > 1 else buf
        tokens = recv.reshape(p_pods, e_loc, cap, d).transpose(1, 0, 2, 3) \
            .reshape(e_loc, p_pods * cap, d)
        aux = jax.lax.pmean(aux, dp)
        return (tokens[None], slot.reshape(bl, sl * k),
                keep.reshape(bl, sl * k), gates.reshape(bl, sl * k), aux)

    def island2(y_tokens, slot, keep, gates):
        y = y_tokens[0].reshape(e_loc, p_pods, cap, d).transpose(1, 0, 2, 3) \
            .reshape(p_pods, e_loc * cap, d)
        y = _exchange(y) if p_pods > 1 else y
        bl, sk = slot.shape
        out = _combine(y.reshape(e * cap, d), slot.reshape(-1),
                       keep.reshape(-1), gates.reshape(bl * sk // k, k),
                       bl * sk // k, k)
        return out.reshape(bl, sk // k, d)

    dp_spec = dp if len(dp) > 1 else dp[0]
    # check_vma=False: impl="plan" packs slots with a pallas kernel, which
    # has no replication rule under shard_map's checker.
    f1 = jax.shard_map(
        island1, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P()),
        out_specs=(P(dp_spec, None, None, None), P(dp_spec, None),
                   P(dp_spec, None), P(dp_spec, None), P()),
        axis_names=set(dp), check_vma=False)
    tokens_g, slot, keep, gates, aux = f1(x, p["router"])

    # auto-world grouped FFN: experts sharded over the slow axis, ff over TP
    from .sharding import current_rules
    rules = current_rules()

    def cstr(a, spec):
        if rules is None:
            return a
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, spec))

    # tokens_g rows are ordered by the dp shard index (dp-axis-major); the
    # EP group of a row is its coordinate along ep_axis.  Reshape so the EP
    # dim is explicit and contract the grouped FFN along it.
    dp_dims = [shape[a] for a in dp]
    dp_spec_full = tuple(dp)
    tg = tokens_g.reshape(*dp_dims, e_loc, p_pods * cap, d)
    tg = cstr(tg, P(*dp_spec_full, None, None, None))
    dt = tg.dtype
    ff_spec = None if cfg.pure_dp else "model"
    if ep_axis is None:
        wg = p["w_gate"].astype(dt)
        wu = p["w_up"].astype(dt)
        wd = p["w_down"].astype(dt)
        wg = cstr(wg, P(None, None, ff_spec))
        wu = cstr(wu, P(None, None, ff_spec))
        wd = cstr(wd, P(None, ff_spec, None))
        w_sub = "edf"
        wd_sub = "efd"
    else:
        wg = p["w_gate"].reshape(p_pods, e_loc, d, -1).astype(dt)
        wu = p["w_up"].reshape(p_pods, e_loc, d, -1).astype(dt)
        wd = p["w_down"].reshape(p_pods, e_loc, -1, d).astype(dt)
        wg = cstr(wg, P(ep_axis, None, None, ff_spec))
        wu = cstr(wu, P(ep_axis, None, None, ff_spec))
        wd = cstr(wd, P(ep_axis, None, ff_spec, None))
        ep_char = "pg"[dp.index(ep_axis)] if len(dp) > 1 else "p"
        w_sub = ep_char + "edf"
        wd_sub = ep_char + "efd"
    tok_sub = ("pgecd" if len(dp) > 1 else "pecd")
    out_sub = tok_sub.replace("d", "f")
    h = jax.nn.silu(jnp.einsum(f"{tok_sub},{w_sub}->{out_sub}", tg, wg)) \
        * jnp.einsum(f"{tok_sub},{w_sub}->{out_sub}", tg, wu)
    y = jnp.einsum(f"{out_sub},{wd_sub}->{tok_sub}", h, wd)
    y = cstr(y, P(*dp_spec_full, None, None, None))
    y = y.reshape(n_shards, e_loc, p_pods * cap, d)

    f2 = jax.shard_map(
        island2, mesh=mesh,
        in_specs=(P(dp_spec, None, None, None), P(dp_spec, None),
                  P(dp_spec, None), P(dp_spec, None)),
        out_specs=P(dp_spec, None, None),
        axis_names=set(dp), check_vma=False)
    out = f2(y, slot, keep, gates)
    return out, aux


def moe_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    dist: Optional[DistContext] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,d], aux_loss scalar)."""
    if dist is not None and x.shape[0] % _dp_size(dist) != 0:
        # batch does not divide the DP shards (long_500k decode: B=1) --
        # the token math is replicated per device; run the local path.
        dist = None
    if dist is not None and (
            dist.ep_axes is None or len(dist.ep_axes) == 1):
        # single-axis EP (mixtral: pod/DCN; dbrx: data/ICI) or no-EP
        # (experts replicated + TP): all use the split-island form, which
        # keeps expert weights out of the manual region (XLA:CPU crash,
        # see _moe_pod_ep) and lets GSPMD own the grouped FFN.
        return _moe_pod_ep(cfg, dist, p, x)
    if dist is None or dist.ep_axes is None or dist.ep_size == 1:
        b, s, d = x.shape
        x_flat = x.reshape(b * s, d)
        gates, eids, aux = _route(cfg, p["router"], x_flat)
        cap = _capacity(cfg, b * s, cfg.moe.num_experts)
        buf, slot, keep = _dispatch(x_flat, eids, cap, cfg.moe.num_experts)
        tokens = buf.reshape(cfg.moe.num_experts, cap, d)
        y = _expert_ffn(cfg, p["w_gate"], p["w_up"], p["w_down"], tokens)
        out = _combine(y.reshape(-1, d), slot, keep, gates, b * s,
                       cfg.moe.top_k)
        return out.reshape(b, s, d), aux

    mesh = dist.mesh
    dp = dist.dp_axes
    ep = dist.ep_axes
    ep_spec = ep if len(ep) > 1 else ep[0]
    island = partial(_moe_island, cfg, dist)
    fn = jax.shard_map(
        island,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),            # x: batch over DP axes
            P(),                          # router: replicated
            P(ep_spec, None, None),       # expert stacks: E over EP axes
            P(ep_spec, None, None),
            P(ep_spec, None, None),
        ),
        out_specs=(P(dp, None, None), P()),
        axis_names=set(dp),               # "model" stays auto inside
        check_vma=False,                  # pallas pack under impl="plan"
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
