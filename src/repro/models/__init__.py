"""Model zoo: composable JAX model definitions for all assigned archs."""

from .dist import DistContext, choose_ep_axes
from .model import Model, build_model, input_specs
from .sharding import MeshRules, logical_constraint, use_mesh_rules

__all__ = [
    "DistContext",
    "choose_ep_axes",
    "Model",
    "build_model",
    "input_specs",
    "MeshRules",
    "logical_constraint",
    "use_mesh_rules",
]
