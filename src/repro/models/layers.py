"""Foundation layers: norms, RoPE, GQA attention (full / sliding-window /
chunked-online-softmax / decode-with-cache), MLPs.

Pure functional style: ``init_*`` builds a params dict, ``*_apply`` consumes
it.  Everything is einsum-based so GSPMD can partition freely; the chunked
attention path keeps peak memory at O(S * chunk) for long sequences and is
mathematically identical to the Pallas flash_attention kernel (same online
softmax; the kernel is the TPU-optimized form, this is the partitioner- and
CPU-friendly form).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import logical_constraint
from ..configs.registry import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = float(1.0 / np.sqrt(d_in))  # python float: no dtype promotion
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype=jnp.float32).astype(
        dtype) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = x32.mean(-1, keepdims=True)
        var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"] + p["bias"]
    else:
        ms = (x32 ** 2).mean(-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-head RMS norm over head_dim (Qwen3 qk-norm)."""
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt((x32 ** 2).mean(-1, keepdims=True) + 1e-6)
    return (y * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    # Pin the head-free rotation tables replicated over the TP axis: without
    # this, GSPMD propagates conflicting (q:16-way, kv:8x2-way) shardings
    # into the broadcast and inserts involuntary full rematerializations.
    cos = logical_constraint(cos, "batch", "act_seq", None, None)
    sin = logical_constraint(sin, "batch", "act_seq", None, None)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnParamsSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h, k, dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, dtype),
        "wk": dense_init(ks[1], d, k * dh, dtype),
        "wv": dense_init(ks[2], d, k * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array,
                 positions: jax.Array, rope: bool = True):
    b, s, _ = x.shape
    h, k, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    kk = (x @ p["wk"].astype(x.dtype)).reshape(b, s, k, dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, k, dh)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        kk = rms_head_norm(kk, p["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kk = apply_rope(kk, positions, cfg.rope_theta)
    q = logical_constraint(q, "batch", "act_seq", "heads", "head_dim")
    # kv heads (2-8) never divide the 16-way TP axis; sharding them forces
    # GSPMD to regather q-sized tensors every layer.  Replicating kv over
    # "model" keeps attention score/context einsums fully local per q-head
    # shard at the cost of one small K*dh all-gather after the projection.
    kk = logical_constraint(kk, "batch", "act_seq", None, None)
    v = logical_constraint(v, "batch", "act_seq", None, None)
    return q, kk, v


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    b, s, k, dh = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, s, k, n_rep, dh)).reshape(b, s, k * n_rep, dh)


def _band_mask(sq: int, skv: int, q_offset, window: Optional[int],
               causal: bool) -> jax.Array:
    """[sq, skv] bool mask. q position = q_offset + i, kv position = j."""
    qi = q_offset + jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m


def mha_einsum(q, k, v, mask) -> jax.Array:
    """Reference attention: q [B,Sq,H,Dh], k/v [B,Skv,H,Dh], mask [Sq,Skv]."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def mha_chunked(q, k, v, *, q_offset, window: Optional[int], causal: bool,
                use_window=True, q_chunk: int = 1024,
                kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax chunked attention: O(Sq*chunk) memory, flash-equivalent.

    Sliding-window chunks that fall fully outside the band are not skipped
    statically here (XLA-friendly uniform loop) but contribute zero after
    masking; the Pallas kernel does skip them.  For *very* long windowed
    prefills use kernel path on TPU.
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    n_q, n_kv = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / np.sqrt(dh)

    q_r = q.reshape(b, n_q, q_chunk, h, dh)

    def per_qchunk(qi, qc):
        # qc: [b, q_chunk, h, dh]
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        acc0 = jnp.zeros((b, q_chunk, h, dh), jnp.float32)

        def per_kvchunk(carry, kj):
            m_prev, l_prev, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(
                jnp.float32) * scale
            qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                band = kpos > qpos - window
                mask &= jnp.logical_or(
                    jnp.logical_not(jnp.asarray(use_window)), band)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(-1)
            acc = acc * corr.transpose(0, 2, 1)[..., None]
            acc = acc + jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype),
                                   vc).astype(jnp.float32)
            return (m_new, l_new, acc), None

        (m, l, acc), _ = jax.lax.scan(
            per_kvchunk, (m0, l0, acc0), jnp.arange(n_kv))
        out = acc / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        return out.astype(q.dtype)

    outs = jax.lax.map(lambda args: per_qchunk(*args),
                       (jnp.arange(n_q), q_r.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).reshape(b, sq, h, dh)


def attention_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    use_window=True,
    chunked_threshold: int = 2048,
    return_kv: bool = False,
):
    """Self-attention over a full sequence (training / prefill).

    ``window`` is the static band size; ``use_window`` may be a traced bool
    (scan-over-layers with per-layer full-attention overrides) -- when falsy
    the band constraint is disabled.  With ``return_kv`` also returns the
    (pre-GQA-repeat) keys/values arranged as a ring-consistent decode cache.
    """
    b, s, d = x.shape
    h, kv = cfg.n_heads, cfg.n_kv_heads
    q, k, v = _project_qkv(cfg, p, x, positions)
    kr = _repeat_kv(k, h // kv)
    vr = _repeat_kv(v, h // kv)
    eff_window = window if (window is not None) else None
    if s > chunked_threshold:
        out = mha_chunked(q, kr, vr, q_offset=0, window=eff_window,
                          use_window=use_window, causal=causal)
    else:
        mask = _band_mask(s, s, 0, eff_window, causal)
        if eff_window is not None:
            full = _band_mask(s, s, 0, None, causal)
            mask = jnp.where(jnp.asarray(use_window), mask, full)
        out = mha_einsum(q, kr, vr, mask)
    out = out.reshape(b, s, h * cfg.resolved_head_dim)
    out = out @ p["wo"].astype(out.dtype)
    out = logical_constraint(out, "batch", "act_seq", "model_dim")
    if not return_kv:
        return out
    return out, (k, v)


def assemble_kv_cache(k: jax.Array, v: jax.Array, window: Optional[int],
                      cache_len: int) -> Tuple[jax.Array, jax.Array]:
    """Place prefill keys/values [B, S, K, Dh] into a decode cache of
    physical length min(cache_len, window or cache_len), ring-aligned so
    position p lives at slot p % phys (matching attention_decode)."""
    b, s = k.shape[:2]
    phys = cache_len if window is None else min(cache_len, window)

    def place(x):
        if s >= phys:
            xw = x[:, s - phys:]
            shift = s % phys
            return jnp.roll(xw, shift, axis=1) if shift else xw
        pad = [(0, 0)] * x.ndim
        pad[1] = (0, phys - s)
        return jnp.pad(x, pad)

    return place(k), place(v)


def attention_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                   # [B, 1, d]
    cache_k: jax.Array,             # [B, S_phys, K, Dh]
    cache_v: jax.Array,
    pos: jax.Array,                 # scalar: index of the new token
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a (ring-buffered, if windowed) KV cache."""
    b = x.shape[0]
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s_phys = cache_k.shape[1]
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions=positions)
    # Decode shards the KV cache over head_dim ("kv_feature" -> model); q
    # must match, or GSPMD all-gathers the entire cache per layer.  Scores
    # become dh-partial dots psum'd over "model" -- tiny [B,H,S] traffic vs
    # gigabytes of cache movement.
    q = logical_constraint(q, "batch", "act_seq", None, "kv_feature")
    k_new = logical_constraint(k_new, "batch", "act_seq", None, "kv_feature")
    v_new = logical_constraint(v_new, "batch", "act_seq", None, "kv_feature")
    # RoPE-at-write: keys stored already rotated at their absolute position,
    # so ring-buffer slot order is irrelevant (softmax is permutation
    # invariant over kv slots).
    slot = pos if window is None else pos % s_phys
    cache_k = jax.lax.dynamic_update_index_in_dim(
        cache_k, k_new[:, 0], slot, axis=1)
    cache_v = jax.lax.dynamic_update_index_in_dim(
        cache_v, v_new[:, 0], slot, axis=1)
    # Grouped-query einsum against the raw cache: materializing the GQA
    # repeat would force an all-gather of the dh-sharded cache.
    g = h // kv
    q5 = q.reshape(b, 1, kv, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", q5, cache_k).astype(
        jnp.float32) / np.sqrt(dh)
    # Valid slots: the min(pos + 1, s_phys) most recent positions.  For the
    # windowed ring buffer (s_phys == window) every written slot is in-window
    # by construction.
    idx = jnp.arange(s_phys)
    valid = idx < jnp.minimum(pos + 1, s_phys)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bqkgs,bskd->bqkgd", probs, cache_v)
    out = out.reshape(b, 1, h * dh) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             dtype=jnp.float32) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {
            "w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d, f, dtype),
        "b_up": jnp.zeros((f,), jnp.float32),
        "w_down": dense_init(ks[1], f, d, dtype),
        "b_down": jnp.zeros((d,), jnp.float32),
    }


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (
            x @ p["w_up"].astype(x.dtype))
        h = logical_constraint(h, "batch", "act_seq", "ff")
        out = h @ p["w_down"].astype(x.dtype)
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype)
                        + p["b_up"].astype(x.dtype))
        h = logical_constraint(h, "batch", "act_seq", "ff")
        out = h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)
    return logical_constraint(out, "batch", "act_seq", "model_dim")
