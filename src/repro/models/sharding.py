"""Logical-axis sharding rules (MaxText-style) for the model zoo.

Model code annotates tensors with *logical* axis names; a ``MeshRules``
binding maps those to physical mesh axes at lowering time.  On a single
device (CPU smoke tests) no rules are bound and every annotation is a no-op,
so the same model code runs everywhere.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshRules",
    "use_mesh_rules",
    "current_rules",
    "logical_constraint",
    "logical_spec",
    "DEFAULT_RULES",
]

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Logical name -> physical mesh axis (or None = replicate)."""

    mesh: Mesh
    batch: Axis = ("pod", "data")
    seq: Axis = None              # sequence usually unsharded...
    act_seq: Axis = None          # ...activation seq dim (SP flips to "model")
    model_dim: Axis = None
    heads: Axis = "model"
    kv_heads: Axis = "model"
    head_dim: Axis = None
    ff: Axis = "model"
    vocab: Axis = "model"
    experts: Axis = None          # EP axes; chosen per arch by choose_ep_axes
    expert_ff: Axis = "model"
    layers: Axis = None
    kv_feature: Axis = "model"    # fused K*dh feature dim of the KV cache

    def spec(self, *names: Optional[str]) -> P:
        """Logical names -> PartitionSpec, deduplicating mesh axes.

        With sequence sharding (act_seq="model") an intermediate like the
        FFN hidden ("batch", "act_seq", "ff") would map "model" twice;
        the RIGHT-most (innermost) use wins and earlier dims replicate --
        i.e. tensors contracted over a TP-sharded dim are gathered over
        seq for that op, the standard SP dataflow.
        """
        entries = []
        for n in names:
            entries.append(None if n is None else getattr(self, n))
        used: set = set()
        out = []
        for e in reversed(entries):
            axes = () if e is None else ((e,) if isinstance(e, str) else e)
            if any(a in used for a in axes):
                out.append(None)
            else:
                used.update(axes)
                out.append(e)
        return P(*reversed(out))

    def sharding(self, *names: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*names))


DEFAULT_RULES = None  # bound per-run via use_mesh_rules

_ACTIVE: contextvars.ContextVar[Optional[MeshRules]] = contextvars.ContextVar(
    "repro_mesh_rules", default=None)


@contextlib.contextmanager
def use_mesh_rules(rules: Optional[MeshRules]):
    token = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(token)


def current_rules() -> Optional[MeshRules]:
    return _ACTIVE.get()


def logical_spec(*names: Optional[str]) -> Optional[P]:
    rules = current_rules()
    return rules.spec(*names) if rules is not None else None


def logical_constraint(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without bound rules."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(*names)))
