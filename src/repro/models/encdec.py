"""Whisper-style encoder-decoder (audio frontend stubbed).

``input_specs`` feeds precomputed frame embeddings [B, encoder_len, d] --
the conv mel frontend is a stub per the assignment.  Encoder: non-causal
self-attention; decoder: causal self-attention + cross-attention with
learned positional embeddings, pre-LN, GELU MLPs, tied embedding head.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.registry import ModelConfig
from .dist import DistContext
from .layers import (
    attention_apply,
    attention_decode,
    embed_init,
    init_attention,
    init_mlp,
    init_norm,
    mha_einsum,
    mlp_apply,
    norm_apply,
    _repeat_kv,
)

__all__ = [
    "init_encdec", "encdec_loss", "encdec_forward",
    "encdec_init_cache", "encdec_decode_step",
]

_MAX_DECODE_POS = 8192  # learned positions table (structural superset)


def _init_cross_attention(key, cfg: ModelConfig) -> dict:
    # same projection structure; k/v read the encoder stream
    return init_attention(key, cfg)


def init_encdec(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    enc_blocks = []
    kb = jax.random.split(ks[0], cfg.n_encoder_layers)
    for i in range(cfg.n_encoder_layers):
        k1, k2 = jax.random.split(kb[i])
        enc_blocks.append({
            "norm1": init_norm(cfg, cfg.d_model),
            "attn": init_attention(k1, cfg),
            "norm2": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(k2, cfg),
        })
    dec_blocks = []
    kd = jax.random.split(ks[1], cfg.n_layers)
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(kd[i], 3)
        dec_blocks.append({
            "norm1": init_norm(cfg, cfg.d_model),
            "attn": init_attention(k1, cfg),
            "norm_x": init_norm(cfg, cfg.d_model),
            "xattn": _init_cross_attention(k2, cfg),
            "norm2": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(k3, cfg),
        })
    return {
        "embed": embed_init(ks[2], cfg.vocab, cfg.d_model, jnp.float32),
        "enc_pos": embed_init(ks[3], cfg.encoder_len, cfg.d_model,
                              jnp.float32),
        "dec_pos": embed_init(ks[4], _MAX_DECODE_POS, cfg.d_model,
                              jnp.float32),
        "enc_blocks": enc_blocks,
        "dec_blocks": dec_blocks,
        "enc_final": init_norm(cfg, cfg.d_model),
        "dec_final": init_norm(cfg, cfg.d_model),
    }


def _cross_attend(cfg: ModelConfig, p: dict, x, enc_k, enc_v):
    """x: [B, Sq, d]; enc_k/enc_v: [B, Se, K, Dh] (already projected)."""
    b, sq, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, sq, h, dh)
    k = _repeat_kv(enc_k, h // kv)
    v = _repeat_kv(enc_v, h // kv)
    mask = jnp.ones((sq, k.shape[1]), bool)
    out = mha_einsum(q, k, v, mask).reshape(b, sq, h * dh)
    return out @ p["wo"].astype(x.dtype)


def _project_enc_kv(cfg: ModelConfig, p: dict, enc_out):
    b, se, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, se, kv, dh)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, se, kv, dh)
    return k, v


def encode(cfg: ModelConfig, params, frames) -> jax.Array:
    """frames: [B, Se, d] stub embeddings -> encoder stream [B, Se, d]."""
    compute = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(compute) + params["enc_pos"].astype(compute)[None]
    se = x.shape[1]
    positions = jnp.broadcast_to(
        jnp.arange(se, dtype=jnp.int32), (x.shape[0], se))
    for blk in params["enc_blocks"]:
        h = norm_apply(cfg, blk["norm1"], x)
        x = x + attention_apply(cfg, blk["attn"], h, positions=positions,
                                causal=False)
        x = x + mlp_apply(cfg, blk["mlp"], norm_apply(cfg, blk["norm2"], x))
    return norm_apply(cfg, params["enc_final"], x)


def encdec_forward(cfg: ModelConfig, params, tokens, extras,
                   dist: Optional[DistContext] = None):
    """Teacher-forced decoder over the full token sequence."""
    enc_out = encode(cfg, params, extras["frames"])
    compute = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    # clamp into the learned table: whisper's real ctx is 448; the 32k shape
    # cells lower structurally with saturated positions beyond the table
    pos_idx = jnp.minimum(jnp.arange(s), _MAX_DECODE_POS - 1)
    pos_tab = jnp.take(params["dec_pos"].astype(compute), pos_idx, axis=0)
    x = jnp.take(params["embed"].astype(compute), tokens, axis=0) \
        + pos_tab[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for blk in params["dec_blocks"]:
        h = norm_apply(cfg, blk["norm1"], x)
        x = x + attention_apply(cfg, blk["attn"], h, positions=positions,
                                causal=True)
        hx = norm_apply(cfg, blk["norm_x"], x)
        ek, ev = _project_enc_kv(cfg, blk["xattn"], enc_out)
        x = x + _cross_attend(cfg, blk["xattn"], hx, ek, ev)
        x = x + mlp_apply(cfg, blk["mlp"], norm_apply(cfg, blk["norm2"], x))
    x = norm_apply(cfg, params["dec_final"], x)
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, jnp.zeros((), jnp.float32)


def encdec_loss(cfg: ModelConfig, params, batch,
                dist: Optional[DistContext] = None):
    logits, aux = encdec_forward(cfg, params, batch["tokens"], batch, dist)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    m = logits.max(-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    ll = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = (lse - ll).mean()
    metrics = {"loss": nll, "nll": nll, "aux": aux,
               "ppl_proxy": jnp.exp(jnp.minimum(nll, 20.0))}
    return nll, metrics


def encdec_init_cache(cfg: ModelConfig, batch: int, seq_len: int,
                      frames: Optional[jax.Array] = None,
                      params: Optional[dict] = None) -> Any:
    """Self-attn KV cache (seq_len) + per-layer projected cross KV.

    With ``frames``+``params`` the cross cache holds the real encoder
    projections; otherwise zeros (structural lowering path passes the
    cache in as an input ShapeDtypeStruct anyway).
    """
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    compute = jnp.dtype(cfg.compute_dtype)
    layers = []
    enc_out = None
    if frames is not None and params is not None:
        enc_out = encode(cfg, params, frames)
    for i in range(cfg.n_layers):
        entry = {
            "k": jnp.zeros((batch, seq_len, kv, dh), compute),
            "v": jnp.zeros((batch, seq_len, kv, dh), compute),
        }
        if enc_out is not None:
            ek, ev = _project_enc_kv(
                cfg, params["dec_blocks"][i]["xattn"], enc_out)
            entry["xk"], entry["xv"] = ek, ev
        else:
            entry["xk"] = jnp.zeros((batch, cfg.encoder_len, kv, dh), compute)
            entry["xv"] = jnp.zeros((batch, cfg.encoder_len, kv, dh), compute)
        layers.append(entry)
    return layers


def encdec_decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                       dist: Optional[DistContext] = None):
    """tokens [B] -> (logits [B, V], cache); cross KV is static per request."""
    compute = jnp.dtype(cfg.compute_dtype)
    b = tokens.shape[0]
    pos_emb = jnp.take(params["dec_pos"],
                       jnp.minimum(pos, _MAX_DECODE_POS - 1), axis=0)
    x = jnp.take(params["embed"].astype(compute), tokens[:, None],
                 axis=0) + pos_emb.astype(compute)[None, None]
    new_cache = []
    for blk, cache_l in zip(params["dec_blocks"], cache):
        h = norm_apply(cfg, blk["norm1"], x)
        entry = dict(cache_l)
        attn, entry["k"], entry["v"] = attention_decode(
            cfg, blk["attn"], h, cache_l["k"], cache_l["v"], pos)
        x = x + attn
        hx = norm_apply(cfg, blk["norm_x"], x)
        x = x + _cross_attend(cfg, blk["xattn"], hx,
                              cache_l["xk"], cache_l["xv"])
        x = x + mlp_apply(cfg, blk["mlp"], norm_apply(cfg, blk["norm2"], x))
        new_cache.append(entry)
    x = norm_apply(cfg, params["dec_final"], x)
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits[:, 0], new_cache
