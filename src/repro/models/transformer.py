"""Decoder-only LM assembly for every non-enc-dec family.

One block vocabulary ("dense" | "moe" | "hybrid" | "m" | "s"), three
execution modes (train forward, prefill-with-cache, decode step), one
parameter layout rule: homogeneous stacks are scanned (``cfg.scan_layers``)
with remat, heterogeneous stacks (xlstm patterns, hymba's mixed cache
shapes) are unrolled lists.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.registry import ModelConfig
from .dist import DistContext
from .layers import (
    assemble_kv_cache,
    attention_apply,
    attention_decode,
    embed_init,
    init_attention,
    init_mlp,
    init_norm,
    mlp_apply,
    norm_apply,
)
from .moe import init_moe, moe_apply
from .sharding import logical_constraint
from .ssm import (
    init_mamba, init_mlstm, init_slstm,
    mamba_apply, mamba_decode, mamba_zero_state,
    mlstm_apply, mlstm_decode, mlstm_zero_state,
    slstm_apply, slstm_decode, slstm_zero_state,
)

__all__ = [
    "layer_kinds", "init_lm", "lm_forward", "lm_loss",
    "init_decode_cache", "lm_decode_step", "lm_prefill",
]


# ---------------------------------------------------------------------------
# block vocabulary
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "ssm":
        assert cfg.block_pattern and len(cfg.block_pattern) == cfg.n_layers
        return tuple(cfg.block_pattern)
    if cfg.family == "hybrid":
        return ("hybrid",) * cfg.n_layers
    if cfg.family == "moe":
        return ("moe",) * cfg.n_layers
    return ("dense",) * cfg.n_layers


def _init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    pdt = jnp.dtype(cfg.param_dtype)
    if kind == "m":
        return {"norm1": init_norm(cfg, d),
                "mlstm": init_mlstm(ks[0], cfg, pdt)}
    if kind == "s":
        return {"norm1": init_norm(cfg, d),
                "slstm": init_slstm(ks[0], cfg, pdt)}
    p = {
        "norm1": init_norm(cfg, d),
        "attn": init_attention(ks[0], cfg, pdt),
        "norm2": init_norm(cfg, d),
    }
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg, pdt)
    elif kind == "hybrid":
        p["mamba"] = init_mamba(ks[1], cfg, pdt)
        p["fuse_norm_attn"] = init_norm(cfg, d)
        p["fuse_norm_ssm"] = init_norm(cfg, d)
        p["mlp"] = init_mlp(ks[2], cfg, dtype=pdt)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, dtype=pdt)
    return p


def _window_args(cfg: ModelConfig, full_flag) -> Tuple[Optional[int], Any]:
    """(window size or None, traced/static use_window flag)."""
    if cfg.swa_window is None:
        return None, False
    if isinstance(full_flag, bool):
        return (None, False) if full_flag else (cfg.swa_window, True)
    # traced flag (scan over layers): window masked dynamically
    return cfg.swa_window, jnp.logical_not(full_flag)


def _block_train(cfg: ModelConfig, p: dict, x, *, positions, dist,
                 kind: str, full_flag, emit_cache: bool = False,
                 cache_len: int = 0):
    """Returns (x, aux) or, with emit_cache, (x, aux, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("m", "s"):
        apply = mlstm_apply if kind == "m" else slstm_apply
        key = "mlstm" if kind == "m" else "slstm"
        h = norm_apply(cfg, p["norm1"], x)
        if emit_cache:
            y, st = apply(cfg, p[key], h, return_state=True)
            cache = {"state": st}
        else:
            y = apply(cfg, p[key], h)
        x = x + y
        return (x, aux, cache) if emit_cache else (x, aux)
    window, use_window = _window_args(cfg, full_flag)
    h = norm_apply(cfg, p["norm1"], x)
    attn_out = attention_apply(cfg, p["attn"], h, positions=positions,
                               window=window, use_window=use_window,
                               return_kv=emit_cache)
    if emit_cache:
        attn_out, (k_raw, v_raw) = attn_out
        # ring/window semantics must match init_decode_cache for this layer
        is_full = full_flag if isinstance(full_flag, bool) else False
        cache_window = None if (cfg.swa_window is None or is_full) \
            else cfg.swa_window
        k_c, v_c = assemble_kv_cache(k_raw, v_raw, cache_window, cache_len)
        cache = {"k": k_c, "v": v_c}
    if kind == "hybrid":
        if emit_cache:
            ssm, st = mamba_apply(cfg, p["mamba"], h, return_state=True)
            cache["ssm"] = st
        else:
            ssm = mamba_apply(cfg, p["mamba"], h)
        fused = 0.5 * (norm_apply(cfg, p["fuse_norm_attn"], attn_out)
                       + norm_apply(cfg, p["fuse_norm_ssm"], ssm))
        x = x + fused
    else:
        x = x + attn_out
    h2 = norm_apply(cfg, p["norm2"], x)
    if kind == "moe":
        y, aux = moe_apply(cfg, p["moe"], h2, dist)
        x = x + y
    else:
        x = x + mlp_apply(cfg, p["mlp"], h2)
    return (x, aux, cache) if emit_cache else (x, aux)


# ---------------------------------------------------------------------------
# params assembly
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> dict:
    kinds = layer_kinds(cfg)
    pdt = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab, cfg.d_model, pdt),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(
            k_head, cfg.vocab, cfg.d_model, pdt).T  # [d, V]
    keys = jax.random.split(k_blocks, cfg.n_layers)
    if cfg.scan_layers:
        assert len(set(kinds)) == 1, "scan requires homogeneous blocks"
        params["blocks"] = jax.vmap(
            lambda k: _init_block(k, cfg, kinds[0]))(keys)
    else:
        params["blocks"] = [
            _init_block(keys[i], cfg, kinds[i]) for i in range(cfg.n_layers)]
    return params


def _full_flags(cfg: ModelConfig) -> jnp.ndarray:
    flags = [i in cfg.full_attn_layers for i in range(cfg.n_layers)]
    return jnp.array(flags)


def _embed_tokens(cfg: ModelConfig, params, tokens, extras) -> jax.Array:
    compute = jnp.dtype(cfg.compute_dtype)
    # cast the table BEFORE the gather: the vocab-sharded lookup lowers to
    # masked-select + all-reduce over "model", which must ride in bf16
    x = jnp.take(params["embed"].astype(compute), tokens, axis=0)
    if cfg.frontend == "vision_stub" and extras is not None:
        fl = cfg.frontend_len
        patch = extras["patch_embeds"].astype(compute)
        x = jnp.concatenate([patch, x[:, fl:]], axis=1) \
            if x.shape[1] > fl else patch[:, :x.shape[1]]
    return logical_constraint(x, "batch", "act_seq", "model_dim")


def _lm_logits(cfg: ModelConfig, params, x) -> jax.Array:
    x = norm_apply(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    return logical_constraint(logits, "batch", "act_seq", "vocab")


# ---------------------------------------------------------------------------
# training forward / loss
# ---------------------------------------------------------------------------

def lm_forward(cfg: ModelConfig, params, tokens, extras=None,
               dist: Optional[DistContext] = None):
    """tokens [B, S] -> (logits [B, S, V], aux)."""
    b, s = tokens.shape
    x = _embed_tokens(cfg, params, tokens, extras)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kinds = layer_kinds(cfg)
    if cfg.scan_layers:
        flags = _full_flags(cfg)

        def body(carry, inp):
            xx, aux_total = carry
            p_l, flag_l = inp
            xx, aux = _block_train(cfg, p_l, xx, positions=positions,
                                   dist=dist, kind=kinds[0],
                                   full_flag=flag_l)
            return (xx, aux_total + aux), None

        carry0 = (x, jnp.zeros((), jnp.float32))
        g = cfg.remat_group
        if cfg.remat and g and cfg.n_layers % g == 0:
            # two-level remat: only n_layers/g group-boundary carries are
            # saved; each group's layers recompute twice in the backward.
            # Cuts saved-activation memory ~g-fold for +1 extra forward.
            inner = jax.checkpoint(body)

            def group(carry, inp):
                return jax.lax.scan(inner, carry, inp)

            n_groups = cfg.n_layers // g
            grouped = jax.tree.map(
                lambda a: a.reshape((n_groups, g) + a.shape[1:]),
                (params["blocks"], flags))
            (x, aux), _ = jax.lax.scan(jax.checkpoint(group), carry0,
                                       grouped)
        else:
            body = jax.checkpoint(body) if cfg.remat else body
            (x, aux), _ = jax.lax.scan(body, carry0,
                                       (params["blocks"], flags))
    else:
        aux = jnp.zeros((), jnp.float32)
        for i, p_l in enumerate(params["blocks"]):
            fn = partial(_block_train, cfg, kind=kinds[i], dist=dist,
                         full_flag=i in cfg.full_attn_layers)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, a = fn(p_l, x, positions=positions)
            aux = aux + a
    return _lm_logits(cfg, params, x), aux


def lm_loss(cfg: ModelConfig, params, batch,
            dist: Optional[DistContext] = None):
    """batch: {"tokens": [B,S], "labels": [B,S], extras...}."""
    logits, aux = lm_forward(cfg, params, batch["tokens"], batch, dist)
    labels = batch["labels"]
    if cfg.bf16_ce:
        # beyond-paper memory knob: never materialize an f32 [B,S,V]
        # tensor -- max/exp stay bf16, only the V-reduction accumulates in
        # f32 (rel. lse error ~3e-3, amortized to zero by normalization).
        m = logits.max(-1, keepdims=True)
        expv = jnp.exp((logits - m))                      # bf16
        denom = jnp.sum(expv, axis=-1, dtype=jnp.float32)
        lse = m[..., 0].astype(jnp.float32) + jnp.log(denom)
        label_logit = jnp.take_along_axis(
            logits, labels[..., None].astype(jnp.int32),
            axis=-1)[..., 0].astype(jnp.float32)
    else:
        logits32 = logits.astype(jnp.float32)
        m = logits32.max(-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits32 - m), axis=-1))
        label_logit = jnp.take_along_axis(
            logits32, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = (lse - label_logit).mean()
    loss = nll + 0.01 * aux
    metrics = {"loss": loss, "nll": nll, "aux": aux,
               "ppl_proxy": jnp.exp(jnp.minimum(nll, 20.0))}
    return loss, metrics


# ---------------------------------------------------------------------------
# decode: cache init, prefill, single step
# ---------------------------------------------------------------------------

def _phys_len(cfg: ModelConfig, seq_len: int, full_attn: bool) -> int:
    if cfg.swa_window is None or full_attn:
        return seq_len
    return min(seq_len, cfg.swa_window)


def _zero_cache_block(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      full_attn: bool) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    compute = jnp.dtype(cfg.compute_dtype)
    if kind == "m":
        return {"state": mlstm_zero_state(cfg, batch)}
    if kind == "s":
        return {"state": slstm_zero_state(cfg, batch)}
    phys = _phys_len(cfg, seq_len, full_attn)
    c = {
        "k": jnp.zeros((batch, phys, kv, dh), compute),
        "v": jnp.zeros((batch, phys, kv, dh), compute),
    }
    if kind == "hybrid":
        c["ssm"] = mamba_zero_state(cfg, batch)
    return c


def init_decode_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Any:
    kinds = layer_kinds(cfg)
    if cfg.scan_layers:
        one = _zero_cache_block(cfg, kinds[0], batch, seq_len,
                                full_attn=False)
        if cfg.full_attn_layers:
            # mixed window/full caches cannot stack; use full-size everywhere
            one = _zero_cache_block(cfg, kinds[0], batch, seq_len, True)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
            one)
    return [
        _zero_cache_block(cfg, kinds[i], batch, seq_len,
                          full_attn=i in cfg.full_attn_layers)
        for i in range(cfg.n_layers)]


def _block_decode(cfg: ModelConfig, p: dict, cache: dict, x, pos, *,
                  kind: str, full_flag, dist) -> Tuple[jax.Array, dict]:
    if kind == "m":
        y, st = mlstm_decode(cfg, p["mlstm"],
                             norm_apply(cfg, p["norm1"], x), cache["state"])
        return x + y, {"state": st}
    if kind == "s":
        y, st = slstm_decode(cfg, p["slstm"],
                             norm_apply(cfg, p["norm1"], x), cache["state"])
        return x + y, {"state": st}
    window = None
    if cfg.swa_window is not None:
        is_full = full_flag if isinstance(full_flag, bool) else False
        phys = cache["k"].shape[1]
        # ring semantics engage only when the cache is window-sized
        window = cfg.swa_window if (not is_full and
                                    phys <= cfg.swa_window) else None
    h = norm_apply(cfg, p["norm1"], x)
    new_cache = dict(cache)
    attn, new_cache["k"], new_cache["v"] = attention_decode(
        cfg, p["attn"], h, cache["k"], cache["v"], pos, window=window)
    if kind == "hybrid":
        ssm, new_cache["ssm"] = mamba_decode(cfg, p["mamba"], h, cache["ssm"])
        x = x + 0.5 * (norm_apply(cfg, p["fuse_norm_attn"], attn)
                       + norm_apply(cfg, p["fuse_norm_ssm"], ssm))
    else:
        x = x + attn
    h2 = norm_apply(cfg, p["norm2"], x)
    if kind == "moe":
        y, _ = moe_apply(cfg, p["moe"], h2, dist)
        x = x + y
    else:
        x = x + mlp_apply(cfg, p["mlp"], h2)
    return x, new_cache


def lm_decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                   dist: Optional[DistContext] = None):
    """tokens [B] int32, pos scalar int32 -> (logits [B, V], new cache)."""
    compute = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"].astype(compute), tokens[:, None], axis=0)
    kinds = layer_kinds(cfg)
    if cfg.scan_layers:
        flags = _full_flags(cfg)

        def body(xx, inp):
            p_l, cache_l, flag_l = inp
            xx, new_cache_l = _block_decode(
                cfg, p_l, cache_l, xx, pos, kind=kinds[0],
                full_flag=flag_l, dist=dist)
            return xx, new_cache_l

        x, new_cache = jax.lax.scan(body, x,
                                    (params["blocks"], cache, flags))
    else:
        new_cache = []
        for i, (p_l, cache_l) in enumerate(zip(params["blocks"], cache)):
            x, c = _block_decode(cfg, p_l, cache_l, x, pos, kind=kinds[i],
                                 full_flag=i in cfg.full_attn_layers,
                                 dist=dist)
            new_cache.append(c)
    logits = _lm_logits(cfg, params, x)
    return logits[:, 0], new_cache


def lm_prefill(cfg: ModelConfig, params, tokens, extras=None,
               dist: Optional[DistContext] = None,
               cache_len: Optional[int] = None):
    """Forward over the full prompt, emitting a decode-ready cache.

    Returns (last-position logits [B, V], cache); decode continues at
    pos = S.  ``cache_len`` sizes the cache (prompt + generation budget,
    default = prompt length).  Windowed layers emit ring-aligned window
    caches (slot p % window holds position p).
    """
    b, s = tokens.shape
    cache_len = cache_len or s
    assert cache_len >= s, "cache must at least hold the prompt"
    x = _embed_tokens(cfg, params, tokens, extras)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kinds = layer_kinds(cfg)
    if cfg.scan_layers:
        flags = _full_flags(cfg)
        # mixed full/window layers cannot stack ring caches: treat all as
        # full-size (matches init_decode_cache's scan branch)
        eff_cfg = cfg
        if cfg.full_attn_layers and cfg.swa_window is not None:
            eff_cfg = dataclasses.replace(cfg, swa_window=None)

        def body(xx, inp):
            p_l, flag_l = inp
            xx, _, cache_l = _block_train(
                eff_cfg, p_l, xx, positions=positions, dist=dist,
                kind=kinds[0], full_flag=flag_l, emit_cache=True,
                cache_len=cache_len)
            return xx, cache_l

        x, cache = jax.lax.scan(body, x, (params["blocks"], flags))
    else:
        cache = []
        for i, p_l in enumerate(params["blocks"]):
            x, _, cache_l = _block_train(
                cfg, p_l, x, positions=positions, dist=dist, kind=kinds[i],
                full_flag=i in cfg.full_attn_layers, emit_cache=True,
                cache_len=cache_len)
            cache.append(cache_l)
    logits = _lm_logits(cfg, params, x[:, -1:])
    return logits[:, 0], cache
