"""Distribution context: which mesh axes play which role for a given run.

``DistContext`` is threaded through model code (None => single-device
reference semantics, used by CPU smoke tests and as the correctness oracle
for the distributed path).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from jax.sharding import Mesh

from ..configs.registry import ModelConfig
from ..core.topology import Topology

__all__ = ["DistContext", "choose_ep_axes"]


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh
    dp_axes: Tuple[str, ...]            # batch-sharded axes (manual in MoE island)
    slow_axis: Optional[str]            # inter-pod DCN axis ("pod"), if present
    ep_axes: Optional[Tuple[str, ...]]  # expert-parallel axes, slow-major
    # Registry name consumed by comm.all_to_all.resolve_all_to_all (the one
    # dispatch point for model code, launch/ and benchmarks).
    a2a_impl: str = "flash"             # flash | direct | hierarchical | auto
    # Physical fabric, when known; a2a_impl="auto" resolves against it
    # (flash on heterogeneous or oversubscribed fabrics, direct on uniform
    # full-bisection ones).
    topology: Optional[Topology] = None
    # Synthesized schedule (core.plan.Plan or simulator.ExecutableSchedule)
    # backing a2a_impl="plan"; "auto" prefers "plan" whenever this is set.
    # Any object, so core stays import-light here; comm.plan_exec duck-types.
    plan: Optional[object] = None

    @property
    def ep_size(self) -> int:
        if not self.ep_axes:
            return 1
        shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        size = 1
        for a in self.ep_axes:
            size *= shape[a]
        return size


def choose_ep_axes(cfg: ModelConfig, mesh: Mesh) -> Optional[Tuple[str, ...]]:
    """Pick EP axes for an arch on a mesh: the largest slow-major prefix of
    the DP axes whose size divides num_experts.

    Priority (production mesh pod=2, data=16):
      E % (pod*data) == 0 -> ("pod", "data")   # megatron-moe-32e: full DCN case
      E % data == 0       -> ("data",)         # dbrx-16e: ICI-only dispatch
      E % pod == 0        -> ("pod",)          # mixtral-8e: DCN dispatch
      otherwise           -> None              # TP-only MoE (experts replicated)
    """
    if cfg.moe is None:
        return None
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    e = cfg.moe.num_experts
    has_pod = "pod" in shape
    pod = shape.get("pod", 1)
    data = shape.get("data", 1)
    if has_pod and e % (pod * data) == 0:
        return ("pod", "data")
    if e % data == 0 and data > 1:
        return ("data",)
    if has_pod and e % pod == 0 and pod > 1:
        return ("pod",)
    return None
