"""Pure-jnp oracle for grouped_matmul."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def grouped_matmul_ref(x, w, counts: Optional[jnp.ndarray] = None):
    """x: [E, C, D] @ w: [E, D, F] with per-expert row masking."""
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    if counts is not None:
        e, c, _ = x.shape
        valid = jnp.arange(c)[None, :, None] < counts[:, None, None]
        y = jnp.where(valid, y, 0.0)
    return y.astype(x.dtype)
