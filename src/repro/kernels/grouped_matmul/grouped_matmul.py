"""Grouped (per-expert) matmul Pallas TPU kernel for MoE FFNs.

Computes y[e] = x[e] @ w[e] for E experts with optional per-expert valid row
counts (capacity buffers are padded; rows beyond ``counts[e]`` are garbage
and must not pollute the MXU accumulation -- they are zero-masked on the
final write, the TPU analogue of megablocks' ragged grouped GEMM).

VMEM tiling: (block_c x block_d) x (block_d x block_f) tiles, f32
accumulator scratch of (block_c, block_f); grid (E, C/bc, F/bf, D/bd) with
the contraction dimension innermost and 'arbitrary'.  All tile dims default
to 128/512 -- MXU-aligned multiples of 128.

Per-expert counts ride in scalar-prefetch memory so the index maps and the
masking see them before the tiles stream in.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(counts_ref, x_ref, w_ref, y_ref, acc_scr,
                *, block_c: int, block_f: int, n_d_blocks: int):
    # program_ids hoisted out of pl.when bodies (interpret-mode requirement)
    e = pl.program_id(0)
    ci = pl.program_id(1)
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _zero():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)          # [bc, bd]
    w = w_ref[0].astype(jnp.float32)          # [bd, bf]
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == n_d_blocks - 1)
    def _write():
        rows = ci * block_c + jax.lax.broadcasted_iota(
            jnp.int32, (block_c, block_f), 0)
        valid = rows < counts_ref[e]
        y_ref[0, ...] = jnp.where(valid, acc_scr[...], 0.0).astype(y_ref.dtype)


def grouped_matmul(
    x: jax.Array,                    # [E, C, D]
    w: jax.Array,                    # [E, D, F]
    counts: Optional[jax.Array] = None,   # [E] int32 valid rows per expert
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    f = w.shape[-1]
    assert w.shape == (e, d, f)
    block_c = min(block_c, c)
    block_d = min(block_d, d)
    block_f = min(block_f, f)
    assert c % block_c == 0 and d % block_d == 0 and f % block_f == 0
    if counts is None:
        counts = jnp.full((e,), c, jnp.int32)
    n_d = d // block_d

    kernel = functools.partial(
        _gmm_kernel, block_c=block_c, block_f=block_f, n_d_blocks=n_d)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(e, c // block_c, f // block_f, n_d),
            in_specs=[
                pl.BlockSpec((1, block_c, block_d),
                             lambda e_, ci, fi, di, counts: (e_, ci, di)),
                pl.BlockSpec((1, block_d, block_f),
                             lambda e_, ci, fi, di, counts: (e_, di, fi)),
            ],
            out_specs=pl.BlockSpec((1, block_c, block_f),
                                   lambda e_, ci, fi, di, counts:
                                   (e_, ci, fi)),
            scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(counts.astype(jnp.int32), x, w)
