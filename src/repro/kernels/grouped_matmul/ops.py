"""jit'd public wrapper for grouped_matmul."""

from __future__ import annotations

from functools import partial

import jax

from .grouped_matmul import grouped_matmul
from .ref import grouped_matmul_ref

__all__ = ["grouped_matmul_op", "grouped_matmul_ref"]


@partial(jax.jit, static_argnames=("block_c", "block_d", "block_f",
                                   "interpret"))
def grouped_matmul_op(x, w, counts=None, *, block_c: int = 128,
                      block_d: int = 512, block_f: int = 128,
                      interpret: bool = False) -> jax.Array:
    return grouped_matmul(x, w, counts, block_c=block_c, block_d=block_d,
                          block_f=block_f, interpret=interpret)
