from .ops import grouped_matmul_op, grouped_matmul_ref

__all__ = ["grouped_matmul_op", "grouped_matmul_ref"]
