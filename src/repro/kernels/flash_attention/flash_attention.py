"""Blockwise (flash) attention Pallas TPU kernel with GQA + sliding window.

The compute hot-spot of every attention arch at long context.  VMEM tiling:
one (block_q, head_dim) query tile and one (block_k, head_dim) key/value
tile resident per grid step; online-softmax running stats live in VMEM
scratch shaped (block_q, 128) (lane-replicated, the standard TPU layout for
per-row scalars).  Grid is (batch*q_heads, n_q_blocks, n_kv_blocks) with the
kv dimension 'arbitrary' (sequential accumulation); causal/windowed tiles
that are fully out-of-band are skipped with pl.when, so a w-token sliding
window does O(S*w) work, not O(S^2).

GQA is handled in the BlockSpec index maps: the kv block for flat head
index bh = b*H + h is (b*K + h // (H//K)) -- no materialized repeat.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, block_q: int, block_k: int,
                  n_kv_blocks: int, causal: bool, window: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # Block-level band check (static shapes, dynamic program ids).
    in_band = jnp.bool_(True)
    if causal:
        in_band &= k_start <= q_start + block_q - 1
    if window is not None:
        in_band &= k_start + block_k - 1 > q_start - window

    @pl.when(in_band)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                      # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                     # [bq, bk]
        corr = jnp.exp(m_prev - m_new)             # [bq, 1]
        l_new = l_scr[:, :1] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        o_ref[0, ...] = (acc_scr[...] /
                         jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                  # [B, H, S, D]
    k: jax.Array,                  # [B, K, S, D]
    v: jax.Array,                  # [B, K, S, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    kv_heads = k.shape[1]
    assert h % kv_heads == 0, "GQA requires H % K == 0"
    group = h // kv_heads
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    n_q, n_kv = s // block_q, s // block_k
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * kv_heads, s, d)
    vf = v.reshape(b * kv_heads, s, d)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        return (bh // h * kv_heads + (bh % h) // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=n_kv, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, _LANES), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),        # output accum
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
