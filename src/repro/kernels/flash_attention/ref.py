"""Pure-jnp oracle for the flash_attention kernel."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None) -> jnp.ndarray:
    """q: [B, H, S, D]; k/v: [B, K, S, D] (GQA repeat here)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
