from .ops import attention_ref, flash_attention_op

__all__ = ["attention_ref", "flash_attention_op"]
