"""jit'd public wrapper for the flash_attention kernel."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .flash_attention import flash_attention
from .ref import attention_ref

__all__ = ["flash_attention_op", "attention_ref"]


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None, block_q: int = 128,
                       block_k: int = 128,
                       interpret: bool = False) -> jax.Array:
    """Blockwise attention; q [B,H,S,D], k/v [B,K,S,D] -> [B,H,S,D].

    On CPU callers must pass interpret=True (the kernel body then executes
    as pure JAX ops); on TPU the Mosaic-compiled kernel runs with the
    BlockSpec VMEM tiling declared in flash_attention.py.
    """
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)
