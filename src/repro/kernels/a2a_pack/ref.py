"""Pure-jnp oracles for a2a_pack / a2a_unpack."""

from __future__ import annotations

import jax.numpy as jnp


def a2a_pack_ref(x, idx, block_rows: int = 1):
    """out block m = x block idx[m] (block_rows=1: out[m] = x[idx[m]])."""
    if block_rows == 1:
        return jnp.take(x, idx, axis=0)
    n, d = x.shape
    blocks = x.reshape(n // block_rows, block_rows, d)
    return jnp.take(blocks, idx, axis=0).reshape(-1, d)


def a2a_unpack_ref(x, idx, n_out_blocks: int = 0, block_rows: int = 1):
    """out block idx[m] = x block m; unnamed output blocks are zero."""
    m = idx.shape[0]
    d = x.shape[-1]
    n_out = max(m, n_out_blocks)
    blocks = x.reshape(m, block_rows, d)
    out = jnp.zeros((n_out, block_rows, d), x.dtype)
    return out.at[idx].set(blocks).reshape(-1, d)
