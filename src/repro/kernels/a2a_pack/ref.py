"""Pure-jnp oracle for a2a_pack."""

from __future__ import annotations

import jax.numpy as jnp


def a2a_pack_ref(x, idx):
    """out[m] = x[idx[m]]."""
    return jnp.take(x, idx, axis=0)
