"""jit'd public wrapper for a2a_pack."""

from __future__ import annotations

from functools import partial

import jax

from .a2a_pack import a2a_pack
from .ref import a2a_pack_ref

__all__ = ["a2a_pack_op", "a2a_pack_ref"]


@partial(jax.jit, static_argnames=("interpret",))
def a2a_pack_op(x, idx, *, interpret: bool = False) -> jax.Array:
    return a2a_pack(x, idx, interpret=interpret)
