"""jit'd public wrappers for a2a_pack / a2a_unpack."""

from __future__ import annotations

from functools import partial

import jax

from .a2a_pack import a2a_pack, a2a_unpack
from .ref import a2a_pack_ref, a2a_unpack_ref

__all__ = ["a2a_pack_op", "a2a_pack_ref", "a2a_unpack_op", "a2a_unpack_ref"]


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def a2a_pack_op(x, idx, *, block_rows: int = 1,
                interpret: bool = False) -> jax.Array:
    return a2a_pack(x, idx, block_rows=block_rows, interpret=interpret)


@partial(jax.jit,
         static_argnames=("n_out_blocks", "block_rows", "interpret"))
def a2a_unpack_op(x, idx, *, n_out_blocks: int = 0, block_rows: int = 1,
                  interpret: bool = False) -> jax.Array:
    return a2a_unpack(x, idx, n_out_blocks=n_out_blocks,
                      block_rows=block_rows, interpret=interpret)
