"""Destination-contiguous token packing Pallas kernel (paper section 5 (2)).

FLASH's implementation note: "bundle the data having the same destination
... eliminating data fragmentation and allowing for consecutive memory
reads."  On TPU the analogue is packing routed token rows into
destination-contiguous order *before* the dispatch All-to-All so every
ppermute chunk is one contiguous HBM stream (and the 128-lane tiles stay
dense).

The kernel is a row gather driven from scalar-prefetch memory: the index
vector rides in SMEM ahead of the grid, and each grid step's *input*
BlockSpec index_map dereferences it -- so the DMA engine fetches exactly the
source row each output slot needs (a data-dependent DMA schedule, no
gather lowering in XLA).  Row blocks of 8 keep the (8, 128) sublane tile
dense; D must be a multiple of 128.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(idx_ref, x_ref, o_ref):
    del idx_ref  # consumed by the index map
    o_ref[...] = x_ref[...]


def a2a_pack(
    x: jax.Array,          # [N, D] token rows
    idx: jax.Array,        # [M] int32: output row m <- x[idx[m]]
    *,
    interpret: bool = False,
) -> jax.Array:
    n, d = x.shape
    m = idx.shape[0]

    return pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx.astype(jnp.int32), x)
