"""Destination-contiguous token packing Pallas kernels (paper section 5 (2)).

FLASH's implementation note: "bundle the data having the same destination
... eliminating data fragmentation and allowing for consecutive memory
reads."  On TPU the analogue is packing routed token rows into
destination-contiguous order *before* the dispatch All-to-All so every
ppermute chunk is one contiguous HBM stream (and the 128-lane tiles stay
dense).  ``a2a_unpack`` is the inverse scatter used after the exchange to
put each received stage buffer back at its source-shard slot.

Both kernels are gathers/scatters driven from scalar-prefetch memory: the
index vector rides in SMEM ahead of the grid, and each grid step's
BlockSpec index_map dereferences it -- so the DMA engine fetches (or
stores) exactly the block each slot needs: a data-dependent DMA schedule,
no gather lowering in XLA.

Block structure: ``block_rows`` rows move per index.  ``block_rows=1`` is
the general row gather; the plan-driven A2A path uses pod-sized blocks
(``block_rows = fast_size * capacity_rows``), and when ``block_rows`` is a
multiple of 8 the grid tiles each block into (8, D) sublane tiles so the
f32 (8, 128) register tile stays dense.  ``D`` need not be a multiple of
128: inputs are zero-padded up to the next lane-tile boundary and the
result sliced back (pad-and-slice fallback).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANE = 128       # last-dim tile width every TPU dtype shares
_SUBLANE = 8      # f32 second-minor tile height


def _pad_lanes(x: jax.Array) -> jax.Array:
    """Zero-pad the last dim up to the next multiple of the 128-lane tile."""
    d = x.shape[-1]
    if d % _LANE == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, _LANE - d % _LANE)))


def _copy_kernel(idx_ref, x_ref, o_ref):
    del idx_ref  # consumed by the index maps
    o_ref[...] = x_ref[...]


def _block_call(x, idx, *, n_out_rows: int, block_rows: int,
                in_map, out_map, interpret: bool):
    """Shared pallas_call builder for pack (gather) and unpack (scatter).

    ``in_map`` / ``out_map`` build the BlockSpec index maps from the
    per-sublane-tile block count ``t`` (blocks per index step); the grid is
    (m,) for single-tile blocks and (m, t) when ``block_rows`` splits into
    8-row sublane tiles.
    """
    d_in = x.shape[-1]
    xp = _pad_lanes(x)
    d = xp.shape[-1]
    m = idx.shape[0]
    if block_rows % _SUBLANE == 0 and block_rows > _SUBLANE:
        t = block_rows // _SUBLANE
        grid = (m, t)
        rows = _SUBLANE
        semantics = ("arbitrary", "arbitrary")
    else:
        t = 1
        grid = (m,)
        rows = block_rows
        semantics = ("arbitrary",)
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((rows, d), in_map(t))],
            out_specs=pl.BlockSpec((rows, d), out_map(t)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_out_rows, d), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(idx.astype(jnp.int32), xp)
    return out[:, :d_in] if d != d_in else out


def a2a_pack(
    x: jax.Array,          # [N, D] token rows (N % block_rows == 0)
    idx: jax.Array,        # [M] int32 block indices: output block m
                           #     <- x rows [idx[m]*r, (idx[m]+1)*r)
    *,
    block_rows: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Gather ``block_rows``-row blocks of ``x`` in ``idx`` order.

    ``block_rows=1`` is the plain row gather ``out[m] = x[idx[m]]``.
    Returns ``[M * block_rows, D]``.
    """
    n, _ = x.shape
    m = idx.shape[0]
    r = block_rows
    if r < 1 or n % r != 0:
        raise ValueError(f"block_rows={r} must divide N={n}")

    if r % _SUBLANE == 0 and r > _SUBLANE:
        # grid (m, t): tile j of output block i <- tile j of block idx[i].
        def in_map(t):
            return lambda i, j, idx_ref: (idx_ref[i] * t + j, 0)

        def out_map(t):
            return lambda i, j, idx_ref: (i * t + j, 0)
    else:
        def in_map(t):
            del t
            return lambda i, idx_ref: (idx_ref[i], 0)

        def out_map(t):
            del t
            return lambda i, idx_ref: (i, 0)

    return _block_call(x, idx, n_out_rows=m * r, block_rows=r,
                       in_map=in_map, out_map=out_map, interpret=interpret)


def a2a_unpack(
    x: jax.Array,          # [M * block_rows, D] packed rows
    idx: jax.Array,        # [M] int32 block indices: output block idx[m]
                           #     <- x rows [m*r, (m+1)*r)
    *,
    n_out_blocks: int = 0,  # output blocks (0 => M); blocks not named by
                            # idx are unspecified (NaN-filled in interpret
                            # mode, stale HBM on hardware) -- callers slice
                            # a trash block off, never read it
    block_rows: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Inverse scatter of ``a2a_pack``: output block ``idx[m]`` <- block
    ``m`` of ``x`` (``block_rows=1``: ``out[idx[m]] = x[m]``).

    ``idx`` must be injective over real output blocks (one writer each;
    duplicate writes to a sliced-off trash block are tolerated -- the grid
    is serial, one lands).  Output blocks not named by ``idx`` are
    unspecified -- full-coverage permutations (the plan-exec use) define
    every real row.  Returns ``[max(M, n_out_blocks) * block_rows, D]``.
    """
    n, _ = x.shape
    m = idx.shape[0]
    r = block_rows
    if r < 1 or n != m * r:
        raise ValueError(f"x rows {n} != M*block_rows = {m}*{r}")
    n_out = max(m, n_out_blocks) * r

    if r % _SUBLANE == 0 and r > _SUBLANE:
        def in_map(t):
            return lambda i, j, idx_ref: (i * t + j, 0)

        def out_map(t):
            return lambda i, j, idx_ref: (idx_ref[i] * t + j, 0)
    else:
        def in_map(t):
            del t
            return lambda i, idx_ref: (i, 0)

        def out_map(t):
            del t
            return lambda i, idx_ref: (idx_ref[i], 0)

    return _block_call(x, idx, n_out_rows=n_out, block_rows=r,
                       in_map=in_map, out_map=out_map, interpret=interpret)
