from .ops import a2a_pack_op, a2a_pack_ref, a2a_unpack_op, a2a_unpack_ref

__all__ = ["a2a_pack_op", "a2a_pack_ref", "a2a_unpack_op", "a2a_unpack_ref"]
