# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from .. import jax_compat  # noqa: F401  (installs shims on older jax)
