"""Fault-tolerant training runtime.

Production loop around a jit'd train_step:
  * auto-resume: restores the newest committed checkpoint on start, so a
    preempted/crashed job relaunches and continues bit-identically (the data
    pipeline is (seed, step)-deterministic);
  * preemption handling: SIGTERM/SIGINT trigger an emergency checkpoint at
    the next step boundary before exit (the TPU-pod eviction contract);
  * straggler watchdog: per-step wall times tracked against a rolling
    median; steps slower than ``straggler_factor``x median are surfaced to a
    callback (on a real fleet this feeds the replacement/elastic controller;
    FLASH itself removes *collective-level* stragglers, this watches the
    *host/step* level);
  * metrics JSONL log for post-hoc analysis.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import statistics
import time
from typing import Any, Callable, Dict, Optional

import jax

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 200
    keep_last: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 32


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        train_step: Callable,          # (state, batch) -> (state, metrics)
        init_state: Callable[[], Any],
        batches: Callable[[int], Dict],  # step -> host batch
        straggler_cb: Optional[Callable[[int, float, float], None]] = None,
        state_shardings: Any = None,
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.init_state = init_state
        self.batches = batches
        self.straggler_cb = straggler_cb or self._default_straggler_cb
        self.state_shardings = state_shardings
        self._preempted = False
        self._step_times: list = []
        self._straggler_events: list = []

    # -- fault tolerance ---------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _resume_or_init(self):
        state = self.init_state()
        start = 0
        if latest_step(self.cfg.ckpt_dir) is not None:
            state, start = restore_checkpoint(
                self.cfg.ckpt_dir, state, shardings=self.state_shardings)
        return state, start

    def _default_straggler_cb(self, step: int, dt: float, median: float):
        self._straggler_events.append(
            {"step": step, "dt": dt, "median": median})

    # -- main loop ----------------------------------------------------------

    def run(self) -> Dict[str, Any]:
        self._install_signal_handlers()
        os.makedirs(self.cfg.ckpt_dir, exist_ok=True)
        log_path = os.path.join(self.cfg.ckpt_dir, "metrics.jsonl")
        state, start = self._resume_or_init()
        last_metrics: Dict[str, float] = {}
        with open(log_path, "a") as log:
            for step in range(start, self.cfg.total_steps):
                t0 = time.perf_counter()
                batch = self.batches(step)
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(jax.tree.leaves(state)[0])
                dt = time.perf_counter() - t0
                self._watch_straggler(step, dt)
                last_metrics = {k: float(v) for k, v in metrics.items()}
                if step % self.cfg.log_every == 0 or \
                        step == self.cfg.total_steps - 1:
                    rec = {"step": step, "dt_s": dt, **last_metrics}
                    log.write(json.dumps(rec) + "\n")
                    log.flush()
                boundary = (step + 1) % self.cfg.ckpt_every == 0
                if boundary or self._preempted or \
                        step == self.cfg.total_steps - 1:
                    save_checkpoint(self.cfg.ckpt_dir, step + 1, state,
                                    keep_last=self.cfg.keep_last)
                if self._preempted:
                    return {"state": state, "stopped_at": step + 1,
                            "preempted": True, "metrics": last_metrics,
                            "stragglers": self._straggler_events}
        return {"state": state, "stopped_at": self.cfg.total_steps,
                "preempted": False, "metrics": last_metrics,
                "stragglers": self._straggler_events}

    def _watch_straggler(self, step: int, dt: float):
        w = self._step_times
        w.append(dt)
        if len(w) > self.cfg.straggler_window:
            w.pop(0)
        if len(w) >= 8:
            med = statistics.median(w)
            if dt > self.cfg.straggler_factor * med:
                self.straggler_cb(step, dt, med)
