"""Serving-step construction + a batched-request demo server.

``make_serve_step`` builds the jit'd one-token decode step against a KV
cache / recurrent state for a shape cell; ``make_prefill_step`` builds the
prompt pass.  Run directly for a CPU-scale batched-serving demo:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..configs import ModelConfig, get_config, smoke_config
from ..models import build_model, use_mesh_rules
from .shardings import cache_shardings, param_shardings
from .train import make_dist_context, make_rules

__all__ = ["make_serve_step", "make_prefill_step", "serve_state_shapes"]


def serve_state_shapes(cfg: ModelConfig, mesh: Optional[Mesh],
                       batch: int, seq_len: int):
    """(params_shape, params_sh, cache_shape, cache_sh) -- no allocation."""
    model = build_model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(batch, seq_len))
    if mesh is None:
        return params_shape, None, cache_shape, None
    return (params_shape, param_shardings(cfg, mesh, params_shape),
            cache_shape, cache_shardings(cfg, mesh, cache_shape))


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh],
                    a2a_impl: Optional[str] = None, plan=None):
    """jit'd (params, cache, tokens [B], pos) -> (logits [B, V], cache).

    ``a2a_impl`` selects the MoE dispatch schedule through the comm-layer
    registry (flash | direct | hierarchical | plan), defaulting to the
    config's.  ``plan`` is the synthesized Plan/ExecutableSchedule that
    backs ``"plan"`` (and that ``"auto"`` prefers); pair with
    ``serving.PlanClient.get_device_schedule`` for the daemon handoff.
    """
    model = build_model(cfg)
    dist = make_dist_context(cfg, mesh, a2a_impl, plan=plan) \
        if mesh is not None else None
    rules = make_rules(cfg, mesh) if mesh is not None else None

    def serve_step(params, cache, tokens, pos):
        with use_mesh_rules(rules):
            return model.decode_step(params, cache, tokens, pos, dist)

    if mesh is None:
        return jax.jit(serve_step)
    return jax.jit(serve_step, donate_argnums=(1,))


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh],
                      a2a_impl: Optional[str] = None, plan=None):
    """jit'd (params, batch) -> (logits, cache | aux)."""
    model = build_model(cfg)
    dist = make_dist_context(cfg, mesh, a2a_impl, plan=plan) \
        if mesh is not None else None
    rules = make_rules(cfg, mesh) if mesh is not None else None

    def prefill_step(params, batch):
        with use_mesh_rules(rules):
            return model.prefill(params, batch, dist)

    return jax.jit(prefill_step)


# -- CPU-scale batched-serving demo ------------------------------------------

def _plan_dispatch_schedules(gen_len: int, use_plan_server: bool) -> None:
    """Plan the MoE dispatch schedule each decode step would issue.

    Models the testbed fabric (4 servers x 8 GPUs) and one drifting MoE
    dispatch matrix per generated token.  With ``use_plan_server`` the
    plan requests route through the serving daemon (``repro.serving``);
    the default stays on the inline path -- ``simulate_many`` over a
    process-local PlanCache -- so the two paths print side by side
    comparable hit rates.
    """
    from ..core.plan import PlanCache
    from ..core.simulator import simulate_many
    from ..core.traffic import ClusterSpec, moe_workload

    cluster = ClusterSpec(n_servers=4, m_gpus=8)
    # Each decode step re-draws gating for the same token budget; every
    # 4th step repeats a seed (hot signatures), the rest drift.
    traj = [moe_workload(cluster, tokens_per_gpu=2048, bytes_per_token=2,
                         seed=(step // 4 if step % 4 == 0 else step))
            for step in range(gen_len)]
    t0 = time.perf_counter()
    if use_plan_server:
        from ..serving import PlanClient, PlanServer

        with PlanServer(workers=2) as srv:
            client = PlanClient(srv, algorithm="flash")
            results = client.simulate_many(traj)
            # Device handoff: each distinct signature's plan comes back
            # with its lowered stage tables; repeats reuse the memoized
            # lowering (counters["lowered"] counts only the cache misses).
            scheds = [client.get_device_schedule(w)[1] for w in traj]
            srv.drain(10.0)
            stats = srv.telemetry_snapshot()
        counters = stats["counters"]
        route = (f"plan-server: hits={counters.get('hits', 0)} "
                 f"warm={counters.get('warm', 0)} "
                 f"cold={counters.get('cold', 0)} "
                 f"upgrades={counters.get('upgrades', 0)}")
        n_stages = sorted({s.n_stages for s in scheds})
        print(f"device handoff: {len(scheds)} schedules, "
              f"{client.counters['lowered']} lowered "
              f"({len(scheds) - client.counters['lowered']} memoized); "
              f"stage counts {n_stages}")
    else:
        cache = PlanCache(capacity=256, warm_start=True)
        results = simulate_many(traj, "flash", cache=cache)
        route = (f"inline: hits={cache.hits} misses={cache.misses} "
                 f"warm={cache.warm_hits}")
    dt = time.perf_counter() - t0
    mean_us = float(np.mean([r.completion_time for r in results])) * 1e6
    print(f"dispatch planning [{route}] {len(traj)} steps in {dt:.3f}s; "
          f"mean schedule completion {mean_us:.1f}us")


def main():
    from ..comm.all_to_all import available_all_to_all_impls

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--a2a", default=None,
                    choices=available_all_to_all_impls() + ["auto"],
                    help="MoE All-to-All schedule (registry name, or "
                         "'auto' to resolve from the fabric topology); "
                         "defaults to the arch config's a2a_impl")
    ap.add_argument("--plan-server", action="store_true",
                    help="route dispatch-schedule planning through the "
                         "plan-serving daemon (repro.serving) instead of "
                         "the inline PlanCache path")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.a2a:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, a2a_impl=args.a2a)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    total = args.prompt_len + args.gen_len

    from ..models.transformer import lm_prefill
    t0 = time.perf_counter()
    logits, cache = lm_prefill(cfg, params, jnp.asarray(prompts),
                               cache_len=total)
    toks = jnp.argmax(logits, -1)
    step = make_serve_step(cfg, mesh=None)
    out = [toks]
    for t in range(args.prompt_len, total - 1):
        logits, cache = step(params, cache, toks, jnp.int32(t))
        toks = jnp.argmax(logits, -1)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    tput = args.batch * gen.shape[1] / dt
    print(f"arch={cfg.name} batch={args.batch} generated={gen.shape[1]} "
          f"tokens/req; {tput:.1f} tok/s total")
    print("sample:", gen[0][:16])
    _plan_dispatch_schedules(args.gen_len, args.plan_server)


if __name__ == "__main__":
    main()
