"""Train-step construction + end-to-end training driver.

``make_train_step`` builds the jit'd (state, batch) -> (state, metrics)
function with full sharding annotations; it is consumed by the dry-run
(lowering only), the examples, and the fault-tolerant Trainer runtime.

Run directly for a real (CPU-scale) training session:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 100 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs import ModelConfig, get_config, smoke_config
from ..models import DistContext, MeshRules, build_model, choose_ep_axes, \
    use_mesh_rules
from ..optim import AdamWConfig, adamw_update, cosine_schedule, \
    init_opt_state
from .mesh import dp_axes, slow_axis
from .shardings import batch_shardings, state_shardings

__all__ = ["make_dist_context", "make_rules", "make_train_step",
           "make_train_state_shapes", "TrainOptions"]


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    adamw: AdamWConfig = AdamWConfig()
    # beyond-paper distributed-optimization knobs
    grad_compression: bool = False   # int8 EF all-gather over the pod axis
    microbatches: int = 1            # grad accumulation: divides live
                                     # activation memory, same math


def make_dist_context(cfg: ModelConfig, mesh: Mesh,
                      a2a_impl: Optional[str] = None,
                      plan=None) -> DistContext:
    """Build the DistContext; ``a2a_impl`` overrides the config's choice.

    ``plan`` (a core.plan.Plan or simulator.ExecutableSchedule) backs
    ``a2a_impl="plan"`` and is preferred by ``"auto"``; it rides along in
    the context so model code never threads it explicitly.

    The implementation name is validated against the one comm-layer
    registry (comm.all_to_all) so every entry point -- training, serving,
    dry-run sweeps -- fails fast on a typo instead of inside shard_map.
    """
    from ..comm.all_to_all import all_to_all_by_name

    impl = a2a_impl or cfg.a2a_impl
    if impl != "auto":
        all_to_all_by_name(impl)  # raises ValueError on unknown impls
    if impl == "plan" and plan is None:
        raise ValueError('a2a_impl="plan" needs a synthesized plan; pass '
                         "plan= (e.g. from serving.client.PlanClient)")
    return DistContext(
        mesh=mesh,
        dp_axes=dp_axes(mesh),
        slow_axis=slow_axis(mesh),
        ep_axes=choose_ep_axes(cfg, mesh),
        a2a_impl=impl,
        plan=plan,
    )


def make_rules(cfg: ModelConfig, mesh: Mesh) -> MeshRules:
    act_seq = "model" if cfg.seq_shard_activations else None
    if cfg.pure_dp:
        # no TP: weights replicated (or FSDP-stored); batch over every axis
        # unless FSDP needs the model axis for parameter storage
        batch = dp_axes(mesh) if cfg.fsdp else tuple(mesh.axis_names)
        return MeshRules(mesh=mesh, batch=batch,
                         act_seq=None, heads=None, kv_heads=None,
                         head_dim=None, ff=None, vocab=None,
                         expert_ff=None, model_dim=None, kv_feature=None)
    return MeshRules(mesh=mesh, batch=dp_axes(mesh), act_seq=act_seq)


def make_train_state_shapes(cfg: ModelConfig, mesh: Optional[Mesh]):
    """abstract state tree (no allocation) + shardings."""
    model = build_model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(init_opt_state, params_shape)
    state_shape = {"params": params_shape, "opt": opt_shape,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if mesh is None:
        return state_shape, None
    return state_shape, state_shardings(cfg, mesh, state_shape)


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh],
                    options: TrainOptions = TrainOptions()):
    """Returns (train_step, state_shape, state_shardings, batch_fn).

    train_step is already jit'd with in/out shardings when a mesh is given.
    """
    model = build_model(cfg)
    dist = make_dist_context(cfg, mesh) if mesh is not None else None
    rules = make_rules(cfg, mesh) if mesh is not None else None
    lr_fn = cosine_schedule(options.peak_lr, options.warmup_steps,
                            options.total_steps)

    def train_step(state, batch):
        with use_mesh_rules(rules):
            def loss_fn(params, mb):
                loss, metrics = model.loss(params, mb, dist)
                return loss, metrics

            n_mb = options.microbatches
            if n_mb > 1:
                # grad accumulation over sequential microbatches: live
                # activations shrink n_mb-fold; grads accumulate in f32
                mbs = jax.tree.map(
                    lambda a: a.reshape((n_mb, a.shape[0] // n_mb)
                                        + a.shape[1:])
                    if a.ndim else a, batch)

                def mb_body(acc, mb):
                    (l, m), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(state["params"], mb)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32) / n_mb,
                        acc, g)
                    return acc, (l, m)

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                grads, (losses, metricses) = jax.lax.scan(
                    mb_body, zero, mbs)
                metrics = jax.tree.map(lambda x: x.mean(0), metricses)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], batch)
            if options.grad_compression and dist is not None \
                    and dist.slow_axis is not None:
                grads = _compress_pod_grads(grads, dist)
            lr = lr_fn(state["step"])
            new_params, new_opt, gnorm = adamw_update(
                grads, state["opt"], state["params"], lr, options.adamw)
            new_state = {"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1}
            metrics = dict(metrics)
            metrics["grad_norm"] = gnorm
            metrics["lr"] = lr
            return new_state, metrics

    state_shape, state_sh = make_train_state_shapes(cfg, mesh)
    if mesh is None:
        return jax.jit(train_step), state_shape, None, None

    def batch_sharding_fn(batch_shape):
        return batch_shardings(mesh, batch_shape,
                               pure_dp=cfg.pure_dp and not cfg.fsdp)

    metrics_shape = {"loss": 0., "nll": 0., "aux": 0., "ppl_proxy": 0.,
                     "grad_norm": 0., "lr": 0.}
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_shape)
    step = jax.jit(
        train_step,
        donate_argnums=(0,),
        out_shardings=(state_sh, repl),
    )
    return step, state_shape, state_sh, batch_sharding_fn


def _compress_pod_grads(grads, dist: DistContext):
    """int8 error-feedback grad sync over the DCN axis (stateless form:
    the quantization residual is re-derived per step inside the island;
    see repro.comm.collectives for the stateful carry variant used in the
    examples)."""
    from ..comm.collectives import ef_compressed_psum

    def island(g):
        total, _err = ef_compressed_psum(g, dist.slow_axis)
        return total / jax.lax.psum(1, dist.slow_axis)

    def one(g):
        # check_vma off: the dequantized sum over the gathered pod axis is
        # pod-invariant by construction, which the checker cannot prove.
        return jax.shard_map(
            island, mesh=dist.mesh, in_specs=P(), out_specs=P(),
            axis_names={dist.slow_axis}, check_vma=False)(g)

    return jax.tree.map(one, grads)


# -- CLI driver (real run, CPU-scale) ----------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opts = TrainOptions(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps)
    step_fn, state_shape, _, _ = make_train_step(cfg, mesh=None,
                                                 options=opts)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}

    from ..data import DataConfig, SyntheticLM
    from ..runtime import Trainer, TrainerConfig

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch), cfg)

    def batches(step: int) -> Dict[str, Any]:
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 4, 1)),
        train_step=step_fn,
        init_state=lambda: state,
        batches=batches,
    )
    result = trainer.run()
    print(f"finished at step {result['stopped_at']} "
          f"loss={result['metrics'].get('loss'):.4f} "
          f"preempted={result['preempted']}")


if __name__ == "__main__":
    main()
