"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state.  The production target is TPU v5e: one pod = 16x16 = 256
chips on ICI; the multi-pod mesh adds the DCN "pod" axis (the paper's slow
inter-server network).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from .. import jax_compat  # noqa: F401  (installs shims on older jax)

try:  # AxisType landed after jax 0.4.x; plain meshes behave the same way
    from jax.sharding import AxisType
except ImportError:
    AxisType = None

__all__ = ["make_production_mesh", "make_mesh", "dp_axes", "slow_axis"]


def _axis_type_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Mesh over the first prod(shape) devices (works on subsets, so small
    test meshes can be carved out of the 512 dry-run host devices)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"need {n} devices for mesh {shape}, have {len(devices)}")
    if len(devices) == n:
        return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
    arr = np.asarray(devices[:n]).reshape(shape)
    return Mesh(arr, axes, **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the batch shards over (everything except the TP axis)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def slow_axis(mesh: Mesh) -> Optional[str]:
    return "pod" if "pod" in mesh.axis_names else None
