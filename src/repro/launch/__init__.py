"""Launcher surface: mesh construction, sharding rules, dry-run, drivers.

NOTE: do not import ``dryrun`` from here -- importing it sets XLA_FLAGS for
512 host devices, which must only happen in a dedicated process.
"""

from .mesh import dp_axes, make_mesh, make_production_mesh, slow_axis

__all__ = ["dp_axes", "make_mesh", "make_production_mesh", "slow_axis"]
