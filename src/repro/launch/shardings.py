"""Parameter / state / batch sharding rules (path-based, MaxText-style).

``param_spec(path, ndim)`` matches the *trailing* dimensions of a leaf by
its name and pads leading dims (the scan-stacked layer axis) with None.
The same table covers optimizer moments (same spec as their parameter) and
decode caches.

Conventions (production mesh: pod x data x model):
  * TP over "model": attention heads / FFN hidden / vocab.
  * DP over ("pod", "data"): batch dim of activations, caches, token inputs.
  * EP over choose_ep_axes(cfg, mesh): expert-stacked MoE weight dim.
  * KV caches shard head_dim over "model" (always divisible: 64/128) and
    batch over DP -- decode attention becomes a dh-partial dot + psum,
    parallelizing cache bandwidth, the decode bottleneck.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.registry import ModelConfig
from ..models.dist import choose_ep_axes

__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "state_shardings", "spec_tree"]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# trailing-dim spec tables ---------------------------------------------------

_MOE_TABLE = {
    "router": (None, None),
    "w_gate": ("__ep__", None, "model"),
    "w_up": ("__ep__", None, "model"),
    "w_down": ("__ep__", "model", None),
}

_PARAM_TABLE = {
    # embeddings / heads
    "embed": ("model", None),
    "lm_head": (None, "model"),
    "enc_pos": (None, None),
    "dec_pos": (None, None),
    # attention
    "wq": (None, "model"),
    "wk": (None, "model"),
    "wv": (None, "model"),
    "wo": ("model", None),
    "q_norm": (None,),
    "k_norm": (None,),
    # dense mlp
    "w_gate": (None, "model"),
    "w_up": (None, "model"),
    "w_down": ("model", None),
    "b_up": ("model",),
    "b_down": (None,),
    # xlstm
    "wif": (None, "model"),
    "wz": (None, "model"),
    "w": (None, "model"),
    "r": (None, "model"),
    # mamba
    "in_proj": (None, "model"),
    "out_proj": ("model", None),
    "conv_w": (None, "model"),
    "a_log": ("model", None),
    "d_skip": ("model",),
    "wb": ("model", None),
    "wc": ("model", None),
    "w_dt": ("model", None),
    "w_dt2": (None, "model"),
    "dt_bias": ("model",),
    # norms
    "scale": (None,),
    "bias": (None,),
}

_CACHE_TABLE = {
    # [*, B, phys, K, dh]
    "k": ("__dp__", None, None, "model"),
    "v": ("__dp__", None, None, "model"),
    "xk": ("__dp__", None, None, "model"),
    "xv": ("__dp__", None, None, "model"),
    # mlstm state
    "C": ("__dp__", None, None, "model"),
    "n": ("__dp__", None, "model"),
    "m": ("__dp__", None),
    # slstm state
    "c": ("__dp__", "model"),
    "h": ("__dp__", "model", None),   # also mamba h [B, d_in, N]
    # mamba conv window [B, K-1, d_in]
    "conv": ("__dp__", None, "model"),
}

# slstm n/h/m collide with mlstm names at different ranks; rank disambiguates.
_CACHE_BY_RANK = {
    ("n", 2): ("__dp__", "model"),
    ("h", 2): ("__dp__", "model"),
    ("m", 1): ("__dp__",),
    ("m", 2): ("__dp__", None),
}


def _resolve(entry, ep, dp):
    out = []
    for e in entry:
        if e == "__ep__":
            out.append(ep)
        elif e == "__dp__":
            out.append(dp)
        else:
            out.append(e)
    return tuple(out)


def _axis_size(mesh: Mesh, entry) -> int:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    if entry is None:
        return 1
    if isinstance(entry, str):
        return shape[entry]
    n = 1
    for a in entry:
        n *= shape[a]
    return n


def _drop_uneven(mesh: Mesh, entry: tuple, shape: tuple) -> tuple:
    """jit in_shardings demand even divisibility; replicate dims that the
    assigned axes do not divide (odd vocab sizes, batch=1 decode, 14-head
    attention on a 16-way TP axis, ...)."""
    out = []
    for dim, e in zip(shape, entry):
        if e is not None and dim % _axis_size(mesh, e) != 0:
            e = None
        out.append(e)
    return tuple(out)


def _trailing_spec(name: str, ndim: int, path: str, ep, dp) -> P:
    in_moe = "/moe/" in path or path.endswith("moe")
    table = dict(_PARAM_TABLE)
    if in_moe:
        table.update(_MOE_TABLE)
    entry = table.get(name)
    if entry is None:
        return P()  # replicate unknown leaves
    entry = _resolve(entry, ep, dp)
    if len(entry) > ndim:
        entry = entry[len(entry) - ndim:]
    pad = (None,) * (ndim - len(entry))
    return P(*(pad + tuple(entry)))


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape):
    """Tree of NamedSharding matching a params shape-tree."""
    ep_axes = choose_ep_axes(cfg, mesh)
    ep = None if ep_axes is None else \
        (ep_axes if len(ep_axes) > 1 else ep_axes[0])
    dp = tuple(a for a in mesh.axis_names if a != "model")

    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(path, leaf):
        name = None
        for part in reversed(path):
            if hasattr(part, "key"):
                name = str(part.key)
                break
        spec = _trailing_spec(name or "", leaf.ndim, _path_str(path), ep, dp)
        entry = tuple(spec)
        if cfg.pure_dp:  # small models: replicate weights, no TP
            entry = tuple(None if e == "model" else e for e in entry)
        if cfg.fsdp and leaf.ndim >= 2:
            # ZeRO-3: additionally shard each weight over the *intra-pod*
            # DP axes on the first free, evenly-divisible dim (GSPMD
            # inserts the FSDP all-gather before use / reduce-scatter on
            # grads).  The pod axis is deliberately excluded: per-layer
            # weight gathers are the hottest collective in the step and
            # must ride ICI, not DCN -- the paper's keep-the-slow-tier-
            # clean principle applied to parameter sharding.
            fsdp_dp = tuple(a for a in mesh.axis_names if a != "pod") \
                if cfg.pure_dp else (tuple(a for a in dp if a != "pod")
                                     or dp)
            fsdp_entry = fsdp_dp if len(fsdp_dp) > 1 else fsdp_dp[0]
            used = {a for e in entry if e
                    for a in ((e,) if isinstance(e, str) else e)}
            if not used & set(fsdp_dp):
                for i, (e, dim) in enumerate(zip(entry, leaf.shape)):
                    if e is None and dim % _axis_size(mesh, fsdp_entry) == 0:
                        entry = entry[:i] + (fsdp_entry,) + entry[i + 1:]
                        break
        entry = _drop_uneven(mesh, entry, leaf.shape)
        return NamedSharding(mesh, P(*entry))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape):
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_entry = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        name = None
        for part in reversed(path):
            if hasattr(part, "key"):
                name = str(part.key)
                break
        # strip the scan-stacked layer dim if present
        entry = _CACHE_BY_RANK.get((name, leaf.ndim)) \
            or _CACHE_BY_RANK.get((name, leaf.ndim - 1)) \
            or _CACHE_TABLE.get(name)
        if entry is None:
            return NamedSharding(mesh, P())
        entry = _resolve(entry, None, dp_entry)
        if len(entry) > leaf.ndim:
            entry = entry[len(entry) - leaf.ndim:]
        pad = (None,) * (leaf.ndim - len(entry))
        entry = _drop_uneven(mesh, pad + tuple(entry), leaf.shape)
        return NamedSharding(mesh, P(*entry))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_shardings(mesh: Mesh, batch_shape, pure_dp: bool = False):
    dp = tuple(mesh.axis_names) if pure_dp \
        else tuple(a for a in mesh.axis_names if a != "model")
    dp_entry = dp if len(dp) > 1 else dp[0]

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        entry = _drop_uneven(
            mesh, (dp_entry,) + (None,) * (leaf.ndim - 1), leaf.shape)
        return NamedSharding(mesh, P(*entry))

    return jax.tree.map(one, batch_shape)


def state_shardings(cfg: ModelConfig, mesh: Mesh, state_shape):
    """TrainState = {params, opt(m, v, count), step}: moments follow params."""
    from ..optim import OptState  # avoid cycle
    del OptState
    params_sh = param_shardings(cfg, mesh, state_shape["params"])
    m_sh = param_shardings(cfg, mesh, state_shape["opt"].m)
    v_sh = param_shardings(cfg, mesh, state_shape["opt"].v)
    opt_sh = type(state_shape["opt"])(
        m=m_sh, v=v_sh, count=NamedSharding(mesh, P()))
    return {"params": params_sh, "opt": opt_sh,
            "step": NamedSharding(mesh, P())}


def spec_tree(shardings):
    return jax.tree.map(lambda s: s.spec, shardings)
