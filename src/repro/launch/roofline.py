"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), TPU v5e constants:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
    memory     = HLO_bytes_per_chip / HBM_bw              [s]
    collective = collective_bytes_per_chip / link_bw      [s]

``cost_analysis`` of the SPMD-partitioned executable reports the
*per-device* program, so terms divide by per-chip peaks directly.
Collective bytes are not in cost_analysis: we parse the optimized HLO and
sum sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with two refinements beyond the assignment's floor:

  * wire-byte factors per op (ring all-reduce moves ~2x its operand, an
    all-to-all moves (n-1)/n of it, a permute moves 1x), and
  * a two-tier split: replica groups that span pods (device ids crossing a
    256-chip boundary on the pod-major mesh) are DCN collectives -- the
    paper's slow tier -- reported separately from ICI collectives.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["HW", "CollectiveStats", "parse_collectives", "roofline_terms"]

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link (assignment constant)
DCN_BW = 25e9                # bytes/s per chip across pods (refined tier)


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    dcn_bw: float = DCN_BW


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(.*?)\}\}?,?")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}")

# per-op wire multiplier applied to the *result* bytes, group size n
def _wire_bytes(op: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / n
    if op == "all-gather":
        return result_bytes * (n - 1) / n        # result is gathered size
    if op == "reduce-scatter":
        return result_bytes * (n - 1)            # result is scattered shard
    if op == "all-to-all":
        return result_bytes * (n - 1) / n
    if op == "collective-permute":
        return float(result_bytes)
    return float(result_bytes)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _iota_groups(g: int, s: int, dims: List[int],
                 perm: Optional[List[int]]) -> np.ndarray:
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if perm:
        ids = ids.transpose(perm)
    return ids.reshape(g, s)


@dataclasses.dataclass
class CollectiveStats:
    simple_bytes: float = 0.0       # assignment floor: sum of op sizes
    wire_bytes: float = 0.0         # ring/permute-aware per-chip estimate
    ici_bytes: float = 0.0          # wire bytes on intra-pod groups
    dcn_bytes: float = 0.0          # wire bytes on pod-crossing groups
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0


_PROMOTED_RE = re.compile(r"to_apply=%\S*promoted")


def parse_collectives(hlo_text: str, pod_size: int = 256) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # count start ops once
        rb = _shape_bytes(shape_str)
        # The CPU backend promotes bf16 reductions to f32 on the wire
        # (to_apply=%add.clone_promoted); a TPU keeps them bf16.  Halve the
        # bytes of promoted reductions so terms reflect the TPU target.
        if _PROMOTED_RE.search(line):
            rb *= 0.5
        n, crosses = _group_info(line, pod_size)
        wb = _wire_bytes(op, rb, n)
        stats.simple_bytes += rb
        stats.wire_bytes += wb
        stats.by_op[op] = stats.by_op.get(op, 0.0) + wb
        if crosses:
            stats.dcn_bytes += wb
        else:
            stats.ici_bytes += wb
        stats.count += 1
    return stats


def _group_info(line: str, pod_size: int) -> Tuple[int, bool]:
    """(group size, does any group cross a pod boundary)."""
    mi = _GROUPS_IOTA_RE.search(line)
    if mi:
        g, s = int(mi.group(1)), int(mi.group(2))
        dims = [int(x) for x in mi.group(3).split(",")]
        perm = [int(x) for x in mi.group(4).split(",")] if mi.group(4) \
            else None
        groups = _iota_groups(g, s, dims, perm)
        crosses = bool(((groups // pod_size).max(axis=1)
                        != (groups // pod_size).min(axis=1)).any())
        return s, crosses
    ml = _GROUPS_LIST_RE.search(line)
    if ml:
        body = ml.group(1)
        sizes, crosses = [], False
        for grp in body.split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if not ids:
                continue
            sizes.append(len(ids))
            pods = {i // pod_size for i in ids}
            crosses |= len(pods) > 1
        return (max(sizes) if sizes else 1), crosses
    mp = _PERMUTE_PAIRS_RE.search(line)
    if mp:
        pairs = re.findall(r"\{(\d+),(\d+)\}", "{" + mp.group(1) + "}")
        crosses = any(int(a) // pod_size != int(b) // pod_size
                      for a, b in pairs)
        return 2, crosses
    return 1, False


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll: CollectiveStats, hw: HW = HW()) -> Dict[str, float]:
    compute = flops_per_chip / hw.peak_flops
    memory = bytes_per_chip / hw.hbm_bw
    collective_simple = coll.simple_bytes / hw.link_bw
    collective = coll.ici_bytes / hw.link_bw + coll.dcn_bytes / hw.dcn_bw
    dominant = max(
        [("compute", compute), ("memory", memory),
         ("collective", collective)], key=lambda kv: kv[1])[0]
    bound = max(compute, memory, collective)
    frac = compute / bound if bound > 0 else 0.0
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "collective_simple_s": collective_simple,
        "ici_bytes": coll.ici_bytes,
        "dcn_bytes": coll.dcn_bytes,
        "dominant": dominant,
        "roofline_fraction": frac,   # compute term / binding term
    }
