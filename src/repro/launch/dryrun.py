import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")  # mute SPMD copy warnings

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import -- jax locks the device
count at first init, and the dry-run needs 512 placeholder CPU devices to
build the production meshes:

    single pod : (16, 16)        ("data", "model")       256 chips
    multi-pod  : (2, 16, 16)     ("pod", "data", "model") 512 chips

For each cell this driver:
  1. builds abstract state/batch trees (ShapeDtypeStruct, no allocation),
  2. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...)``,
  3. ``.compile()``  -- sharding mismatches / OOM / unsupported collectives
     fail HERE and are bugs in the system,
  4. records memory_analysis(), cost_analysis(), and the parsed collective
     schedule (repro.launch.roofline) as JSON for EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
        --mesh multi --out results/
    python -m repro.launch.dryrun --all --mesh single --out results/
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from ..configs import SHAPES, get_config, list_archs, skip_reason
from ..models.model import input_specs
from .mesh import make_production_mesh
from .roofline import CollectiveStats, parse_collectives, roofline_terms
from .serve import make_prefill_step, make_serve_step, serve_state_shapes
from .shardings import batch_shardings
from .train import TrainOptions, make_train_step


def _lower_cell(cfg, shape, mesh, a2a_impl: Optional[str] = None,
                extra_overrides: Optional[dict] = None):
    """Returns (lowered, compiled) for one cell."""
    import dataclasses as dc
    overrides = dict(extra_overrides or {})
    if a2a_impl:
        overrides["a2a_impl"] = a2a_impl
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    batch_shape = jax.tree.map(
        lambda s: s,
        input_specs(cfg, shape.kind, shape.seq_len, shape.global_batch))

    if shape.kind == "train":
        step, state_shape, state_sh, batch_sh_fn = make_train_step(
            cfg, mesh, TrainOptions(microbatches=cfg.microbatches))
        batch_sh = batch_sh_fn(batch_shape)
        lowered = step.lower(
            _with_sh(state_shape, state_sh), _with_sh(batch_shape, batch_sh))
    elif shape.kind == "prefill":
        params_shape, params_sh, _, _ = serve_state_shapes(
            cfg, mesh, shape.global_batch, shape.seq_len)
        step = make_prefill_step(cfg, mesh)
        batch_sh = batch_shardings(mesh, batch_shape)
        lowered = step.lower(
            _with_sh(params_shape, params_sh),
            _with_sh(batch_shape, batch_sh))
    elif shape.kind == "decode":
        params_shape, params_sh, cache_shape, cache_sh = serve_state_shapes(
            cfg, mesh, shape.global_batch, shape.seq_len)
        step = make_serve_step(cfg, mesh)
        batch_sh = batch_shardings(mesh, batch_shape)
        lowered = step.lower(
            _with_sh(params_shape, params_sh),
            _with_sh(cache_shape, cache_sh),
            _with_sh({"t": batch_shape["tokens"]},
                     {"t": batch_sh["tokens"]})["t"],
            _with_sh({"p": batch_shape["pos"]},
                     {"p": batch_sh["pos"]})["p"])
    else:
        raise ValueError(shape.kind)
    compiled = lowered.compile()
    return lowered, compiled


def _with_sh(shape_tree, sh_tree):
    """Attach shardings to ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree, sh_tree)


def _cell_costs(compiled) -> tuple:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text(), pod_size=256)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _extrapolated_costs(cfg, shape, mesh, a2a_impl, overrides):
    """XLA cost analysis counts a while-loop (scan-over-layers) body ONCE.

    For scanned archs we therefore lower unrolled 2- and 3-layer variants
    and extrapolate linearly in layer count: cost(L) = c2 + (L-2)*(c3-c2).
    Memory analysis / compile proof still come from the true scanned module.
    """
    import dataclasses as dc
    vals = {}
    for l in (2, 3):
        c = dc.replace(cfg, n_layers=l, scan_layers=False)
        _, compiled = _lower_cell(c, shape, mesh, a2a_impl, overrides)
        vals[l] = _cell_costs(compiled)
    big = cfg.n_layers

    def lin(a, b):
        return a + (big - 2) * (b - a)

    f = lin(vals[2][0], vals[3][0])
    by = lin(vals[2][1], vals[3][1])
    c2, c3 = vals[2][2], vals[3][2]
    coll = CollectiveStats(
        simple_bytes=lin(c2.simple_bytes, c3.simple_bytes),
        wire_bytes=lin(c2.wire_bytes, c3.wire_bytes),
        ici_bytes=lin(c2.ici_bytes, c3.ici_bytes),
        dcn_bytes=lin(c2.dcn_bytes, c3.dcn_bytes),
        by_op={k: lin(c2.by_op.get(k, 0.0), c3.by_op.get(k, 0.0))
               for k in set(c2.by_op) | set(c3.by_op)},
        count=int(lin(c2.count, c3.count)),
    )
    return f, by, coll


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             a2a_impl: Optional[str] = None,
             overrides: Optional[dict] = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        import dataclasses as dc
        overrides = dict(overrides)
        capf = overrides.pop("capacity_factor", None)
        if capf is not None and cfg.moe is not None:
            cfg = dc.replace(cfg, moe=dc.replace(cfg.moe,
                                                 capacity_factor=capf))
        cfg_over = {k: v for k, v in overrides.items()
                    if k in {f.name for f in dc.fields(cfg)}}
        cfg = dc.replace(cfg, **cfg_over)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    try:
        lowered, compiled = _lower_cell(cfg, shape, mesh, a2a_impl)
    except Exception as e:  # noqa: BLE001 - reported as cell failure
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "failed", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:]}
    compile_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    flops_raw, bytes_raw, coll_raw = _cell_costs(compiled)
    if cfg.scan_layers and cfg.n_layers > 3:
        try:
            flops, bytes_accessed, coll = _extrapolated_costs(
                cfg, shape, mesh, a2a_impl, None)
            cost_source = "unrolled-2/3-extrapolation"
        except Exception as e:  # noqa: BLE001
            flops, bytes_accessed, coll = flops_raw, bytes_raw, coll_raw
            cost_source = f"scan-body-once (extrapolation failed: {e})"
    else:
        flops, bytes_accessed, coll = flops_raw, bytes_raw, coll_raw
        cost_source = "direct"
    if shape.kind == "train" and cfg.microbatches > 1:
        # the grad-accumulation scan body is also counted once by cost
        # analysis; scale to the per-step total (peak memory is NOT scaled:
        # one microbatch lives at a time -- that is the point)
        n_mb = cfg.microbatches
        flops *= n_mb
        bytes_accessed *= n_mb
        coll = CollectiveStats(
            simple_bytes=coll.simple_bytes * n_mb,
            wire_bytes=coll.wire_bytes * n_mb,
            ici_bytes=coll.ici_bytes * n_mb,
            dcn_bytes=coll.dcn_bytes * n_mb,
            by_op={k: v * n_mb for k, v in coll.by_op.items()},
            count=coll.count * n_mb)
        cost_source += f" x{n_mb}-microbatches"
    terms = roofline_terms(flops, bytes_accessed, coll)

    n = cfg.n_params()
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "a2a_impl": a2a_impl or cfg.a2a_impl,
        "overrides": overrides or {},
        "status": "ok",
        "n_chips": n_chips,
        "compile_s": round(compile_s, 2),
        "cost_source": cost_source,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "collectives": {
            "count": coll.count,
            "simple_bytes": coll.simple_bytes,
            "wire_bytes": coll.wire_bytes,
            "ici_bytes": coll.ici_bytes,
            "dcn_bytes": coll.dcn_bytes,
            "by_op": coll.by_op,
        },
        "roofline": terms,
        "model_flops_total": model_flops,
        "model_flops_per_chip": model_flops / n_chips,
        "useful_flop_ratio": (model_flops / n_chips) / flops
        if flops else None,
        "params_total": n,
        "params_active": n_active,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--a2a", choices=["flash", "direct", "hierarchical"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field overrides key=value (python literals)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            import ast
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    cells = []
    if args.all:
        for arch in list_archs():
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required unless --all")
        cells.append((args.arch, args.shape))

    for arch, shape_name in cells:
        res = run_cell(arch, shape_name, args.mesh, args.a2a,
                       overrides or None)
        tag = f"{arch}.{shape_name}.{args.mesh}"
        if args.a2a:
            tag += f".{args.a2a}"
        if overrides:
            tag += "." + "_".join(f"{k}-{v}" for k, v in overrides.items())
        line = {k: v for k, v in res.items()
                if k in ("arch", "shape", "mesh", "status", "compile_s",
                         "flops_per_chip", "reason", "error")}
        print(json.dumps(line))
        if res["status"] == "ok":
            mem = res["memory"]
            print(f"  memory/chip: args={_gb(mem['argument_bytes'])} "
                  f"temp={_gb(mem['temp_bytes'])} "
                  f"peak={_gb(mem['peak_bytes'])}")
            r = res["roofline"]
            print(f"  roofline: compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s "
                  f"dominant={r['dominant']}")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)


def _gb(x):
    return f"{x / (1 << 30):.2f}GB" if x is not None else "?"


if __name__ == "__main__":
    main()
