"""First-class two-tier fabric model: named link-level resources.

The paper's claim is efficient scheduling on *heterogeneous* two-tier
fabrics (H200 NVLink vs MI300X xGMI, mixed NIC generations, degraded
links), but a ``ClusterSpec`` models the cluster as two scalars -- every
server, NIC and link identical.  ``Topology`` replaces those scalars with
explicit resources:

  * one ``ServerFabric`` per server -- intra topology type, per-link
    bandwidth and GPU count (mixed-generation servers);
  * a per-NIC capacity matrix ``nic_bw[server, nic]`` in bytes/s
    (heterogeneous NIC speeds; a degraded link is a scaled entry, a failed
    link is a zero);
  * an optional scale-out ``oversubscription`` factor capping the
    aggregate cross-fabric ("spine") bandwidth at
    ``sum(nic_bw) / oversubscription`` per direction.

``Topology.from_cluster`` is the adapter that keeps every existing
``ClusterSpec`` call site working: a homogeneous Topology derived from a
spec reproduces the scalar cost model exactly (the link-level executor in
simulator.py is golden-tested to <= 1e-9 relative error against the
scalar formulas).  ``fingerprint()`` is the content hash that keys
``PlanCache`` entries and stamps synthesized Plans, so a traffic matrix
replayed on a different fabric can never be served a stale plan.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ServerFabric",
    "Topology",
    "fabric_path_bandwidth",
    "fabric_a2a_bandwidth",
    "bw_div",
    "bw_sdiv",
    "uniform_nic_shares",
]


@functools.lru_cache(maxsize=64)
def uniform_nic_shares(n_servers: int, m_gpus: int) -> np.ndarray:
    """Memoized uniform ``(n, n, m)`` rail-share fallback (``1/m`` per rail).

    The executor, the Plan validator and the homogeneous synthesis path all
    need this array whenever a plan carries no explicit ``nic_shares``;
    memoizing per shape means a serving loop stops paying an O(n^2 m)
    allocation on every executed plan.  The array is frozen read-only
    because every caller shares the same instance.
    """
    shares = np.full((n_servers, n_servers, m_gpus), 1.0 / m_gpus)
    shares.flags.writeable = False
    return shares


def bw_div(x, bw) -> np.ndarray:
    """Elementwise x / bw with failed links handled: 0 bandwidth carries
    nothing in finite time (inf when bytes > 0, 0 when idle)."""
    x, bw = np.broadcast_arrays(np.asarray(x, dtype=np.float64),
                                np.asarray(bw, dtype=np.float64))
    out = np.zeros(x.shape)
    np.divide(x, bw, out=out, where=bw > 0)
    out[(bw <= 0) & (x > 0)] = np.inf
    return out


def bw_sdiv(x: float, bw: float) -> float:
    """Scalar form of bw_div: same zero-bandwidth contract."""
    if x <= 0:
        return 0.0
    return x / bw if bw > 0 else float("inf")


def fabric_path_bandwidth(intra_topology: str, b_intra: float,
                          m_gpus: int) -> float:
    """Effective single-path intra-server bandwidth under the topology.

    full_mesh / switch: a pairwise transfer rides one dedicated link.
    ring: average path crosses m/4 hops sharing the ring -> ~4/m of a link.
    hybrid_cube (DGX-1 style): ~half of full-mesh efficiency.
    These coarse factors reproduce the ordering of paper Fig 16a.
    """
    if intra_topology in ("full_mesh", "switch"):
        return b_intra
    if intra_topology == "ring":
        return b_intra * 4.0 / max(m_gpus, 4)
    if intra_topology == "hybrid_cube":
        return b_intra * 0.5
    raise ValueError(f"unknown intra topology {intra_topology!r}")


def fabric_a2a_bandwidth(intra_topology: str, b_intra: float,
                         m_gpus: int) -> float:
    """Aggregate per-GPU bandwidth during an intra-server All-to-All.

    Coarse per-topology efficiency factors, calibrated to reproduce the
    paper's Fig 16a ordering (switch/full-mesh near-optimal; ring and
    hybrid-cube at 0.86-0.92x due to multi-hop shuffles).
    """
    if intra_topology in ("full_mesh",):
        return b_intra * max(m_gpus - 1, 1)
    if intra_topology == "switch":
        return b_intra  # switch port caps a GPU at one link rate
    if intra_topology == "ring":
        # two directions, average path m/4 hops sharing ring capacity
        return b_intra * 2 * 4.0 / max(m_gpus, 4)
    if intra_topology == "hybrid_cube":
        # 4 links/GPU, ~half usable bisection for an A2A shuffle
        return b_intra * 2
    raise ValueError(f"unknown intra topology {intra_topology!r}")


@dataclasses.dataclass(frozen=True)
class ServerFabric:
    """One server's intra fabric: type, per-link bandwidth, GPU count."""

    intra_topology: str = "full_mesh"
    b_intra: float = 64e9
    m_gpus: int = 8

    def path_bandwidth(self) -> float:
        return fabric_path_bandwidth(self.intra_topology, self.b_intra,
                                     self.m_gpus)

    def a2a_bandwidth(self) -> float:
        return fabric_a2a_bandwidth(self.intra_topology, self.b_intra,
                                    self.m_gpus)

    def to_dict(self) -> Dict[str, Any]:
        return {"intra_topology": self.intra_topology,
                "b_intra": float(self.b_intra),
                "m_gpus": int(self.m_gpus)}


@dataclasses.dataclass(frozen=True, eq=False)
class Topology:
    """Two-tier fabric as explicit per-server / per-NIC resources.

    Attributes:
      fabrics: one ``ServerFabric`` per server.
      nic_bw: (n_servers, m_gpus) per-NIC bandwidth, bytes/s.  Uplink =
        downlink (full duplex, paper assumption (1)).  Zero = failed link.
      alpha: per-stage wakeup latency (alpha-beta model, paper 6.3).
      oversubscription: scale-out fabric factor >= 1; the spine carries at
        most ``sum(nic_bw) / oversubscription`` bytes/s per direction.
        1.0 = full bisection (no effect).
    """

    fabrics: Tuple[ServerFabric, ...]
    nic_bw: np.ndarray
    alpha: float = 10e-6
    oversubscription: float = 1.0

    def __post_init__(self):
        # Defensive copy + freeze: fingerprint()/__hash__ key PlanCache
        # entries, so the array must never change under us.
        nic = np.array(self.nic_bw, dtype=np.float64, order="C", copy=True)
        nic.flags.writeable = False
        object.__setattr__(self, "nic_bw", nic)
        object.__setattr__(self, "fabrics", tuple(self.fabrics))
        n = len(self.fabrics)
        if n == 0:
            raise ValueError("topology needs at least one server")
        counts = {f.m_gpus for f in self.fabrics}
        if len(counts) != 1:
            raise ValueError(
                "heterogeneous per-server GPU counts are not supported "
                f"yet (got {sorted(counts)}); see ROADMAP open items")
        m = self.fabrics[0].m_gpus
        if nic.shape != (n, m):
            raise ValueError(
                f"nic_bw shape {nic.shape} != (n_servers, m_gpus) = "
                f"({n}, {m})")
        if np.any(nic < 0):
            raise ValueError("NIC bandwidths must be >= 0")
        if self.oversubscription < 1.0:
            raise ValueError(
                f"oversubscription must be >= 1, got {self.oversubscription}")
        # Derived per-resource capacities, computed once (the executor reads
        # them several times per plan); frozen like nic_bw.
        for attr, arr in (
                ("_send_caps", nic.sum(axis=1)),
                ("_intra_path_bw",
                 np.array([f.path_bandwidth() for f in self.fabrics])),
                ("_intra_a2a_bw",
                 np.array([f.a2a_bandwidth() for f in self.fabrics]))):
            arr.flags.writeable = False
            object.__setattr__(self, attr, arr)

    # -- shape ----------------------------------------------------------

    @property
    def n_servers(self) -> int:
        return len(self.fabrics)

    @property
    def m_gpus(self) -> int:
        return self.fabrics[0].m_gpus

    @property
    def n_gpus(self) -> int:
        return self.n_servers * self.m_gpus

    # -- derived link-level capacities ----------------------------------

    @property
    def send_caps(self) -> np.ndarray:
        """(n_servers,) aggregate NIC capacity per server, one direction."""
        return self._send_caps

    @property
    def spine_bandwidth(self) -> float:
        """Aggregate cross-fabric bandwidth per direction (scale-out tier)."""
        return float(self.nic_bw.sum()) / self.oversubscription

    @property
    def intra_path_bw(self) -> np.ndarray:
        """(n_servers,) single-path intra bandwidth per server fabric."""
        return self._intra_path_bw

    @property
    def intra_a2a_bw(self) -> np.ndarray:
        """(n_servers,) per-GPU intra All-to-All bandwidth per fabric."""
        return self._intra_a2a_bw

    def theorem1_time(self, line_sums, inter_total: float) -> float:
        """Theorem 1 lower bound on this fabric: each server's max(row, col)
        line sum over its aggregate NIC capacity, and the whole exchange
        over the spine.  Single source of truth for the BoundStage executor
        branch and ``optimal_completion_time``."""
        per_server = bw_div(np.asarray(line_sums, dtype=np.float64),
                            self.send_caps)
        return max(float(per_server.max(initial=0.0)),
                   bw_sdiv(float(inter_total), self.spine_bandwidth))

    @property
    def is_homogeneous(self) -> bool:
        """Identical fabrics, identical NICs, full-bisection spine.

        Memoized: the fabric is frozen, and the serving/repair hot paths
        consult this on every synthesized plan."""
        homog = self.__dict__.get("_is_homogeneous")
        if homog is None:
            homog = bool(len(set(self.fabrics)) == 1
                         and np.all(self.nic_bw == self.nic_bw.flat[0])
                         and self.oversubscription == 1.0)
            object.__setattr__(self, "_is_homogeneous", homog)
        return homog

    def pair_capacity(self) -> np.ndarray:
        """(n, n) aggregate bandwidth each server pair can sustain.

        Rail-aligned fabric: rail g of the (src, dst) pair is capped by the
        slower of the two endpoint NICs, so the pair carries at most
        ``sum_g min(nic_bw[src, g], nic_bw[dst, g])`` bytes/s in each
        direction.  Zero on the diagonal (a server is not a pair with
        itself) and for fully disconnected pairs (every rail failed).  This
        is the per-edge weight of the capacity-aware Birkhoff synthesis
        (``birkhoff_decompose(..., capacity_aware=True)``) and the
        denominator of its time-domain traffic matrix.
        """
        caps = np.minimum(self.nic_bw[:, None, :],
                          self.nic_bw[None, :, :]).sum(axis=-1)
        np.fill_diagonal(caps, 0.0)
        return caps

    def nic_shares(self) -> np.ndarray:
        """(n, n, m) fraction of the (src, dst) server-pair bytes each rail
        should carry so all rails of the pair drain simultaneously.

        Rail g of a pair is capped by the slower of the two endpoint NICs
        (rail-aligned fabric: NIC g talks to NIC g), so shares are
        proportional to ``min(nic_bw[src, g], nic_bw[dst, g])`` -- uniform
        1/m on a homogeneous fabric, zero on a failed rail (the pair's
        traffic routes around it), uniform fallback for a fully
        disconnected pair."""
        n, m = self.nic_bw.shape
        caps = np.minimum(self.nic_bw[:, None, :], self.nic_bw[None, :, :])
        tot = caps.sum(axis=-1, keepdims=True)
        shares = np.full((n, n, m), 1.0 / m)
        np.divide(caps, tot, out=shares, where=tot > 0)
        return shares

    # -- adapters --------------------------------------------------------

    @classmethod
    def from_cluster(cls, cluster) -> "Topology":
        """ClusterSpec -> homogeneous Topology adapter (exact cost parity)."""
        fabric = ServerFabric(intra_topology=cluster.intra_topology,
                              b_intra=cluster.b_intra,
                              m_gpus=cluster.m_gpus)
        nic = np.full((cluster.n_servers, cluster.m_gpus), cluster.b_inter)
        topo = cls(fabrics=(fabric,) * cluster.n_servers, nic_bw=nic,
                   alpha=cluster.alpha)
        # Homogeneous by construction: seed the memo so per-iteration
        # consumers (every synthesized plan checks) never recompute it.
        object.__setattr__(topo, "_is_homogeneous", True)
        return topo

    def cluster_view(self):
        """Nearest ClusterSpec (shape + back-compat scalar fields).

        Exact round-trip for ``from_cluster`` topologies; for heterogeneous
        ones the scalars are the fastest resource of each tier and only the
        *shape* fields should be trusted -- timing goes through the
        topology itself.
        """
        from .traffic import ClusterSpec

        return ClusterSpec(
            n_servers=self.n_servers,
            m_gpus=self.m_gpus,
            b_intra=float(max(f.b_intra for f in self.fabrics)),
            b_inter=float(self.nic_bw.max()),
            alpha=self.alpha,
            intra_topology=self.fabrics[0].intra_topology,
        )

    # -- scenario constructors ------------------------------------------

    @classmethod
    def homogeneous(cls, n_servers: int, m_gpus: int, *,
                    b_intra: float = 64e9, b_inter: float = 12.5e9,
                    alpha: float = 10e-6,
                    intra_topology: str = "full_mesh") -> "Topology":
        fabric = ServerFabric(intra_topology=intra_topology,
                              b_intra=b_intra, m_gpus=m_gpus)
        return cls(fabrics=(fabric,) * n_servers,
                   nic_bw=np.full((n_servers, m_gpus), b_inter),
                   alpha=alpha)

    def with_nic_bw(self, nic_bw) -> "Topology":
        return dataclasses.replace(self, nic_bw=np.asarray(nic_bw))

    def degrade_nic(self, server: int, nic: int,
                    factor: float) -> "Topology":
        """One NIC running at ``factor`` of its nominal speed (0 = failed)."""
        if not 0.0 <= factor <= 1.0:
            raise ValueError(f"degrade factor must be in [0, 1], got {factor}")
        nic_bw = self.nic_bw.copy()
        nic_bw[server, nic] *= factor
        return self.with_nic_bw(nic_bw)

    def fail_nic(self, server: int, nic: int) -> "Topology":
        return self.degrade_nic(server, nic, 0.0)

    def degrade_server(self, server: int, factor: float) -> "Topology":
        """Every NIC of one server at ``factor`` of nominal (thermal
        throttling, PCIe fault): the whole server becomes a slow rail set."""
        if not 0.0 <= factor <= 1.0:
            raise ValueError(f"degrade factor must be in [0, 1], got {factor}")
        nic_bw = self.nic_bw.copy()
        nic_bw[server] *= factor
        return self.with_nic_bw(nic_bw)

    def with_oversubscription(self, factor: float) -> "Topology":
        return dataclasses.replace(self, oversubscription=float(factor))

    def with_server_nic_speeds(self, speeds: Sequence[float]) -> "Topology":
        """Mixed NIC generations: per-server uniform NIC speed override."""
        if len(speeds) != self.n_servers:
            raise ValueError(
                f"need {self.n_servers} per-server speeds, got {len(speeds)}")
        nic_bw = np.tile(np.asarray(speeds, dtype=np.float64)[:, None],
                         (1, self.m_gpus))
        return self.with_nic_bw(nic_bw)

    # -- identity --------------------------------------------------------

    def fingerprint(self) -> str:
        """Stable content hash: keys PlanCache entries and stamps Plans.

        Computed once and memoized -- the instance is immutable (frozen
        dataclass, read-only nic_bw) and the hash sits on the per-miss
        cache path, where traffic/family/plan keys would otherwise each
        re-hash the full NIC matrix."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            h = hashlib.blake2b(digest_size=16)
            for f in self.fabrics:
                h.update(repr((f.intra_topology, f.b_intra,
                               f.m_gpus)).encode())
            h.update(self.nic_bw.tobytes())
            h.update(repr((self.alpha, self.oversubscription)).encode())
            fp = h.hexdigest()
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    def __eq__(self, other) -> bool:
        if not isinstance(other, Topology):
            return NotImplemented
        return (self.fabrics == other.fabrics
                and self.nic_bw.shape == other.nic_bw.shape
                and np.array_equal(self.nic_bw, other.nic_bw)
                and self.alpha == other.alpha
                and self.oversubscription == other.oversubscription)

    def __hash__(self) -> int:
        return hash(self.fingerprint())

    # -- serialization ---------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "fabrics": [f.to_dict() for f in self.fabrics],
            "nic_bw": self.nic_bw.tolist(),
            "alpha": float(self.alpha),
            "oversubscription": float(self.oversubscription),
        }

    @classmethod
    def from_dict(cls, d: Optional[Dict[str, Any]]) -> Optional["Topology"]:
        if d is None:
            return None
        return cls(
            fabrics=tuple(ServerFabric(**f) for f in d["fabrics"]),
            nic_bw=np.asarray(d["nic_bw"], dtype=np.float64),
            alpha=float(d["alpha"]),
            oversubscription=float(d.get("oversubscription", 1.0)),
        )
