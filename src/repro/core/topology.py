"""First-class two-tier fabric model: named link-level resources.

The paper's claim is efficient scheduling on *heterogeneous* two-tier
fabrics (H200 NVLink vs MI300X xGMI, mixed NIC generations, degraded
links), but a ``ClusterSpec`` models the cluster as two scalars -- every
server, NIC and link identical.  ``Topology`` replaces those scalars with
explicit resources:

  * one ``ServerFabric`` per server -- intra topology type, per-link
    bandwidth and GPU count (mixed-generation servers);
  * a per-NIC capacity matrix ``nic_bw[server, nic]`` in bytes/s
    (heterogeneous NIC speeds; a degraded link is a scaled entry, a failed
    link is a zero);
  * an optional scale-out ``oversubscription`` factor capping the
    aggregate cross-fabric ("spine") bandwidth at
    ``sum(nic_bw) / oversubscription`` per direction.

``Topology.from_cluster`` is the adapter that keeps every existing
``ClusterSpec`` call site working: a homogeneous Topology derived from a
spec reproduces the scalar cost model exactly (the link-level executor in
simulator.py is golden-tested to <= 1e-9 relative error against the
scalar formulas).  ``fingerprint()`` is the content hash that keys
``PlanCache`` entries and stamps synthesized Plans, so a traffic matrix
replayed on a different fabric can never be served a stale plan.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ServerFabric",
    "Topology",
    "fabric_path_bandwidth",
    "fabric_a2a_bandwidth",
    "bw_div",
    "bw_sdiv",
    "uniform_nic_shares",
]


@functools.lru_cache(maxsize=64)
def uniform_nic_shares(n_servers: int, m_gpus: int) -> np.ndarray:
    """Memoized uniform ``(n, n, m)`` rail-share fallback (``1/m`` per rail).

    The executor, the Plan validator and the homogeneous synthesis path all
    need this array whenever a plan carries no explicit ``nic_shares``;
    memoizing per shape means a serving loop stops paying an O(n^2 m)
    allocation on every executed plan.  The array is frozen read-only
    because every caller shares the same instance.
    """
    shares = np.full((n_servers, n_servers, m_gpus), 1.0 / m_gpus)
    shares.flags.writeable = False
    return shares


def bw_div(x, bw) -> np.ndarray:
    """Elementwise x / bw with failed links handled: 0 bandwidth carries
    nothing in finite time (inf when bytes > 0, 0 when idle)."""
    x, bw = np.broadcast_arrays(np.asarray(x, dtype=np.float64),
                                np.asarray(bw, dtype=np.float64))
    out = np.zeros(x.shape)
    np.divide(x, bw, out=out, where=bw > 0)
    out[(bw <= 0) & (x > 0)] = np.inf
    return out


def bw_sdiv(x: float, bw: float) -> float:
    """Scalar form of bw_div: same zero-bandwidth contract."""
    if x <= 0:
        return 0.0
    return x / bw if bw > 0 else float("inf")


def fabric_path_bandwidth(intra_topology: str, b_intra: float,
                          m_gpus: int) -> float:
    """Effective single-path intra-server bandwidth under the topology.

    full_mesh / switch: a pairwise transfer rides one dedicated link.
    ring: average path crosses m/4 hops sharing the ring -> ~4/m of a link.
    hybrid_cube (DGX-1 style): ~half of full-mesh efficiency.
    These coarse factors reproduce the ordering of paper Fig 16a.
    """
    if intra_topology in ("full_mesh", "switch"):
        return b_intra
    if intra_topology == "ring":
        return b_intra * 4.0 / max(m_gpus, 4)
    if intra_topology == "hybrid_cube":
        return b_intra * 0.5
    raise ValueError(f"unknown intra topology {intra_topology!r}")


def fabric_a2a_bandwidth(intra_topology: str, b_intra: float,
                         m_gpus: int) -> float:
    """Aggregate per-GPU bandwidth during an intra-server All-to-All.

    Coarse per-topology efficiency factors, calibrated to reproduce the
    paper's Fig 16a ordering (switch/full-mesh near-optimal; ring and
    hybrid-cube at 0.86-0.92x due to multi-hop shuffles).
    """
    if intra_topology in ("full_mesh",):
        return b_intra * max(m_gpus - 1, 1)
    if intra_topology == "switch":
        return b_intra  # switch port caps a GPU at one link rate
    if intra_topology == "ring":
        # two directions, average path m/4 hops sharing ring capacity
        return b_intra * 2 * 4.0 / max(m_gpus, 4)
    if intra_topology == "hybrid_cube":
        # 4 links/GPU, ~half usable bisection for an A2A shuffle
        return b_intra * 2
    raise ValueError(f"unknown intra topology {intra_topology!r}")


@dataclasses.dataclass(frozen=True)
class ServerFabric:
    """One server's intra fabric: type, per-link bandwidth, GPU count."""

    intra_topology: str = "full_mesh"
    b_intra: float = 64e9
    m_gpus: int = 8

    def path_bandwidth(self) -> float:
        return fabric_path_bandwidth(self.intra_topology, self.b_intra,
                                     self.m_gpus)

    def a2a_bandwidth(self) -> float:
        return fabric_a2a_bandwidth(self.intra_topology, self.b_intra,
                                    self.m_gpus)

    def to_dict(self) -> Dict[str, Any]:
        return {"intra_topology": self.intra_topology,
                "b_intra": float(self.b_intra),
                "m_gpus": int(self.m_gpus)}


@dataclasses.dataclass(frozen=True, eq=False)
class Topology:
    """Two-tier fabric as explicit per-server / per-NIC resources.

    Attributes:
      fabrics: one ``ServerFabric`` per server.
      nic_bw: (n_servers, m_gpus) per-NIC *transmit* bandwidth, bytes/s.
        Zero = failed link.  With ``nic_bw_rx`` unset this is also the
        receive rate (full duplex, paper assumption (1)).
      alpha: per-stage wakeup latency (alpha-beta model, paper 6.3).
      oversubscription: scale-out fabric factor >= 1; the spine carries at
        most ``sum(nic_bw) / oversubscription`` bytes/s per direction.
        1.0 = full bisection (no effect).
      nic_bw_rx: optional (n_servers, m_gpus) per-NIC *receive* bandwidth
        for asymmetric up/down rates (a congested downlink, a degraded
        receive pipeline).  None = symmetric (receive mirrors ``nic_bw``);
        an array equal to ``nic_bw`` is normalized back to None so the
        fingerprint of a symmetric fabric is representation-independent.
      nominal_nic_bw / nominal_nic_rx: pre-degradation rates captured by
        the first degrade/fail constructor so ``recover_nic`` can restore
        them.  Bookkeeping only: excluded from ``fingerprint()``/``__eq__``
        (two fabrics with identical live rates schedule identically) and
        dropped automatically once every link is back at nominal, so
        ``t.fail_nic(s, g).recover_nic(s, g)`` *is* ``t``.
    """

    fabrics: Tuple[ServerFabric, ...]
    nic_bw: np.ndarray
    alpha: float = 10e-6
    oversubscription: float = 1.0
    nic_bw_rx: Optional[np.ndarray] = None
    nominal_nic_bw: Optional[np.ndarray] = None
    nominal_nic_rx: Optional[np.ndarray] = None

    def __post_init__(self):
        # Defensive copy + freeze: fingerprint()/__hash__ key PlanCache
        # entries, so the array must never change under us.
        nic = np.array(self.nic_bw, dtype=np.float64, order="C", copy=True)
        nic.flags.writeable = False
        object.__setattr__(self, "nic_bw", nic)
        object.__setattr__(self, "fabrics", tuple(self.fabrics))
        n = len(self.fabrics)
        if n == 0:
            raise ValueError("topology needs at least one server")
        counts = {f.m_gpus for f in self.fabrics}
        if len(counts) != 1:
            raise ValueError(
                "heterogeneous per-server GPU counts are not supported "
                f"yet (got {sorted(counts)}); see ROADMAP open items")
        m = self.fabrics[0].m_gpus
        if nic.shape != (n, m):
            raise ValueError(
                f"nic_bw shape {nic.shape} != (n_servers, m_gpus) = "
                f"({n}, {m})")
        if np.any(nic < 0):
            raise ValueError("NIC bandwidths must be >= 0")
        if self.oversubscription < 1.0:
            raise ValueError(
                f"oversubscription must be >= 1, got {self.oversubscription}")
        rx = self._freeze_optional("nic_bw_rx", nic.shape)
        if rx is not None and np.array_equal(rx, nic):
            # Symmetric-by-value fabrics normalize to the symmetric
            # representation so fingerprints cannot fork on how the same
            # rates were spelled.
            object.__setattr__(self, "nic_bw_rx", None)
            rx = None
        if rx is not None and np.any(rx < 0):
            raise ValueError("NIC bandwidths must be >= 0")
        nom_tx = self._freeze_optional("nominal_nic_bw", nic.shape)
        nom_rx = self._freeze_optional("nominal_nic_rx", nic.shape)
        if nom_tx is not None:
            eff_rx = rx if rx is not None else nic
            eff_nom_rx = nom_rx if nom_rx is not None else nom_tx
            if np.array_equal(nom_tx, nic) and np.array_equal(
                    eff_nom_rx, eff_rx):
                # Fully recovered: the nominal bookkeeping is spent.
                object.__setattr__(self, "nominal_nic_bw", None)
                object.__setattr__(self, "nominal_nic_rx", None)
        elif nom_rx is not None:
            raise ValueError("nominal_nic_rx requires nominal_nic_bw")
        # Derived per-resource capacities, computed once (the executor reads
        # them several times per plan); frozen like nic_bw.
        recv = self.nic_bw_rx if self.nic_bw_rx is not None else nic
        for attr, arr in (
                ("_send_caps", nic.sum(axis=1)),
                ("_recv_caps", recv.sum(axis=1)),
                ("_intra_path_bw",
                 np.array([f.path_bandwidth() for f in self.fabrics])),
                ("_intra_a2a_bw",
                 np.array([f.a2a_bandwidth() for f in self.fabrics]))):
            arr.flags.writeable = False
            object.__setattr__(self, attr, arr)

    def _freeze_optional(self, attr: str,
                         shape: Tuple[int, int]) -> Optional[np.ndarray]:
        arr = getattr(self, attr)
        if arr is None:
            return None
        arr = np.array(arr, dtype=np.float64, order="C", copy=True)
        if arr.shape != shape:
            raise ValueError(f"{attr} shape {arr.shape} != nic_bw "
                             f"shape {shape}")
        arr.flags.writeable = False
        object.__setattr__(self, attr, arr)
        return arr

    # -- shape ----------------------------------------------------------

    @property
    def n_servers(self) -> int:
        return len(self.fabrics)

    @property
    def m_gpus(self) -> int:
        return self.fabrics[0].m_gpus

    @property
    def n_gpus(self) -> int:
        return self.n_servers * self.m_gpus

    # -- derived link-level capacities ----------------------------------

    @property
    def nic_tx(self) -> np.ndarray:
        """(n, m) per-NIC transmit bandwidth (alias of ``nic_bw``)."""
        return self.nic_bw

    @property
    def nic_rx(self) -> np.ndarray:
        """(n, m) per-NIC receive bandwidth; ``nic_bw`` when symmetric.

        Returns the *same array object* as ``nic_bw`` on symmetric
        fabrics, so executor hot paths that hoist both planes pay nothing
        extra there."""
        return self.nic_bw_rx if self.nic_bw_rx is not None else self.nic_bw

    @property
    def is_symmetric(self) -> bool:
        """True when receive rates mirror transmit rates everywhere."""
        return self.nic_bw_rx is None

    @property
    def send_caps(self) -> np.ndarray:
        """(n_servers,) aggregate NIC transmit capacity per server."""
        return self._send_caps

    @property
    def recv_caps(self) -> np.ndarray:
        """(n_servers,) aggregate NIC receive capacity per server."""
        return self._recv_caps

    @property
    def spine_bandwidth(self) -> float:
        """Aggregate cross-fabric bandwidth per direction (scale-out tier).

        Under asymmetric rates the spine can move no more than the slower
        of what the servers can collectively inject or drain."""
        cap = float(self.nic_bw.sum())
        if self.nic_bw_rx is not None:
            cap = min(cap, float(self.nic_bw_rx.sum()))
        return cap / self.oversubscription

    @property
    def intra_path_bw(self) -> np.ndarray:
        """(n_servers,) single-path intra bandwidth per server fabric."""
        return self._intra_path_bw

    @property
    def intra_a2a_bw(self) -> np.ndarray:
        """(n_servers,) per-GPU intra All-to-All bandwidth per fabric."""
        return self._intra_a2a_bw

    def theorem1_time(self, line_sums, inter_total: float) -> float:
        """Theorem 1 lower bound on this fabric: each server's max(row, col)
        line sum over its aggregate NIC capacity, and the whole exchange
        over the spine.  Single source of truth for the BoundStage executor
        branch and ``optimal_completion_time``.

        Under asymmetric rates the combined line sum is charged against
        ``max(send_caps, recv_caps)`` per server -- still a valid lower
        bound, since ``max(row, col) / max(tx, rx)`` never exceeds
        ``max(row / tx, col / rx)`` -- and degrades to the exact symmetric
        form when the planes coincide."""
        caps = self.send_caps
        if self.nic_bw_rx is not None:
            caps = np.maximum(caps, self.recv_caps)
        per_server = bw_div(np.asarray(line_sums, dtype=np.float64), caps)
        return max(float(per_server.max(initial=0.0)),
                   bw_sdiv(float(inter_total), self.spine_bandwidth))

    @property
    def is_homogeneous(self) -> bool:
        """Identical fabrics, identical NICs, full-bisection spine.

        Memoized: the fabric is frozen, and the serving/repair hot paths
        consult this on every synthesized plan."""
        homog = self.__dict__.get("_is_homogeneous")
        if homog is None:
            homog = bool(len(set(self.fabrics)) == 1
                         and self.nic_bw_rx is None
                         and np.all(self.nic_bw == self.nic_bw.flat[0])
                         and self.oversubscription == 1.0)
            object.__setattr__(self, "_is_homogeneous", homog)
        return homog

    def pair_capacity(self) -> np.ndarray:
        """(n, n) aggregate bandwidth each server pair can sustain.

        Rail-aligned fabric: rail g of the (src, dst) pair is capped by the
        slower of the two endpoint NICs, so the pair carries at most
        ``sum_g min(nic_bw[src, g], nic_bw[dst, g])`` bytes/s in each
        direction.  Zero on the diagonal (a server is not a pair with
        itself) and for fully disconnected pairs (every rail failed).  This
        is the per-edge weight of the capacity-aware Birkhoff synthesis
        (``birkhoff_decompose(..., capacity_aware=True)``) and the
        denominator of its time-domain traffic matrix.

        Rail g of the pair moves data from the source NIC's *transmit*
        plane into the destination NIC's *receive* plane, so under
        asymmetric rates the matrix is ``sum_g min(tx[src, g],
        rx[dst, g])`` and need not be symmetric.
        """
        caps = np.minimum(self.nic_tx[:, None, :],
                          self.nic_rx[None, :, :]).sum(axis=-1)
        np.fill_diagonal(caps, 0.0)
        return caps

    def nic_shares(self) -> np.ndarray:
        """(n, n, m) fraction of the (src, dst) server-pair bytes each rail
        should carry so all rails of the pair drain simultaneously.

        Rail g of a pair is capped by the slower of the two endpoint NICs
        (rail-aligned fabric: NIC g talks to NIC g), so shares are
        proportional to ``min(nic_bw[src, g], nic_bw[dst, g])`` -- uniform
        1/m on a homogeneous fabric, zero on a failed rail (the pair's
        traffic routes around it), uniform fallback for a fully
        disconnected pair."""
        n, m = self.nic_bw.shape
        caps = np.minimum(self.nic_tx[:, None, :], self.nic_rx[None, :, :])
        tot = caps.sum(axis=-1, keepdims=True)
        shares = np.full((n, n, m), 1.0 / m)
        np.divide(caps, tot, out=shares, where=tot > 0)
        return shares

    # -- adapters --------------------------------------------------------

    @classmethod
    def from_cluster(cls, cluster) -> "Topology":
        """ClusterSpec -> homogeneous Topology adapter (exact cost parity)."""
        fabric = ServerFabric(intra_topology=cluster.intra_topology,
                              b_intra=cluster.b_intra,
                              m_gpus=cluster.m_gpus)
        nic = np.full((cluster.n_servers, cluster.m_gpus), cluster.b_inter)
        topo = cls(fabrics=(fabric,) * cluster.n_servers, nic_bw=nic,
                   alpha=cluster.alpha)
        # Homogeneous by construction: seed the memo so per-iteration
        # consumers (every synthesized plan checks) never recompute it.
        object.__setattr__(topo, "_is_homogeneous", True)
        return topo

    def cluster_view(self):
        """Nearest ClusterSpec (shape + back-compat scalar fields).

        Exact round-trip for ``from_cluster`` topologies; for heterogeneous
        ones the scalars are the fastest resource of each tier and only the
        *shape* fields should be trusted -- timing goes through the
        topology itself.
        """
        from .traffic import ClusterSpec

        return ClusterSpec(
            n_servers=self.n_servers,
            m_gpus=self.m_gpus,
            b_intra=float(max(f.b_intra for f in self.fabrics)),
            b_inter=float(self.nic_bw.max()),
            alpha=self.alpha,
            intra_topology=self.fabrics[0].intra_topology,
        )

    # -- scenario constructors ------------------------------------------

    @classmethod
    def homogeneous(cls, n_servers: int, m_gpus: int, *,
                    b_intra: float = 64e9, b_inter: float = 12.5e9,
                    alpha: float = 10e-6,
                    intra_topology: str = "full_mesh") -> "Topology":
        fabric = ServerFabric(intra_topology=intra_topology,
                              b_intra=b_intra, m_gpus=m_gpus)
        return cls(fabrics=(fabric,) * n_servers,
                   nic_bw=np.full((n_servers, m_gpus), b_inter),
                   alpha=alpha)

    _KEEP = object()  # sentinel: "leave this plane as it is"

    def with_nic_bw(self, nic_bw, *, nic_bw_rx=_KEEP,
                    keep_nominal: bool = False) -> "Topology":
        """New transmit (and optionally receive) rates.

        A plain call defines a *new fabric*: any recovery bookkeeping is
        dropped.  The degrade/fail/recover constructors pass
        ``keep_nominal=True`` so the pre-degradation rates survive the
        edit (captured from the current rates on the first degradation).
        """
        if nic_bw_rx is Topology._KEEP:
            nic_bw_rx = self.nic_bw_rx
        if keep_nominal:
            nom_tx = (self.nominal_nic_bw if self.nominal_nic_bw is not None
                      else self.nic_bw)
            nom_rx = (self.nominal_nic_rx if self.nominal_nic_bw is not None
                      else self.nic_bw_rx)
        else:
            nom_tx = nom_rx = None
        return dataclasses.replace(
            self, nic_bw=np.asarray(nic_bw), nic_bw_rx=nic_bw_rx,
            nominal_nic_bw=nom_tx, nominal_nic_rx=nom_rx)

    def with_nic_rx(self, nic_bw_rx) -> "Topology":
        """Asymmetric up/down rates: override the receive plane only."""
        return self.with_nic_bw(self.nic_bw, nic_bw_rx=np.asarray(nic_bw_rx))

    @staticmethod
    def _check_direction(direction: str) -> None:
        if direction not in ("both", "up", "down"):
            raise ValueError(
                f"direction must be 'both', 'up' or 'down', got {direction!r}")

    def _scale(self, sel, factor: float, direction: str) -> "Topology":
        """Scale one NIC (or a whole server row) in the named plane(s),
        preserving the nominal rates for a later ``recover_nic``."""
        tx = self.nic_bw
        rx = self.nic_bw_rx
        if direction != "both" and rx is None:
            # A single-plane edit on a symmetric fabric forks the planes:
            # the untouched plane must keep its current rate, so the
            # receive mirror becomes explicit first.  'both' keeps
            # symmetric fabrics symmetric (rx stays an implicit mirror).
            rx = np.array(tx)
        if direction in ("up", "both"):
            tx = tx.copy()
            tx[sel] *= factor
        if direction in ("down", "both") and rx is not None:
            rx = np.array(rx)
            rx[sel] *= factor
        return self.with_nic_bw(tx, nic_bw_rx=rx, keep_nominal=True)

    def degrade_nic(self, server: int, nic: int, factor: float,
                    direction: str = "both") -> "Topology":
        """One NIC running at ``factor`` of its nominal speed (0 = failed).

        ``direction`` selects the plane: ``"both"`` (default), ``"up"``
        (transmit only) or ``"down"`` (receive only) for asymmetric
        up/down degradation scenarios."""
        if not 0.0 <= factor <= 1.0:
            raise ValueError(f"degrade factor must be in [0, 1], got {factor}")
        self._check_direction(direction)
        return self._scale((server, nic), factor, direction)

    def fail_nic(self, server: int, nic: int,
                 direction: str = "both") -> "Topology":
        return self.degrade_nic(server, nic, 0.0, direction)

    def degrade_server(self, server: int, factor: float,
                       direction: str = "both") -> "Topology":
        """Every NIC of one server at ``factor`` of nominal (thermal
        throttling, PCIe fault): the whole server becomes a slow rail set."""
        if not 0.0 <= factor <= 1.0:
            raise ValueError(f"degrade factor must be in [0, 1], got {factor}")
        self._check_direction(direction)
        return self._scale(server, factor, direction)

    def fail_server(self, server: int,
                    direction: str = "both") -> "Topology":
        """Whole server off the fabric (power loss, kernel panic)."""
        return self.degrade_server(server, 0.0, direction)

    def recover_nic(self, server: int, nic: int) -> "Topology":
        """Inverse of degrade/fail: one NIC back at its pre-degradation
        rate (both planes).  A no-op when nothing was degraded through the
        scenario constructors; once every link is nominal again the
        recovered topology compares and fingerprints equal to the
        original."""
        return self._restore((server, nic))

    def recover_server(self, server: int) -> "Topology":
        """Every NIC of one server back at its pre-degradation rate."""
        return self._restore(server)

    def _restore(self, sel) -> "Topology":
        nom_tx = self.nominal_nic_bw
        if nom_tx is None:
            return self  # nothing recorded as degraded
        tx = self.nic_bw.copy()
        tx[sel] = nom_tx[sel]
        rx = self.nic_bw_rx
        if rx is not None:
            nom_rx = (self.nominal_nic_rx if self.nominal_nic_rx is not None
                      else nom_tx)
            rx = rx.copy()
            rx[sel] = nom_rx[sel]
        return self.with_nic_bw(tx, nic_bw_rx=rx, keep_nominal=True)

    def with_oversubscription(self, factor: float) -> "Topology":
        return dataclasses.replace(self, oversubscription=float(factor))

    def with_server_nic_speeds(self, speeds: Sequence[float]) -> "Topology":
        """Mixed NIC generations: per-server uniform NIC speed override."""
        if len(speeds) != self.n_servers:
            raise ValueError(
                f"need {self.n_servers} per-server speeds, got {len(speeds)}")
        nic_bw = np.tile(np.asarray(speeds, dtype=np.float64)[:, None],
                         (1, self.m_gpus))
        return self.with_nic_bw(nic_bw, nic_bw_rx=None)

    # -- identity --------------------------------------------------------

    def fingerprint(self) -> str:
        """Stable content hash: keys PlanCache entries and stamps Plans.

        Computed once and memoized -- the instance is immutable (frozen
        dataclass, read-only nic_bw) and the hash sits on the per-miss
        cache path, where traffic/family/plan keys would otherwise each
        re-hash the full NIC matrix."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            h = hashlib.blake2b(digest_size=16)
            for f in self.fabrics:
                h.update(repr((f.intra_topology, f.b_intra,
                               f.m_gpus)).encode())
            h.update(self.nic_bw.tobytes())
            if self.nic_bw_rx is not None:
                h.update(b"rx")
                h.update(self.nic_bw_rx.tobytes())
            h.update(repr((self.alpha, self.oversubscription)).encode())
            fp = h.hexdigest()
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    def __eq__(self, other) -> bool:
        # Nominal (recovery) rates are deliberately excluded: fabrics with
        # identical live rates schedule identically, and normalization in
        # __post_init__ guarantees a fully-recovered topology compares
        # equal to the pristine original.
        if not isinstance(other, Topology):
            return NotImplemented
        if (self.nic_bw_rx is None) != (other.nic_bw_rx is None):
            return False
        if self.nic_bw_rx is not None and not np.array_equal(
                self.nic_bw_rx, other.nic_bw_rx):
            return False
        return (self.fabrics == other.fabrics
                and self.nic_bw.shape == other.nic_bw.shape
                and np.array_equal(self.nic_bw, other.nic_bw)
                and self.alpha == other.alpha
                and self.oversubscription == other.oversubscription)

    def __hash__(self) -> int:
        return hash(self.fingerprint())

    # -- serialization ---------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "fabrics": [f.to_dict() for f in self.fabrics],
            "nic_bw": self.nic_bw.tolist(),
            "alpha": float(self.alpha),
            "oversubscription": float(self.oversubscription),
        }
        # Optional planes serialize only when present, so symmetric /
        # pristine fabrics keep the pre-existing JSON shape.
        for key in ("nic_bw_rx", "nominal_nic_bw", "nominal_nic_rx"):
            arr = getattr(self, key)
            if arr is not None:
                d[key] = arr.tolist()
        return d

    @classmethod
    def from_dict(cls, d: Optional[Dict[str, Any]]) -> Optional["Topology"]:
        if d is None:
            return None

        def opt(key):
            arr = d.get(key)
            return None if arr is None else np.asarray(arr, dtype=np.float64)

        return cls(
            fabrics=tuple(ServerFabric(**f) for f in d["fabrics"]),
            nic_bw=np.asarray(d["nic_bw"], dtype=np.float64),
            alpha=float(d["alpha"]),
            oversubscription=float(d.get("oversubscription", 1.0)),
            nic_bw_rx=opt("nic_bw_rx"),
            nominal_nic_bw=opt("nominal_nic_bw"),
            nominal_nic_rx=opt("nominal_nic_rx"),
        )
