"""FLASH: two-tier All-to-All scheduling (the paper's core contribution).

One Scheduler -> Plan -> Executor pipeline: every algorithm (FLASH and the
paper's baselines) is a registered ``Scheduler`` synthesizing a typed,
scheduler-agnostic ``Plan`` (plan.py); a single generic alpha-beta executor
(simulator.py) times any Plan.  ``PlanCache`` skips re-synthesis when a
dynamic-MoE traffic fingerprint repeats across iterations.  The Theorem 1-3
analytic bounds live in bounds.py.
"""

from .birkhoff import (
    DecompositionState,
    Stage,
    StageBlock,
    birkhoff_decompose,
    effective_pair_caps,
    max_line_sum,
    stage_duration,
)
from .bounds import gap_bound, t_flash_worst_case, t_optimal
from .plan import (
    BarrierStage,
    BoundStage,
    FanOutBurst,
    IntraOverlapPhase,
    LoadBalancePhase,
    PermutationBlock,
    PermutationStage,
    Plan,
    PlanCache,
    PlanValidationError,
    RailStage,
    RedistributePhase,
    cluster_family_key,
    plan_family_key,
    traffic_fingerprint,
)
from .schedulers import (
    FlashPlan,
    RepairConfig,
    Scheduler,
    available_schedulers,
    flash_schedule,
    get_scheduler,
    optimal_completion_time,
    register_scheduler,
    synthesis_time,
)
from .simulator import (
    ALGORITHMS,
    ExecutableSchedule,
    SimResult,
    compile_plan,
    execute_plan,
    simulate,
    simulate_many,
)
from .topology import ServerFabric, Topology, uniform_nic_shares
from .traffic import (
    ClusterSpec,
    Workload,
    balanced_workload,
    capacity_matched_workload,
    moe_workload,
    random_workload,
    server_reduce,
    skewed_workload,
)

__all__ = [
    "Stage",
    "StageBlock",
    "DecompositionState",
    "birkhoff_decompose",
    "effective_pair_caps",
    "max_line_sum",
    "stage_duration",
    "gap_bound",
    "t_flash_worst_case",
    "t_optimal",
    "Plan",
    "PlanCache",
    "cluster_family_key",
    "plan_family_key",
    "PlanValidationError",
    "traffic_fingerprint",
    "LoadBalancePhase",
    "PermutationStage",
    "PermutationBlock",
    "BarrierStage",
    "FanOutBurst",
    "RailStage",
    "BoundStage",
    "RedistributePhase",
    "IntraOverlapPhase",
    "Scheduler",
    "RepairConfig",
    "register_scheduler",
    "get_scheduler",
    "available_schedulers",
    "optimal_completion_time",
    "FlashPlan",
    "flash_schedule",
    "synthesis_time",
    "ALGORITHMS",
    "SimResult",
    "ExecutableSchedule",
    "compile_plan",
    "simulate",
    "simulate_many",
    "execute_plan",
    "ServerFabric",
    "Topology",
    "uniform_nic_shares",
    "ClusterSpec",
    "Workload",
    "balanced_workload",
    "capacity_matched_workload",
    "moe_workload",
    "random_workload",
    "server_reduce",
    "skewed_workload",
]
