"""FLASH: two-tier All-to-All scheduling (the paper's core contribution).

Host-side schedule synthesis (Birkhoff decomposition over the server-level
traffic matrix), the paper's baselines, the alpha-beta simulator used for
every benchmark figure, and the Theorem 1-3 analytic bounds.
"""

from .birkhoff import Stage, birkhoff_decompose, max_line_sum
from .bounds import gap_bound, t_flash_worst_case, t_optimal
from .schedulers import FlashPlan, flash_schedule, synthesis_time
from .simulator import ALGORITHMS, SimResult, simulate
from .traffic import (
    ClusterSpec,
    Workload,
    balanced_workload,
    moe_workload,
    random_workload,
    server_reduce,
    skewed_workload,
)

__all__ = [
    "Stage",
    "birkhoff_decompose",
    "max_line_sum",
    "gap_bound",
    "t_flash_worst_case",
    "t_optimal",
    "FlashPlan",
    "flash_schedule",
    "synthesis_time",
    "ALGORITHMS",
    "SimResult",
    "simulate",
    "ClusterSpec",
    "Workload",
    "balanced_workload",
    "moe_workload",
    "random_workload",
    "server_reduce",
    "skewed_workload",
]
