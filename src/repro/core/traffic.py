"""Traffic-matrix abstractions and workload generators.

A GPU-level All-to-All workload on a cluster of n servers x m GPUs is an
(n*m, n*m) nonnegative matrix ``W`` where ``W[g, h]`` is the number of bytes
GPU g must deliver to GPU h.  FLASH's load-balance step collapses it to a
server-level (n, n) matrix T plus per-server intra traffic S_i (paper
section 4.3): after balancing, every one of the m GPUs of server a carries
exactly T[a, b] / m bytes for server b.

Generators mirror the paper's evaluation workloads (section 6): balanced,
random (uniform), skewed (Zipf), plus an MoE-gating generator reproducing the
Megatron-LM measurement methodology of Fig 4 (top-k routing with a skewed
expert-popularity prior, traffic matrix changing every iteration).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "ClusterSpec",
    "Workload",
    "balanced_workload",
    "random_workload",
    "skewed_workload",
    "moe_workload",
    "server_reduce",
]


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Two-tier cluster model (paper Fig 6).

    Bandwidths are bytes/second *per link*: ``b_intra`` for one intra-server
    link (NVLink / xGMI / ICI) and ``b_inter`` for one GPU's NIC (uplink =
    downlink = b_inter, assumption (1) in section 3).  ``alpha`` is the static
    per-stage wakeup latency of the alpha-beta model (section 6.3).
    """

    n_servers: int
    m_gpus: int
    b_intra: float = 64e9  # 64 GB/s per Infinity Fabric link (MI300X testbed)
    b_inter: float = 12.5e9  # 100 Gbps NIC
    alpha: float = 10e-6
    intra_topology: str = "full_mesh"  # full_mesh | switch | ring | hybrid_cube

    @property
    def n_gpus(self) -> int:
        return self.n_servers * self.m_gpus

    @property
    def bw_ratio(self) -> float:
        return self.b_intra / self.b_inter

    def intra_path_bandwidth(self) -> float:
        """Effective single-path intra-server bandwidth under the topology.

        full_mesh / switch: a pairwise transfer rides one dedicated link.
        ring: average path crosses m/4 hops sharing the ring -> ~4/m of a link.
        hybrid_cube (DGX-1 style): ~half of full-mesh efficiency.
        These coarse factors reproduce the ordering of paper Fig 16a.
        """
        if self.intra_topology in ("full_mesh", "switch"):
            return self.b_intra
        if self.intra_topology == "ring":
            return self.b_intra * 4.0 / max(self.m_gpus, 4)
        if self.intra_topology == "hybrid_cube":
            return self.b_intra * 0.5
        raise ValueError(f"unknown intra topology {self.intra_topology!r}")

    def intra_a2a_bandwidth(self) -> float:
        """Aggregate per-GPU bandwidth during an intra-server All-to-All.

        Coarse per-topology efficiency factors, calibrated to reproduce the
        paper's Fig 16a ordering (switch/full-mesh near-optimal; ring and
        hybrid-cube at 0.86-0.92x due to multi-hop shuffles).
        """
        if self.intra_topology in ("full_mesh",):
            return self.b_intra * max(self.m_gpus - 1, 1)
        if self.intra_topology == "switch":
            return self.b_intra  # switch port caps a GPU at one link rate
        if self.intra_topology == "ring":
            # two directions, average path m/4 hops sharing ring capacity
            return self.b_intra * 2 * 4.0 / max(self.m_gpus, 4)
        if self.intra_topology == "hybrid_cube":
            # 4 links/GPU, ~half usable bisection for an A2A shuffle
            return self.b_intra * 2
        raise ValueError(f"unknown intra topology {self.intra_topology!r}")


@dataclasses.dataclass(frozen=True)
class Workload:
    """GPU-level traffic matrix plus the cluster it runs on."""

    cluster: ClusterSpec
    matrix: np.ndarray  # (n_gpus, n_gpus), zero diagonal

    def __post_init__(self):
        n = self.cluster.n_gpus
        if self.matrix.shape != (n, n):
            raise ValueError(
                f"matrix shape {self.matrix.shape} != ({n}, {n})")

    @property
    def total_bytes(self) -> float:
        return float(self.matrix.sum())

    def server_matrix(self) -> np.ndarray:
        """(n, n) inter-server byte matrix T with zero diagonal."""
        t, _ = server_reduce(self.matrix, self.cluster.m_gpus)
        return t

    def intra_bytes(self) -> np.ndarray:
        """S_i: bytes that stay inside each server."""
        _, s = server_reduce(self.matrix, self.cluster.m_gpus)
        return s


def server_reduce(w: np.ndarray, m: int):
    """Collapse a GPU-level matrix to (server-level T, intra byte vector S)."""
    n_gpus = w.shape[0]
    n = n_gpus // m
    blocks = w.reshape(n, m, n, m).sum(axis=(1, 3))  # (n, n) incl. diagonal
    s = np.diag(blocks).copy()
    t = blocks.copy()
    np.fill_diagonal(t, 0.0)
    return t, s


def _zero_diag(w: np.ndarray) -> np.ndarray:
    np.fill_diagonal(w, 0.0)
    return w


def balanced_workload(cluster: ClusterSpec, size_per_pair: float) -> Workload:
    """Every GPU sends `size_per_pair` bytes to every other GPU."""
    n = cluster.n_gpus
    w = np.full((n, n), float(size_per_pair))
    return Workload(cluster, _zero_diag(w))


def random_workload(
    cluster: ClusterSpec, mean_size: float, seed: int = 0
) -> Workload:
    """Pairwise sizes ~ Uniform[0, 2 * mean] (paper 'Random')."""
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    w = rng.uniform(0.0, 2.0 * mean_size, size=(n, n))
    return Workload(cluster, _zero_diag(w))


def skewed_workload(
    cluster: ClusterSpec,
    mean_size: float,
    zipf_s: float = 1.2,
    seed: int = 0,
) -> Workload:
    """Pairwise sizes follow a Zipf-ranked distribution (paper 'Skewed').

    Ranks are randomly assigned to (src, dst) pairs; sizes are rescaled so the
    total equals the balanced workload's total, making AlgoBW comparable
    across skew factors (as in Fig 13).
    """
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    n_pairs = n * (n - 1)
    ranks = np.arange(1, n_pairs + 1, dtype=np.float64)
    sizes = ranks ** (-zipf_s)
    sizes *= (mean_size * n_pairs) / sizes.sum()
    rng.shuffle(sizes)
    w = np.zeros((n, n))
    idx = [(i, j) for i in range(n) for j in range(n) if i != j]
    for (i, j), v in zip(idx, sizes):
        w[i, j] = v
    return Workload(cluster, w)


def moe_workload(
    cluster: ClusterSpec,
    tokens_per_gpu: int,
    bytes_per_token: int,
    top_k: int = 2,
    expert_skew: float = 0.6,
    seed: int = 0,
    n_experts: Optional[int] = None,
) -> Workload:
    """All-to-All dispatch matrix induced by top-k MoE gating.

    Each GPU hosts one expert (DeepSeek-style, paper section 6.2) unless
    ``n_experts`` says otherwise.  Expert popularity follows a Dirichlet prior
    with concentration ``expert_skew`` (smaller = more skew), reproducing the
    measured 12.5x p90/median skew of Fig 4a at the defaults.
    """
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    e = n_experts or n
    popularity = rng.dirichlet(np.full(e, expert_skew))
    w = np.zeros((n, n))
    for src in range(n):
        # Multinomial token split across top-k draws from the popularity prior.
        counts = np.zeros(e)
        for _ in range(top_k):
            counts += rng.multinomial(tokens_per_gpu, popularity)
        for expert, c in enumerate(counts):
            dst = expert % n
            if dst != src and c > 0:
                w[src, dst] += c * bytes_per_token
    return Workload(cluster, w)
