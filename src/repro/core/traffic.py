"""Traffic-matrix abstractions and workload generators.

A GPU-level All-to-All workload on a cluster of n servers x m GPUs is an
(n*m, n*m) nonnegative matrix ``W`` where ``W[g, h]`` is the number of bytes
GPU g must deliver to GPU h.  FLASH's load-balance step collapses it to a
server-level (n, n) matrix T plus per-server intra traffic S_i (paper
section 4.3): after balancing, every one of the m GPUs of server a carries
exactly T[a, b] / m bytes for server b.

Generators mirror the paper's evaluation workloads (section 6): balanced,
random (uniform), skewed (Zipf), plus an MoE-gating generator reproducing the
Megatron-LM measurement methodology of Fig 4 (top-k routing with a skewed
expert-popularity prior, traffic matrix changing every iteration).

Every generator accepts either a ``ClusterSpec`` (homogeneous two-scalar
model) or a ``Topology`` (first-class heterogeneous fabric, topology.py);
the resulting ``Workload`` carries the topology so schedulers synthesize
against the real fabric and PlanCache keys include it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from .topology import Topology, fabric_a2a_bandwidth, fabric_path_bandwidth

__all__ = [
    "ClusterSpec",
    "Workload",
    "balanced_workload",
    "random_workload",
    "skewed_workload",
    "moe_workload",
    "capacity_matched_workload",
    "server_reduce",
]


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Two-tier cluster model (paper Fig 6), homogeneous scalar form.

    Bandwidths are bytes/second *per link*: ``b_intra`` for one intra-server
    link (NVLink / xGMI / ICI) and ``b_inter`` for one GPU's NIC (uplink =
    downlink = b_inter, assumption (1) in section 3).  ``alpha`` is the static
    per-stage wakeup latency of the alpha-beta model (section 6.3).

    For heterogeneous fabrics (mixed NIC speeds, degraded links, per-server
    fabric types) use ``Topology`` (topology.py); ``to_topology()`` is the
    adapter.
    """

    n_servers: int
    m_gpus: int
    b_intra: float = 64e9  # 64 GB/s per Infinity Fabric link (MI300X testbed)
    b_inter: float = 12.5e9  # 100 Gbps NIC
    alpha: float = 10e-6
    intra_topology: str = "full_mesh"  # full_mesh | switch | ring | hybrid_cube

    @property
    def n_gpus(self) -> int:
        return self.n_servers * self.m_gpus

    @property
    def bw_ratio(self) -> float:
        return self.b_intra / self.b_inter

    def intra_path_bandwidth(self) -> float:
        """Effective single-path intra-server bandwidth under the topology."""
        return fabric_path_bandwidth(self.intra_topology, self.b_intra,
                                     self.m_gpus)

    def intra_a2a_bandwidth(self) -> float:
        """Aggregate per-GPU bandwidth during an intra-server All-to-All."""
        return fabric_a2a_bandwidth(self.intra_topology, self.b_intra,
                                    self.m_gpus)

    def to_topology(self) -> Topology:
        """Adapter to the first-class fabric model (homogeneous instance)."""
        return Topology.from_cluster(self)


ClusterLike = Union[ClusterSpec, Topology]


def _resolve_cluster(cluster: ClusterLike):
    """Normalize a ClusterSpec-or-Topology argument to (spec, topology)."""
    if isinstance(cluster, Topology):
        return cluster.cluster_view(), cluster
    return cluster, None


@dataclasses.dataclass(frozen=True)
class Workload:
    """GPU-level traffic matrix plus the fabric it runs on.

    ``topology`` is optional: when None, a homogeneous Topology is derived
    from ``cluster`` on demand (``topo``), so the two-scalar call sites
    keep working unchanged.
    """

    cluster: ClusterSpec
    matrix: np.ndarray  # (n_gpus, n_gpus), zero diagonal
    topology: Optional[Topology] = None

    def __post_init__(self):
        n = self.cluster.n_gpus
        if self.matrix.shape != (n, n):
            raise ValueError(
                f"matrix shape {self.matrix.shape} != ({n}, {n})")
        if np.any(self.matrix < 0):
            bad = np.argwhere(self.matrix < 0)[0]
            raise ValueError(
                f"traffic matrix has negative entries (e.g. "
                f"W[{bad[0]}, {bad[1]}] = {self.matrix[bad[0], bad[1]]}); "
                "byte counts must be >= 0")
        diag = np.diagonal(self.matrix)
        if np.any(diag != 0):
            g = int(np.argmax(diag != 0))
            raise ValueError(
                f"traffic matrix has self-traffic on the diagonal "
                f"(W[{g}, {g}] = {diag[g]}); a GPU does not send to itself "
                "-- zero the diagonal")
        if self.topology is not None and (
                self.topology.n_servers != self.cluster.n_servers
                or self.topology.m_gpus != self.cluster.m_gpus):
            raise ValueError(
                f"topology shape ({self.topology.n_servers}, "
                f"{self.topology.m_gpus}) != cluster shape "
                f"({self.cluster.n_servers}, {self.cluster.m_gpus})")

    @property
    def topo(self) -> Topology:
        """The fabric to schedule against (derived when not explicit).

        The derived homogeneous Topology is memoized so repeated accesses
        (fingerprinting, synthesis, execution) share one instance -- and
        with it, its memoized ``fingerprint()``."""
        if self.topology is not None:
            return self.topology
        derived = self.__dict__.get("_derived_topo")
        if derived is None:
            derived = Topology.from_cluster(self.cluster)
            object.__setattr__(self, "_derived_topo", derived)
        return derived

    @property
    def total_bytes(self) -> float:
        return float(self.matrix.sum())

    def server_matrix(self) -> np.ndarray:
        """(n, n) inter-server byte matrix T with zero diagonal."""
        return self.reductions()[0]

    def intra_bytes(self) -> np.ndarray:
        """S_i: bytes that stay inside each server."""
        return self.reductions()[1]

    def reductions(self):
        """Memoized ``(t_server, s_intra, per_gpu_dest)`` for this matrix.

        ``per_gpu_dest`` is the (n, m, n) per-(server, gpu, dest-server)
        byte sums; the server matrix and intra vector derive from it, so
        the whole family costs one pass over the GPU matrix.  Memoized
        because every consumer of a workload re-reduces the same frozen
        matrix -- fingerprinting, synthesis, warm repair, execution -- and
        the O(n_gpus^2) pass dwarfs incremental repair itself."""
        out = self.__dict__.get("_reductions")
        if out is None:
            n, m = self.cluster.n_servers, self.cluster.m_gpus
            per_gpu_dest = self.matrix.reshape(n, m, n, m).sum(axis=3)
            blocks = per_gpu_dest.sum(axis=1)  # (n, n) incl. diagonal
            s = np.diag(blocks).copy()
            t = blocks.copy()
            np.fill_diagonal(t, 0.0)
            out = (t, s, per_gpu_dest)
            object.__setattr__(self, "_reductions", out)
        return out


def server_reduce(w: np.ndarray, m: int):
    """Collapse a GPU-level matrix to (server-level T, intra byte vector S)."""
    n_gpus = w.shape[0]
    n = n_gpus // m
    blocks = w.reshape(n, m, n, m).sum(axis=(1, 3))  # (n, n) incl. diagonal
    s = np.diag(blocks).copy()
    t = blocks.copy()
    np.fill_diagonal(t, 0.0)
    return t, s


def _zero_diag(w: np.ndarray) -> np.ndarray:
    np.fill_diagonal(w, 0.0)
    return w


def balanced_workload(cluster: ClusterLike, size_per_pair: float) -> Workload:
    """Every GPU sends `size_per_pair` bytes to every other GPU."""
    cluster, topo = _resolve_cluster(cluster)
    n = cluster.n_gpus
    w = np.full((n, n), float(size_per_pair))
    return Workload(cluster, _zero_diag(w), topo)


def random_workload(
    cluster: ClusterLike, mean_size: float, seed: int = 0
) -> Workload:
    """Pairwise sizes ~ Uniform[0, 2 * mean] (paper 'Random')."""
    cluster, topo = _resolve_cluster(cluster)
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    w = rng.uniform(0.0, 2.0 * mean_size, size=(n, n))
    return Workload(cluster, _zero_diag(w), topo)


def skewed_workload(
    cluster: ClusterLike,
    mean_size: float,
    zipf_s: float = 1.2,
    seed: int = 0,
) -> Workload:
    """Pairwise sizes follow a Zipf-ranked distribution (paper 'Skewed').

    Ranks are randomly assigned to (src, dst) pairs; sizes are rescaled so the
    total equals the balanced workload's total, making AlgoBW comparable
    across skew factors (as in Fig 13).
    """
    cluster, topo = _resolve_cluster(cluster)
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    n_pairs = n * (n - 1)
    ranks = np.arange(1, n_pairs + 1, dtype=np.float64)
    sizes = ranks ** (-zipf_s)
    sizes *= (mean_size * n_pairs) / sizes.sum()
    rng.shuffle(sizes)
    # Scatter the shuffled sizes over the off-diagonal entries in row-major
    # order (boolean assignment fills in C order, matching the (i, j) i != j
    # enumeration).
    w = np.zeros((n, n))
    w[~np.eye(n, dtype=bool)] = sizes
    return Workload(cluster, w, topo)


def capacity_matched_workload(
    topology: Topology, mean_size: float, seed: int = 0
) -> Workload:
    """Random traffic scaled to follow pair capacity: a serving load
    balancer keeps slow servers lightly loaded, so pairwise sizes are
    ``random_workload`` entries scaled by the normalized server-pair
    capacity (``Topology.pair_capacity``).  The regime where
    capacity-aware synthesis pays: capacity-blind equal-byte slots park
    fast pairs behind lightly-loaded slow stragglers (DESIGN.md 1d).
    """
    w = random_workload(topology, mean_size, seed=seed)
    caps = topology.pair_capacity()
    scale = caps / max(float(caps.max()), 1.0)
    np.fill_diagonal(scale, 1.0)
    m = topology.m_gpus
    mat = w.matrix * np.kron(scale, np.ones((m, m)))
    return Workload(w.cluster, mat, w.topology)


def moe_workload(
    cluster: ClusterLike,
    tokens_per_gpu: int,
    bytes_per_token: int,
    top_k: int = 2,
    expert_skew: float = 0.6,
    seed: int = 0,
    n_experts: Optional[int] = None,
) -> Workload:
    """All-to-All dispatch matrix induced by top-k MoE gating.

    Each GPU hosts one expert (DeepSeek-style, paper section 6.2) unless
    ``n_experts`` says otherwise.  Expert popularity follows a Dirichlet prior
    with concentration ``expert_skew`` (smaller = more skew), reproducing the
    measured 12.5x p90/median skew of Fig 4a at the defaults.
    """
    cluster, topo = _resolve_cluster(cluster)
    rng = np.random.default_rng(seed)
    n = cluster.n_gpus
    e = n_experts or n
    popularity = rng.dirichlet(np.full(e, expert_skew))
    # One batched draw: (n, top_k, e) multinomials consume the generator
    # stream in the same src-major, draw-minor order as the per-GPU loop.
    counts = rng.multinomial(
        tokens_per_gpu, popularity, size=(n, top_k)).sum(axis=1)  # (n, e)
    # Fold experts onto their host GPUs (expert % n) and drop self-traffic.
    w = np.zeros((n, n))
    np.add.at(w.T, np.arange(e) % n, counts.astype(np.float64).T)
    return Workload(cluster, _zero_diag(w) * float(bytes_per_token), topo)
