"""Link-level plan executor (paper 6.3, generalized to heterogeneous fabrics).

One executor times *every* scheduler: it walks a scheduler-agnostic ``Plan``
(core/plan.py) and interprets each typed phase against the *named resources*
of a ``Topology`` (core/topology.py) -- per-NIC send/recv occupancy, per-
server intra fabrics, and the scale-out spine:

  * every flow is pinned to the NICs and fabrics it actually crosses: an
    inter-server flow is limited by ``min`` of its endpoint NIC capacities,
    an intra-server flow by its server's fabric;
  * a server's inter-server slot bytes are split across its NICs by the
    plan's ``nic_shares`` (FLASH's capacity-proportional rebalance target;
    uniform 1/m when the plan is topology-blind) -- on a degraded or
    mixed-speed fabric the blind uniform split strands bytes on the slow
    NIC while the aware split keeps every NIC draining simultaneously;
  * every inter phase is additionally bounded by the spine:
    ``stage_inter_bytes / (sum(nic_bw) / oversubscription)`` -- inert at
    full bisection, binding when the scale-out tier is oversubscribed.

On a homogeneous topology all of this reduces algebraically to the scalar
alpha-beta model (each transfer costs ``alpha + bytes / bandwidth``;
concurrent transfers on a shared resource divide its bandwidth), and the
executor reproduces the scalar executor's completion times to <= 1e-9
relative error (golden-tested in tests/test_plan_ir.py).

Incast and straggler effects remain properties of stage *types*, not
algorithm names:

  * PermutationStage -- incast-free/straggler-free; ascending consecutive
    stages pipeline (stage k's redistribute hides under stage k+1's
    transfer; the un-hidden residual is charged explicitly, so the Theorem 2
    bound holds even when the intra fabric is slow -- ring topology,
    Fig 16a).
  * BarrierStage -- waits for its slowest flow (the straggler effect,
    Fig 3b).
  * FanOutBurst -- models incast collapse: once simultaneous inbound flow
    bytes at a NIC exceed what switch buffers absorb, goodput degrades by
    1 / (1 + gamma * (k - 1)) (retransmissions + queueing), matching the
    ~91x degradation the paper measured for RCCL at 32 GPUs on large
    balanced transfers (Fig 12a).  Size-weighted effective concurrency:
    short flows drain early, so skew *reduces* collision frequency.
  * RailStage -- the max-loaded rail is the straggler; one wakeup per
    rotation round.
  * BoundStage -- the Theorem 1 analytic bound, per-server line sums
    against per-server aggregate NIC capacity.

The figure of merit is *algorithmic bandwidth*:

    AlgoBW = total_bytes / completion_time / n_gpus      [bytes/s/GPU]

``simulate(w, name)`` is the one-call pipeline: registry lookup ->
synthesis (optionally via a PlanCache) -> execution.  Passing
``topology=`` executes a plan on a *different* fabric than it was
synthesized for -- the topology-blindness experiment of
benchmarks/fig_hetero.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Mapping, Optional

import numpy as np

from .plan import (
    BarrierStage,
    BoundStage,
    FanOutBurst,
    IntraOverlapPhase,
    LoadBalancePhase,
    PermutationStage,
    Plan,
    PlanCache,
    RailStage,
    RedistributePhase,
)
from .birkhoff import live_slots
from .schedulers import SCHEDULERS, get_scheduler
from .topology import Topology, bw_div as _div, bw_sdiv as _sdiv
from .traffic import Workload

__all__ = ["SimResult", "simulate", "execute_plan", "ALGORITHMS"]

# Incast model constants (FanOutBurst stages only).
_INCAST_GAMMA = 4.0
_INCAST_BUFFER_BYTES = 32e6  # per-receiver absorption before collapse


@dataclasses.dataclass(frozen=True)
class SimResult:
    algorithm: str
    completion_time: float
    algbw: float  # bytes / s / GPU
    breakdown: Dict[str, float]
    n_stages: int
    synth_seconds: float
    memory_bytes: float  # peak buffer footprint across the job

    def algbw_gbps(self) -> float:
        return self.algbw / 1e9


def _perm_stage_time(topo: Topology, ph: PermutationStage,
                     shares: np.ndarray) -> float:
    """One permutation stage, link-level (no alpha): each live sender i
    ships its slot to perm[i] -- the uniform ``size`` bytes, or its
    per-sender ``slots[i]`` when the stage is capacity-aware -- split
    across its NICs by ``shares``; rail g of the pair is capped by the
    slower endpoint NIC; the stage also crosses the spine once."""
    src, dst, slot = live_slots(ph.perm, ph.slots, ph.size)
    if src.size == 0:
        return 0.0
    rail_caps = np.minimum(topo.nic_bw[src], topo.nic_bw[dst])  # (k, m)
    flows = slot[:, None] * shares[src, dst]                    # (k, m)
    spine_bytes = (ph.size * len(src) if ph.slots is None  # exact blind form
                   else float(slot.sum()))
    t = float(_div(flows, rail_caps).max(initial=0.0))
    spine = _sdiv(spine_bytes, topo.spine_bandwidth)
    return max(t, spine)


def _stage_redistribute_time(topo: Topology, ph: PermutationStage,
                             worst_a2a: float) -> float:
    """Hidden redistribute of one stage: each *receiver* spreads its slot
    over its own server fabric, so the stage is charged at the worst fabric
    it actually touches -- not the cluster-wide slowest (that model
    overcharges every fast server on mixed fabrics).  Padding-only stages
    keep the legacy cluster-min charge (they touch no server)."""
    m = topo.m_gpus
    src, dst, slot = live_slots(ph.perm, ph.slots, ph.size)
    if src.size == 0:
        return _sdiv(ph.size / m, worst_a2a)
    return float(_div(slot / m, topo.intra_a2a_bw[dst]).max(initial=0.0))


def _tail_redistribute_time(topo: Topology, bytes_per_gpu: float,
                            last_stage: Optional[PermutationStage]) -> float:
    """Tail RedistributePhase: the *last* permutation stage's redistribute.
    Receiver j spreads its share of the tail bytes -- scaled by its slot's
    fraction of the stage (slot_j / size; 1 for uniform slots) -- over its
    own fabric, like the hidden redistributes.  Plans without permutation
    stages (hierarchical scatter) keep the conservative cluster-min charge.
    """
    if last_stage is not None and last_stage.size > 0:
        src, dst, slot = live_slots(last_stage.perm, last_stage.slots,
                                    last_stage.size)
        if src.size:
            per_recv = bytes_per_gpu * (slot / float(last_stage.size))
            return float(_div(per_recv,
                              topo.intra_a2a_bw[dst]).max(initial=0.0))
    return _sdiv(bytes_per_gpu, float(topo.intra_a2a_bw.min()))


def _permutation_times(topo: Topology, stages: List[PermutationStage],
                       shares: np.ndarray) -> Dict[str, float]:
    """Ascending Birkhoff stage pipeline (paper 4.3 / Theorem 2).

    inter: sum over stages of alpha + link-level stage time.
    hidden_residual: stage k's redistribute must fit under stage k+1's
      transfer because l_k <= l_{k+1} and B1 > B2 (Theorem 2 pipelining
      argument); any excess is charged.  The redistribute rides the worst
      fabric among the stage's receivers.
    """
    worst_a2a = float(topo.intra_a2a_bw.min())
    times = [_perm_stage_time(topo, ph, shares) for ph in stages]
    inter = 0.0
    hidden_residual = 0.0
    for k, ph in enumerate(stages):
        inter += topo.alpha + times[k]
        if k + 1 < len(stages):
            redis = _stage_redistribute_time(topo, ph, worst_a2a)
            hidden_residual += max(0.0, redis - times[k + 1])
    return {"inter": inter, "hidden_residual": hidden_residual}


def _fanout_time(topo: Topology, ph: FanOutBurst) -> float:
    """One burst: receiver NICs fair-share + incast; sender uplinks bound;
    intra traffic rides each server's fabric concurrently; one wakeup."""
    n, m = topo.n_servers, topo.m_gpus
    nic = topo.nic_bw
    blk = ph.matrix.reshape(n, m, n, m)
    # Zero the same-server sender rows per receiver: intra rides the fast
    # fabric, not the NIC.
    inter_flows = blk * (1.0 - np.eye(n))[:, None, :, None]
    inbound = inter_flows.sum(axis=(0, 1))          # (n, m) per receiver NIC
    fmax = inter_flows.max(axis=(0, 1), initial=0.0)
    senders = np.divide(inbound, fmax, out=np.zeros_like(inbound),
                        where=fmax > 0)
    base = _div(inbound, nic)
    collapse = (inbound > _INCAST_BUFFER_BYTES) & (senders > 1)
    if collapse.any():
        over = inbound - _INCAST_BUFFER_BYTES
        eta = 1.0 / (1.0 + _INCAST_GAMMA * (senders - 1))
        collapsed = (_div(np.full_like(inbound, _INCAST_BUFFER_BYTES), nic)
                     + _div(np.maximum(over, 0.0), nic * eta))
        base = np.where(collapse, collapsed, base)
    t = float(base.max(initial=0.0))
    # Sender uplinks (no incast on the send side).
    outbound = inter_flows.sum(axis=(2, 3))          # (n, m) per sender NIC
    t = max(t, float(_div(outbound, nic).max(initial=0.0)))
    # Intra traffic rides each server's fabric concurrently.
    intra_per_gpu = np.einsum("agah->ag", blk)       # (n, m)
    t = max(t, float(_div(intra_per_gpu,
                          topo.intra_a2a_bw[:, None]).max(initial=0.0)))
    # Everything crosses the spine at once.
    t = max(t, _sdiv(float(inter_flows.sum()), topo.spine_bandwidth))
    return t + topo.alpha


def _barrier_time(topo: Topology, ph: BarrierStage) -> float:
    """Slowest flow of a barrier-synchronized flow set, each flow pinned to
    the resources it crosses (endpoint NICs, or the source server fabric)."""
    m = topo.m_gpus
    src = np.arange(len(ph.sizes))
    dst = ph.dsts.astype(np.int64)
    src_s, src_g = src // m, src % m
    dst_s, dst_g = dst // m, dst % m
    same = src_s == dst_s
    inter_caps = np.minimum(topo.nic_bw[src_s, src_g],
                            topo.nic_bw[dst_s, dst_g])
    bw = np.where(same, topo.intra_path_bw[src_s], inter_caps)
    stage = float(_div(ph.sizes, bw).max(initial=0.0))
    spine = _sdiv(float(ph.sizes[~same].sum()), topo.spine_bandwidth)
    return max(stage, spine)


def execute_plan(plan: Plan, w: Workload, *,
                 topology: Optional[Topology] = None) -> SimResult:
    """Time a Plan against a Topology's link-level resources.

    Phase semantics are dispatched on phase *type* (see module docstring);
    overlap phases (IntraOverlapPhase) are resolved against the inter
    phase's duration after all stages are timed.  The breakdown always sums
    to completion_time.

    Args:
      plan: the synthesized schedule.
      w: the workload (total-bytes accounting).
      topology: execution fabric override.  Default: the topology the plan
        was synthesized for.  Passing a different (same-shape) fabric times
        a topology-blind schedule on the real degraded/heterogeneous
        fabric.
    """
    topo = topology if topology is not None else plan.topo
    if (topo.n_servers, topo.m_gpus) != (plan.cluster.n_servers,
                                         plan.cluster.m_gpus):
        raise ValueError(
            f"execution topology shape ({topo.n_servers}, {topo.m_gpus}) "
            f"!= plan shape ({plan.cluster.n_servers}, "
            f"{plan.cluster.m_gpus})")
    m = topo.m_gpus
    breakdown: Dict[str, float] = {}
    n_stages = 0
    overlap_phases = []

    def add(key: str, dt: float) -> None:
        breakdown[key] = breakdown.get(key, 0.0) + dt

    perm_stages = [p for p in plan.phases if isinstance(p, PermutationStage)]
    if perm_stages:
        # Shares are only consumed by permutation timing; the uniform
        # fallback is built lazily so non-FLASH plans never allocate it.
        shares = (plan.nic_shares if plan.nic_shares is not None
                  else np.full((topo.n_servers, topo.n_servers, m), 1.0 / m))
        for key, dt in _permutation_times(topo, perm_stages,
                                          shares).items():
            add(key, dt)
        n_stages += len(perm_stages)

    for ph in plan.phases:
        if isinstance(ph, PermutationStage):
            continue  # timed collectively above (pipelined group)
        if isinstance(ph, LoadBalancePhase):
            head = float(_div(ph.moved_per_gpu,
                              topo.intra_a2a_bw[:, None]).max(initial=0.0))
            if ph.charge_alpha and float(
                    ph.moved_per_gpu.max(initial=0.0)) > 0:
                head += topo.alpha
            add("head", head)
        elif isinstance(ph, BarrierStage):
            stage = _barrier_time(topo, ph)
            if stage > 0:
                add("inter", topo.alpha + stage)
            n_stages += 1
        elif isinstance(ph, FanOutBurst):
            add("inter", _fanout_time(topo, ph))
            n_stages += 1
        elif isinstance(ph, RailStage):
            rail = max(float(_div(ph.send, topo.nic_bw).max(initial=0.0)),
                       float(_div(ph.recv, topo.nic_bw).max(initial=0.0)))
            spine = _sdiv(float(ph.send.sum()), topo.spine_bandwidth)
            add("inter", max(rail, spine))
            add("sync", topo.alpha * max(ph.n_rounds, 1))
            n_stages += ph.n_rounds
        elif isinstance(ph, BoundStage):
            if ph.line_sums is not None:
                t = topo.theorem1_time(ph.line_sums, ph.inter_total)
            else:  # legacy scalar form (pre-topology serialized plans)
                t = max(_sdiv(ph.bound_bytes, float(topo.send_caps.max())),
                        _sdiv(ph.inter_total, topo.spine_bandwidth))
            add("inter", t)
            n_stages += 1
        elif isinstance(ph, RedistributePhase):
            tail = _tail_redistribute_time(
                topo, ph.bytes_per_gpu,
                perm_stages[-1] if perm_stages else None)
            if ph.charge_alpha:
                tail += topo.alpha
            add("tail", tail)
        elif isinstance(ph, IntraOverlapPhase):
            overlap_phases.append(ph)
        else:
            raise TypeError(f"executor cannot time phase {ph!r}")

    # Local traffic S_i spreads over the m GPUs' intra fabric and overlaps
    # the inter phase; only the residual beyond it is charged.
    for ph in overlap_phases:
        v = float(_div(ph.per_server,
                       m * topo.intra_a2a_bw).max(initial=0.0))
        intra_t = (v + topo.alpha) if float(
            ph.per_server.max(initial=0.0)) > 0 else 0.0
        add("intra_residual",
            max(0.0, intra_t - breakdown.get("inter", 0.0)))

    t = max(sum(breakdown.values()), 1e-30)
    total = w.total_bytes
    # Memory: send + recv buffers (2x) plus algorithm-specific staging.
    mem = 2.0 * total + plan.extra_memory_bytes
    return SimResult(
        algorithm=plan.algorithm,
        completion_time=t,
        algbw=total / t / topo.n_gpus if t > 0 else float("inf"),
        breakdown=breakdown,
        n_stages=n_stages,
        synth_seconds=plan.synth_seconds,
        memory_bytes=mem,
    )


def simulate(
    w: Workload,
    algorithm: str,
    *,
    plan: Optional[Plan] = None,
    cache: Optional[PlanCache] = None,
    topology: Optional[Topology] = None,
) -> SimResult:
    """Scheduler -> Plan -> Executor, in one call.

    Args:
      w: the GPU-level workload (its ``topo`` drives synthesis).
      algorithm: registry name (see available_schedulers()).
      plan: pre-synthesized Plan to execute (skips synthesis entirely).
      cache: optional PlanCache; on a repeated (traffic, topology)
        fingerprint the cached Plan is executed without re-synthesis
        (hit/miss counters on the cache record the reuse rate).
      topology: execution fabric override (see ``execute_plan``): times the
        plan on a fabric other than the one it was synthesized for.
    """
    if plan is None:
        scheduler = get_scheduler(algorithm)
        if cache is not None:
            plan = cache.get_or_synthesize(scheduler, w)
        else:
            plan = scheduler.synthesize(w)
    else:
        if plan.algorithm != algorithm:
            raise ValueError(
                f"plan was synthesized by {plan.algorithm!r}, asked to "
                f"execute as {algorithm!r}")
        if topology is None and \
                plan.topo.fingerprint() != w.topo.fingerprint():
            raise ValueError(
                "plan was synthesized for a different fabric than the "
                "workload's topology (stale plan after a fabric change?); "
                "re-synthesize, or pass topology= explicitly to time the "
                "blind schedule on the new fabric")
    return execute_plan(plan, w, topology=topology)


class _AlgorithmView(Mapping):
    """Live name -> simulate-callable view over the scheduler registry
    (back-compat for the seed's ALGORITHMS dict)."""

    def __iter__(self) -> Iterator[str]:
        return iter(SCHEDULERS)

    def __len__(self) -> int:
        return len(SCHEDULERS)

    def __getitem__(self, name: str):
        if name not in SCHEDULERS:
            raise KeyError(name)

        def run(w: Workload, **kw) -> SimResult:
            return simulate(w, name, **kw)

        return run


ALGORITHMS = _AlgorithmView()
