"""Link-level plan executor (paper 6.3), compiled for dynamic MoE serving.

One executor times *every* scheduler.  It understands a scheduler-agnostic
``Plan`` (core/plan.py) against the *named resources* of a ``Topology``
(core/topology.py) -- per-NIC send/recv occupancy, per-server intra
fabrics, and the scale-out spine:

  * every flow is pinned to the NICs and fabrics it actually crosses: an
    inter-server flow is limited by ``min`` of its endpoint NIC capacities,
    an intra-server flow by its server's fabric;
  * a server's inter-server slot bytes are split across its NICs by the
    plan's ``nic_shares`` (FLASH's capacity-proportional rebalance target;
    uniform 1/m when the plan is topology-blind) -- on a degraded or
    mixed-speed fabric the blind uniform split strands bytes on the slow
    NIC while the aware split keeps every NIC draining simultaneously;
  * every inter phase is additionally bounded by the spine:
    ``stage_inter_bytes / (sum(nic_bw) / oversubscription)`` -- inert at
    full bisection, binding when the scale-out tier is oversubscribed.

There are two execution paths over one timing model:

  * **Compiled (default)** -- ``compile_plan(plan, topology)`` (or
    ``Plan.compile()``) flattens all phases into padded array form once --
    stacked (S, n) permutation/slot matrices, gathered rail shares,
    receiver-fabric vectors, spine divisors -- and times every permutation
    stage, hidden redistribute and barrier stage in one vectorized pass.
    The resulting ``ExecutableSchedule`` carries the finished breakdown
    (the timing model depends only on (plan, topology), never on which
    traffic matrix is being accounted), so ``execute(w)`` costs one
    matrix reduction and ``execute_batch`` amortizes even that over a
    (B, N, N) stack.  ``Plan.compile`` memoizes the schedule on the plan
    per execution-topology fingerprint, so a ``PlanCache`` hit skips
    synthesis *and* compilation -- the serving-loop regime where traffic
    shifts every few hundred milliseconds and the executor used to re-walk
    O(stages) Python per iteration.
  * **Interpreted (oracle)** -- ``execute_plan(..., reference=True)``
    keeps the original per-phase walk, like
    ``birkhoff_decompose(reference=True)``: the compiled path is
    parity-tested against it to <= 1e-12 for every registered scheduler
    (tests/test_compiled_executor.py).

On a homogeneous topology all of this reduces algebraically to the scalar
alpha-beta model (each transfer costs ``alpha + bytes / bandwidth``;
concurrent transfers on a shared resource divide its bandwidth), and the
executor reproduces the scalar executor's completion times to <= 1e-9
relative error (golden-tested in tests/test_plan_ir.py).

Incast and straggler effects remain properties of stage *types*, not
algorithm names:

  * PermutationStage -- incast-free/straggler-free; ascending consecutive
    stages pipeline (stage k's redistribute hides under stage k+1's
    transfer; the un-hidden residual is charged explicitly, so the Theorem 2
    bound holds even when the intra fabric is slow -- ring topology,
    Fig 16a).
  * BarrierStage -- waits for its slowest flow (the straggler effect,
    Fig 3b).
  * FanOutBurst -- models incast collapse: once simultaneous inbound flow
    bytes at a NIC exceed what switch buffers absorb, goodput degrades by
    1 / (1 + gamma * (k - 1)) (retransmissions + queueing), matching the
    ~91x degradation the paper measured for RCCL at 32 GPUs on large
    balanced transfers (Fig 12a).  Size-weighted effective concurrency:
    short flows drain early, so skew *reduces* collision frequency.
  * RailStage -- the max-loaded rail is the straggler; one wakeup per
    rotation round.
  * BoundStage -- the Theorem 1 analytic bound, per-server line sums
    against per-server aggregate NIC capacity.

The figure of merit is *algorithmic bandwidth*:

    AlgoBW = total_bytes / completion_time / n_gpus      [bytes/s/GPU]

``simulate(w, name)`` is the one-call pipeline: registry lookup ->
synthesis (optionally via a PlanCache) -> compiled execution.
``simulate_many(workloads, name, cache=...)`` is its batched front door
for traffic trajectories.  Passing ``topology=`` executes a plan on a
*different* fabric than it was synthesized for -- the topology-blindness
experiment of benchmarks/fig_hetero.py.
"""

from __future__ import annotations

import dataclasses
from types import MappingProxyType
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Union

import numpy as np

from .plan import (
    BarrierStage,
    BoundStage,
    FanOutBurst,
    IntraOverlapPhase,
    LoadBalancePhase,
    PermutationBlock,
    PermutationStage,
    Plan,
    PlanCache,
    RailStage,
    RedistributePhase,
)
from .birkhoff import live_slots_batch
from .schedulers import SCHEDULERS, get_scheduler
from .topology import (
    Topology,
    bw_div as _div,
    bw_sdiv as _sdiv,
    uniform_nic_shares,
)
from .traffic import Workload

__all__ = [
    "SimResult",
    "ExecutableSchedule",
    "compile_plan",
    "simulate",
    "simulate_many",
    "execute_plan",
    "ALGORITHMS",
]

# Incast model constants (FanOutBurst stages only).
_INCAST_GAMMA = 4.0
_INCAST_BUFFER_BYTES = 32e6  # per-receiver absorption before collapse

# The compiler's vectorized stage pass works on (block, n, m) scratch
# arrays; blocking bounds peak scratch memory at large stage counts
# (n=256 has ~65k stages) without ever falling back to per-stage Python.
_COMPILE_BLOCK_ELEMS = 4_000_000


@dataclasses.dataclass(frozen=True)
class SimResult:
    algorithm: str
    completion_time: float
    algbw: float  # bytes / s / GPU
    breakdown: Dict[str, float]
    n_stages: int
    synth_seconds: float
    memory_bytes: float  # peak buffer footprint across the job

    def algbw_gbps(self) -> float:
        return self.algbw / 1e9


# -- interpreted oracle ----------------------------------------------------
#
# The original per-phase walk.  Kept verbatim as the parity oracle for the
# compiled path (``execute_plan(..., reference=True)``), exactly like the
# reference Birkhoff decomposer backs the incremental engines.

def _perm_stage_time(topo: Topology, ph: PermutationStage,
                     shares: np.ndarray) -> float:
    """One permutation stage, link-level (no alpha): each live sender i
    ships its slot to perm[i] -- the uniform ``size`` bytes, or its
    per-sender ``slots[i]`` when the stage is capacity-aware -- split
    across its NICs by ``shares``; rail g of the pair is capped by the
    slower endpoint NIC; the stage also crosses the spine once."""
    src, dst, slot = ph.live()
    if src.size == 0:
        return 0.0
    rail_caps = np.minimum(topo.nic_tx[src], topo.nic_rx[dst])  # (k, m)
    flows = slot[:, None] * shares[src, dst]                    # (k, m)
    spine_bytes = (ph.size * len(src) if ph.slots is None  # exact blind form
                   else float(slot.sum()))
    t = float(_div(flows, rail_caps).max(initial=0.0))
    spine = _sdiv(spine_bytes, topo.spine_bandwidth)
    return max(t, spine)


def _stage_redistribute_time(topo: Topology, ph: PermutationStage,
                             worst_a2a: float) -> float:
    """Hidden redistribute of one stage: each *receiver* spreads its slot
    over its own server fabric, so the stage is charged at the worst fabric
    it actually touches -- not the cluster-wide slowest (that model
    overcharges every fast server on mixed fabrics).  Padding-only stages
    keep the legacy cluster-min charge (they touch no server)."""
    m = topo.m_gpus
    src, dst, slot = ph.live()
    if src.size == 0:
        return _sdiv(ph.size / m, worst_a2a)
    return float(_div(slot / m, topo.intra_a2a_bw[dst]).max(initial=0.0))


def _tail_redistribute_time(topo: Topology, bytes_per_gpu: float,
                            last_stage: Optional[PermutationStage]) -> float:
    """Tail RedistributePhase: the *last* permutation stage's redistribute.
    Receiver j spreads its share of the tail bytes -- scaled by its slot's
    fraction of the stage (slot_j / size; 1 for uniform slots) -- over its
    own fabric, like the hidden redistributes.  Plans without permutation
    stages (hierarchical scatter) keep the conservative cluster-min charge.
    """
    if last_stage is not None and last_stage.size > 0:
        src, dst, slot = last_stage.live()
        if src.size:
            per_recv = bytes_per_gpu * (slot / float(last_stage.size))
            return float(_div(per_recv,
                              topo.intra_a2a_bw[dst]).max(initial=0.0))
    return _sdiv(bytes_per_gpu, float(topo.intra_a2a_bw.min()))


def _permutation_times(topo: Topology, stages: List[PermutationStage],
                       shares: np.ndarray) -> Dict[str, float]:
    """Ascending Birkhoff stage pipeline (paper 4.3 / Theorem 2).

    inter: sum over stages of alpha + link-level stage time.
    hidden_residual: stage k's redistribute must fit under stage k+1's
      transfer because l_k <= l_{k+1} and B1 > B2 (Theorem 2 pipelining
      argument); any excess is charged.  The redistribute rides the worst
      fabric among the stage's receivers.
    """
    worst_a2a = float(topo.intra_a2a_bw.min())
    times = [_perm_stage_time(topo, ph, shares) for ph in stages]
    inter = 0.0
    hidden_residual = 0.0
    for k, ph in enumerate(stages):
        inter += topo.alpha + times[k]
        if k + 1 < len(stages):
            redis = _stage_redistribute_time(topo, ph, worst_a2a)
            hidden_residual += max(0.0, redis - times[k + 1])
    return {"inter": inter, "hidden_residual": hidden_residual}


def _fanout_time(topo: Topology, ph: FanOutBurst) -> float:
    """One burst: receiver NICs fair-share + incast; sender uplinks bound;
    intra traffic rides each server's fabric concurrently; one wakeup."""
    n, m = topo.n_servers, topo.m_gpus
    nic = topo.nic_rx  # inbound fair-share + incast ride the receive plane
    blk = ph.matrix.reshape(n, m, n, m)
    # Zero the same-server sender rows per receiver: intra rides the fast
    # fabric, not the NIC.
    inter_flows = blk * (1.0 - np.eye(n))[:, None, :, None]
    inbound = inter_flows.sum(axis=(0, 1))          # (n, m) per receiver NIC
    fmax = inter_flows.max(axis=(0, 1), initial=0.0)
    senders = np.divide(inbound, fmax, out=np.zeros_like(inbound),
                        where=fmax > 0)
    base = _div(inbound, nic)
    collapse = (inbound > _INCAST_BUFFER_BYTES) & (senders > 1)
    if collapse.any():
        over = inbound - _INCAST_BUFFER_BYTES
        eta = 1.0 / (1.0 + _INCAST_GAMMA * (senders - 1))
        collapsed = (_div(np.full_like(inbound, _INCAST_BUFFER_BYTES), nic)
                     + _div(np.maximum(over, 0.0), nic * eta))
        base = np.where(collapse, collapsed, base)
    t = float(base.max(initial=0.0))
    # Sender uplinks (no incast on the send side).
    outbound = inter_flows.sum(axis=(2, 3))          # (n, m) per sender NIC
    t = max(t, float(_div(outbound, topo.nic_tx).max(initial=0.0)))
    # Intra traffic rides each server's fabric concurrently.
    intra_per_gpu = np.einsum("agah->ag", blk)       # (n, m)
    t = max(t, float(_div(intra_per_gpu,
                          topo.intra_a2a_bw[:, None]).max(initial=0.0)))
    # Everything crosses the spine at once.
    t = max(t, _sdiv(float(inter_flows.sum()), topo.spine_bandwidth))
    return t + topo.alpha


def _barrier_time(topo: Topology, ph: BarrierStage) -> float:
    """Slowest flow of a barrier-synchronized flow set, each flow pinned to
    the resources it crosses (endpoint NICs, or the source server fabric)."""
    m = topo.m_gpus
    src = np.arange(len(ph.sizes))
    dst = ph.dsts.astype(np.int64)
    src_s, src_g = src // m, src % m
    dst_s, dst_g = dst // m, dst % m
    same = src_s == dst_s
    inter_caps = np.minimum(topo.nic_tx[src_s, src_g],
                            topo.nic_rx[dst_s, dst_g])
    bw = np.where(same, topo.intra_path_bw[src_s], inter_caps)
    stage = float(_div(ph.sizes, bw).max(initial=0.0))
    spine = _sdiv(float(ph.sizes[~same].sum()), topo.spine_bandwidth)
    return max(stage, spine)


# The remaining phase types are timed by shared helpers used verbatim by
# the interpreted walk and the compiler so the two paths cannot drift.

def _overlap_residual_time(topo: Topology, ph: IntraOverlapPhase,
                           inter_total: float) -> float:
    """Local traffic S_i spreads over the m GPUs' intra fabric and overlaps
    the inter phase; only the residual beyond it is charged."""
    v = float(_div(ph.per_server,
                   topo.m_gpus * topo.intra_a2a_bw).max(initial=0.0))
    intra_t = (v + topo.alpha) if float(
        ph.per_server.max(initial=0.0)) > 0 else 0.0
    return max(0.0, intra_t - inter_total)


def _simple_phase_time(topo: Topology, ph, last_stage, add) -> int:
    """Time one of the one-per-plan phase types, shared verbatim by the
    interpreted walk and the compiler; returns the stage-count increment.
    ``last_stage`` is the plan's final permutation stage (the pipeline
    tail's shape), or None.  Permutation, barrier and overlap phases are
    each path's own business (batched vs per-phase); anything else unknown
    is an error."""
    if isinstance(ph, LoadBalancePhase):
        head = float(_div(ph.moved_per_gpu,
                          topo.intra_a2a_bw[:, None]).max(initial=0.0))
        if ph.charge_alpha and float(
                ph.moved_per_gpu.max(initial=0.0)) > 0:
            head += topo.alpha
        add("head", head)
        return 0
    if isinstance(ph, FanOutBurst):
        add("inter", _fanout_time(topo, ph))
        return 1
    if isinstance(ph, RailStage):
        rail = max(float(_div(ph.send, topo.nic_tx).max(initial=0.0)),
                   float(_div(ph.recv, topo.nic_rx).max(initial=0.0)))
        spine = _sdiv(float(ph.send.sum()), topo.spine_bandwidth)
        add("inter", max(rail, spine))
        add("sync", topo.alpha * max(ph.n_rounds, 1))
        return ph.n_rounds
    if isinstance(ph, BoundStage):
        if ph.line_sums is not None:
            t = topo.theorem1_time(ph.line_sums, ph.inter_total)
        else:  # legacy scalar form (pre-topology serialized plans)
            t = max(_sdiv(ph.bound_bytes, float(topo.send_caps.max())),
                    _sdiv(ph.inter_total, topo.spine_bandwidth))
        add("inter", t)
        return 1
    if isinstance(ph, RedistributePhase):
        tail = _tail_redistribute_time(topo, ph.bytes_per_gpu, last_stage)
        if ph.charge_alpha:
            tail += topo.alpha
        add("tail", tail)
        return 0
    raise TypeError(f"executor cannot time phase {ph!r}")


def _check_execution_shape(plan: Plan, topo: Topology) -> None:
    if (topo.n_servers, topo.m_gpus) != (plan.cluster.n_servers,
                                         plan.cluster.m_gpus):
        raise ValueError(
            f"execution topology shape ({topo.n_servers}, {topo.m_gpus}) "
            f"!= plan shape ({plan.cluster.n_servers}, "
            f"{plan.cluster.m_gpus})")


def _plan_shares(plan: Plan, topo: Topology) -> np.ndarray:
    """The plan's rail shares, or the memoized uniform fallback (the old
    executor allocated a fresh (n, n, m) array per call for every
    non-FLASH plan)."""
    if plan.nic_shares is not None:
        return plan.nic_shares
    return uniform_nic_shares(topo.n_servers, topo.m_gpus)


def _execute_plan_interpreted(plan: Plan, w: Workload,
                              topology: Optional[Topology] = None
                              ) -> SimResult:
    """The original per-phase walk (see ``execute_plan``)."""
    topo = topology if topology is not None else plan.topo
    _check_execution_shape(plan, topo)
    breakdown: Dict[str, float] = {}
    n_stages = 0
    overlap_phases = []

    def add(key: str, dt: float) -> None:
        breakdown[key] = breakdown.get(key, 0.0) + dt

    perm_stages: List[PermutationStage] = []
    for p in plan.phases:
        if isinstance(p, PermutationStage):
            perm_stages.append(p)
        elif isinstance(p, PermutationBlock):
            perm_stages.extend(p.iter_stages())  # per-stage oracle walk
    if perm_stages:
        shares = _plan_shares(plan, topo)
        for key, dt in _permutation_times(topo, perm_stages,
                                          shares).items():
            add(key, dt)
        n_stages += len(perm_stages)
    last_stage = perm_stages[-1] if perm_stages else None

    for ph in plan.phases:
        if isinstance(ph, (PermutationStage, PermutationBlock)):
            continue  # timed collectively above (pipelined group)
        if isinstance(ph, BarrierStage):
            stage = _barrier_time(topo, ph)
            if stage > 0:
                add("inter", topo.alpha + stage)
            n_stages += 1
        elif isinstance(ph, IntraOverlapPhase):
            overlap_phases.append(ph)
        else:
            n_stages += _simple_phase_time(topo, ph, last_stage, add)

    # Overlap phases resolve against the finished inter total.
    for ph in overlap_phases:
        add("intra_residual",
            _overlap_residual_time(topo, ph, breakdown.get("inter", 0.0)))

    t = max(sum(breakdown.values()), 1e-30)
    total = w.total_bytes
    # Memory: send + recv buffers (2x) plus algorithm-specific staging.
    mem = 2.0 * total + plan.extra_memory_bytes
    return SimResult(
        algorithm=plan.algorithm,
        completion_time=t,
        algbw=total / t / topo.n_gpus,
        breakdown=breakdown,
        n_stages=n_stages,
        synth_seconds=plan.synth_seconds,
        memory_bytes=mem,
    )


# -- compiled execution ----------------------------------------------------

TrafficBatch = Union[np.ndarray, Sequence[Union[Workload, np.ndarray]]]


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutableSchedule:
    """A Plan compiled against one execution Topology.

    The link-level timing model is a function of (plan, topology) only --
    the traffic matrix enters execution solely through its byte total
    (AlgoBW / memory accounting) -- so compilation finishes the entire
    breakdown once and ``execute`` is O(1) beyond that reduction.  Built
    by ``compile_plan`` / ``Plan.compile`` (which memoizes per topology
    fingerprint); parity with the interpreted executor is <= 1e-12
    (tests/test_compiled_executor.py).
    """

    plan: Plan
    topology: Topology
    completion_time: float
    # Read-only: the schedule is shared by every execute() of a memoized
    # compile, and completion_time is precomputed from these values.
    breakdown: Mapping[str, float]
    n_stages: int

    def _result(self, total_bytes: float) -> SimResult:
        t = self.completion_time
        plan = self.plan
        return SimResult(
            algorithm=plan.algorithm,
            completion_time=t,
            algbw=total_bytes / t / self.topology.n_gpus,
            breakdown=dict(self.breakdown),
            n_stages=self.n_stages,
            synth_seconds=plan.synth_seconds,
            memory_bytes=2.0 * total_bytes + plan.extra_memory_bytes,
        )

    def lower_device(self, n_pods: Optional[int] = None):
        """The device lowering of this schedule's plan (a DeviceSchedule).

        Bridges to ``comm.plan_exec.lower_plan`` -- lazily, so the
        host-only core keeps importing without jax.  Memoized on the plan
        per pod count, like ``Plan.compile`` per topology fingerprint.
        """
        from ..comm.plan_exec import lower_plan

        return lower_plan(self.plan, n_pods=n_pods)

    def _check_workload(self, w: Workload) -> None:
        if (w.cluster.n_servers, w.cluster.m_gpus) != (
                self.plan.cluster.n_servers, self.plan.cluster.m_gpus):
            raise ValueError(
                f"workload shape ({w.cluster.n_servers}, "
                f"{w.cluster.m_gpus}) != compiled plan shape "
                f"({self.plan.cluster.n_servers}, "
                f"{self.plan.cluster.m_gpus})")

    def execute(self, w: Workload) -> SimResult:
        """Account one workload against the compiled timing."""
        self._check_workload(w)
        return self._result(w.total_bytes)

    def execute_batch(self, traffic: TrafficBatch) -> List[SimResult]:
        """Time a whole trajectory of traffic against this schedule.

        ``traffic`` is a (B, N, N) stack of GPU-level matrices (one NumPy
        reduction for the batch), or a sequence of Workloads / matrices.
        Element b of the result equals ``execute_plan(plan, w_b)`` exactly
        -- the batched form of the dynamic-MoE drift experiment, where one
        synthesized schedule is held while traffic shifts under it.
        """
        n_gpus = self.plan.cluster.n_gpus
        if isinstance(traffic, np.ndarray):
            if traffic.ndim != 3 or traffic.shape[1:] != (n_gpus, n_gpus):
                raise ValueError(
                    f"traffic stack shape {traffic.shape} != "
                    f"(B, {n_gpus}, {n_gpus})")
            totals = traffic.reshape(traffic.shape[0], -1).sum(axis=1)
        else:
            mats = []
            for t in traffic:
                if isinstance(t, Workload):
                    self._check_workload(t)  # same contract as execute()
                    mats.append(t.matrix)
                else:
                    mats.append(np.asarray(t))
            for mat in mats:
                if mat.shape != (n_gpus, n_gpus):
                    raise ValueError(
                        f"traffic matrix shape {mat.shape} != "
                        f"({n_gpus}, {n_gpus})")
            totals = np.array([mat.sum() for mat in mats])
        return [self._result(float(t)) for t in totals]


def _stack_perm_arrays(phases, n: int):
    """Stack the plan's permutation phases (stages and blocks, in order)
    into ``(perms, sizes, slot2d, has_slots)`` arrays for the compiler's
    vectorized pass.  A lone PermutationBlock -- the incremental trajectory
    engine's emission -- passes its arrays through without copying."""
    if len(phases) == 1 and isinstance(phases[0], PermutationBlock):
        b = phases[0]
        perms = np.asarray(b.perms, dtype=np.int64)
        if perms.shape[1:] != (n,):
            raise ValueError(
                f"permutation stages must all have {n} senders to compile "
                f"(got shape {perms.shape})")
        return (perms, np.asarray(b.sizes, dtype=np.float64), b.slot2d(),
                np.full(perms.shape[0], b.slots is not None))
    perms_l, sizes_l, slots_l, has_l = [], [], [], []
    for p in phases:
        if isinstance(p, PermutationBlock):
            if p.n_stages == 0:
                continue
            perms_l.append(np.asarray(p.perms, dtype=np.int64))
            sizes_l.append(np.asarray(p.sizes, dtype=np.float64))
            slots_l.append(p.slot2d())
            has_l.append(np.full(p.n_stages, p.slots is not None))
        else:
            perms_l.append(np.asarray(p.perm, dtype=np.int64)[None, :])
            sizes_l.append(np.array([float(p.size)]))
            slots_l.append(
                (np.asarray(p.slots, dtype=np.float64)
                 if p.slots is not None
                 else np.full(len(p.perm), float(p.size)))[None, :])
            has_l.append(np.array([p.slots is not None]))
    if any(a.shape[-1] != n for a in perms_l):
        raise ValueError(
            f"permutation stages must all have {n} senders to compile "
            f"(got widths {sorted({a.shape[-1] for a in perms_l})})")
    if not perms_l:
        return (np.full((0, n), -1, dtype=np.int64), np.zeros(0),
                np.zeros((0, n)), np.zeros(0, dtype=bool))
    return (np.concatenate(perms_l, axis=0), np.concatenate(sizes_l),
            np.concatenate(slots_l, axis=0), np.concatenate(has_l))


def _last_perm_stage(phases) -> Optional[PermutationStage]:
    """The final (non-empty) permutation stage of the plan -- the shape the
    pipeline-tail redistribute spreads over."""
    for p in reversed(phases):
        if isinstance(p, PermutationBlock):
            if p.n_stages:
                return p.stage_view(p.n_stages - 1)
        else:
            return p
    return None


def _compiled_perm_group(topo: Topology, perms: np.ndarray,
                         sizes: np.ndarray, slot2d: np.ndarray,
                         has_slots: np.ndarray, shares: np.ndarray):
    """One vectorized pass over all permutation stages (stacked arrays
    from ``_stack_perm_arrays``).

    Returns (times, redis) where ``times[k]`` is stage k's link-level
    transfer time (spine included) and ``redis[k]`` its
    hidden-redistribute time -- the padded equivalents of
    ``_perm_stage_time`` / ``_stage_redistribute_time`` with dead senders
    contributing exactly nothing.
    """
    n, m = topo.n_servers, topo.m_gpus
    s_count = perms.shape[0]
    mask, dst, slot2d = live_slots_batch(perms, slot2d)
    live_count = mask.sum(axis=1)

    tx, rx = topo.nic_tx, topo.nic_rx
    a2a = topo.intra_a2a_bw
    rows_idx = np.arange(n)
    times = np.empty(s_count)
    redis = np.empty(s_count)
    block = max(1, _COMPILE_BLOCK_ELEMS // max(n * m, 1))
    for lo in range(0, s_count, block):
        hi = min(s_count, lo + block)
        p_blk = dst[lo:hi]                                   # (b, n)
        sl_blk = slot2d[lo:hi]                               # (b, n)
        rail_caps = np.minimum(tx[None, :, :], rx[p_blk])    # (b, n, m)
        flows = sl_blk[:, :, None] * shares[rows_idx[None, :], p_blk]
        times[lo:hi] = _div(flows, rail_caps).max(axis=(1, 2), initial=0.0)
        redis[lo:hi] = _div(sl_blk / m, a2a[p_blk]).max(axis=1, initial=0.0)

    # Spine: exact blind form (size * live senders) vs per-slot sum.
    spine_bytes = np.where(has_slots, slot2d.sum(axis=1),
                           sizes * live_count)
    times = np.maximum(times, _div(spine_bytes, topo.spine_bandwidth))
    # Padding-only stages: zero transfer (the interpreted path returns
    # before the spine term) but the legacy cluster-min redistribute.
    empty = live_count == 0
    if empty.any():
        times[empty] = 0.0
        redis[empty] = _div(sizes[empty] / m, float(a2a.min()))
    return times, redis


def compile_plan(plan: Plan, topology: Optional[Topology] = None
                 ) -> ExecutableSchedule:
    """Flatten a Plan into an ExecutableSchedule against one Topology.

    All permutation stages (and their hidden redistributes) are timed in
    one padded vectorized pass, barrier stages in another; the remaining
    phase types are one-per-plan and timed directly.  Phase *semantics*
    are identical to the interpreted walk -- this is a change of loop
    structure, not of timing model.  Prefer ``Plan.compile`` (memoized);
    this function always compiles fresh.
    """
    topo = topology if topology is not None else plan.topo
    _check_execution_shape(plan, topo)
    m = topo.m_gpus
    breakdown: Dict[str, float] = {}
    n_stages = 0

    def add(key: str, dt: float) -> None:
        breakdown[key] = breakdown.get(key, 0.0) + dt

    perm_phases = [p for p in plan.phases
                   if isinstance(p, (PermutationStage, PermutationBlock))]
    if perm_phases:
        perms, sizes, slot2d, has_slots = _stack_perm_arrays(
            perm_phases, topo.n_servers)
        if perms.shape[0]:
            shares = _plan_shares(plan, topo)
            times, redis = _compiled_perm_group(topo, perms, sizes, slot2d,
                                                has_slots, shares)
            add("inter", float((times + topo.alpha).sum()))
            # Stage k's redistribute hides under stage k+1's transfer;
            # the `where` keeps inf-vs-inf stages at zero residual exactly
            # like the interpreted `max(0.0, inf - inf)`.
            add("hidden_residual", float(
                np.where(redis[:-1] > times[1:], redis[:-1] - times[1:],
                         0.0).sum()))
            n_stages += int(perms.shape[0])
    last_stage = _last_perm_stage(perm_phases)

    barrier = [p for p in plan.phases if isinstance(p, BarrierStage)]
    if barrier and len({p.sizes.shape for p in barrier}) == 1:
        flows = np.stack([p.sizes for p in barrier])            # (K, N)
        dsts = np.stack([p.dsts for p in barrier]).astype(np.int64)
        src = np.arange(flows.shape[1])
        src_s, src_g = src // m, src % m
        dst_s, dst_g = dsts // m, dsts % m
        same = dst_s == src_s[None, :]
        caps = np.minimum(topo.nic_tx[src_s, src_g][None, :],
                          topo.nic_rx[dst_s, dst_g])
        bw = np.where(same, topo.intra_path_bw[src_s][None, :], caps)
        stage_t = _div(flows, bw).max(axis=1, initial=0.0)
        spine_t = _div(np.where(same, 0.0, flows).sum(axis=1),
                       topo.spine_bandwidth)
        t = np.maximum(stage_t, spine_t)
        if (t > 0).any():  # all-zero groups add no key, like interpreted
            add("inter", float(np.where(t > 0, topo.alpha + t, 0.0).sum()))
        n_stages += len(barrier)
        barrier = []  # consumed by the batched pass

    for ph in plan.phases:
        if isinstance(ph, (PermutationStage, PermutationBlock)):
            continue  # timed collectively above
        if isinstance(ph, BarrierStage):
            if barrier:  # ragged fallback: stages of mismatched width
                stage = _barrier_time(topo, ph)
                if stage > 0:
                    add("inter", topo.alpha + stage)
                n_stages += 1
        elif isinstance(ph, IntraOverlapPhase):
            pass  # resolved against the final inter total below
        else:
            n_stages += _simple_phase_time(topo, ph, last_stage, add)

    for ph in plan.phases:
        if isinstance(ph, IntraOverlapPhase):
            add("intra_residual",
                _overlap_residual_time(topo, ph, breakdown.get("inter",
                                                               0.0)))

    return ExecutableSchedule(
        plan=plan,
        topology=topo,
        completion_time=max(sum(breakdown.values()), 1e-30),
        breakdown=MappingProxyType(breakdown),
        n_stages=n_stages,
    )


def execute_plan(plan: Plan, w: Workload, *,
                 topology: Optional[Topology] = None,
                 reference: bool = False) -> SimResult:
    """Time a Plan against a Topology's link-level resources.

    Phase semantics are dispatched on phase *type* (see module docstring);
    overlap phases (IntraOverlapPhase) are resolved against the inter
    phase's duration after all stages are timed.  The breakdown always sums
    to completion_time.

    Execution goes through the compiled path: the plan's memoized
    ``ExecutableSchedule`` (compiled on first use per execution topology)
    accounts the workload in O(1) beyond the matrix byte total -- repeated
    execution of a cached plan stops paying O(stages) Python per call.

    Args:
      plan: the synthesized schedule.
      w: the workload (total-bytes accounting).
      topology: execution fabric override.  Default: the topology the plan
        was synthesized for.  Passing a different (same-shape) fabric times
        a topology-blind schedule on the real degraded/heterogeneous
        fabric.
      reference: run the original interpreted per-phase walk instead (the
        parity oracle; no compilation, no memoization).
    """
    if reference:
        return _execute_plan_interpreted(plan, w, topology=topology)
    return plan.compile(topology).execute(w)


def _check_plan_algorithm(plan: Plan, algorithm: str) -> None:
    if plan.algorithm != algorithm:
        raise ValueError(
            f"plan was synthesized by {plan.algorithm!r}, asked to "
            f"execute as {algorithm!r}")


def _check_plan_fabric(plan: Plan, w: Workload) -> None:
    if plan.topo.fingerprint() != w.topo.fingerprint():
        raise ValueError(
            "plan was synthesized for a different fabric than the "
            "workload's topology (stale plan after a fabric change?); "
            "re-synthesize, or pass topology= explicitly to time the "
            "blind schedule on the new fabric")


def _seed_cache(plan: Plan, cache: Optional[PlanCache]) -> None:
    """A pre-synthesized plan handed to a cached call seeds the cache
    under the plan's *own* traffic fingerprint, so replaying the traffic
    it was synthesized for hits from now on.  (Keying by the executed
    workload would poison the cache in drift experiments, where a stale
    plan is deliberately executed against new traffic.)"""
    if cache is not None and plan.fingerprint is not None:
        cache.insert(plan.fingerprint, plan)


def _resolve_plan(w: Workload, algorithm: str, plan: Optional[Plan],
                  cache: Optional[PlanCache],
                  topology: Optional[Topology]) -> Plan:
    """Shared synthesis/lookup front half of simulate / simulate_many."""
    if plan is None:
        scheduler = get_scheduler(algorithm)
        if cache is not None:
            return cache.get_or_synthesize(scheduler, w)
        return scheduler.synthesize(w)
    _check_plan_algorithm(plan, algorithm)
    if topology is None:
        _check_plan_fabric(plan, w)
    _seed_cache(plan, cache)
    return plan


def simulate(
    w: Workload,
    algorithm: str,
    *,
    plan: Optional[Plan] = None,
    cache: Optional[PlanCache] = None,
    topology: Optional[Topology] = None,
    reference: bool = False,
) -> SimResult:
    """Scheduler -> Plan -> Executor, in one call.

    Args:
      w: the GPU-level workload (its ``topo`` drives synthesis).
      algorithm: registry name (see available_schedulers()).
      plan: pre-synthesized Plan to execute (skips synthesis entirely).
        With ``cache=`` it is also inserted under its own traffic
        fingerprint so later replays of that traffic hit.
      cache: optional PlanCache; on a repeated (traffic, topology)
        fingerprint the cached Plan -- with its compiled schedule already
        attached -- is executed without re-synthesis (hit/miss counters on
        the cache record the reuse rate).
      topology: execution fabric override (see ``execute_plan``): times the
        plan on a fabric other than the one it was synthesized for.
      reference: time via the interpreted oracle executor.
    """
    plan = _resolve_plan(w, algorithm, plan, cache, topology)
    return execute_plan(plan, w, topology=topology, reference=reference)


def simulate_many(
    workloads: Sequence[Workload],
    algorithm: str,
    *,
    plan: Optional[Plan] = None,
    cache: Optional[PlanCache] = None,
    topology: Optional[Topology] = None,
    reference: bool = False,
    fuse: bool = False,
) -> List[SimResult]:
    """Batched front door: time a trajectory of workloads in order.

    The serving-loop pipeline (paper: "traffic shifts every few hundred
    milliseconds") per element: cache lookup (exact hit -> cached plan with
    its compiled schedule attached; near-miss -> warm repair when the cache
    enables it) -> compiled execution.  Runs of consecutive workloads that
    resolve to the *same* plan are accounted through one
    ``ExecutableSchedule.execute_batch`` call.  Equivalent to
    ``[simulate(w, algorithm, ...) for w in workloads]`` result-for-result
    (regression-tested), minus the per-iteration executor overhead.

    Args:
      workloads: the traffic trajectory, in serving order.
      plan: hold one pre-synthesized Plan for the whole trajectory (the
        drift experiment: how does a stale schedule fare as traffic moves).
      fuse: synthesize the whole trajectory up front through the
        scheduler's ``synthesize_trajectory`` (FLASH: incremental
        delta-decomposition chained across adjacent matrices) instead of
        resolving plans one by one; the fused plans also seed ``cache``.
        Ignored when the scheduler does not fuse or ``plan`` is held.
    """
    workloads = list(workloads)
    fused: Optional[List[Plan]] = None
    if fuse and plan is None:
        scheduler = get_scheduler(algorithm)
        if hasattr(scheduler, "synthesize_trajectory"):
            fused = scheduler.synthesize_trajectory(workloads)
            for p in fused:
                _seed_cache(p, cache)
    if reference:
        return [simulate(w, algorithm,
                         plan=fused[i] if fused is not None else plan,
                         cache=cache, topology=topology, reference=True)
                for i, w in enumerate(workloads)]
    results: List[Optional[SimResult]] = [None] * len(workloads)
    run_sched: Optional[ExecutableSchedule] = None
    run_idx: List[int] = []

    def flush() -> None:
        if run_sched is not None and run_idx:
            batch = run_sched.execute_batch(
                [workloads[i] for i in run_idx])
            for i, r in zip(run_idx, batch):
                results[i] = r
        run_idx.clear()

    if plan is not None:
        # Loop-invariant for a held plan: check and seed the cache once,
        # not once per trajectory element.
        _check_plan_algorithm(plan, algorithm)
        _seed_cache(plan, cache)
    for i, w in enumerate(workloads):
        if plan is not None:
            if topology is None:
                _check_plan_fabric(plan, w)
            p = plan
        elif fused is not None:
            p = fused[i]
        else:
            p = _resolve_plan(w, algorithm, None, cache, topology)
        sched = p.compile(topology)
        if sched is not run_sched:
            flush()
            run_sched = sched
        run_idx.append(i)
    flush()
    return results  # type: ignore[return-value]


class _AlgorithmView(Mapping):
    """Live name -> simulate-callable view over the scheduler registry
    (back-compat for the seed's ALGORITHMS dict)."""

    def __iter__(self) -> Iterator[str]:
        return iter(SCHEDULERS)

    def __len__(self) -> int:
        return len(SCHEDULERS)

    def __getitem__(self, name: str):
        if name not in SCHEDULERS:
            raise KeyError(name)

        def run(w: Workload, **kw) -> SimResult:
            return simulate(w, name, **kw)

        return run


ALGORITHMS = _AlgorithmView()
