"""Alpha-beta cost-model simulator for All-to-All schedules (paper 6.3).

Each transfer costs ``alpha + bytes / bandwidth``; concurrent transfers on a
shared resource (a NIC, an intra-server fabric) divide its bandwidth.  The
simulator times every scheduler in schedulers.py and reports the paper's
figure of merit, *algorithmic bandwidth*:

    AlgoBW = total_bytes / completion_time / n_gpus      [bytes/s/GPU]

FanOut additionally models incast collapse: once the simultaneous inbound
flow count at a NIC exceeds what switch buffers absorb, goodput degrades by
1 / (1 + gamma * (k - 1)) (retransmissions + queueing), matching the ~91x
degradation the paper measured for RCCL at 32 GPUs on large balanced
transfers (Fig 12a).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from .schedulers import (
    FlashPlan,
    flash_schedule,
    hierarchical_nic_loads,
    optimal_completion_time,
    spreadout_stages,
)
from .traffic import Workload

__all__ = ["SimResult", "simulate", "ALGORITHMS"]

# Incast model constants (FanOut only).
_INCAST_GAMMA = 4.0
_INCAST_BUFFER_BYTES = 32e6  # per-receiver absorption before collapse


@dataclasses.dataclass(frozen=True)
class SimResult:
    algorithm: str
    completion_time: float
    algbw: float  # bytes / s / GPU
    breakdown: Dict[str, float]
    n_stages: int
    synth_seconds: float
    memory_bytes: float  # peak buffer footprint across the job

    def algbw_gbps(self) -> float:
        return self.algbw / 1e9


def _result(w: Workload, name: str, t: float, breakdown, n_stages, synth,
            mem) -> SimResult:
    total = w.total_bytes
    return SimResult(
        algorithm=name,
        completion_time=t,
        algbw=total / t / w.cluster.n_gpus if t > 0 else float("inf"),
        breakdown=dict(breakdown),
        n_stages=n_stages,
        synth_seconds=synth,
        memory_bytes=mem,
    )


def simulate_optimal(w: Workload) -> SimResult:
    t = optimal_completion_time(w)
    t = max(t, 1e-30)
    return _result(w, "optimal", t, {"inter": t}, 1, 0.0,
                   2.0 * w.total_bytes)


def simulate_flash(w: Workload, plan: FlashPlan | None = None) -> SimResult:
    """Time the three-phase FLASH pipeline (paper 4.3 / Theorem 2).

    head:  load balance (intra A2A), not hidden.
    inter: sum over ascending Birkhoff stages of alpha + l_k / (m * B2);
           stage k's redistribute hides under stage k+1's transfer because
           l_k <= l_{k+1} and B1 > B2 (Theorem 2 pipelining argument); any
           residual is charged explicitly, so the bound holds even when the
           intra fabric is slow (ring topology, Fig 16a).
    tail:  the last stage's redistribute (pipeline tail).
    intra: local traffic S_i overlaps the inter phase; only the residual
           beyond the inter phase length is charged.
    """
    c = w.cluster
    if plan is None:
        plan = flash_schedule(w)
    m = c.m_gpus
    bw_intra = c.intra_a2a_bandwidth()
    bw_path = c.intra_path_bandwidth()

    head = (plan.lb_moved_per_gpu.max(initial=0.0) / bw_intra
            + (c.alpha if plan.lb_moved_per_gpu.max(initial=0.0) > 0 else 0.0))

    sizes = plan.stage_sizes()
    inter = 0.0
    hidden_residual = 0.0
    for k, l in enumerate(sizes):
        inter += c.alpha + l / (m * c.b_inter)
        if k + 1 < len(sizes):
            # redistribute of stage k must fit under transfer of stage k+1
            redis = (l / m) / bw_intra
            nxt = sizes[k + 1] / (m * c.b_inter)
            hidden_residual += max(0.0, redis - nxt)
    tail = ((sizes[-1] / m) / bw_intra + c.alpha) if len(sizes) else 0.0

    # Local traffic S_i spreads over the m GPUs' intra fabric (FLASH
    # balances it like everything else; Theorem 2's single-path placement
    # is the worst-case bound, not the schedule's behaviour).
    s_max = plan.intra_bytes.max(initial=0.0)
    intra_t = (s_max / (m * bw_intra) + c.alpha) if s_max > 0 else 0.0
    del bw_path
    intra_residual = max(0.0, intra_t - inter)

    t = head + inter + hidden_residual + tail + intra_residual
    t = max(t, 1e-30)
    # Memory: send + recv buffers (2x) plus staging for load balance and
    # redistribute (the measured ~2.6x slope of Fig 17b).
    mem = 2.0 * w.total_bytes + plan.lb_moved_per_gpu.sum() + plan.inter_bytes / m
    return _result(
        w, "flash", t,
        {"head": head, "inter": inter, "hidden_residual": hidden_residual,
         "tail": tail, "intra_residual": intra_residual},
        plan.n_stages, plan.synth_seconds, mem)


def simulate_spreadout(w: Workload) -> SimResult:
    """MPI SpreadOut: barrier-synchronized stages; each stage waits for its
    slowest flow (the straggler effect, Fig 3b)."""
    c = w.cluster
    n_gpus = c.n_gpus
    m = c.m_gpus
    bw_path = c.intra_path_bandwidth()
    t = 0.0
    for k, sizes in enumerate(spreadout_stages(w), start=1):
        shift = k
        stage = 0.0
        for g in range(n_gpus):
            dst = (g + shift) % n_gpus
            same_server = (g // m) == (dst // m)
            bw = bw_path if same_server else c.b_inter
            stage = max(stage, sizes[g] / bw)
        if stage > 0:
            t += c.alpha + stage
    t = max(t, 1e-30)
    return _result(w, "spreadout", t, {"inter": t}, n_gpus - 1, 0.0,
                   2.0 * w.total_bytes)


def simulate_fanout(w: Workload) -> SimResult:
    """RCCL FanOut: everything at once; NICs fair-share; incast collapse
    beyond buffer absorption."""
    c = w.cluster
    n, m = c.n_servers, c.m_gpus
    blk = w.matrix.reshape(n, m, n, m)
    t = 0.0
    for b in range(n):
        for h in range(m):
            flows = blk[:, :, b, h].copy()
            flows[b, :] = 0.0  # intra rides the fast fabric
            inbound = flows.sum()
            # Size-weighted effective concurrency: short flows drain early,
            # so skew *reduces* collision frequency (paper section 6.1.1's
            # RCCL observation); balanced => equals the flow count.
            fmax = flows.max()
            senders = float(inbound / fmax) if fmax > 0 else 0.0
            base = inbound / c.b_inter
            if inbound > _INCAST_BUFFER_BYTES and senders > 1:
                over = inbound - _INCAST_BUFFER_BYTES
                eta = 1.0 / (1.0 + _INCAST_GAMMA * (senders - 1))
                base = (_INCAST_BUFFER_BYTES / c.b_inter
                        + over / (c.b_inter * eta))
            t = max(t, base)
    for a in range(n):  # sender uplinks (no incast on send side)
        for g in range(m):
            outbound = blk[a, g].sum() - blk[a, g, a].sum()
            t = max(t, outbound / c.b_inter)
    # Intra traffic rides the fast fabric concurrently.
    intra_t = max(
        (blk[a, g, a].sum() / c.intra_a2a_bandwidth()
         for a in range(n) for g in range(m)),
        default=0.0)
    t = max(t, intra_t) + c.alpha
    t = max(t, 1e-30)
    return _result(w, "fanout", t, {"inter": t}, 1, 0.0, 2.0 * w.total_bytes)


def simulate_hierarchical(w: Workload) -> SimResult:
    """MSCCL-style rail-aligned hierarchical A2A.

    Matches FLASH on balanced workloads (every rail carries the same bytes)
    but cannot rebalance across NICs under skew -- the max-loaded rail
    becomes the straggler.
    """
    c = w.cluster
    send, recv, gather = hierarchical_nic_loads(w)
    bw_intra = c.intra_a2a_bandwidth()
    head = gather.max(initial=0.0) / bw_intra
    inter = max(send.max(initial=0.0), recv.max(initial=0.0)) / c.b_inter
    # Scatter at the receiver pipelines with inter arrivals; charge tail only.
    tail = recv.max(initial=0.0) / max(c.m_gpus, 1) / bw_intra
    t = head + inter + tail + c.alpha * max(c.n_servers - 1, 1)
    t = max(t, 1e-30)
    mem = 2.0 * w.total_bytes + gather.sum()
    return _result(w, "hierarchical", t,
                   {"head": head, "inter": inter, "tail": tail},
                   c.n_servers - 1, 0.0, mem)


ALGORITHMS = {
    "optimal": simulate_optimal,
    "flash": simulate_flash,
    "spreadout": simulate_spreadout,
    "fanout": simulate_fanout,
    "hierarchical": simulate_hierarchical,
}


def simulate(w: Workload, algorithm: str) -> SimResult:
    try:
        fn = ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; pick from {sorted(ALGORITHMS)}")
    return fn(w)
