"""Generic alpha-beta plan executor (paper 6.3).

One executor times *every* scheduler: it walks a scheduler-agnostic ``Plan``
(core/plan.py) and interprets each typed phase under the alpha-beta cost
model -- each transfer costs ``alpha + bytes / bandwidth``; concurrent
transfers on a shared resource (a NIC, an intra-server fabric) divide its
bandwidth.  Incast and straggler effects are properties of stage *types*,
not algorithm names:

  * PermutationStage -- incast-free/straggler-free; ascending consecutive
    stages pipeline (stage k's redistribute hides under stage k+1's
    transfer; the un-hidden residual is charged explicitly, so the Theorem 2
    bound holds even when the intra fabric is slow -- ring topology,
    Fig 16a).
  * BarrierStage -- waits for its slowest flow (the straggler effect,
    Fig 3b).
  * FanOutBurst -- models incast collapse: once simultaneous inbound flow
    bytes at a NIC exceed what switch buffers absorb, goodput degrades by
    1 / (1 + gamma * (k - 1)) (retransmissions + queueing), matching the
    ~91x degradation the paper measured for RCCL at 32 GPUs on large
    balanced transfers (Fig 12a).  Size-weighted effective concurrency:
    short flows drain early, so skew *reduces* collision frequency.
  * RailStage -- the max-loaded rail is the straggler; one wakeup per
    rotation round.
  * BoundStage -- the Theorem 1 analytic bound.

The figure of merit is *algorithmic bandwidth*:

    AlgoBW = total_bytes / completion_time / n_gpus      [bytes/s/GPU]

``simulate(w, name)`` is the one-call pipeline: registry lookup ->
synthesis (optionally via a PlanCache) -> execution.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Mapping, Optional

import numpy as np

from .plan import (
    BarrierStage,
    BoundStage,
    FanOutBurst,
    IntraOverlapPhase,
    LoadBalancePhase,
    PermutationStage,
    Plan,
    PlanCache,
    RailStage,
    RedistributePhase,
)
from .schedulers import SCHEDULERS, get_scheduler
from .traffic import Workload

__all__ = ["SimResult", "simulate", "execute_plan", "ALGORITHMS"]

# Incast model constants (FanOutBurst stages only).
_INCAST_GAMMA = 4.0
_INCAST_BUFFER_BYTES = 32e6  # per-receiver absorption before collapse


@dataclasses.dataclass(frozen=True)
class SimResult:
    algorithm: str
    completion_time: float
    algbw: float  # bytes / s / GPU
    breakdown: Dict[str, float]
    n_stages: int
    synth_seconds: float
    memory_bytes: float  # peak buffer footprint across the job

    def algbw_gbps(self) -> float:
        return self.algbw / 1e9


def _permutation_times(plan: Plan, sizes: np.ndarray) -> Dict[str, float]:
    """Ascending Birkhoff stage pipeline (paper 4.3 / Theorem 2).

    inter: sum over stages of alpha + l_k / (m * B2).
    hidden_residual: stage k's redistribute must fit under stage k+1's
      transfer because l_k <= l_{k+1} and B1 > B2 (Theorem 2 pipelining
      argument); any excess is charged.
    """
    c = plan.cluster
    m = c.m_gpus
    bw_intra = c.intra_a2a_bandwidth()
    inter = 0.0
    hidden_residual = 0.0
    for k, l in enumerate(sizes):
        inter += c.alpha + l / (m * c.b_inter)
        if k + 1 < len(sizes):
            redis = (l / m) / bw_intra
            nxt = sizes[k + 1] / (m * c.b_inter)
            hidden_residual += max(0.0, redis - nxt)
    return {"inter": inter, "hidden_residual": hidden_residual}


def _fanout_time(plan: Plan, ph: FanOutBurst) -> float:
    """One burst: receiver NICs fair-share + incast; sender uplinks bound;
    intra traffic rides the fast fabric concurrently; one wakeup."""
    c = plan.cluster
    n, m = c.n_servers, c.m_gpus
    blk = ph.matrix.reshape(n, m, n, m)
    # Zero the same-server sender rows per receiver: intra rides the fast
    # fabric, not the NIC.
    inter_flows = blk * (1.0 - np.eye(n))[:, None, :, None]
    inbound = inter_flows.sum(axis=(0, 1))          # (n, m) per receiver NIC
    fmax = inter_flows.max(axis=(0, 1), initial=0.0)
    senders = np.divide(inbound, fmax, out=np.zeros_like(inbound),
                        where=fmax > 0)
    base = inbound / c.b_inter
    collapse = (inbound > _INCAST_BUFFER_BYTES) & (senders > 1)
    if collapse.any():
        over = inbound - _INCAST_BUFFER_BYTES
        eta = 1.0 / (1.0 + _INCAST_GAMMA * (senders - 1))
        with np.errstate(divide="ignore", invalid="ignore"):
            collapsed = (_INCAST_BUFFER_BYTES / c.b_inter
                         + over / (c.b_inter * eta))
        base = np.where(collapse, collapsed, base)
    t = float(base.max(initial=0.0))
    # Sender uplinks (no incast on the send side).
    outbound = inter_flows.sum(axis=(2, 3))          # (n, m) per sender NIC
    t = max(t, float(outbound.max(initial=0.0)) / c.b_inter)
    # Intra traffic rides the fast fabric concurrently.
    intra_per_gpu = np.einsum("agah->ag", blk)       # (n, m)
    t = max(t, float(intra_per_gpu.max(initial=0.0))
            / c.intra_a2a_bandwidth())
    return t + c.alpha


def execute_plan(plan: Plan, w: Workload) -> SimResult:
    """Time a Plan under the alpha-beta model.

    Phase semantics are dispatched on phase *type* (see module docstring);
    overlap phases (IntraOverlapPhase) are resolved against the inter
    phase's duration after all stages are timed.  The breakdown always sums
    to completion_time.
    """
    c = plan.cluster
    m = c.m_gpus
    bw_intra = c.intra_a2a_bandwidth()
    breakdown: Dict[str, float] = {}
    n_stages = 0
    overlap_phases = []

    def add(key: str, dt: float) -> None:
        breakdown[key] = breakdown.get(key, 0.0) + dt

    perm_sizes = np.array([p.size for p in plan.phases
                           if isinstance(p, PermutationStage)])
    if len(perm_sizes):
        for key, dt in _permutation_times(plan, perm_sizes).items():
            add(key, dt)
        n_stages += len(perm_sizes)

    for ph in plan.phases:
        if isinstance(ph, PermutationStage):
            continue  # timed collectively above (pipelined group)
        if isinstance(ph, LoadBalancePhase):
            moved = float(ph.moved_per_gpu.max(initial=0.0))
            head = moved / bw_intra
            if ph.charge_alpha and moved > 0:
                head += c.alpha
            add("head", head)
        elif isinstance(ph, BarrierStage):
            same = (np.arange(len(ph.sizes)) // m) == (ph.dsts // m)
            bw = np.where(same, c.intra_path_bandwidth(), c.b_inter)
            stage = float((ph.sizes / bw).max(initial=0.0))
            if stage > 0:
                add("inter", c.alpha + stage)
            n_stages += 1
        elif isinstance(ph, FanOutBurst):
            add("inter", _fanout_time(plan, ph))
            n_stages += 1
        elif isinstance(ph, RailStage):
            add("inter", max(float(ph.send.max(initial=0.0)),
                             float(ph.recv.max(initial=0.0))) / c.b_inter)
            add("sync", c.alpha * max(ph.n_rounds, 1))
            n_stages += ph.n_rounds
        elif isinstance(ph, BoundStage):
            add("inter", ph.bound_bytes / (m * c.b_inter))
            n_stages += 1
        elif isinstance(ph, RedistributePhase):
            tail = ph.bytes_per_gpu / bw_intra
            if ph.charge_alpha:
                tail += c.alpha
            add("tail", tail)
        elif isinstance(ph, IntraOverlapPhase):
            overlap_phases.append(ph)
        else:
            raise TypeError(f"executor cannot time phase {ph!r}")

    # Local traffic S_i spreads over the m GPUs' intra fabric and overlaps
    # the inter phase; only the residual beyond it is charged.
    for ph in overlap_phases:
        s_max = float(ph.per_server.max(initial=0.0))
        intra_t = (s_max / (m * bw_intra) + c.alpha) if s_max > 0 else 0.0
        add("intra_residual",
            max(0.0, intra_t - breakdown.get("inter", 0.0)))

    t = max(sum(breakdown.values()), 1e-30)
    total = w.total_bytes
    # Memory: send + recv buffers (2x) plus algorithm-specific staging.
    mem = 2.0 * total + plan.extra_memory_bytes
    return SimResult(
        algorithm=plan.algorithm,
        completion_time=t,
        algbw=total / t / c.n_gpus if t > 0 else float("inf"),
        breakdown=breakdown,
        n_stages=n_stages,
        synth_seconds=plan.synth_seconds,
        memory_bytes=mem,
    )


def simulate(
    w: Workload,
    algorithm: str,
    *,
    plan: Optional[Plan] = None,
    cache: Optional[PlanCache] = None,
) -> SimResult:
    """Scheduler -> Plan -> Executor, in one call.

    Args:
      w: the GPU-level workload.
      algorithm: registry name (see available_schedulers()).
      plan: pre-synthesized Plan to execute (skips synthesis entirely).
      cache: optional PlanCache; on a repeated traffic fingerprint the
        cached Plan is executed without re-synthesis (hit/miss counters on
        the cache record the reuse rate).
    """
    if plan is None:
        scheduler = get_scheduler(algorithm)
        if cache is not None:
            plan = cache.get_or_synthesize(scheduler, w)
        else:
            plan = scheduler.synthesize(w)
    elif plan.algorithm != algorithm:
        raise ValueError(
            f"plan was synthesized by {plan.algorithm!r}, asked to "
            f"execute as {algorithm!r}")
    return execute_plan(plan, w)


class _AlgorithmView(Mapping):
    """Live name -> simulate-callable view over the scheduler registry
    (back-compat for the seed's ALGORITHMS dict)."""

    def __iter__(self) -> Iterator[str]:
        return iter(SCHEDULERS)

    def __len__(self) -> int:
        return len(SCHEDULERS)

    def __getitem__(self, name: str):
        if name not in SCHEDULERS:
            raise KeyError(name)

        def run(w: Workload, **kw) -> SimResult:
            return simulate(w, name, **kw)

        return run


ALGORITHMS = _AlgorithmView()
