"""Scheduler-agnostic Plan IR: the contract between synthesis and execution.

A ``Plan`` is a typed, ordered sequence of phases describing *what moves
where, under which concurrency semantics* -- with no timing model attached.
Schedulers (schedulers.py) synthesize Plans; the single generic alpha-beta
executor (simulator.py) times them.  Incast and straggler effects are
properties of *stage types*, not algorithm names:

  * ``PermutationStage``  -- one sender per receiver, equal chunk size
                             (incast-free, straggler-free; FLASH/Birkhoff).
                             Consecutive permutation stages pipeline: stage
                             k's intra redistribute hides under stage k+1's
                             inter transfer (paper Theorem 2).
  * ``BarrierStage``      -- a barrier-synchronized set of point-to-point
                             flows; the stage waits for its slowest flow
                             (the straggler effect; MPI SpreadOut).
  * ``FanOutBurst``       -- everything at once; NICs fair-share and incast
                             collapse beyond buffer absorption (RCCL FanOut).
  * ``RailStage``         -- rail-aligned NIC loads progressing in rotation
                             rounds (MSCCL-style hierarchical).
  * ``BoundStage``        -- analytic Theorem-1 bound (the 'optimal' line;
                             not executable on hardware, timeable here).

Pre/post phases: ``LoadBalancePhase`` (intra-server shedding before the
inter phase), ``RedistributePhase`` (the un-hidden pipeline tail) and
``IntraOverlapPhase`` (local traffic overlapped with the inter phase).

Every phase serializes to plain JSON-compatible dicts (``to_dict`` /
``from_dict`` via the ``PHASE_KINDS`` registry) and reports the genuine
payload bytes it carries so ``Plan.validate`` can check byte conservation
against the source workload.

``PlanCache`` keys synthesized plans by a traffic-matrix fingerprint --
the paper's dynamic-MoE reuse story: expert routing shifts every few
hundred milliseconds but frequently *repeats* signatures across iterations,
so re-synthesis can be skipped when the fingerprint hits (hit/miss counters
exposed).  See DESIGN.md section 1.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, ClassVar, Dict, List, Optional, Tuple

import numpy as np

from ..analysis.locks import make_rlock
from .birkhoff import live_slots, live_slots_batch
from .topology import Topology, uniform_nic_shares
from .traffic import ClusterSpec, Workload, server_reduce

__all__ = [
    "Plan",
    "PlanValidationError",
    "PlanCache",
    "traffic_fingerprint",
    "cluster_family_key",
    "plan_family_key",
    "LoadBalancePhase",
    "PermutationStage",
    "PermutationBlock",
    "BarrierStage",
    "FanOutBurst",
    "RailStage",
    "BoundStage",
    "RedistributePhase",
    "IntraOverlapPhase",
    "PHASE_KINDS",
]


class PlanValidationError(ValueError):
    """A Plan fails structural or byte-conservation checks."""


# kind string -> phase class, for from_dict round-tripping.
PHASE_KINDS: Dict[str, type] = {}


def register_phase(cls):
    PHASE_KINDS[cls.kind] = cls
    return cls


def _np2d(v) -> np.ndarray:
    return np.asarray(v, dtype=np.float64)


def _listify(a: np.ndarray):
    return np.asarray(a, dtype=np.float64).tolist()


@dataclasses.dataclass(frozen=True, eq=False)
class PhaseBase:
    """Common serialization + payload-accounting interface.

    ``payload(cluster)`` returns ``(inter_bytes, intra_bytes)`` of *genuine
    workload payload* this phase carries across the inter-server network and
    the intra-server fabric respectively.  Auxiliary movement (load-balance
    shedding, redistribute copies) reports (0, 0): it is overhead the
    schedule added, not workload bytes, so it is excluded from conservation.
    """

    kind: ClassVar[str] = "base"

    def payload(self, cluster: ClusterSpec) -> Tuple[float, float]:
        return 0.0, 0.0

    def to_dict(self) -> Dict[str, Any]:
        raise NotImplementedError

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PhaseBase":
        raise NotImplementedError


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class LoadBalancePhase(PhaseBase):
    """Intra-server head phase: each GPU sheds ``moved_per_gpu`` bytes over
    the intra fabric before the inter phase starts (FLASH load balance /
    hierarchical rail gather).  Auxiliary movement: not payload."""

    kind: ClassVar[str] = "load_balance"
    moved_per_gpu: np.ndarray  # (n_servers, m_gpus)
    charge_alpha: bool = True  # FLASH charges a wakeup; rail gather does not

    def to_dict(self):
        return {"kind": self.kind,
                "moved_per_gpu": _listify(self.moved_per_gpu),
                "charge_alpha": bool(self.charge_alpha)}

    @classmethod
    def from_dict(cls, d):
        return cls(moved_per_gpu=_np2d(d["moved_per_gpu"]),
                   charge_alpha=bool(d["charge_alpha"]))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class PermutationStage(PhaseBase):
    """One incast-free, straggler-free inter-server stage: server i sends a
    ``size``-byte slot to server ``perm[i]`` (-1 = idle padding slot);
    ``sent[i]`` is the genuine payload inside the slot.

    ``slots`` is None for capacity-blind stages (uniform ``size``-byte
    slots).  Capacity-aware synthesis sizes each sender's slot to its pair
    capacity (``slots[i] = window * pair_capacity(i, perm[i])``) so every
    pair drains in the same time window -- equal-*time* slots, the
    heterogeneous-fabric generalization of straggler freedom; ``size`` is
    then the largest slot.
    """

    kind: ClassVar[str] = "permutation"
    perm: Tuple[int, ...]
    size: float
    sent: Tuple[float, ...]
    slots: Optional[Tuple[float, ...]] = None

    def payload(self, cluster):
        return float(sum(self.sent)), 0.0

    @property
    def real_bytes(self) -> float:
        return float(sum(self.sent))

    def live(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Memoized ``live_slots`` of this stage: ``(src, dst, slot)``.

        The interpreted executor consults a stage's live senders up to
        three times (transfer, hidden redistribute, pipeline tail) and the
        validator once more; the stage is frozen, so the extraction is
        computed once and shared.  The arrays are read-only."""
        cached = self.__dict__.get("_live")
        if cached is None:
            cached = live_slots(self.perm, self.slots, self.size)
            for a in cached:
                a.flags.writeable = False
            object.__setattr__(self, "_live", cached)
        return cached

    def to_dict(self):
        d = {"kind": self.kind, "perm": list(self.perm),
             "size": float(self.size), "sent": list(self.sent)}
        if self.slots is not None:
            d["slots"] = list(self.slots)
        return d

    @classmethod
    def from_dict(cls, d):
        slots = d.get("slots")
        return cls(perm=tuple(int(j) for j in d["perm"]),
                   size=float(d["size"]),
                   sent=tuple(float(x) for x in d["sent"]),
                   slots=None if slots is None
                   else tuple(float(x) for x in slots))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class PermutationBlock(PhaseBase):
    """A run of consecutive permutation stages carried as stacked arrays.

    Semantically identical to emitting ``len(sizes)`` PermutationStages in
    order -- same pipelining, same slot rules -- but the incremental
    trajectory engine (birkhoff.DecompositionState) re-emits ~n^2 stages
    per drift step, and materializing that many per-stage objects costs
    more than the decomposition delta itself.  ``perms`` is (S, n) with -1
    for idle senders, ``sizes`` (S,), ``sent`` (S, n) genuine payload
    bytes, and ``slots`` either None (capacity-blind: uniform ``size``-byte
    slots) or (S, n) per-sender slot bytes (capacity-aware).
    """

    kind: ClassVar[str] = "permutation_block"
    perms: np.ndarray
    sizes: np.ndarray
    sent: np.ndarray
    slots: Optional[np.ndarray] = None

    @property
    def n_stages(self) -> int:
        return int(self.sizes.shape[0])

    def payload(self, cluster):
        return float(self.sent.sum()), 0.0

    @property
    def real_bytes(self) -> float:
        return float(self.sent.sum())

    def slot2d(self) -> np.ndarray:
        """(S, n) per-sender slot bytes; blind rows broadcast the size."""
        if self.slots is not None:
            return np.asarray(self.slots, dtype=np.float64)
        return np.broadcast_to(
            np.asarray(self.sizes, dtype=np.float64)[:, None],
            self.perms.shape)

    def live_batch(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Memoized ``live_slots_batch``: ``(mask, dst, slot)`` over all S
        stages -- the compiled executor's and validator's shared view."""
        cached = self.__dict__.get("_live_batch")
        if cached is None:
            cached = live_slots_batch(self.perms, self.slot2d())
            for a in cached:
                a.flags.writeable = False
            object.__setattr__(self, "_live_batch", cached)
        return cached

    def stage_view(self, k: int) -> PermutationStage:
        """Stage ``k`` as an equivalent PermutationStage (interop paths:
        the interpreted executor, FlashPlan export, the pipeline tail)."""
        return PermutationStage(
            perm=tuple(int(j) for j in self.perms[k]),
            size=float(self.sizes[k]),
            sent=tuple(float(x) for x in self.sent[k]),
            slots=None if self.slots is None
            else tuple(float(x) for x in self.slots[k]))

    def iter_stages(self):
        return (self.stage_view(k) for k in range(self.n_stages))

    def to_dict(self):
        d = {"kind": self.kind,
             "perms": [[int(j) for j in row] for row in self.perms],
             "sizes": _listify(self.sizes),
             "sent": [_listify(row) for row in self.sent]}
        if self.slots is not None:
            d["slots"] = [_listify(row) for row in self.slots]
        return d

    @classmethod
    def from_dict(cls, d):
        slots = d.get("slots")
        return cls(perms=np.asarray(d["perms"], dtype=np.int64),
                   sizes=_np2d(d["sizes"]),
                   sent=_np2d(d["sent"]),
                   slots=None if slots is None else _np2d(slots))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class BarrierStage(PhaseBase):
    """Barrier-synchronized flow set: GPU g sends ``sizes[g]`` bytes to GPU
    ``dsts[g]``; the stage completes when the slowest flow does."""

    kind: ClassVar[str] = "barrier"
    sizes: np.ndarray  # (n_gpus,)
    dsts: np.ndarray   # (n_gpus,) destination GPU index per source GPU

    def _same_server(self, cluster: ClusterSpec) -> np.ndarray:
        m = cluster.m_gpus
        src = np.arange(len(self.sizes))
        return (src // m) == (self.dsts.astype(np.int64) // m)

    def payload(self, cluster):
        same = self._same_server(cluster)
        return (float(self.sizes[~same].sum()),
                float(self.sizes[same].sum()))

    def to_dict(self):
        return {"kind": self.kind, "sizes": _listify(self.sizes),
                "dsts": [int(j) for j in self.dsts]}

    @classmethod
    def from_dict(cls, d):
        return cls(sizes=_np2d(d["sizes"]),
                   dsts=np.asarray(d["dsts"], dtype=np.int64))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class FanOutBurst(PhaseBase):
    """All flows of a GPU-level matrix launched at once: receiver NICs
    fair-share and collapse under incast; intra-server traffic rides the
    fast fabric concurrently."""

    kind: ClassVar[str] = "fanout_burst"
    matrix: np.ndarray  # (n_gpus, n_gpus)

    def payload(self, cluster):
        n, m = cluster.n_servers, cluster.m_gpus
        blk = self.matrix.reshape(n, m, n, m)
        intra = float(sum(blk[a, :, a, :].sum() for a in range(n)))
        return float(self.matrix.sum()) - intra, intra

    def to_dict(self):
        return {"kind": self.kind, "matrix": _listify(self.matrix)}

    @classmethod
    def from_dict(cls, d):
        return cls(matrix=_np2d(d["matrix"]))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class RailStage(PhaseBase):
    """Rail-aligned inter-server phase: NIC i of server a carries
    ``send[a, i]`` outbound / ``recv[a, i]`` inbound bytes, progressing in
    ``n_rounds`` rotation rounds (one wakeup each).  The max-loaded rail is
    the straggler."""

    kind: ClassVar[str] = "rail"
    send: np.ndarray  # (n_servers, m_gpus)
    recv: np.ndarray  # (n_servers, m_gpus)
    n_rounds: int

    def payload(self, cluster):
        return float(self.send.sum()), 0.0

    def to_dict(self):
        return {"kind": self.kind, "send": _listify(self.send),
                "recv": _listify(self.recv), "n_rounds": int(self.n_rounds)}

    @classmethod
    def from_dict(cls, d):
        return cls(send=_np2d(d["send"]), recv=_np2d(d["recv"]),
                   n_rounds=int(d["n_rounds"]))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class BoundStage(PhaseBase):
    """Analytic Theorem-1 phase: ``bound_bytes`` (the max line sum of the
    server matrix) crossing the aggregate per-server NIC bandwidth.
    ``inter_total`` records the genuine inter-server bytes represented."""

    kind: ClassVar[str] = "bound"
    bound_bytes: float
    inter_total: float
    # Per-server max(row, col) line sums; lets the link-level executor bound
    # each server against its own aggregate NIC capacity (heterogeneous
    # fabrics).  None = legacy scalar form.
    line_sums: Optional[Tuple[float, ...]] = None

    def payload(self, cluster):
        return float(self.inter_total), 0.0

    def to_dict(self):
        d = {"kind": self.kind, "bound_bytes": float(self.bound_bytes),
             "inter_total": float(self.inter_total)}
        if self.line_sums is not None:
            d["line_sums"] = [float(x) for x in self.line_sums]
        return d

    @classmethod
    def from_dict(cls, d):
        ls = d.get("line_sums")
        return cls(bound_bytes=float(d["bound_bytes"]),
                   inter_total=float(d["inter_total"]),
                   line_sums=None if ls is None else
                   tuple(float(x) for x in ls))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class RedistributePhase(PhaseBase):
    """Pipeline-tail intra phase: ``bytes_per_gpu`` bytes per GPU moved over
    the intra fabric after the last inter stage (auxiliary movement)."""

    kind: ClassVar[str] = "redistribute"
    bytes_per_gpu: float
    charge_alpha: bool = True

    def to_dict(self):
        return {"kind": self.kind, "bytes_per_gpu": float(self.bytes_per_gpu),
                "charge_alpha": bool(self.charge_alpha)}

    @classmethod
    def from_dict(cls, d):
        return cls(bytes_per_gpu=float(d["bytes_per_gpu"]),
                   charge_alpha=bool(d["charge_alpha"]))


@register_phase
@dataclasses.dataclass(frozen=True, eq=False)
class IntraOverlapPhase(PhaseBase):
    """Per-server local traffic S_i spread over the server's intra fabric,
    overlapped with the inter phase: only the residual beyond the inter
    phase's duration is charged."""

    kind: ClassVar[str] = "intra_overlap"
    per_server: np.ndarray  # (n_servers,) S_i bytes

    def payload(self, cluster):
        return 0.0, float(self.per_server.sum())

    def to_dict(self):
        return {"kind": self.kind, "per_server": _listify(self.per_server)}

    @classmethod
    def from_dict(cls, d):
        return cls(per_server=_np2d(d["per_server"]))


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """A synthesized All-to-All schedule, decoupled from any timing model.

    Attributes:
      algorithm: registry name of the scheduler that produced this plan.
      cluster: the two-tier cluster the plan targets (scalar shape view).
      phases: ordered typed phases (see module docstring).
      synth_seconds: wall-clock schedule-synthesis time (paper Fig 17a).
      extra_memory_bytes: staging buffers beyond the universal 2x send/recv
        footprint (FLASH's load-balance + redistribute staging, Fig 17b).
      accounts_intra: whether this plan explicitly schedules the workload's
        intra-server bytes (validate() only checks intra conservation then).
      fingerprint: traffic-matrix fingerprint of the source workload
        (includes the topology fingerprint).
      topology: the link-level fabric this plan was synthesized for; None
        means "the homogeneous fabric derived from ``cluster``" (``topo``
        resolves it).  Executing a plan on a *different* fabric than it was
        synthesized for is a deliberate topology-blindness experiment --
        pass the override to ``execute_plan``.
      nic_shares: optional (n_servers, n_servers, m_gpus) per-rail fraction
        of each (src, dst) server pair's slot bytes, fixed at synthesis
        time (FLASH's capacity-proportional rebalance target; rail g of a
        pair is capped by the slower endpoint NIC).  None = uniform 1/m.
      capacity_aware: provenance flag -- the permutation stages were
        synthesized against the topology's pair capacities (per-sender
        ``slots`` sized to drain in a common window).  ``validate()`` then
        additionally checks slot-vs-rail feasibility: no rail of any live
        pair may need longer than the stage's window to drain its share.
    """

    algorithm: str
    cluster: ClusterSpec
    phases: Tuple[PhaseBase, ...]
    synth_seconds: float = 0.0
    extra_memory_bytes: float = 0.0
    accounts_intra: bool = True
    fingerprint: Optional[str] = None
    topology: Optional[Topology] = None
    nic_shares: Optional[np.ndarray] = None
    capacity_aware: bool = False

    @property
    def topo(self) -> Topology:
        """The fabric the plan was synthesized for (derived when None).

        Memoized like ``Workload.topo``: validation, execution and cache
        keying all consult it, and the derived instance carries the
        memoized ``fingerprint()``."""
        if self.topology is not None:
            return self.topology
        derived = self.__dict__.get("_derived_topo")
        if derived is None:
            derived = Topology.from_cluster(self.cluster)
            object.__setattr__(self, "_derived_topo", derived)
        return derived

    def compile(self, topology: Optional[Topology] = None):
        """Compile this plan for repeated execution: an ExecutableSchedule.

        The compiler (``simulator.compile_plan``) flattens every phase
        into padded array form and times the whole plan in one vectorized
        pass; the result answers ``execute(w)`` / ``execute_batch(stack)``
        with no per-stage Python at all.  Compiled schedules are memoized
        on the plan per *execution-topology* fingerprint -- the compiled
        cache slot that rides along with the Plan inside a ``PlanCache``,
        so a cache hit skips synthesis *and* compilation, and a topology
        change (new fingerprint) transparently recompiles instead of
        serving stale link capacities.
        """
        from .simulator import compile_plan

        topo = topology if topology is not None else self.topo
        memo = self.__dict__.get("_compiled")
        if memo is None:
            memo = {}
            object.__setattr__(self, "_compiled", memo)
        key = topo.fingerprint()
        sched = memo.get(key)
        if sched is None:
            sched = compile_plan(self, topology=topo)
            if len(memo) >= 8:  # serving loops see 1-2 fabrics per plan
                memo.clear()
            memo[key] = sched
        return sched

    def iter_perm_stages(self):
        """Every inter-server permutation in execution order, as tuples.

        The device-lowering view consumed by ``comm.plan_exec.lower_plan``:
        ``perm[i]`` is server ``i``'s send target this stage (-1 = idle).
        Only PermutationStage / PermutationBlock phases carry an explicit
        static permutation; other stage kinds (FanOutBurst, RailStage,
        BoundStage) yield nothing here and are covered by the lowering's
        fallback rotations instead.
        """
        for p in self.phases:
            if isinstance(p, PermutationStage):
                yield tuple(int(j) for j in p.perm)
            elif isinstance(p, PermutationBlock):
                for row in p.perms:
                    yield tuple(int(j) for j in row)

    @property
    def stages(self) -> Tuple[PhaseBase, ...]:
        """The inter-server stage phases, in execution order."""
        return tuple(p for p in self.phases if isinstance(
            p, (PermutationStage, PermutationBlock, BarrierStage,
                FanOutBurst, RailStage, BoundStage)))

    @property
    def n_stages(self) -> int:
        total = 0
        for p in self.stages:
            if isinstance(p, RailStage):
                total += p.n_rounds
            elif isinstance(p, PermutationBlock):
                total += p.n_stages
            else:
                total += 1
        return total

    @property
    def inter_bytes(self) -> float:
        """Genuine payload bytes crossing the inter-server network."""
        return float(sum(p.payload(self.cluster)[0] for p in self.phases))

    # -- serialization --------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "algorithm": self.algorithm,
            "cluster": dataclasses.asdict(self.cluster),
            "phases": [p.to_dict() for p in self.phases],
            "synth_seconds": float(self.synth_seconds),
            "extra_memory_bytes": float(self.extra_memory_bytes),
            "accounts_intra": bool(self.accounts_intra),
            "fingerprint": self.fingerprint,
            "topology": None if self.topology is None
            else self.topology.to_dict(),
            "nic_shares": None if self.nic_shares is None
            else _listify(self.nic_shares),
            "capacity_aware": bool(self.capacity_aware),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Plan":
        phases = []
        for pd in d["phases"]:
            try:
                phase_cls = PHASE_KINDS[pd["kind"]]
            except KeyError:
                raise PlanValidationError(
                    f"unknown phase kind {pd['kind']!r}; known: "
                    f"{sorted(PHASE_KINDS)}")
            phases.append(phase_cls.from_dict(pd))
        return cls(
            algorithm=d["algorithm"],
            cluster=ClusterSpec(**d["cluster"]),
            phases=tuple(phases),
            synth_seconds=float(d["synth_seconds"]),
            extra_memory_bytes=float(d["extra_memory_bytes"]),
            accounts_intra=bool(d["accounts_intra"]),
            fingerprint=d.get("fingerprint"),
            topology=Topology.from_dict(d.get("topology")),
            nic_shares=None if d.get("nic_shares") is None
            else _np2d(d["nic_shares"]),
            capacity_aware=bool(d.get("capacity_aware", False)),
        )

    # -- validation -----------------------------------------------------

    def validate(self, w: Workload, rtol: float = 1e-6) -> None:
        """Check structure and byte conservation against the workload.

        Raises PlanValidationError if the plan's inter-server stages do not
        collectively carry exactly the workload's inter-server bytes (and,
        when ``accounts_intra``, its intra-server bytes too), or if any
        permutation stage has incast (two senders per receiver) or
        self-traffic.
        """
        if w.cluster != self.cluster:
            raise PlanValidationError(
                f"plan targets {self.cluster}, workload runs on {w.cluster}")
        if self.topo.fingerprint() != w.topo.fingerprint():
            raise PlanValidationError(
                "plan was synthesized for a different topology than the "
                "workload's fabric (stale plan?); re-synthesize or pass an "
                "explicit execution-topology override to execute_plan")
        self.validate_structure(rtol)

        t_server, s_intra = server_reduce(w.matrix, self.cluster.m_gpus)
        inter_expected = float(t_server.sum())
        intra_expected = float(s_intra.sum())
        inter_carried = 0.0
        intra_carried = 0.0
        for p in self.phases:
            i, s = p.payload(self.cluster)
            inter_carried += i
            intra_carried += s

        scale = max(inter_expected, intra_expected, 1.0)
        if abs(inter_carried - inter_expected) > rtol * scale:
            raise PlanValidationError(
                f"inter-server bytes not conserved: plan carries "
                f"{inter_carried:.6g}, workload has {inter_expected:.6g}")
        if self.accounts_intra and \
                abs(intra_carried - intra_expected) > rtol * scale:
            raise PlanValidationError(
                f"intra-server bytes not conserved: plan carries "
                f"{intra_carried:.6g}, workload has {intra_expected:.6g}")

    def validate_structure(self, rtol: float = 1e-6) -> None:
        """Workload-independent structural checks.

        Everything ``validate`` can prove without the source traffic
        matrix: permutation stages are incast- and self-traffic-free,
        payloads fit their slots, blocks are shape-consistent, and (for
        capacity-aware plans) every stage is slot-vs-rail feasible on the
        plan's own fabric.  The static plan verifier (analysis/planlint.py)
        audits serialized plans and live cache contents through this entry
        point, where no workload is available.
        """
        for p in self.phases:
            if isinstance(p, PermutationStage):
                live = [j for j in p.perm if j >= 0]
                if len(live) != len(set(live)):
                    raise PlanValidationError(
                        f"permutation stage has incast: {p.perm}")
                if any(i == j for i, j in enumerate(p.perm)):
                    raise PlanValidationError(
                        f"permutation stage has self-traffic: {p.perm}")
                if p.size < 0 or any(s < 0 or s > p.size * (1 + rtol)
                                     for s in p.sent):
                    raise PlanValidationError(
                        "permutation stage payload exceeds slot size")
                if p.slots is not None:
                    if len(p.slots) != len(p.perm):
                        raise PlanValidationError(
                            f"permutation stage has {len(p.perm)} senders "
                            f"but {len(p.slots)} slot sizes")
                    if any(sl < 0 or sl > p.size * (1 + rtol)
                           for sl in p.slots):
                        raise PlanValidationError(
                            "per-sender slot exceeds the stage size")
                    if any(s > sl * (1 + rtol)
                           for s, sl in zip(p.sent, p.slots)):
                        raise PlanValidationError(
                            "permutation stage payload exceeds its "
                            "per-sender slot")
            elif isinstance(p, PermutationBlock):
                self._validate_block(p, rtol)
        if self.capacity_aware:
            self._check_slot_rail_feasibility(rtol)

    def _validate_block(self, p: "PermutationBlock", rtol: float) -> None:
        """PermutationStage structural checks, vectorized over a block."""
        perms = np.asarray(p.perms, dtype=np.int64)
        sent = np.asarray(p.sent, dtype=np.float64)
        sizes = np.asarray(p.sizes, dtype=np.float64)
        s_count, n = perms.shape
        if sent.shape != (s_count, n) or sizes.shape != (s_count,):
            raise PlanValidationError(
                f"permutation block arrays disagree: perms {perms.shape}, "
                f"sent {sent.shape}, sizes {sizes.shape}")
        live = perms >= 0
        if s_count:
            dst = np.where(live, perms, 0)
            if int(perms.max(initial=-1)) >= n or \
                    int(perms.min(initial=0)) < -1:
                raise PlanValidationError(
                    "permutation block destination out of range")
            recv = np.zeros((s_count, n))
            np.add.at(recv, (np.arange(s_count)[:, None], dst),
                      live.astype(np.float64))
            if recv.max(initial=0.0) > 1:
                k = int(np.argwhere(recv > 1)[0][0])
                raise PlanValidationError(
                    f"permutation stage has incast: "
                    f"{tuple(perms[k].tolist())}")
            if bool((live & (perms == np.arange(n)[None, :])).any()):
                raise PlanValidationError(
                    "permutation block stage has self-traffic")
        if (sizes < 0).any() or (sent < 0).any() or \
                (sent > sizes[:, None] * (1 + rtol)).any():
            raise PlanValidationError(
                "permutation stage payload exceeds slot size")
        if p.slots is not None:
            slots = np.asarray(p.slots, dtype=np.float64)
            if slots.shape != (s_count, n):
                raise PlanValidationError(
                    f"permutation block has {s_count}x{n} senders but "
                    f"{slots.shape} slot sizes")
            if (slots < 0).any() or \
                    (slots > sizes[:, None] * (1 + rtol)).any():
                raise PlanValidationError(
                    "per-sender slot exceeds the stage size")
            if (sent > slots * (1 + rtol)).any():
                raise PlanValidationError(
                    "permutation stage payload exceeds its per-sender slot")

    def _check_slot_rail_feasibility(self, rtol: float) -> None:
        """Capacity-aware invariant: within each permutation stage, no rail
        of any live pair needs longer than the stage's window (the slowest
        pair's slot over its pair capacity) to drain its share of the slot.
        Capacity-proportional slots + shares satisfy this with equality;
        uniform shares grafted onto heterogeneous slots (or slots from a
        different fabric than ``topology``) fail it loudly.

        Pairs with zero pair capacity are excluded from both the window and
        the rail check: a fully-failed pair makes the stage take forever
        regardless of shares (the executor reports infinity), and letting
        its infinite window vouch for the *healthy* pairs would make the
        check vacuous exactly when the fabric is most degraded.
        """
        from .topology import bw_div

        topo = self.topo
        caps = topo.pair_capacity()
        m = topo.m_gpus
        shares = (self.nic_shares if self.nic_shares is not None
                  else uniform_nic_shares(topo.n_servers, m))
        for k, p in enumerate(self.phases):
            if isinstance(p, PermutationBlock):
                self._check_block_rails(p, k, caps, shares, topo, rtol)
                continue
            if not isinstance(p, PermutationStage):
                continue
            src, dst, slot = p.live()
            finite = caps[src, dst] > 0
            src, dst, slot = src[finite], dst[finite], slot[finite]
            if src.size == 0:
                continue
            window = float(bw_div(slot, caps[src, dst]).max(initial=0.0))
            rail_caps = np.minimum(topo.nic_tx[src], topo.nic_rx[dst])
            rail_t = bw_div(slot[:, None] * shares[src, dst], rail_caps)
            worst = float(rail_t.max(initial=0.0))
            if worst > window * (1 + rtol):
                raise PlanValidationError(
                    f"stage {k} is slot-vs-rail infeasible: a rail needs "
                    f"{worst:.6g}s to drain its share but the stage window "
                    f"is {window:.6g}s (shares inconsistent with the "
                    "fabric's pair capacities?)")

    def _check_block_rails(self, p: "PermutationBlock", k: int,
                           caps: np.ndarray, shares: np.ndarray,
                           topo: Topology, rtol: float) -> None:
        """Slot-vs-rail feasibility over a whole block in one pass: the
        same per-stage invariant as the PermutationStage branch, with the
        per-stage window and worst-rail reductions batched over S stages."""
        from .topology import bw_div

        s_count, n = p.perms.shape
        if s_count == 0:
            return
        mask, dst, slot = p.live_batch()
        stage_i, src = np.nonzero(mask)
        d = dst[stage_i, src]
        sl = slot[stage_i, src]
        finite = caps[src, d] > 0
        stage_i, src, d, sl = (stage_i[finite], src[finite], d[finite],
                               sl[finite])
        if src.size == 0:
            return
        windows = np.zeros(s_count)
        np.maximum.at(windows, stage_i, bw_div(sl, caps[src, d]))
        rail_caps = np.minimum(topo.nic_tx[src], topo.nic_rx[d])
        rail_t = bw_div(sl[:, None] * shares[src, d], rail_caps).max(axis=1)
        worst = np.zeros(s_count)
        np.maximum.at(worst, stage_i, rail_t)
        bad = worst > windows * (1 + rtol)
        if bad.any():
            b = int(np.flatnonzero(bad)[0])
            raise PlanValidationError(
                f"stage {k}[{b}] is slot-vs-rail infeasible: a rail needs "
                f"{worst[b]:.6g}s to drain its share but the stage window "
                f"is {windows[b]:.6g}s (shares inconsistent with the "
                "fabric's pair capacities?)")


# -- synthesis caching ----------------------------------------------------

def _family_key(cluster: ClusterSpec, topo_fingerprint: str,
                algorithm: str) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(repr(dataclasses.astuple(cluster)).encode())
    h.update(topo_fingerprint.encode())
    h.update(algorithm.encode())
    return h.hexdigest()


def cluster_family_key(w: Workload, algorithm: str = "") -> str:
    """Fingerprint of (cluster, topology, algorithm) *without* the traffic
    matrix: every workload of a job on a fixed fabric shares it.

    PlanCache's warm-start path uses it to find "the most recent plan for
    this cluster and algorithm" when the exact traffic fingerprint misses --
    dynamic MoE traffic rarely repeats exactly, but consecutive iterations
    are near-misses that can seed a repair instead of a cold synthesis.
    The ClusterSpec scalars are hashed alongside the topology fingerprint
    because repair requires the previous plan's cluster to match exactly
    (e.g. two specs can share a fabric but differ in alpha).
    """
    return _family_key(w.cluster, w.topo.fingerprint(), algorithm)


def plan_family_key(plan: Plan) -> str:
    """The family key a synthesized Plan belongs to.

    Agrees with ``cluster_family_key(w, plan.algorithm)`` for the workload
    the plan was synthesized from, which lets ``PlanCache.insert`` maintain
    the family index from the plan alone (and prune it on eviction).
    """
    return _family_key(plan.cluster, plan.topo.fingerprint(), plan.algorithm)


def traffic_fingerprint(w: Workload, algorithm: str = "") -> str:
    """Stable fingerprint of (traffic matrix, topology, algorithm).

    Dynamic MoE traffic changes every iteration but frequently repeats
    signatures (hot expert sets recur across steps); an exact content hash
    is what lets PlanCache skip re-synthesis on repeats while never serving
    a stale plan for different traffic.  The topology fingerprint (which
    covers the cluster shape, every per-server fabric, every NIC capacity
    and the oversubscription factor) is part of the key, so the same matrix
    replayed on a different fabric always misses.

    Memoized per (Workload instance, algorithm): Workload is frozen and
    its matrix is treated as immutable after construction (same contract
    as the memoized ``Workload.topo``), and the content hash is the
    dominant cost of a cache hit on the serving fast path -- replaying a
    trajectory of Workload objects must not re-hash every matrix on every
    visit.
    """
    memo = w.__dict__.get("_traffic_fp")
    if memo is not None:
        fp = memo.get(algorithm)
        if fp is not None:
            return fp
    h = hashlib.blake2b(digest_size=16)
    mat = np.ascontiguousarray(w.matrix, dtype=np.float64)
    h.update(str(mat.shape).encode())
    h.update(mat.tobytes())
    h.update(w.topo.fingerprint().encode())
    h.update(algorithm.encode())
    fp = h.hexdigest()
    if memo is None:
        memo = {}
        object.__setattr__(w, "_traffic_fp", memo)
    memo[algorithm] = fp
    return fp


class PlanCache:
    """LRU cache of synthesized Plans keyed by traffic fingerprint.

    The paper's synthesis is already microseconds-cheap, but at MoE serving
    rates (thousands of iterations/second across layers) even that adds up
    -- and expert-routing signatures repeat across iterations.  ``lookup``
    /``get_or_synthesize`` skip re-synthesis on a repeated fingerprint and
    expose hit/miss counters for the reuse-rate telemetry.

    With ``warm_start=True``, an exact-fingerprint miss falls back to the
    most recent cached plan for the same (cluster, topology, algorithm)
    family: schedulers exposing ``repair_plan`` (FLASH) then seed the new
    plan with the cached plan's permutations and synthesize only the
    traffic delta, so a small MoE routing shift costs a repair instead of a
    cold synthesis.  Warm repairs still count as misses (a fresh plan is
    produced) and are tallied separately in ``warm_hits``.  Off by default:
    a repaired plan is byte-conserving and incast-free but generally a
    slightly longer stage list than cold synthesis, so reuse-vs-quality is
    an explicit opt-in.

    Compiled execution rides along for free: ``Plan.compile`` memoizes its
    ``ExecutableSchedule`` *on the plan object*, keyed by the execution
    topology's fingerprint, so a cache hit hands back a plan whose
    compiled schedule is already attached -- the serving loop skips
    synthesis and compilation and pays only the O(1) compiled execute.

    The cache is safe under concurrent access (the plan-serving daemon in
    ``repro.serving`` shares one instance across worker and client
    threads): one lock guards the LRU store, the family index and the
    counters, ``stats()`` returns an atomic snapshot of the counters (the
    bare attributes remain readable for back-compat but can tear across a
    multi-field read), and ``get_or_synthesize`` never holds the lock
    during synthesis -- two threads racing the same fingerprint may both
    synthesize, but the insert re-check keeps one canonical Plan per key
    so every caller gets the same object.
    """

    def __init__(self, capacity: int = 256, warm_start: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.warm_start = warm_start
        self._lock = make_rlock("PlanCache._lock")
        self._store: "OrderedDict[str, Plan]" = OrderedDict()
        self._family: Dict[str, str] = {}  # family key -> latest exact key
        self._key_family: Dict[str, str] = {}  # exact key -> its family
        self._family_count: Dict[str, int] = {}  # family -> live cached keys
        self.hits = 0
        self.misses = 0
        self.warm_hits = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    @property
    def hit_rate(self) -> float:
        with self._lock:
            total = self.hits + self.misses
            return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, float]:
        """Atomic snapshot of the counters.

        Reading ``hits`` / ``misses`` / ``hit_rate`` as separate attribute
        accesses can tear mid-update under concurrent serving (a lookup
        between the two reads skews the ratio); this returns all of them
        from one critical section."""
        with self._lock:
            total = self.hits + self.misses
            return {
                "hits": self.hits,
                "misses": self.misses,
                "warm_hits": self.warm_hits,
                "size": len(self._store),
                "capacity": self.capacity,
                "hit_rate": self.hits / total if total else 0.0,
            }

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self._family.clear()
            self._key_family.clear()
            self._family_count.clear()
            self.hits = 0
            self.misses = 0
            self.warm_hits = 0

    def lookup(self, key: str) -> Optional[Plan]:
        with self._lock:
            plan = self._store.get(key)
            if plan is not None:
                self._store.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
            return plan

    def peek(self, key: str) -> Optional[Plan]:
        """Counter-free, order-preserving lookup.

        The serving daemon's workers re-check the store after a client's
        fast-path miss already counted; a second ``lookup`` would double
        count and perturb the LRU order for what is one logical request.
        """
        with self._lock:
            return self._store.get(key)

    def peek_family(self, family: str) -> Optional[Plan]:
        """The most recent cached plan of a (cluster, topology, algorithm)
        family (see ``cluster_family_key``), without touching counters --
        the warm-repair seed for the serving daemon's near-miss path."""
        with self._lock:
            key = self._family.get(family)
            return self._store.get(key) if key is not None else None

    def family_heads(self) -> List[Tuple[str, Plan]]:
        """Snapshot of every family's canonical (MRU) plan: ``(family
        key, plan)`` pairs.  The fabric-event pipeline walks this to find
        the plan families a topology change affects (those whose plan
        carries the pre-event fabric fingerprint) and re-repair each one
        against the new capacities instead of letting it go cold."""
        with self._lock:
            return [(family, self._store[key])
                    for family, key in self._family.items()
                    if key in self._store]

    def evict(self, key: str) -> bool:
        """Drop one entry (and its family-index membership) by exact key.

        Returns whether the key was present.  TTL/staleness policies
        layered on top of the LRU (serving/policy.py) use this to expire
        entries the LRU order alone would keep alive."""
        with self._lock:
            plan = self._store.pop(key, None)
            if plan is None:
                return False
            self._drop_family_member_locked(key, self._key_family.pop(key))
            return True

    def insert(self, key: str, plan: Plan) -> None:
        with self._lock:
            self._insert_locked(key, plan)

    def _insert_locked(self, key: str, plan: Plan) -> None:
        family = plan_family_key(plan)
        old_family = self._key_family.get(key)
        if old_family is not None and old_family != family:
            # Overwrite with a different-family plan (hand-inserted key).
            del self._key_family[key]
            self._drop_family_member_locked(key, old_family)
        self._store[key] = plan
        self._store.move_to_end(key)
        if key not in self._key_family:
            self._key_family[key] = family
            self._family_count[family] = \
                self._family_count.get(family, 0) + 1
        self._family[family] = key
        while len(self._store) > self.capacity:
            evicted, _ = self._store.popitem(last=False)
            self._drop_family_member_locked(evicted, self._key_family.pop(evicted))

    def _drop_family_member_locked(self, key: str, family: str) -> None:
        """Keep the family index in lockstep with the LRU store: without
        this, long-running serving grows ``_family`` without bound and a
        stale family -> evicted-key pointer silently turns every warm start
        cold.  The membership count makes the common case -- one cached
        plan per fabric, family dies with its key -- O(1); only a family
        with surviving members pays a scan to repoint at the most recently
        used survivor."""
        remaining = self._family_count[family] - 1
        if remaining:
            self._family_count[family] = remaining
        else:
            del self._family_count[family]
        if self._family.get(family) != key:
            return
        if not remaining:
            del self._family[family]
            return
        for other in reversed(self._store):
            if self._key_family.get(other) == family:
                self._family[family] = other
                return
        del self._family[family]  # unreachable while counts are coherent

    def get_or_synthesize(self, scheduler, w: Workload) -> Plan:
        """Return the cached Plan for (w, scheduler) or synthesize + cache.

        On an exact miss with ``warm_start`` enabled, a same-family cached
        plan seeds ``scheduler.repair_plan`` instead of a cold synthesis.

        Thread-safe, and synthesis runs *outside* the lock: concurrent
        misses on the same fingerprint may each synthesize, but the insert
        re-check below keeps the first inserted Plan canonical -- later
        racers return it instead of overwriting, so repeated lookups of
        one fingerprint always yield one object (and its memoized
        compiled schedule).
        """
        key = traffic_fingerprint(w, scheduler.name)
        with self._lock:
            plan = self._store.get(key)
            if plan is not None:
                self._store.move_to_end(key)
                self.hits += 1
                return plan
            self.misses += 1
            prev = None
            if self.warm_start and hasattr(scheduler, "try_repair_plan"):
                prev = self._store.get(
                    self._family.get(cluster_family_key(w, scheduler.name),
                                     ""))
                # The family key pins (cluster, topology, algorithm), but a
                # stale or hand-inserted entry must degrade to cold, never
                # propagate a repair error out of a cache lookup.
                if prev is not None and (prev.cluster != w.cluster or
                                         prev.topo.fingerprint()
                                         != w.topo.fingerprint()):
                    prev = None
        plan = None
        if prev is not None:
            plan = scheduler.try_repair_plan(prev, w, fingerprint=key)
        warm = plan is not None
        if plan is None:
            plan = scheduler.synthesize(w, fingerprint=key)
        with self._lock:
            existing = self._store.get(key)
            if existing is not None:  # lost the race: keep the canonical plan
                self._store.move_to_end(key)
                return existing
            if warm:
                self.warm_hits += 1
            self._insert_locked(key, plan)  # repoints _family[family] to key
        return plan
