"""All-to-All schedulers: FLASH and the paper's baselines, as Plan synthesis.

Every scheduler is a ``Scheduler`` subclass behind the ``register_scheduler``
registry.  ``Scheduler.synthesize`` consumes a GPU-level ``Workload`` and
produces a scheduler-agnostic ``Plan`` (core/plan.py) that the single
generic alpha-beta executor (simulator.py) times -- adding an algorithm
means adding one class here, never forking the simulator.

  * flash        -- the paper's contribution: intra load balance, then the
                    ascending Birkhoff stage list of the server-level
                    matrix (PermutationStages), redistribute tail hidden
                    under the pipeline.
  * fanout       -- RCCL default: every GPU transmits to all peers at once
                    (one FanOutBurst; incast is the burst's property).
  * spreadout    -- MPI: N-1 barrier-synchronized stages, stage k pairs
                    g -> (g + k) mod N (BarrierStages; stragglers are the
                    barrier's property).
  * hierarchical -- MSCCL-style rail-aligned: GPU i of each server
                    aggregates local traffic for rail-i peers, then ships
                    it over NIC i (gather head + RailStage + scatter tail).
  * optimal      -- Theorem 1 bound (BoundStage; the 'optimal' line in
                    every figure).

``flash_schedule`` survives as a numeric-parity shim returning the legacy
``FlashPlan`` view of the synthesized Plan.
"""

from __future__ import annotations

import abc
import dataclasses
import time
from typing import ClassVar, Dict, List, Optional, Tuple, Type

import numpy as np

from ..analysis.locks import check_forbidden
from .birkhoff import (
    AUTO_EXACT_MAX_N,
    DecompositionState,
    Stage,
    birkhoff_decompose,
    effective_pair_caps,
    max_line_sum,
    stage_duration,
)
from .plan import (
    BarrierStage,
    BoundStage,
    FanOutBurst,
    IntraOverlapPhase,
    LoadBalancePhase,
    PermutationBlock,
    PermutationStage,
    Plan,
    RailStage,
    RedistributePhase,
    traffic_fingerprint,
)
from .topology import uniform_nic_shares
from .traffic import ClusterSpec, Workload

__all__ = [
    "Scheduler",
    "register_scheduler",
    "get_scheduler",
    "available_schedulers",
    "SCHEDULERS",
    "RepairConfig",
    "FlashScheduler",
    "CapacityAwareFlashScheduler",
    "FanOutScheduler",
    "SpreadOutScheduler",
    "HierarchicalScheduler",
    "OptimalScheduler",
    "FlashPlan",
    "flash_schedule",
    "spreadout_stages",
    "hierarchical_nic_loads",
    "optimal_completion_time",
    "synthesis_time",
]


# -- registry --------------------------------------------------------------

SCHEDULERS: Dict[str, Type["Scheduler"]] = {}


def register_scheduler(cls: Type["Scheduler"]) -> Type["Scheduler"]:
    """Class decorator: registers ``cls`` under ``cls.name``."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls.__name__} must define a class-level `name`")
    SCHEDULERS[cls.name] = cls
    return cls


def get_scheduler(name: str) -> "Scheduler":
    try:
        return SCHEDULERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; pick from {sorted(SCHEDULERS)}")


def available_schedulers() -> List[str]:
    return sorted(SCHEDULERS)


class Scheduler(abc.ABC):
    """Base class: synthesize a Plan from a Workload.

    Subclasses implement ``plan_phases`` returning (phases,
    extra_memory_bytes); the base wraps them into a Plan with synthesis
    wall-time (the paper's 'scheduling time' metric, Fig 17a) and the
    traffic fingerprint used by PlanCache.
    """

    name: ClassVar[str] = ""
    accounts_intra: ClassVar[bool] = True

    @abc.abstractmethod
    def plan_phases(self, w: Workload) -> Tuple[tuple, float]:
        """Return (phases, extra_memory_bytes) or (phases,
        extra_memory_bytes, nic_shares) for topology-aware schedulers."""
        ...

    def synthesize(self, w: Workload,
                   fingerprint: Optional[str] = None) -> Plan:
        check_forbidden("synthesize")
        t0 = time.perf_counter()
        out = self.plan_phases(w)
        synth = time.perf_counter() - t0
        return self._build_plan(w, out, synth, fingerprint)

    def synthesize_bounded(self, w: Workload, budget_seconds:
                           Optional[float] = None,
                           fingerprint: Optional[str] = None
                           ) -> Tuple[Plan, bool]:
        """Synthesize under a soft wall-clock budget: ``(plan, exact)``.

        The serving daemon's cold path must answer *now*, not after the
        best possible synthesis -- so a scheduler may trade plan quality
        for latency when its predicted synthesis cost exceeds the budget,
        returning ``exact=False`` to signal that a background upgrade to
        the unbounded plan is worthwhile.  The base implementation has no
        degraded mode (every baseline synthesizes in O(n) -- the budget
        cannot bind), so it always returns the exact plan; FLASH overrides
        this with the fast repair-engine decomposition.
        """
        del budget_seconds  # no degraded mode: the exact plan is the answer
        return self.synthesize(w, fingerprint=fingerprint), True

    def _build_plan(self, w: Workload, out, synth: float,
                    fingerprint: Optional[str]) -> Plan:
        """Wrap a ``plan_phases``-shaped result into a Plan (shared by the
        cold synthesize and warm repair paths)."""
        phases, extra_mem = out[0], out[1]
        nic_shares = out[2] if len(out) > 2 else None
        # Fingerprint hashing (O(matrix bytes)) stays outside the timed
        # window: synth_seconds is the paper's Fig 17a synthesis metric.
        if fingerprint is None:
            fingerprint = traffic_fingerprint(w, self.name)
        return Plan(
            algorithm=self.name,
            cluster=w.cluster,
            phases=tuple(phases),
            synth_seconds=synth,
            extra_memory_bytes=float(extra_mem),
            accounts_intra=self.accounts_intra,
            fingerprint=fingerprint,
            topology=w.topology,
            nic_shares=nic_shares,
            capacity_aware=getattr(self, "capacity_aware", False),
        )


# -- FLASH -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RepairConfig:
    """Tunable knobs for warm-started repair (``try_repair_plan``).

    The ratchet thresholds decide when a repair is *not* a near-miss and
    the caller should cold-synthesize instead:

      * ``max_residual_fraction`` -- bail when more than this fraction of
        the new traffic falls outside the previous plan's permutations.
      * ``max_stage_drift`` -- bail when chained repairs stretch the stage
        list past this multiple of the Birkhoff bound (n^2 - 2n + 2).
      * ``quality_ratchet`` -- incremental engine only: bail when the
        repaired stage windows sum to more than this multiple of the exact
        lower bound (the completion-time audit of DESIGN.md 1f).
      * ``headroom`` -- incremental engine only: extra slack (fraction of
        each pair's traffic) on the last slot of every pair, absorbing
        traffic *growth* without structural change.
      * ``incremental`` -- route repair through the stateful
        ``DecompositionState`` delta engine (default); False falls back to
        the legacy one-shot refill loop, which re-walks the previous stage
        list per miss and carries no state (the CI speedup baseline).
    """

    max_residual_fraction: float = 0.25
    max_stage_drift: float = 2.0
    quality_ratchet: float = 1.10
    headroom: float = 0.5
    incremental: bool = True

    def for_topology_change(self) -> "RepairConfig":
        """Relaxed copy for cross-fabric re-repair (fault events).

        The quality ratchet prices drift against a *fixed* fabric's lower
        bound; after a degrade/fail event the old stage structure is
        necessarily a little off the new fabric's optimum, and the serving
        contract is degraded-but-valid-now with an exact re-synthesis
        upgrading it in the background.  Floor the ratchet so a bounded
        mismatch does not force every family cold at once."""
        floor = TOPOLOGY_CHANGE_QUALITY_RATCHET
        if self.quality_ratchet >= floor:
            return self
        return dataclasses.replace(self, quality_ratchet=floor)


# A re-repaired plan may run up to this multiple of the new fabric's exact
# lower bound before the repair is rejected as not-worth-keeping (the
# fig_fault CI guard asserts the *measured* post-event completion stays
# well inside this against a cold synthesis on the degraded fabric).
TOPOLOGY_CHANGE_QUALITY_RATCHET = 1.75

DEFAULT_REPAIR_CONFIG = RepairConfig()

# Stash attribute for the DecompositionState a repaired plan carries to
# the next miss of its family.  Plans are frozen dataclasses, so the state
# rides in __dict__ via object.__setattr__ and is *claimed* (popped) by
# exactly one successor -- dict.pop is atomic under the GIL, so concurrent
# daemon misses cannot share one state's mutable structure.
_STATE_ATTR = "_decomp_state"


@register_scheduler
class FlashScheduler(Scheduler):
    """Three-phase, two-tier FLASH schedule (paper 4.2-4.3).

    This is the code path whose latency the paper reports as ~15-32 us on
    small clusters; it is pure NumPy + Hopcroft-Karp and runs per iteration
    on the host control thread (paper Fig 10).
    """

    name = "flash"
    accounts_intra = True
    # Synthesize the Birkhoff stages against the fabric's pair capacities
    # (time-domain decomposition, per-sender slots).  Off here: "flash"
    # stays bit-identical to the capacity-blind engine; the "flash_ca"
    # registration below is the opt-in.
    capacity_aware: ClassVar[bool] = False

    def plan_phases(self, w: Workload):
        return self._plan_phases(w, policy="auto")

    def _plan_phases(self, w: Workload, policy: str):
        t_server, s_intra, _ = w.reductions()
        stages = birkhoff_decompose(
            t_server, sort_ascending=True, coalesce=True, policy=policy,
            topology=w.topo if self.capacity_aware else None,
            capacity_aware=self.capacity_aware)
        return self._phases_from_stages(w, t_server, s_intra, stages)

    # Observed cold-synthesis seconds per (algorithm, n_servers), EWMA.
    # Class-level so every scheduler instance (the serving daemon builds
    # them on demand) shares one latency model; keys include the name so
    # flash and flash_ca never mix.
    _synth_ewma: ClassVar[Dict[Tuple[str, int], float]] = {}

    def synthesize_bounded(self, w: Workload, budget_seconds:
                           Optional[float] = None,
                           fingerprint: Optional[str] = None
                           ) -> Tuple[Plan, bool]:
        """FLASH under a latency budget (see ``Scheduler.synthesize_bounded``).

        The cost model is an EWMA of observed cold-synthesis times for
        this (algorithm, n_servers); when the estimate exceeds the budget
        the decomposition runs with ``policy="repair"`` -- the augmenting
        path engine that is the fast mode beyond ``AUTO_EXACT_MAX_N``
        servers -- instead of the default auto policy.  Below that size
        the repair engine produces a valid but generally different (and
        slightly longer) stage list than the exact engine, so the plan is
        flagged inexact and the serving daemon schedules a background
        upgrade; at or beyond it the repair engine *is* what unbounded
        synthesis runs, so the degraded path is already exact.
        """
        key = (self.name, w.cluster.n_servers)
        est = self._synth_ewma.get(key)
        if budget_seconds is None or est is None or est <= budget_seconds:
            plan = self.synthesize(w, fingerprint=fingerprint)
            obs = plan.synth_seconds
            self._synth_ewma[key] = obs if est is None \
                else 0.7 * est + 0.3 * obs
            return plan, True
        t0 = time.perf_counter()
        out = self._plan_phases(w, policy="repair")
        plan = self._build_plan(w, out, time.perf_counter() - t0,
                                fingerprint)
        return plan, w.cluster.n_servers > AUTO_EXACT_MAX_N

    def _lb_phase(self, w: Workload, t_server: np.ndarray):
        """Load-balance phase shared by the stage-list and stage-block plan
        builders: per (server, gpu), how many bytes must this GPU shed so
        that every local GPU holds exactly its rail's share of T[a, j] for
        every dest j?  Shares are proportional to rail capacity, min(src
        NIC, dst NIC) per rail (topology-aware rebalance): on a homogeneous
        fabric this is the paper's uniform T/m split; with degraded or
        mixed-speed NICs the fast rails carry more so every rail of a pair
        drains simultaneously.  Homogeneous fabrics share the memoized
        uniform array instead of recomputing the capacity mins on every
        synthesis (serving-loop hot path)."""
        n, m = w.cluster.n_servers, w.cluster.m_gpus
        homog = w.topo.is_homogeneous
        shares = (uniform_nic_shares(n, m) if homog
                  else w.topo.nic_shares())  # (n, n, m): [src, dst, rail]
        per_gpu_dest = w.reductions()[2]  # (n, m, n)
        if homog:
            # Uniform shares are 1/m everywhere: a scalar broadcast beats
            # the elementwise product with the transposed (n, m, n) view.
            target = t_server[:, None, :] * (1.0 / m)
        else:
            target = t_server[:, None, :] * shares.transpose(0, 2, 1)
        excess = per_gpu_dest - target
        np.maximum(excess, 0.0, out=excess)
        excess[np.arange(n), :, np.arange(n)] = 0.0  # intra not balanced
        lb_moved = excess.sum(axis=2)  # (n, m) total bytes each GPU sheds
        return LoadBalancePhase(moved_per_gpu=lb_moved,
                                charge_alpha=True), shares, lb_moved

    def _phases_from_stages(self, w: Workload, t_server: np.ndarray,
                            s_intra: np.ndarray, stages):
        """Wrap a Birkhoff stage list (cold-synthesized or warm-repaired)
        into the three-phase FLASH plan for workload ``w``."""
        m = w.cluster.m_gpus
        lb, shares, lb_moved = self._lb_phase(w, t_server)
        phases = [lb]
        phases += [PermutationStage(perm=s.perm, size=s.size, sent=s.sent,
                                    slots=s.slots)
                   for s in stages]
        if stages:
            phases.append(RedistributePhase(
                bytes_per_gpu=stages[-1].size / m, charge_alpha=True))
        phases.append(IntraOverlapPhase(per_server=s_intra))

        inter_bytes = float(sum(s.real_bytes for s in stages))
        # Staging beyond 2x send/recv: load-balance + redistribute buffers
        # (the measured ~2.6x slope of Fig 17b).
        extra_mem = float(lb_moved.sum()) + inter_bytes / m
        # Uniform shares are the executor's fallback: carrying a dense
        # (n, n, m) array on every homogeneous plan would only bloat the
        # PlanCache and JSON wire format.
        if w.topo.is_homogeneous:
            return tuple(phases), extra_mem
        return tuple(phases), extra_mem, shares

    def _phases_from_block(self, w: Workload, t_server: np.ndarray,
                           s_intra: np.ndarray, block):
        """Stage-block counterpart of ``_phases_from_stages``: wrap one
        ``StageBlock`` emission of the incremental engine as a single
        ``PermutationBlock`` phase, keeping its stacked arrays intact (no
        per-stage object materialization on the repair hot path)."""
        m = w.cluster.m_gpus
        lb, shares, lb_moved = self._lb_phase(w, t_server)
        phases = [lb]
        inter_bytes = 0.0
        if len(block):
            phases.append(PermutationBlock(
                perms=block.perms, sizes=block.sizes, sent=block.sent,
                slots=block.slots))
            phases.append(RedistributePhase(
                bytes_per_gpu=float(block.sizes[-1]) / m, charge_alpha=True))
            # The emitted block conserves the inter-server matrix exactly
            # (refill + residual = T); summing the small matrix beats
            # summing the (S, n) sent array.
            inter_bytes = float(t_server.sum())
        phases.append(IntraOverlapPhase(per_server=s_intra))
        extra_mem = float(lb_moved.sum()) + inter_bytes / m
        if w.topo.is_homogeneous:
            return tuple(phases), extra_mem
        return tuple(phases), extra_mem, shares

    # Default repair knobs; instances (or the serving daemon) may override
    # with ``sched.repair_config = RepairConfig(...)``.
    repair_config: ClassVar[Optional[RepairConfig]] = None

    def try_repair_plan(self, prev: Plan, w: Workload,
                        fingerprint: Optional[str] = None, *,
                        config: Optional[RepairConfig] = None,
                        stats: Optional[dict] = None,
                        topology_change: bool = False) -> Optional[Plan]:
        """Warm-started re-synthesis: seed the new plan with the previous
        plan's permutations instead of a cold Birkhoff decomposition.

        The near-miss path for dynamic MoE (paper Fig 4): when traffic
        shifts a little between iterations, the old stage list is almost
        right -- so the previous stages' slots are refilled with the new
        matrix's bytes (capped by slot size) and only the residual that did
        not fit is decomposed fresh.  A small shift therefore costs a fill
        pass plus a tiny decomposition instead of a full synthesis.  The
        result is a valid FLASH plan (byte-conserving, incast-free) but
        generally a different -- and slightly longer -- stage list than
        cold synthesis; PlanCache only takes this path when explicitly
        enabled (``warm_start=True``).

        Two engines sit behind this entry point, selected by
        ``config.incremental`` (see ``RepairConfig``): the stateful
        ``DecompositionState`` delta engine, which carries the decomposition
        structure from plan to plan so consecutive misses of a family pay
        only the drift delta, and the legacy one-shot loop that re-walks
        ``prev``'s stage list each call.  ``stats``, when passed, is filled
        with the engine's audit record (mode, residual_fraction, and on the
        incremental path n_stages/quality or the tripped ratchet).

        Returns None when the shift is no near-miss (the caller should
        cold-synthesize): too much traffic falls outside the old
        permutations, chained repairs would drift far past the Birkhoff
        stage bound, or the incremental quality ratchet tripped.

        ``topology_change=True`` relaxes the fabric-fingerprint match for
        fault-tolerant re-repair: ``prev`` was synthesized on a different
        (pre-event) topology of the same shape, and its stage structure is
        re-repaired against ``w.topo``'s *new* pair capacities -- the
        carried delta state is discarded (its water-fill thresholds embed
        the old fabric's capacities) and rebuilt fresh from the plan's
        phases, so shares, slots and validation all reflect the degraded
        or recovered fabric.
        """
        if prev.algorithm != self.name:
            raise ValueError(
                f"cannot warm-start {self.name!r} from a {prev.algorithm!r} "
                "plan")
        if prev.cluster != w.cluster:
            raise ValueError(
                "warm-start requires the previous plan's cluster to match "
                "the new workload's")
        if not topology_change and \
                prev.topo.fingerprint() != w.topo.fingerprint():
            raise ValueError(
                "warm-start requires the previous plan's (cluster, "
                "topology) to match the new workload's fabric; pass "
                "topology_change=True to re-repair across a fabric event")
        cfg = config if config is not None else \
            (self.repair_config or DEFAULT_REPAIR_CONFIG)
        if topology_change:
            # Any carried state is priced in the old fabric's capacities;
            # drop it so neither this repair nor a later claim reuses it.
            prev.__dict__.pop(_STATE_ATTR, None)
            cfg = cfg.for_topology_change()
            if stats is not None:
                stats["topology_change"] = True
        # Like fingerprint hashing (see _build_plan), the O(gpu-matrix)
        # reduction is input normalization shared with execution and
        # fingerprinting, not synthesis: memoized on the workload and kept
        # outside the timed window.
        t_server, s_intra, _ = w.reductions()
        t0 = time.perf_counter()
        if cfg.incremental:
            return self._repair_incremental(prev, w, t_server, s_intra, cfg,
                                            stats, t0, fingerprint)
        return self._repair_oneshot(prev, w, t_server, s_intra, cfg,
                                    stats, t0, fingerprint)

    def _claim_state(self, prev: Plan) -> Optional[DecompositionState]:
        """Pop the carried DecompositionState off ``prev``, if it has one
        this scheduler can reuse.  Popping (not reading) makes the handoff
        exclusive: one successor plan inherits the mutable structure."""
        state = prev.__dict__.pop(_STATE_ATTR, None)
        if state is None or state.invalid:
            return None
        if state.n != prev.cluster.n_servers or \
                state.aware != self.capacity_aware:
            return None
        return state

    def _state_from_plan(self, prev: Plan,
                         w: Workload, headroom: float
                         ) -> Optional[DecompositionState]:
        """Rebuild a DecompositionState from ``prev``'s permutation phases
        (the cold-plan bootstrap: a freshly synthesized plan carries no
        state, only stages)."""
        # Batch the per-stage tuples into single np.array calls: a cold
        # 32-server plan carries ~n^2 PermutationStage rows, and one
        # stacked conversion is ~20x cheaper than a per-phase
        # asarray+concatenate chain.
        perm_rows, sent_rows = [], []
        perms_l, sent_l = [], []
        for p in prev.phases:
            if isinstance(p, PermutationStage):
                perm_rows.append(p.perm)
                sent_rows.append(p.sent)
            elif isinstance(p, PermutationBlock):
                if p.n_stages:
                    perms_l.append(np.asarray(p.perms, dtype=np.int64))
                    sent_l.append(np.asarray(p.sent, dtype=np.float64))
        if perm_rows:
            perms_l.append(np.array(perm_rows, dtype=np.int64))
            sent_l.append(np.array(sent_rows, dtype=np.float64))
        if not perms_l:
            return None
        caps_eff = (effective_pair_caps(w.topo.pair_capacity())
                    if self.capacity_aware else None)
        return DecompositionState(
            np.concatenate(perms_l, axis=0), np.concatenate(sent_l, axis=0),
            caps_eff=caps_eff, headroom=headroom)

    def seed_repair_state(self, plan: Plan, w: Workload, *,
                          config: Optional[RepairConfig] = None) -> None:
        """Attach a fresh ``DecompositionState`` to a cold-synthesized plan
        so the family's *first* warm repair already runs the delta path.

        The state rebuild is the one per-family bootstrap cost of the
        incremental engine (stacking ~n^2 stage tuples into arrays and
        indexing them); paying it here, alongside the cold decomposition it
        derives from, keeps every subsequent miss at delta cost.  Safe to
        skip -- ``try_repair_plan`` rebuilds lazily when no state rides the
        previous plan."""
        cfg = config if config is not None else \
            (self.repair_config or DEFAULT_REPAIR_CONFIG)
        state = self._state_from_plan(plan, w, cfg.headroom)
        if state is not None:
            object.__setattr__(plan, _STATE_ATTR, state)

    def _repair_incremental(self, prev, w, t_server, s_intra, cfg, stats,
                            t0, fingerprint) -> Optional[Plan]:
        state = self._claim_state(prev)
        if state is None:
            state = self._state_from_plan(prev, w, cfg.headroom)
            if state is None:
                return None  # prev carries zero traffic: nothing to refill
        block, st = state.update(
            t_server,
            max_residual_fraction=cfg.max_residual_fraction,
            max_stage_drift=cfg.max_stage_drift,
            quality_ratchet=cfg.quality_ratchet)
        if stats is not None:
            stats.update(st)
        if block is None:  # a ratchet tripped; state is dead
            return None
        out = self._phases_from_block(w, t_server, s_intra, block)
        plan = self._build_plan(w, out, time.perf_counter() - t0,
                                fingerprint)
        # Hand the (still valid) state to the new plan: the family's next
        # miss chains through it instead of rebuilding from phases.
        object.__setattr__(plan, _STATE_ATTR, state)
        return plan

    def _repair_oneshot(self, prev, w, t_server, s_intra, cfg, stats,
                        t0, fingerprint) -> Optional[Plan]:
        """Legacy stateless repair: re-walk ``prev``'s stage list, refill
        each slot, decompose the residual.  Kept as the CI baseline the
        incremental engine is measured against, and as the
        ``incremental=False`` escape hatch."""
        n = w.cluster.n_servers
        if stats is not None:
            stats["mode"] = "oneshot"
        remaining = t_server.copy()
        reused = []
        prev_stages: list = []
        for ph in prev.phases:
            if isinstance(ph, PermutationStage):
                prev_stages.append(ph)
            elif isinstance(ph, PermutationBlock):
                # A block plan (incremental engine output) repairs fine
                # one-shot too; expand to per-stage views for the loop.
                prev_stages.extend(ph.iter_stages())
        for p in prev_stages:
            perm = np.asarray(p.perm, dtype=np.int64)
            li = np.flatnonzero(perm >= 0)
            lj = perm[li]
            cap_slot = (np.asarray(p.slots, dtype=np.float64)[li]
                        if p.slots is not None else p.size)
            take = np.minimum(remaining[li, lj], cap_slot)
            remaining[li, lj] -= take
            # The slot only needs to fit the largest refilled payload:
            # shrinking it sheds the padding a traffic *decrease* left
            # behind (an increase lands in the residual decomposition).
            size = float(take.max(initial=0.0))
            if size <= 0.0:  # stage carries nothing anymore: drop it
                continue
            sent = np.zeros(n)
            sent[li] = take
            slots = None
            if self.capacity_aware:
                # Re-weight on repair: every pair's slot shrinks to its
                # refilled payload, so the stage window is set by the
                # slowest refilled pair, not the old padding.
                slot_arr = np.zeros(n)
                slot_arr[li] = take
                slots = tuple(slot_arr.tolist())
            reused.append(Stage(perm=p.perm, size=size,
                                sent=tuple(sent.tolist()), slots=slots))
        res_frac = float(remaining.sum()) / max(float(t_server.sum()), 1.0)
        if stats is not None:
            stats["residual_fraction"] = res_frac
        if res_frac > cfg.max_residual_fraction:
            # Too much traffic fell outside the old permutations: a
            # repaired plan would be far from the cold optimum.
            if stats is not None:
                stats["tripped"] = "residual"
            return None
        if self.capacity_aware:
            residual = birkhoff_decompose(remaining, sort_ascending=True,
                                          coalesce=True, topology=w.topo,
                                          capacity_aware=True)
            # Ascending *durations* preserve the Theorem 2 pipeline on the
            # heterogeneous fabric (byte sizes alone order it wrongly when
            # pair capacities differ).
            caps = w.topo.pair_capacity()
            stages = sorted(reused + residual,
                            key=lambda s: stage_duration(s, caps))
        else:
            residual = birkhoff_decompose(remaining, sort_ascending=True,
                                          coalesce=True)
            stages = sorted(reused + residual, key=lambda s: s.size)
        if stats is not None:
            stats["n_stages"] = len(stages)
        if len(stages) > cfg.max_stage_drift * (n * n - 2 * n + 2):
            # Chained repairs accumulate residual slivers; reset before the
            # stage count (and its per-stage wakeup cost) drifts.
            if stats is not None:
                stats["tripped"] = "stages"
            return None
        out = self._phases_from_stages(w, t_server, s_intra, stages)
        return self._build_plan(w, out, time.perf_counter() - t0,
                                fingerprint)

    def repair_plan(self, prev: Plan, w: Workload,
                    fingerprint: Optional[str] = None, *,
                    config: Optional[RepairConfig] = None) -> Plan:
        """``try_repair_plan`` with a cold-synthesis fallback: always
        returns a valid plan for ``w`` (repaired on a near-miss, fresh
        otherwise)."""
        plan = self.try_repair_plan(prev, w, fingerprint=fingerprint,
                                    config=config)
        if plan is None:
            plan = self.synthesize(w, fingerprint=fingerprint)
        return plan

    def synthesize_trajectory(self, workloads, *,
                              config: Optional[RepairConfig] = None
                              ) -> List[Plan]:
        """Fuse synthesis across a whole traffic window (dynamic MoE
        serving, paper Fig 4): cold-synthesize the first workload, then
        chain every subsequent one through the incremental repair engine,
        so the window pays one full decomposition plus per-step deltas.

        Repeated matrices (MoE traffic revisits signatures) are answered
        from a fingerprint memo without re-synthesis and without disturbing
        the repair chain -- the carried state keeps tracking the newest
        *fresh* matrix.  When a repair ratchet trips mid-window the step
        falls back to cold synthesis and the chain restarts from it.

        Returns one Plan per workload, aligned with the input; repeats
        share the same Plan object.
        """
        cfg = config if config is not None else \
            (self.repair_config or DEFAULT_REPAIR_CONFIG)
        plans: List[Plan] = []
        memo: Dict[str, Plan] = {}
        head: Optional[Plan] = None  # newest structurally-fresh plan
        for w in workloads:
            key = traffic_fingerprint(w, self.name)
            plan = memo.get(key)
            if plan is None:
                if head is not None:
                    plan = self.try_repair_plan(head, w, fingerprint=key,
                                                config=config)
                if plan is None:
                    plan = self.synthesize(w, fingerprint=key)
                    if cfg.incremental:
                        self.seed_repair_state(plan, w, config=cfg)
                memo[key] = plan
                head = plan
            plans.append(plan)
        return plans


@register_scheduler
class CapacityAwareFlashScheduler(FlashScheduler):
    """FLASH with capacity-aware Birkhoff synthesis (opt-in, ``flash_ca``).

    Same three-phase plan shape as ``flash``, but the stage list comes from
    the time-domain decomposition of ``T / pair_capacity`` with
    high-capacity-first matchings (birkhoff.py module docstring): each
    pair's byte slot is sized so every pair of a stage drains in the same
    window, and stages sort by ascending duration.  On a uniform-capacity
    fabric the decomposition degenerates to the blind one, so this
    scheduler only diverges from ``flash`` where pair capacities differ
    (degraded NICs, mixed NIC generations).  Registered under its own name
    so plans, cache families and warm repairs never mix with the blind
    engine's.
    """

    name = "flash_ca"
    capacity_aware = True


# -- FanOut ----------------------------------------------------------------

@register_scheduler
class FanOutScheduler(Scheduler):
    """RCCL default: zero synthesis, one burst of the whole matrix."""

    name = "fanout"
    accounts_intra = True

    def plan_phases(self, w: Workload):
        return (FanOutBurst(matrix=np.array(w.matrix, dtype=np.float64)),), \
            0.0


# -- SpreadOut -------------------------------------------------------------

@register_scheduler
class SpreadOutScheduler(Scheduler):
    """MPI SpreadOut: N-1 barrier stages, stage k pairs g -> (g+k) mod N."""

    name = "spreadout"
    accounts_intra = True

    def plan_phases(self, w: Workload):
        n_gpus = w.cluster.n_gpus
        g = np.arange(n_gpus)
        phases = []
        for k, sizes in enumerate(spreadout_stages(w), start=1):
            phases.append(BarrierStage(sizes=sizes, dsts=(g + k) % n_gpus))
        return tuple(phases), 0.0


# -- Hierarchical ----------------------------------------------------------

@register_scheduler
class HierarchicalScheduler(Scheduler):
    """MSCCL-style rail-aligned hierarchical A2A.

    Matches FLASH on balanced workloads (every rail carries the same bytes)
    but cannot rebalance across NICs under skew -- the max-loaded rail
    becomes the straggler.  Intra-server traffic is not scheduled (rides
    the fabric for free in this model), so ``accounts_intra`` is False.
    """

    name = "hierarchical"
    accounts_intra = False

    def plan_phases(self, w: Workload):
        c = w.cluster
        send, recv, gather = hierarchical_nic_loads(w)
        phases = (
            LoadBalancePhase(moved_per_gpu=gather, charge_alpha=False),
            RailStage(send=send, recv=recv, n_rounds=c.n_servers - 1),
            # Scatter at the receiver pipelines with inter arrivals;
            # charge tail only.
            RedistributePhase(
                bytes_per_gpu=float(recv.max(initial=0.0)) / max(c.m_gpus, 1),
                charge_alpha=False),
        )
        return phases, float(gather.sum())


# -- Optimal (Theorem 1) ---------------------------------------------------

@register_scheduler
class OptimalScheduler(Scheduler):
    """Theorem 1 lower bound: max line sum of the server matrix over the
    aggregate per-server NIC bandwidth.  Not executable on hardware; used
    as the 'optimal' line in every figure."""

    name = "optimal"
    accounts_intra = False

    def plan_phases(self, w: Workload):
        t_server = w.server_matrix()
        # Per-server max(row, col) line sums let the executor bound each
        # server against its own aggregate NIC capacity (heterogeneous NICs).
        line = np.maximum(t_server.sum(axis=1), t_server.sum(axis=0))
        return (BoundStage(bound_bytes=max_line_sum(t_server),
                           inter_total=float(t_server.sum()),
                           line_sums=tuple(float(x) for x in line)),), 0.0


# -- synthesis helpers (vectorized hot paths) ------------------------------

def spreadout_stages(w: Workload) -> List[np.ndarray]:
    """SpreadOut: stage k (k = 1..N-1) pairs GPU g with GPU (g + k) mod N.

    Returns per-stage (N,) arrays of flow sizes; flow g in stage k goes
    g -> (g + k) mod N.  One vectorized gather builds all N-1 stages.
    """
    n_gpus = w.cluster.n_gpus
    g = np.arange(n_gpus)
    k = np.arange(1, n_gpus)[:, None]
    sizes = w.matrix[g[None, :], (g[None, :] + k) % n_gpus]  # (N-1, N)
    return list(sizes)


def hierarchical_nic_loads(w: Workload):
    """MSCCL-style rail-aligned aggregation: per-NIC send/recv byte loads.

    GPU i of server a aggregates (intra-server gather) all local bytes whose
    destination is GPU i of any remote server, then ships it over NIC i to
    the rail peer.  Returns (send_loads, recv_loads, gather_bytes) each of
    shape (n_servers, m).  Fully vectorized (synthesis-speed hot path).
    """
    c = w.cluster
    n, m = c.n_servers, c.m_gpus
    blk = w.matrix.reshape(n, m, n, m)          # [a, g, b, h]
    ar = np.arange(n)
    per_rail = blk.sum(axis=1)                  # [a, b, i]: over local srcs
    diag_rail = per_rail[ar, ar, :]             # [a, i]: own-server block
    send = per_rail.sum(axis=1) - diag_rail     # inter bytes NIC (a, i) ships
    recv = per_rail.sum(axis=0) - diag_rail     # inter bytes NIC (b, i) takes
    own_abi = np.einsum("aibi->abi", blk)       # blk[a, i, b, i]
    own = own_abi.sum(axis=1) - own_abi[ar, ar, :]  # GPU i's own rail bytes
    gather = send - own                         # arriving from local peers
    return send, recv, gather


def optimal_completion_time(w: Workload) -> float:
    """Theorem 1, link-level: each server's max(row, col) line sum over its
    own aggregate NIC capacity, and the whole exchange over the spine.
    Reduces to ``max_line_sum / (m * b_inter)`` on homogeneous fabrics."""
    t_server = w.server_matrix()
    line = np.maximum(t_server.sum(axis=1), t_server.sum(axis=0))
    return w.topo.theorem1_time(line, float(t_server.sum()))


def synthesis_time(
    n_servers: Optional[int] = None,
    m_gpus: Optional[int] = None,
    seed: int = 0,
    workload: Optional[Workload] = None,
) -> float:
    """Measure FLASH schedule-synthesis wall time for a random workload.

    Used by benchmarks/fig17_overhead.py to reproduce the scheduling-time
    claim (us-scale vs TACCL's minutes-to-hours).  Pass either a cluster
    shape (``n_servers``/``m_gpus``) for a generated workload, or an
    explicit ``workload=``; shape arguments that conflict with an explicit
    workload raise instead of being silently ignored.
    """
    from .traffic import random_workload

    if workload is None:
        if n_servers is None:
            raise ValueError("pass n_servers (and optionally m_gpus) or "
                             "an explicit workload=")
        cluster = ClusterSpec(n_servers=n_servers,
                              m_gpus=8 if m_gpus is None else m_gpus)
        workload = random_workload(cluster, mean_size=1 << 20, seed=seed)
    else:
        c = workload.cluster
        if (n_servers is not None and n_servers != c.n_servers) or \
                (m_gpus is not None and m_gpus != c.m_gpus):
            raise ValueError(
                f"conflicting arguments: workload= runs on "
                f"({c.n_servers} servers, {c.m_gpus} GPUs) but "
                f"n_servers={n_servers}, m_gpus={m_gpus} were also given")
    return FlashScheduler().synthesize(workload).synth_seconds


# -- legacy FlashPlan shim -------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlashPlan:
    """Legacy view of a FLASH Plan (pre-IR API, kept for back-compat).

    Attributes:
      stages: Birkhoff stages over the *server-level* matrix, ascending size
        (paper 4.3: ascending order lets stage k's redistribute hide under
        stage k+1's inter-server transfer).
      lb_moved_per_gpu: (n_servers, m) bytes each GPU must shed during the
        load-balance phase (max over destinations handled concurrently).
      redistribute_tail: bytes/GPU redistributed after the *last* stage (the
        un-hidden pipeline tail).
      intra_bytes: S_i per server, overlapped with the first inter stage.
      synth_seconds: wall-clock time spent computing this plan.
    """

    cluster: ClusterSpec
    stages: List[Stage]
    lb_moved_per_gpu: np.ndarray
    redistribute_tail: float
    intra_bytes: np.ndarray
    synth_seconds: float

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def inter_bytes(self) -> float:
        """Genuine bytes crossing the inter-server network."""
        return float(sum(s.real_bytes for s in self.stages))

    def stage_sizes(self) -> np.ndarray:
        return np.array([s.size for s in self.stages])

    @classmethod
    def from_plan(cls, plan: Plan) -> "FlashPlan":
        if plan.algorithm != "flash":
            raise ValueError(f"not a flash plan: {plan.algorithm!r}")
        stages = []
        for p in plan.phases:
            if isinstance(p, PermutationStage):
                stages.append(Stage(perm=p.perm, size=p.size, sent=p.sent))
            elif isinstance(p, PermutationBlock):
                stages.extend(Stage(perm=s.perm, size=s.size, sent=s.sent)
                              for s in p.iter_stages())
        lb = next(p.moved_per_gpu for p in plan.phases
                  if isinstance(p, LoadBalancePhase))
        tail = next((p.bytes_per_gpu for p in plan.phases
                     if isinstance(p, RedistributePhase)), 0.0)
        s_intra = next(p.per_server for p in plan.phases
                       if isinstance(p, IntraOverlapPhase))
        return cls(cluster=plan.cluster, stages=stages, lb_moved_per_gpu=lb,
                   redistribute_tail=tail, intra_bytes=s_intra,
                   synth_seconds=plan.synth_seconds)


def flash_schedule(w: Workload) -> FlashPlan:
    """Back-compat shim: synthesize FLASH and return the legacy view."""
    return FlashPlan.from_plan(FlashScheduler().synthesize(w))
