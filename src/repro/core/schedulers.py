"""All-to-All schedulers: FLASH and the paper's baselines.

Every scheduler consumes a GPU-level ``Workload`` and produces a ``Plan`` that
the alpha-beta simulator (simulator.py) can time.  ``flash_schedule`` is the
paper's contribution: the three-phase, two-tier schedule whose inter-server
stage list comes from the Birkhoff decomposition of the server-level matrix.

Baselines (paper section 6.1):
  * FanOut     -- RCCL default: every GPU transmits to all peers at once.
  * SpreadOut  -- MPI: N-1 barrier-synchronized stages, stage k pairs
                  g -> (g + k) mod N.
  * Hierarchical -- MSCCL-style rail-aligned: GPU i of each server aggregates
                  local traffic for rail-i peers, then ships it over NIC i.
  * LP bound   -- Theorem 1 optimal completion time (not executable, used as
                  the 'optimal' line in every figure).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from .birkhoff import Stage, birkhoff_decompose, max_line_sum
from .traffic import ClusterSpec, Workload, server_reduce

__all__ = [
    "FlashPlan",
    "flash_schedule",
    "spreadout_stages",
    "hierarchical_nic_loads",
    "synthesis_time",
]


@dataclasses.dataclass(frozen=True)
class FlashPlan:
    """Output of FLASH schedule synthesis for one traffic matrix.

    Attributes:
      stages: Birkhoff stages over the *server-level* matrix, ascending size
        (paper 4.3: ascending order lets stage k's redistribute hide under
        stage k+1's inter-server transfer).
      lb_moved_per_gpu: (n_servers, m) bytes each GPU must shed during the
        load-balance phase (max over destinations handled concurrently).
      redistribute_tail: bytes/GPU redistributed after the *last* stage (the
        un-hidden pipeline tail).
      intra_bytes: S_i per server, overlapped with the first inter stage.
      synth_seconds: wall-clock time spent computing this plan (the paper's
        'scheduling time' metric, Fig 17a).
    """

    cluster: ClusterSpec
    stages: List[Stage]
    lb_moved_per_gpu: np.ndarray
    redistribute_tail: float
    intra_bytes: np.ndarray
    synth_seconds: float

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def inter_bytes(self) -> float:
        """Genuine bytes crossing the inter-server network."""
        return float(sum(s.real_bytes for s in self.stages))

    def stage_sizes(self) -> np.ndarray:
        return np.array([s.size for s in self.stages])


def flash_schedule(w: Workload) -> FlashPlan:
    """Synthesize the complete FLASH plan for a workload.

    This is the code path whose latency the paper reports as ~15-32 us on
    small clusters; it is pure NumPy + Hopcroft-Karp and runs per iteration
    on the host control thread (paper Fig 10).
    """
    t0 = time.perf_counter()
    cluster = w.cluster
    n, m = cluster.n_servers, cluster.m_gpus
    t_server, s_intra = server_reduce(w.matrix, m)

    # Load-balance phase: per (server, gpu), how many bytes must this GPU
    # shed so that every local GPU holds exactly T[a, j] / m for every dest j?
    per_gpu_dest = w.matrix.reshape(n, m, n, m).sum(axis=3)  # (n, m, n)
    target = t_server / m  # (n, n); diagonal 0
    excess = np.maximum(per_gpu_dest - target[:, None, :], 0.0)
    for a in range(n):
        excess[a, :, a] = 0.0  # intra-server traffic is not load balanced
    lb_moved = excess.sum(axis=2)  # (n, m) total bytes each GPU sheds

    stages = birkhoff_decompose(t_server, sort_ascending=True, coalesce=True)
    tail = stages[-1].size / m if stages else 0.0
    synth = time.perf_counter() - t0
    return FlashPlan(
        cluster=cluster,
        stages=stages,
        lb_moved_per_gpu=lb_moved,
        redistribute_tail=tail,
        intra_bytes=s_intra,
        synth_seconds=synth,
    )


def spreadout_stages(w: Workload) -> List[np.ndarray]:
    """SpreadOut: stage k (k = 1..N-1) pairs GPU g with GPU (g + k) mod N.

    Returns per-stage (N,) arrays of flow sizes; flow g in stage k goes
    g -> (g + k) mod N.
    """
    n_gpus = w.cluster.n_gpus
    out = []
    for k in range(1, n_gpus):
        sizes = np.array(
            [w.matrix[g, (g + k) % n_gpus] for g in range(n_gpus)])
        out.append(sizes)
    return out


def hierarchical_nic_loads(w: Workload):
    """MSCCL-style rail-aligned aggregation: per-NIC send/recv byte loads.

    GPU i of server a aggregates (intra-server gather) all local bytes whose
    destination is GPU i of any remote server, then ships them over NIC i to
    the rail peer.  Returns (send_loads, recv_loads, gather_bytes) each of
    shape (n_servers, m).
    """
    c = w.cluster
    n, m = c.n_servers, c.m_gpus
    blk = w.matrix.reshape(n, m, n, m)  # [a, g, b, h]
    send = np.zeros((n, m))
    recv = np.zeros((n, m))
    gather = np.zeros((n, m))
    for a in range(n):
        for i in range(m):
            inter = blk[a, :, :, i].sum() - blk[a, :, a, i].sum()
            send[a, i] = inter
            own = blk[a, i, :, i].sum() - blk[a, i, a, i]
            gather[a, i] = inter - own  # bytes arriving from local peers
    for b in range(n):
        for i in range(m):
            recv[b, i] = blk[:, :, b, i].sum() - blk[b, :, b, i].sum()
    return send, recv, gather


def synthesis_time(
    n_servers: int,
    m_gpus: int = 8,
    seed: int = 0,
    workload: Optional[Workload] = None,
) -> float:
    """Measure FLASH schedule-synthesis wall time for a random workload.

    Used by benchmarks/fig17_overhead.py to reproduce the scheduling-time
    claim (us-scale vs TACCL's minutes-to-hours).
    """
    from .traffic import random_workload

    if workload is None:
        cluster = ClusterSpec(n_servers=n_servers, m_gpus=m_gpus)
        workload = random_workload(cluster, mean_size=1 << 20, seed=seed)
    plan = flash_schedule(workload)
    return plan.synth_seconds


def optimal_completion_time(w: Workload) -> float:
    """Theorem 1: max line sum of the server matrix over aggregate NIC bw."""
    c = w.cluster
    t_server = w.server_matrix()
    return max_line_sum(t_server) / (c.m_gpus * c.b_inter)
