"""Birkhoff-von Neumann decomposition of a server-level traffic matrix.

The heart of FLASH's inter-server stage synthesis (paper section 4.2): an
arbitrary nonnegative n x n traffic matrix T is padded to a matrix with equal
row and column sums ("doubly stochastic" up to scale) and decomposed into a
sum of scaled permutation matrices

    T + P = sum_k  w_k * Perm(pi_k)

Each (pi_k, w_k) becomes one inter-server transfer stage in which server i
sends exactly w_k bytes to server pi_k(i) -- one sender per receiver (incast
free) and equal sizes within the stage (straggler free).  The classic bound
guarantees at most n^2 - 2n + 2 stages.

All of this runs on the host in NumPy: the paper's deployment (Fig 10) runs
the scheduler on a CPU control thread per iteration, and synthesis time is one
of the two evaluation axes.  Hopcroft-Karp perfect matching on the positive
support keeps the whole decomposition at O(n^4.5) worst case, microseconds to
milliseconds in practice (reproduced in benchmarks/fig17_overhead.py).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Sequence

import numpy as np

__all__ = [
    "Stage",
    "pad_to_doubly_balanced",
    "hopcroft_karp",
    "birkhoff_decompose",
    "max_line_sum",
]

# Relative tolerance used to treat float residuals as zero.
_EPS_REL = 1e-9


@dataclasses.dataclass(frozen=True)
class Stage:
    """One incast-free, straggler-free inter-server transfer stage.

    perm[i] = j means server i sends to server j during this stage; -1 means
    server i idles (its matched entry was pure padding).  ``size`` is the
    stage's chunk size -- the stage lasts size/(m*B2) regardless of how much
    *real* data each slot carries.  ``sent[i]`` is the genuine byte count
    transferred by server i (<= size; the remainder of the slot is padding,
    i.e. link idle time inside the stage).
    """

    perm: tuple
    size: float
    sent: tuple

    @property
    def active(self) -> int:
        return sum(1 for j in self.perm if j >= 0)

    @property
    def real_bytes(self) -> float:
        return float(sum(self.sent))

    def as_matrix(self, n: int) -> np.ndarray:
        m = np.zeros((n, n))
        for i, j in enumerate(self.perm):
            if j >= 0:
                m[i, j] = self.sent[i]
        return m


def max_line_sum(t: np.ndarray) -> float:
    """max(max row sum, max col sum): the quantity Birkhoff preserves and the
    numerator of the paper's Theorem 1 optimal completion time."""
    return float(max(t.sum(axis=1).max(), t.sum(axis=0).max()))


def pad_to_doubly_balanced(t: np.ndarray) -> np.ndarray:
    """Return padding P >= 0 such that T + P has all row and column sums equal
    to max_line_sum(T).

    Greedy deficit pairing: repeatedly pick a row with remaining deficit and a
    column with remaining deficit and close the smaller of the two.  Each step
    zeroes at least one deficit, so it terminates in <= 2n steps.  Total row
    deficit always equals total column deficit, so both pools empty together.
    """
    t = np.asarray(t, dtype=np.float64)
    n = t.shape[0]
    if t.shape != (n, n):
        raise ValueError(f"traffic matrix must be square, got {t.shape}")
    if (t < 0).any():
        raise ValueError("traffic matrix must be nonnegative")

    target = max_line_sum(t)
    pad = np.zeros_like(t)
    row_def = target - t.sum(axis=1)
    col_def = target - t.sum(axis=0)
    rows = deque(i for i in range(n) if row_def[i] > 0)
    cols = deque(j for j in range(n) if col_def[j] > 0)
    while rows and cols:
        i, j = rows[0], cols[0]
        amt = min(row_def[i], col_def[j])
        pad[i, j] += amt
        row_def[i] -= amt
        col_def[j] -= amt
        if row_def[i] <= target * _EPS_REL:
            rows.popleft()
        if col_def[j] <= target * _EPS_REL:
            cols.popleft()
    return pad


def hopcroft_karp(adj: Sequence[Sequence[int]], n_right: int) -> List[int]:
    """Maximum bipartite matching via Hopcroft-Karp, O(E * sqrt(V)).

    adj[u] lists right-vertices reachable from left-vertex u.  Returns
    match_left where match_left[u] is the matched right vertex (or -1).
    """
    n_left = len(adj)
    INF = float("inf")
    match_l = [-1] * n_left
    match_r = [-1] * n_right
    dist = [0.0] * n_left

    def bfs() -> bool:
        q = deque()
        for u in range(n_left):
            if match_l[u] == -1:
                dist[u] = 0
                q.append(u)
            else:
                dist[u] = INF
        found = False
        while q:
            u = q.popleft()
            for v in adj[u]:
                w = match_r[v]
                if w == -1:
                    found = True
                elif dist[w] == INF:
                    dist[w] = dist[u] + 1
                    q.append(w)
        return found

    def dfs(u: int) -> bool:
        for v in adj[u]:
            w = match_r[v]
            if w == -1 or (dist[w] == dist[u] + 1 and dfs(w)):
                match_l[u] = v
                match_r[v] = u
                return True
        dist[u] = INF
        return False

    while bfs():
        for u in range(n_left):
            if match_l[u] == -1:
                dfs(u)
    return match_l


def birkhoff_decompose(
    t: np.ndarray,
    *,
    sort_ascending: bool = True,
    coalesce: bool = True,
) -> List[Stage]:
    """Decompose a nonnegative square traffic matrix into Birkhoff stages.

    Args:
      t: (n, n) nonnegative matrix of inter-server byte counts.  The diagonal
        (intra-server traffic) must be zero -- FLASH handles it separately by
        overlapping it with the first inter-server stage.
      sort_ascending: execute stages in ascending size order so each stage's
        intra-server redistribute (over B1) hides under the *next* stage's
        inter-server transfer (over B2); see the Theorem 2 pipelining argument.
      coalesce: merge consecutive stages that share an identical permutation
        support (reduces stage count, whose minimization is NP-hard [20] --
        this is the cheap 80 percent).

    Returns:
      List of Stage.  sum_k stage_k.as_matrix upper-bounds T elementwise and
      matches it exactly on the support of T (padding shows up as idle slots,
      perm[i] == -1, never as real traffic).
    """
    t = np.asarray(t, dtype=np.float64).copy()
    n = t.shape[0]
    if n == 0:
        return []
    if np.abs(np.diag(t)).max(initial=0.0) > 0:
        raise ValueError("diagonal (intra-server) traffic must be zero")
    total = max_line_sum(t)
    if total <= 0:
        return []
    eps = total * _EPS_REL

    work = t + pad_to_doubly_balanced(t)
    real = t  # mutated alongside `work` to track genuine remaining bytes

    stages: List[Stage] = []
    # Each iteration removes at least one nonzero entry of `work`, and `work`
    # starts with at most n^2 nonzeros: classic <= n^2 - 2n + 2 stage bound.
    for _ in range(n * n + 2 * n):
        if work.max() <= eps:
            break
        adj = [[j for j in range(n) if work[i, j] > eps] for i in range(n)]
        match = hopcroft_karp(adj, n)
        if any(m == -1 for m in match):
            # Can only happen through float erosion of an almost-zero line;
            # route remaining mass greedily and stop.
            _greedy_drain(real, stages, eps)
            break
        w = min(work[i, match[i]] for i in range(n))
        perm = []
        sent = []
        for i in range(n):
            j = match[i]
            work[i, j] -= w
            if real[i, j] > eps:
                amt = min(real[i, j], w)
                real[i, j] -= amt
                perm.append(j)
                sent.append(float(amt))
            else:
                perm.append(-1)  # padding-only slot: server i idles
                sent.append(0.0)
        stages.append(Stage(perm=tuple(perm), size=float(w), sent=tuple(sent)))
    else:  # pragma: no cover - loop bound is a mathematical guarantee
        raise RuntimeError("Birkhoff decomposition failed to terminate")

    if coalesce:
        stages = _coalesce(stages)
    if sort_ascending:
        stages.sort(key=lambda s: s.size)
    return stages


def _coalesce(stages: List[Stage]) -> List[Stage]:
    merged: dict = {}
    order: List[tuple] = []
    for s in stages:
        if s.perm in merged:
            size, sent = merged[s.perm]
            merged[s.perm] = (size + s.size,
                              tuple(a + b for a, b in zip(sent, s.sent)))
        else:
            merged[s.perm] = (s.size, s.sent)
            order.append(s.perm)
    return [Stage(perm=p, size=merged[p][0], sent=merged[p][1])
            for p in order]


def _greedy_drain(real: np.ndarray, stages: List[Stage], eps: float) -> None:
    """Fallback for pathological float residue: one stage per remaining entry."""
    n = real.shape[0]
    idx = np.argwhere(real > eps)
    for i, j in idx:
        perm = [-1] * n
        sent = [0.0] * n
        perm[int(i)] = int(j)
        sent[int(i)] = float(real[i, j])
        stages.append(Stage(perm=tuple(perm), size=float(real[i, j]),
                            sent=tuple(sent)))
        real[i, j] = 0.0
