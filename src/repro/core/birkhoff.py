"""Birkhoff-von Neumann decomposition of a server-level traffic matrix.

The heart of FLASH's inter-server stage synthesis (paper section 4.2): an
arbitrary nonnegative n x n traffic matrix T is padded to a matrix with equal
row and column sums ("doubly stochastic" up to scale) and decomposed into a
sum of scaled permutation matrices

    T + P = sum_k  w_k * Perm(pi_k)

Each (pi_k, w_k) becomes one inter-server transfer stage in which server i
sends exactly w_k bytes to server pi_k(i) -- one sender per receiver (incast
free) and equal sizes within the stage (straggler free).  The classic bound
guarantees at most n^2 - 2n + 2 stages.

All of this runs on the host: the paper's deployment (Fig 10) runs the
scheduler on a CPU control thread per iteration, and synthesis time is one of
the two evaluation axes.  Three engines share one stage loop whose float math
(stage weight, subtraction, ``sent`` extraction) is fancy-indexed NumPy; they
differ in how the per-stage perfect matching is obtained:

  * ``policy="exact"`` -- *bit-identical* to the reference.  The positive
    support's adjacency lists are maintained incrementally (stage
    subtraction only ever zeroes matched entries, so a handful of removals
    per stage replaces the reference's O(n^2) per-stage rebuild), and the
    matching Hopcroft-Karp's first phase would build from scratch -- a
    first-fit greedy -- is maintained incrementally under those removals.
    When the greedy is imperfect, the exact Hopcroft-Karp augmentation
    phases run from it, which by construction reproduces the from-scratch
    result (see below).
  * ``policy="repair"`` -- the scale engine.  The previous stage's perfect
    matching stays near-perfect after subtraction (only its own entries can
    hit zero), so it is repaired with augmenting-path searches from the few
    unmatched rows instead of re-running Hopcroft-Karp from scratch:
    amortized O(n * E) over the whole decomposition instead of O(E sqrt(V))
    per stage.  Stage lists are equally valid (same makespan = max line
    sum, same stage bound, incast-free) but not bit-identical to the
    reference -- property-tested rather than golden-tested.
  * ``reference=True`` -- the original interpreted loop (per-stage adjacency
    rebuild, from-scratch Hopcroft-Karp, entry-by-entry updates), kept as
    the golden oracle for the exact engine's identity tests.

``policy="auto"`` (the default) selects "exact" up to ``AUTO_EXACT_MAX_N``
servers -- covering every golden-parity workload and the paper's testbed
scale, so default callers keep seed-identical plans -- and "repair" beyond,
where synthesis speed is the binding constraint (ROADMAP north star) and no
stage list is pinned.

Capacity-aware synthesis (``capacity_aware=True`` with a ``topology=``): on
a heterogeneous fabric the equal-byte-slot stage is no longer
straggler-free -- a slow server pair stretches every stage it rides while
fast pairs idle out their slots.  The aware mode therefore decomposes the
*time* matrix ``tau = T / pair_capacity`` (DESIGN.md section 1d): a stage of
time-weight ``w`` gives pair (i, j) a byte slot of ``w *
pair_capacity(i, j)``, so every pair in the stage drains in the same
``w``-second window (equal-*time* slots, the heterogeneous generalization
of straggler freedom), and both matching engines prefer high-capacity
edges (per-row adjacency ordered by descending ``min``-endpoint capacity;
the exact engine's first-fit tie-breaks and the repair engine's
augmenting-path searches follow that order).  Stages sort ascending by
*duration*, which is what the Theorem 2 pipelining argument needs --
low-capacity pairs automatically ride the small byte slots.  The
capacity-blind path is bit-identical to before: ``capacity_aware=False``
never looks at the topology, and a uniform-capacity fabric degenerates to
the blind decomposition exactly.

Why "exact" can be incremental: Hopcroft-Karp's first BFS/DFS phase on an
empty matching is exactly a first-fit greedy (row u takes the smallest free
column of its adjacency; no augmentation happens because every ``dist`` is
0), and that greedy matching is uniquely characterized by the invariant

    pick[i] = min { j in adj(i) : inv[j] == -1 or inv[j] >= i }     (or -1)

so *any* procedure restoring the invariant after edge deletions lands on the
matching the reference would recompute from scratch; the subsequent
augmentation phases are then a deterministic function of (support, greedy
matching) and can be replayed verbatim.  tests/test_birkhoff.py holds the
stage-list-identity property test against the reference engine.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..analysis.locks import check_forbidden, make_lock

__all__ = [
    "Stage",
    "StageBlock",
    "DecompositionState",
    "pad_to_doubly_balanced",
    "hopcroft_karp",
    "birkhoff_decompose",
    "effective_pair_caps",
    "max_line_sum",
    "live_slots",
    "live_slots_batch",
    "stage_duration",
    "AUTO_EXACT_MAX_N",
]

# Relative tolerance used to treat float residuals as zero.
_EPS_REL = 1e-9

# policy="auto" runs the bit-identical exact engine up to this many servers
# (the golden suite and the paper's testbed all sit well below it) and the
# repair engine beyond, where synthesis latency dominates.
AUTO_EXACT_MAX_N = 32


@dataclasses.dataclass(frozen=True)
class Stage:
    """One incast-free, straggler-free inter-server transfer stage.

    perm[i] = j means server i sends to server j during this stage; -1 means
    server i idles (its matched entry was pure padding).  ``size`` is the
    stage's chunk size -- the stage lasts size/(m*B2) regardless of how much
    *real* data each slot carries.  ``sent[i]`` is the genuine byte count
    transferred by server i (<= size; the remainder of the slot is padding,
    i.e. link idle time inside the stage).

    ``slots`` is None for capacity-blind stages (every sender's slot is the
    uniform ``size`` bytes).  Capacity-aware stages carry per-sender slot
    sizes instead: slot i is ``w * pair_capacity(i, perm[i])`` bytes for
    the stage's time-weight ``w``, so all pairs drain in the same window;
    ``size`` is then the largest slot (``sent[i] <= slots[i] <= size``).
    """

    perm: tuple
    size: float
    sent: tuple
    slots: Optional[tuple] = None

    def __post_init__(self):
        if len(self.perm) != len(self.sent):
            raise ValueError(
                f"perm has {len(self.perm)} slots but sent has "
                f"{len(self.sent)} entries; one genuine-byte count per slot")
        if self.slots is not None and len(self.slots) != len(self.perm):
            raise ValueError(
                f"perm has {len(self.perm)} slots but slots has "
                f"{len(self.slots)} entries; one slot size per sender")

    @property
    def active(self) -> int:
        return sum(1 for j in self.perm if j >= 0)

    @property
    def real_bytes(self) -> float:
        return float(sum(self.sent))

    def as_matrix(self, n: int) -> np.ndarray:
        m = np.zeros((n, n))
        perm = np.asarray(self.perm, dtype=np.int64)
        live = perm >= 0
        m[np.flatnonzero(live), perm[live]] = np.asarray(
            self.sent, dtype=np.float64)[live]
        return m


def max_line_sum(t: np.ndarray) -> float:
    """max(max row sum, max col sum): the quantity Birkhoff preserves and the
    numerator of the paper's Theorem 1 optimal completion time."""
    return float(max(t.sum(axis=1).max(), t.sum(axis=0).max()))


def pad_to_doubly_balanced(t: np.ndarray) -> np.ndarray:
    """Return padding P >= 0 such that T + P has all row and column sums equal
    to max_line_sum(T).

    Greedy deficit pairing: repeatedly pick a row with remaining deficit and a
    column with remaining deficit and close the smaller of the two.  Each step
    zeroes at least one deficit, so it terminates in <= 2n steps.  Total row
    deficit always equals total column deficit, so both pools empty together.
    """
    t = np.asarray(t, dtype=np.float64)
    n = t.shape[0]
    if t.shape != (n, n):
        raise ValueError(f"traffic matrix must be square, got {t.shape}")
    if (t < 0).any():
        raise ValueError("traffic matrix must be nonnegative")

    target = max_line_sum(t)
    pad = np.zeros_like(t)
    row_def = target - t.sum(axis=1)
    col_def = target - t.sum(axis=0)
    rows = deque(i for i in range(n) if row_def[i] > 0)
    cols = deque(j for j in range(n) if col_def[j] > 0)
    while rows and cols:
        i, j = rows[0], cols[0]
        amt = min(row_def[i], col_def[j])
        pad[i, j] += amt
        row_def[i] -= amt
        col_def[j] -= amt
        if row_def[i] <= target * _EPS_REL:
            rows.popleft()
        if col_def[j] <= target * _EPS_REL:
            cols.popleft()
    return pad


def hopcroft_karp(adj: Sequence[Sequence[int]], n_right: int) -> List[int]:
    """Maximum bipartite matching via Hopcroft-Karp, O(E * sqrt(V)).

    adj[u] lists right-vertices reachable from left-vertex u.  Returns
    match_left where match_left[u] is the matched right vertex (or -1).
    """
    n_left = len(adj)
    match_l = [-1] * n_left
    match_r = [-1] * n_right
    _augment_phases(adj, match_l, match_r)
    return match_l


def _augment_phases(adj: Sequence[Sequence[int]], match_l: List[int],
                    match_r: List[int]) -> None:
    """Hopcroft-Karp's BFS/DFS phases, in place, from any starting matching.

    This is the reference algorithm's main loop verbatim.  Started from an
    empty matching it *is* ``hopcroft_karp``; started from the first-fit
    greedy matching it reproduces the from-scratch result bit-for-bit,
    because the from-scratch run's first phase builds exactly that greedy
    (all ``dist`` are 0, so no augmentation can happen) and every later
    phase is a deterministic function of (support, current matching).
    """
    n_left = len(adj)
    INF = float("inf")
    dist = [0.0] * n_left

    def bfs() -> bool:
        q = deque()
        for u in range(n_left):
            if match_l[u] == -1:
                dist[u] = 0
                q.append(u)
            else:
                dist[u] = INF
        found = False
        while q:
            u = q.popleft()
            for v in adj[u]:
                w = match_r[v]
                if w == -1:
                    found = True
                elif dist[w] == INF:
                    dist[w] = dist[u] + 1
                    q.append(w)
        return found

    def dfs(u: int) -> bool:
        for v in adj[u]:
            w = match_r[v]
            if w == -1 or (dist[w] == dist[u] + 1 and dfs(w)):
                match_l[u] = v
                match_r[v] = u
                return True
        dist[u] = INF
        return False

    while bfs():
        for u in range(n_left):
            if match_l[u] == -1:
                dfs(u)


# -- incremental matching machinery ----------------------------------------

class _CanonicalGreedy:
    """First-fit greedy matching maintained incrementally (exact engine).

    ``pick[i]`` is row i's matched column (-1 if unmatched), ``inv`` the
    inverse map.  The state always satisfies the first-fit invariant (module
    docstring), which uniquely pins it to the matching Hopcroft-Karp's first
    phase would build from scratch on the current support.  ``delete_edges``
    restores the invariant after a stage subtraction zeroes matched entries:
    an affected row re-picks the smallest column that is free, kept, or
    owned by a larger row (stealing makes the victim re-pick), a freed
    column is re-offered to the smallest row that prefers it, and taking a
    column pushes any smaller claimant so it can steal back.  Cascades are
    short in practice: each steal strictly shrinks the thief's pick.

    ``rank`` generalizes "smallest column" to an arbitrary per-row
    preference order (capacity-aware synthesis: ``row_adj`` comes sorted by
    descending pair capacity and ``rank[i, j]`` is column j's position in
    row i's order).  ``rank=None`` keeps the original ascending-index
    comparisons bit-for-bit -- the blind path never allocates or consults a
    rank matrix.  Row order (whose first-fit turn comes first) stays the
    ascending row index in both modes, so ``col_adj`` stays row-sorted.
    """

    def __init__(self, row_adj: List[List[int]], col_adj: List[List[int]],
                 rank: Optional[np.ndarray] = None):
        self.row_adj = row_adj  # shared with the stage loop, pruned there
        self.col_adj = col_adj
        self.rank = rank
        n = len(row_adj)
        self.pick = [-1] * n
        self.inv = [-1] * n
        free = [True] * n
        for i in range(n):
            for j in row_adj[i]:
                if free[j]:
                    self.pick[i] = j
                    self.inv[j] = i
                    free[j] = False
                    break
        self.n_unmatched = sum(1 for p in self.pick if p == -1)

    @property
    def perfect(self) -> bool:
        return self.n_unmatched == 0

    def delete_edges(self, pairs) -> None:
        """Re-establish the invariant after ``pairs`` left the support.

        Only deletions of *currently picked* edges matter: an unpicked edge
        (i, j) with j < pick[i] was already owned by a smaller row (that is
        the invariant), so removing it cannot change any first-fit choice.
        """
        heap: List[int] = []
        freed: List[int] = []
        pick, inv = self.pick, self.inv
        for i, j in pairs:
            if pick[i] == j:
                pick[i] = -1
                inv[j] = -1
                self.n_unmatched += 1
                heapq.heappush(heap, i)
                freed.append(j)
        self._drain(heap, freed)

    def _prefers(self, y: int, a: int, b: int) -> bool:
        """Does row y rank column a strictly before column b (b != -1)?"""
        if self.rank is None:
            return a < b
        return self.rank[y, a] < self.rank[y, b]

    def _drain(self, heap: List[int], freed: List[int]) -> None:
        row_adj, col_adj = self.row_adj, self.col_adj
        pick, inv = self.pick, self.inv
        while heap or freed:
            if heap:
                x = heapq.heappop(heap)
                # Canonical re-pick: smallest column free, kept, or owned by
                # a larger row (first-fit reaches it before that row's turn).
                new = -1
                for c in row_adj[x]:
                    o = inv[c]
                    if o == -1 or o >= x:
                        new = c
                        break
                old = pick[x]
                if new == old:
                    continue
                if old != -1:
                    inv[old] = -1
                    freed.append(old)
                else:
                    self.n_unmatched -= 1
                pick[x] = new
                if new == -1:
                    self.n_unmatched += 1
                    continue
                r = inv[new]
                if r != -1:  # steal from the larger row; it re-picks
                    pick[r] = -1
                    self.n_unmatched += 1
                    heapq.heappush(heap, r)
                inv[new] = x
                # Claimant check: a smaller row whose first-fit turn came
                # before x's may canonically own `new`; push it so it can
                # steal back.
                for y in col_adj[new]:
                    if y >= x:
                        break
                    p = pick[y]
                    if p == -1 or self._prefers(y, new, p):
                        heapq.heappush(heap, y)
                        break
                continue
            j = freed.pop()
            if inv[j] != -1:
                continue
            # Smallest row that would have taken j at its first-fit turn.
            for y in self.col_adj[j]:
                p = pick[y]
                if p == -1 or self._prefers(y, j, p):
                    heapq.heappush(heap, y)
                    # Re-offer until someone takes it: y's re-pick may
                    # settle on a smaller column, which removes y from j's
                    # candidate set -- strict progress.
                    freed.append(j)
                    break


def _kuhn_augment(row_adj: List[List[int]], mask: np.ndarray,
                  match_l: List[int], match_r: List[int], root: int,
                  free_cols: List[int]) -> bool:
    """One augmenting-path search from unmatched ``root`` (repair engine).

    The matching was perfect before this stage's subtraction, so the only
    free columns are the just-zeroed ones (``free_cols``, typically one):
    every expanded row first O(1)-tests its mask entry against those targets
    instead of discovering a free column by scanning, which keeps paths a
    couple of hops long.  Iterative DFS (paths can still be ~n long in the
    eroded endgame; no recursion limit risk); on success the path is flipped
    into the matching in place.
    """
    visited = bytearray(len(match_r))
    stack = [root]
    iters = [iter(row_adj[root])]
    down_col = [-1]  # column each stacked row used to descend

    def finish(x: int, c: int) -> None:
        # Augment: x takes c; every ancestor takes its descent column.
        match_l[x] = c
        match_r[c] = x
        for d in range(len(stack) - 1, 0, -1):
            r, cc = stack[d - 1], down_col[d]
            match_l[r] = cc
            match_r[cc] = r

    while stack:
        x = stack[-1]
        for f in free_cols:
            if match_r[f] == -1 and mask[x, f]:
                finish(x, f)
                return True
        descended = False
        for c in iters[-1]:
            if visited[c]:
                continue
            visited[c] = 1
            o = match_r[c]
            if o == -1:  # safety net: a free column outside free_cols
                finish(x, c)
                return True
            stack.append(o)
            iters.append(iter(row_adj[o]))
            down_col.append(c)
            descended = True
            break
        if not descended:
            stack.pop()
            iters.pop()
            down_col.pop()
    return False


# -- decomposition engines -------------------------------------------------

def birkhoff_decompose(
    t: np.ndarray,
    *,
    sort_ascending: bool = True,
    coalesce: bool = True,
    reference: bool = False,
    policy: str = "auto",
    topology=None,
    capacity_aware: bool = False,
) -> List[Stage]:
    """Decompose a nonnegative square traffic matrix into Birkhoff stages.

    Args:
      t: (n, n) nonnegative matrix of inter-server byte counts.  The diagonal
        (intra-server traffic) must be zero -- FLASH handles it separately by
        overlapping it with the first inter-server stage.
      sort_ascending: execute stages in ascending size order so each stage's
        intra-server redistribute (over B1) hides under the *next* stage's
        inter-server transfer (over B2); see the Theorem 2 pipelining argument.
        Capacity-aware stages sort by *duration* instead of byte size --
        the quantity the pipelining argument actually needs.
      coalesce: merge consecutive stages that share an identical permutation
        support (reduces stage count, whose minimization is NP-hard [20] --
        this is the cheap 80 percent).
      reference: run the original interpreted engine (per-stage adjacency
        rebuild + from-scratch Hopcroft-Karp) instead of an incremental one.
        Bit-identical to policy="exact"; the golden oracle for tests, O(n)
        times slower.  Overrides ``policy``.
      policy: "exact" (bit-identical to the reference, incremental greedy +
        replayed augmentation), "repair" (previous stage's perfect matching
        patched by augmenting paths; fastest, equally valid but different
        stage lists), or "auto" (exact up to AUTO_EXACT_MAX_N servers,
        repair beyond -- see module docstring).
      topology: the fabric whose ``pair_capacity()`` weights the
        capacity-aware decomposition.  Required (and only consulted) when
        ``capacity_aware=True``.
      capacity_aware: decompose the time matrix ``t / pair_capacity``
        instead of the byte matrix, emitting per-sender byte ``slots``
        proportional to pair capacity so every pair of a stage drains in
        the same window, with both matching engines preferring
        high-capacity edges (module docstring).  On a uniform-capacity
        fabric this degenerates to the blind decomposition exactly.

    Returns:
      List of Stage.  sum_k stage_k.as_matrix upper-bounds T elementwise and
      matches it exactly on the support of T (padding shows up as idle slots,
      perm[i] == -1, never as real traffic).
    """
    check_forbidden("birkhoff_decompose")
    t = np.asarray(t, dtype=np.float64).copy()
    n = t.shape[0]
    if n == 0:
        return []
    if np.abs(np.diag(t)).max(initial=0.0) > 0:
        raise ValueError("diagonal (intra-server) traffic must be zero")

    if capacity_aware:
        if reference:
            raise ValueError(
                "the reference oracle is capacity-blind; drop reference=True "
                "or capacity_aware=True")
        caps = _pair_caps(topology, n)
        offdiag = caps[~np.eye(n, dtype=bool)]  # empty for n == 1: uniform
        if offdiag.size and not np.all(offdiag == offdiag.flat[0]):
            return _capacity_aware_stages(t, caps, n, sort_ascending,
                                          coalesce, policy)
        # Uniform pair capacity: time and byte domains coincide up to one
        # global scale, so fall through to the blind path (bit-identical
        # stages, no redundant slots carried).

    total = max_line_sum(t)
    if total <= 0:
        return []
    eps = total * _EPS_REL

    work = t + pad_to_doubly_balanced(t)
    real = t  # mutated alongside `work` to track genuine remaining bytes

    if reference:
        stages = _reference_stages(work, real, n, eps)
    else:
        stages = _incremental_stages(work, real, n, eps,
                                     _resolve_policy(policy, n))

    if coalesce:
        stages = _coalesce(stages)
    if sort_ascending:
        stages.sort(key=lambda s: s.size)
    return stages


def _resolve_policy(policy: str, n: int) -> str:
    if policy == "auto":
        policy = "exact" if n <= AUTO_EXACT_MAX_N else "repair"
    if policy not in ("exact", "repair"):
        raise ValueError(
            f"unknown policy {policy!r}; pick from auto/exact/repair")
    return policy


def _pair_caps(topology, n: int) -> np.ndarray:
    if topology is None:
        raise ValueError("capacity_aware=True requires topology=")
    if topology.n_servers != n:
        raise ValueError(
            f"topology has {topology.n_servers} servers but the traffic "
            f"matrix is {n}x{n}")
    return topology.pair_capacity()


def effective_pair_caps(caps: np.ndarray) -> np.ndarray:
    """Pair capacities as the time-domain decomposition consumes them.

    A fully disconnected pair can never drain -- keep it schedulable (the
    executor charges infinity) by converting at the slowest live capacity.
    The diagonal is forced to 1.0; it is never consulted because traffic
    matrices carry a zero diagonal.
    """
    n = caps.shape[0]
    off = ~np.eye(n, dtype=bool)
    pos = caps[off & (caps > 0)]
    fallback = float(pos.min()) if pos.size else 1.0
    caps_eff = np.where(caps > 0, caps, fallback)
    np.fill_diagonal(caps_eff, 1.0)
    return caps_eff


def _capacity_pref_rank(caps_eff: np.ndarray) -> np.ndarray:
    """Per-row preference: descending pair capacity, ascending index on ties
    (stable argsort), so uniform-capacity rows keep first-fit order."""
    n = caps_eff.shape[0]
    order = np.argsort(-caps_eff, axis=1, kind="stable")
    rank = np.empty((n, n), dtype=np.int64)
    np.put_along_axis(rank, order, np.broadcast_to(np.arange(n), (n, n)),
                      axis=1)
    return rank


def _capacity_aware_stages(t: np.ndarray, caps: np.ndarray, n: int,
                           sort_ascending: bool, coalesce: bool,
                           policy: str) -> List[Stage]:
    """Time-domain decomposition: stages of tau = t / pair_capacity, matched
    with high-capacity-first preference, converted back to byte slots."""
    caps_eff = effective_pair_caps(caps)

    tau = t / caps_eff
    total = max_line_sum(tau)
    if total <= 0:
        return []
    eps = total * _EPS_REL
    work = tau + pad_to_doubly_balanced(tau)

    rank = _capacity_pref_rank(caps_eff)

    stages = _incremental_stages(work, tau, n, eps,
                                 _resolve_policy(policy, n), pref_rank=rank)
    if coalesce:
        stages = _coalesce(stages)
    if sort_ascending:
        stages.sort(key=lambda s: s.size)  # time units: ascending durations
    out = []
    for s in stages:
        byte_stage = _stage_to_bytes(s, caps_eff, n)
        if byte_stage is not None:  # padding-only stages carry nothing
            out.append(byte_stage)
    return out


def live_slots(perm, slots, size: float):
    """Shared slot-extraction idiom: ``(src, dst, slot)`` for a stage's
    live senders -- their row indices, destinations, and per-sender slot
    bytes (the uniform ``size`` when ``slots`` is None).  Used by the
    executor, the validator and the duration helpers so slot semantics
    live in one place."""
    perm = np.asarray(perm, dtype=np.int64)
    src = np.flatnonzero(perm >= 0)
    dst = perm[src]
    slot = (np.asarray(slots, dtype=np.float64)[src] if slots is not None
            else np.full(src.size, float(size)))
    return src, dst, slot


def live_slots_batch(perms, slots):
    """Batched ``live_slots`` over ``S`` stacked stages.

    Args:
      perms: (S, n) int array of stage permutations (-1 = idle sender).
      slots: (S, n) float array of per-sender slot bytes; the caller fills
        capacity-blind rows with the stage's uniform ``size``.

    Returns ``(mask, dst, slot)``: the (S, n) live-sender mask, the
    destination indices clipped to 0 where idle (safe for fancy indexing),
    and the slot bytes zeroed where idle -- so downstream vectorized math
    can run over the full padded arrays with dead senders contributing
    exactly nothing.  This is the compile-time counterpart of the
    per-stage ``live_slots`` idiom (used by the plan compiler in
    simulator.py to time all permutation stages in one pass).
    """
    perms = np.asarray(perms, dtype=np.int64)
    mask = perms >= 0
    dst = np.where(mask, perms, 0)
    slot = np.where(mask, np.asarray(slots, dtype=np.float64), 0.0)
    return mask, dst, slot


def _stage_to_bytes(s: Stage, caps: np.ndarray, n: int) -> Optional[Stage]:
    """Convert one time-domain stage (weight w seconds) into byte slots:
    pair (i, j) gets a ``w * caps[i, j]``-byte slot, so every pair drains
    in the same w-second window."""
    perm = np.asarray(s.perm, dtype=np.int64)
    rows = np.flatnonzero(perm >= 0)
    if rows.size == 0:
        return None
    c = caps[rows, perm[rows]]
    slots = np.zeros(n)
    slots[rows] = s.size * c
    sent = np.zeros(n)
    sent[rows] = np.asarray(s.sent, dtype=np.float64)[rows] * c
    return Stage(perm=s.perm, size=float(slots.max(initial=0.0)),
                 sent=tuple(sent.tolist()), slots=tuple(slots.tolist()))


def stage_duration(stage: Stage, caps: np.ndarray) -> float:
    """Seconds a stage occupies on the fabric whose pair capacities are
    ``caps``: the slowest live pair's slot over its capacity.  Uniform
    ``size``-byte slots when the stage carries no per-sender slots."""
    src, dst, slot = live_slots(stage.perm, stage.slots, stage.size)
    if src.size == 0:
        return 0.0
    c = caps[src, dst]
    out = np.full(src.size, np.inf)
    np.divide(slot, c, out=out, where=c > 0)
    out[(c <= 0) & (slot <= 0)] = 0.0
    return float(out.max(initial=0.0))


def _incremental_stages(work: np.ndarray, real: np.ndarray, n: int,
                        eps: float, policy: str,
                        pref_rank: Optional[np.ndarray] = None,
                        init_match: Optional[List[int]] = None,
                        seed_out: Optional[List[List[int]]] = None
                        ) -> List[Stage]:
    """Shared vectorized stage loop for the exact and repair engines.

    Per stage, the float math is pure NumPy fancy indexing; the support's
    adjacency lists shrink incrementally (only matched entries can hit
    zero); the two policies differ solely in how the next perfect matching
    is obtained from the previous one.  ``pref_rank`` (capacity-aware
    synthesis) orders each row's adjacency by the given per-row preference
    instead of ascending column index, which steers both engines' matching
    choices toward high-capacity edges; None keeps the original order
    bit-for-bit.

    ``init_match`` warm-seeds the repair engine's first matching: edges of a
    previous decomposition's perfect matching that still lie on the current
    support are adopted, and only the rows they no longer cover pay
    augmenting-path searches -- the "targeted at changed rows/cols" half of
    incremental trajectory synthesis (DecompositionState).  ``seed_out``,
    when given, receives that first perfect matching (one append) so the
    caller can carry it to the next delta.  Both are ignored by the exact
    engine, whose matching is pinned by the first-fit invariant.
    """
    mask = work > eps
    if pref_rank is None:
        row_adj: List[List[int]] = [np.flatnonzero(mask[i]).tolist()
                                    for i in range(n)]
    else:
        row_adj = []
        for i in range(n):
            cols = np.flatnonzero(mask[i])
            row_adj.append(
                cols[np.argsort(pref_rank[i, cols], kind="stable")].tolist())
    col_adj: List[List[int]] = [np.flatnonzero(mask[:, j]).tolist()
                                for j in range(n)]
    nnz = int(mask.sum())

    exact = policy == "exact"
    greedy: Optional[_CanonicalGreedy] = None
    match_l: List[int] = []
    match_r: List[int] = []
    n_free = 0  # unmatched rows of the maintained matching (repair engine)
    if exact:
        greedy = _CanonicalGreedy(row_adj, col_adj, rank=pref_rank)
    else:
        # Repair engine: one full matching up front, patched ever after.
        match_l = [-1] * n
        match_r = [-1] * n
        if init_match is not None:
            # Adopt surviving edges of the carried matching; the augment
            # phases below only have to repair the rows that lost theirs.
            for i, j in enumerate(init_match):
                if 0 <= j < n and mask[i, j] and match_r[j] == -1:
                    match_l[i] = j
                    match_r[j] = i
        _augment_phases(row_adj, match_l, match_r)
        n_free = sum(1 for m in match_l if m == -1)
        if seed_out is not None:
            seed_out.append(list(match_l))

    rows = np.arange(n)
    stages: List[Stage] = []
    # Each iteration removes at least one nonzero entry of `work`, and `work`
    # starts with at most n^2 nonzeros: classic <= n^2 - 2n + 2 stage bound.
    for _ in range(n * n + 2 * n):
        if nnz == 0:  # mask mirrors (work > eps): same stop condition
            break
        imperfect = False
        if exact:
            if greedy.perfect:
                match = greedy.pick
            else:
                match = list(greedy.pick)
                inv = list(greedy.inv)
                _augment_phases(row_adj, match, inv)
                imperfect = any(m < 0 for m in match)
        else:
            match = match_l
            imperfect = n_free > 0
        if imperfect:
            # Can only happen through float erosion of an almost-zero line;
            # route remaining mass greedily and stop.
            _greedy_drain(real, stages, eps)
            break
        match_arr = np.array(match, dtype=np.int64)
        vals = work[rows, match_arr]
        w = float(vals.min())
        newvals = vals - w
        work[rows, match_arr] = newvals
        zero = newvals <= eps

        rvals = real[rows, match_arr]
        has_real = rvals > eps
        amt = np.where(has_real, np.minimum(rvals, w), 0.0)
        real[rows, match_arr] = rvals - amt
        perm = np.where(has_real, match_arr, -1)
        stages.append(Stage(perm=tuple(perm.tolist()), size=w,
                            sent=tuple(amt.tolist())))

        zr, zc = rows[zero], match_arr[zero]
        mask[zr, zc] = False
        pairs = list(zip(zr.tolist(), zc.tolist()))
        for i, j in pairs:
            row_adj[i].remove(j)
            col_adj[j].remove(i)
        nnz -= len(pairs)
        if nnz == 0:
            break
        if exact:
            greedy.delete_edges(pairs)
        else:
            # The zeroed entries are the matching's own edges: unmatch those
            # rows, then re-match each with one augmenting-path search
            # targeted at the just-freed columns.
            for i, j in pairs:
                match_l[i] = -1
                match_r[j] = -1
            free_cols = [j for _, j in pairs]
            for i, _ in pairs:
                if match_l[i] == -1 and \
                        not _kuhn_augment(row_adj, mask, match_l, match_r,
                                          i, free_cols):
                    # Float erosion can strand a row even though mass
                    # remains; one from-scratch rebuild confirms before the
                    # drain fallback triggers at the top of the next pass.
                    _augment_phases(row_adj, match_l, match_r)
                    break
            n_free = sum(1 for m in match_l if m == -1) \
                if any(match_l[i] == -1 for i, _ in pairs) else 0
    else:  # pragma: no cover - loop bound is a mathematical guarantee
        raise RuntimeError("Birkhoff decomposition failed to terminate")
    return stages


def _reference_stages(work: np.ndarray, real: np.ndarray, n: int,
                      eps: float) -> List[Stage]:
    """The original interpreted decomposition loop (golden oracle)."""
    stages: List[Stage] = []
    for _ in range(n * n + 2 * n):
        if work.max() <= eps:
            break
        adj = [[j for j in range(n) if work[i, j] > eps] for i in range(n)]
        match = hopcroft_karp(adj, n)
        if any(m == -1 for m in match):
            # Can only happen through float erosion of an almost-zero line;
            # route remaining mass greedily and stop.
            _greedy_drain(real, stages, eps)
            break
        w = min(work[i, match[i]] for i in range(n))
        perm = []
        sent = []
        for i in range(n):
            j = match[i]
            work[i, j] -= w
            if real[i, j] > eps:
                amt = min(real[i, j], w)
                real[i, j] -= amt
                perm.append(j)
                sent.append(float(amt))
            else:
                perm.append(-1)  # padding-only slot: server i idles
                sent.append(0.0)
        stages.append(Stage(perm=tuple(perm), size=float(w), sent=tuple(sent)))
    else:  # pragma: no cover - loop bound is a mathematical guarantee
        raise RuntimeError("Birkhoff decomposition failed to terminate")
    return stages


def _coalesce(stages: List[Stage]) -> List[Stage]:
    merged: dict = {}
    order: List[tuple] = []
    for s in stages:
        if s.perm in merged:
            size, sent = merged[s.perm]
            merged[s.perm] = (size + s.size,
                              tuple(a + b for a, b in zip(sent, s.sent)))
        else:
            merged[s.perm] = (s.size, s.sent)
            order.append(s.perm)
    return [Stage(perm=p, size=merged[p][0], sent=merged[p][1])
            for p in order]


# -- incremental trajectory synthesis ---------------------------------------

@dataclasses.dataclass(frozen=True)
class StageBlock:
    """A whole stage list as stacked arrays (one emission of the
    incremental engine).

    ``perms`` is (S, n) int64 with -1 for idle senders, ``sizes`` (S,) the
    per-stage chunk sizes, ``sent`` (S, n) the genuine bytes each sender
    carries, and ``slots`` either None (capacity-blind: every live slot is
    the uniform stage size) or (S, n) per-sender slot bytes.  Stages are
    already in execution order (ascending size, or ascending duration when
    capacity-aware).  Keeping the arrays stacked is the point: a drifting
    trajectory re-emits ~n^2 stages per step, and materializing that many
    Stage/PermutationStage objects costs more than the decomposition delta
    itself.
    """

    perms: np.ndarray
    sizes: np.ndarray
    sent: np.ndarray
    slots: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return int(self.sizes.shape[0])

    def to_stages(self) -> List[Stage]:
        """Expand into per-stage objects (tests / interop, not hot paths)."""
        out: List[Stage] = []
        for k in range(len(self)):
            out.append(Stage(
                perm=tuple(self.perms[k].tolist()),
                size=float(self.sizes[k]),
                sent=tuple(self.sent[k].tolist()),
                slots=(tuple(self.slots[k].tolist())
                       if self.slots is not None else None)))
        return out


class DecompositionState:
    """Birkhoff decomposition *maintained* across a drifting trajectory.

    Instead of re-decomposing every matrix from scratch (or re-walking a
    cached ancestor's stage list in Python), the state keeps the previous
    decomposition's structure -- stage permutations, per-slot byte
    capacities, and the repair engine's last perfect matching -- and
    ``update(t_new)`` re-derives a valid stage list for the next matrix of
    the trajectory in three vectorized moves:

      1. *Refill*: every existing slot re-fills from the new matrix by a
         water-fill over each pair's slots in stage order (``take =
         clip(t_pair - prior_cap, 0, cap)`` with a segmented cumsum), so
         shrinking traffic shrinks slots in place and growing traffic
         spills into each pair's last slot, which carries ``headroom``
         extra capacity exactly to absorb drift without structural change.
      2. *Residual*: whatever the slots could not absorb is decomposed
         fresh -- but it is a sparse few-percent matrix, and the repair
         engine is warm-seeded with the previous residual's perfect
         matching (augmenting-path work only on changed rows/cols).  New
         stages join the state, so the structure tracks the trajectory.
      3. *Ratchet*: repair quality can only be audited, not guaranteed --
         cumulative drift could in principle stretch the stage list.  The
         update trips (returns no block and invalidates the state) when the
         residual fraction, live stage count, or total window length
         crosses the configured bounds; the caller then resynthesizes cold
         and builds a fresh state.  This bounds trajectory degradation by
         construction.

    One state serves one (cluster, topology, algorithm) plan family.
    ``update`` is serialized by an internal lock; callers hand the state
    from plan to plan (see FlashScheduler.try_repair_plan) so a family's
    misses chain through it.
    """

    def __init__(self, perms: np.ndarray, sent: np.ndarray, *,
                 caps_eff: Optional[np.ndarray] = None,
                 headroom: float = 0.5):
        perms = np.asarray(perms, dtype=np.int64)
        sent = np.asarray(sent, dtype=np.float64)
        if perms.ndim != 2 or perms.shape != sent.shape:
            raise ValueError(
                f"perms {perms.shape} and sent {sent.shape} must be "
                f"matching (S, n) arrays")
        self.n = int(perms.shape[1])
        self.aware = caps_eff is not None
        self.caps_eff = (np.asarray(caps_eff, dtype=np.float64)
                         if caps_eff is not None else None)
        if self.aware and self.caps_eff.shape != (self.n, self.n):
            raise ValueError("caps_eff must be (n, n)")
        self.headroom = float(headroom)
        self.invalid = False
        self.updates = 0
        self._rank = (_capacity_pref_rank(self.caps_eff)
                      if self.aware else None)
        self._res_seed: Optional[List[int]] = None
        self._take_buf: Optional[np.ndarray] = None
        self._lock = make_lock("DecompositionState._lock")
        # Slots with no byte capacity can never carry traffic; drop them at
        # ingest so the flat index stays dense.
        self._perms2d = np.where(sent > 0.0, perms, -1)
        self._capmat = np.where(sent > 0.0, sent, 0.0)
        self._build_index()

    @classmethod
    def from_stages(cls, stages: Sequence[Stage], n: int, *,
                    caps_eff: Optional[np.ndarray] = None,
                    headroom: float = 0.5) -> "DecompositionState":
        """Seed a state from a cold decomposition's stage list."""
        if len(stages) == 0:
            perms = np.full((0, n), -1, dtype=np.int64)
            sent = np.zeros((0, n))
        else:
            perms = np.array([s.perm for s in stages], dtype=np.int64)
            sent = np.array([s.sent for s in stages], dtype=np.float64)
        return cls(perms, sent, caps_eff=caps_eff, headroom=headroom)

    # -- flat slot index -----------------------------------------------------

    def _build_index(self) -> None:
        """Flatten live slots into arrays sorted by (pair, stage order).

        The water-fill needs each pair's slots contiguous and in stage
        order so an exclusive prefix sum of capacities gives every slot's
        fill threshold.  Rebuilt only when the structure changes (residual
        stages appended), never on a pure refill.
        """
        n = self.n
        stage_idx, src = np.nonzero(self._capmat > 0.0)
        dst = self._perms2d[stage_idx, src]
        pair = src * n + dst
        # Single fused-key sort (pair-major, stage-minor): one stable
        # argsort is ~3x cheaper than the equivalent two-pass lexsort.
        n_store = self._perms2d.shape[0]
        order = np.argsort(pair * n_store + stage_idx, kind="stable")
        # Everything the refill touches per update is kept in the
        # STAGE-MAJOR domain (np.nonzero is already row-major): the
        # per-slot fill thresholds need pair-contiguity only here, at
        # build time, so the water-fill cumsums run pair-major and are
        # scattered back once.  update() is then pure elementwise work on
        # these flat arrays plus one reduceat per stage -- no dense (S, n)
        # pass and no per-update permutation.
        self._sm_stage = stage_idx
        self._sm_src = src
        self._sm_flat = src * n + dst  # ravel index into t_new
        self._sm_out_flat = stage_idx * n + src  # ravel index into (S, n)
        if stage_idx.size:
            stg_cuts = np.flatnonzero(np.diff(stage_idx)) + 1
            self._stg_start = np.concatenate(([0], stg_cuts))
            self._stg_ids = stage_idx[self._stg_start]
        else:
            self._stg_start = np.zeros(0, dtype=np.int64)
            self._stg_ids = np.zeros(0, dtype=np.int64)
        # True when every stored stage owns at least one slot (the normal
        # case: stages are born with traffic): the per-stage reduceat then
        # yields sizes directly, no zeros+scatter.
        self._stg_full = self._stg_ids.size == self._perms2d.shape[0]
        self._sm_paircap = self.caps_eff[src, dst] if self.aware else None
        cap = self._capmat[stage_idx, src][order]
        pair_sorted = pair[order]
        cuts = np.flatnonzero(np.diff(pair_sorted)) + 1
        start = np.concatenate(([0], cuts))
        end = np.concatenate((cuts, [pair_sorted.size]))
        if pair_sorted.size == 0:
            start = np.zeros(0, dtype=np.int64)
            end = np.zeros(0, dtype=np.int64)
        # Headroom rides each pair's last (largest-threshold) slot: growth
        # within `headroom x pair_total` refills in place, no new stages.
        cap_fill = cap.copy()
        if start.size:
            pair_tot = np.add.reduceat(cap, start)
            cap_fill[end - 1] += self.headroom * pair_tot
        cum = np.cumsum(cap_fill)
        prior = cum - cap_fill
        if start.size:
            prior = prior - np.repeat(prior[start], end - start)
        # Scatter thresholds back to stage-major slot positions.
        self._cap_sm = np.empty_like(cap_fill)
        self._cap_sm[order] = cap_fill
        self._prior_sm = np.empty_like(prior)
        self._prior_sm[order] = prior
        # Closed-form fill totals: a water-fill delivers min(t_pair,
        # pair capacity), so the residual never needs the per-slot takes.
        self._pair_cap_tot = np.zeros((n, n))
        if start.size:
            src_first = src[order][start]
            dst_first = dst[order][start]
            self._pair_cap_tot[src_first, dst_first] = np.add.reduceat(
                cap_fill, start)

    def _append_live(self, stages: Sequence[Stage],
                     take_sm: np.ndarray) -> np.ndarray:
        """Extend the flat index with freshly decomposed residual stages,
        in place -- no full rebuild.  New stages append at the end of the
        store (small residual slivers, executed last).  The carried
        headroom stays where it is; each touched pair gains extra headroom
        on its last *new* slot, so the invariant ``pair fill capacity =
        slot bytes + headroom x pair bytes`` keeps tracking the traffic.
        Returns ``take_sm`` extended with the new slots' takes (each new
        slot carries exactly its decomposed bytes this step).
        """
        n = self.n
        n_old_stages = self._perms2d.shape[0]
        n_old_slots = take_sm.size
        perms = np.array([s.perm for s in stages], dtype=np.int64)
        sent = np.array([s.sent for s in stages], dtype=np.float64)
        live = sent > 0.0
        perms = np.where(live, perms, -1)
        self._perms2d = np.concatenate([self._perms2d, perms], axis=0)
        self._capmat = np.concatenate(
            [self._capmat, np.where(live, sent, 0.0)], axis=0)
        f_idx, src = np.nonzero(live)
        stage = n_old_stages + f_idx
        dst = perms[f_idx, src]
        flat = src * n + dst
        cap = sent[f_idx, src]
        # Water-fill thresholds: a new slot fills only after everything
        # its pair already had -- stored slots incl. their headroom, plus
        # earlier new slots of the same pair in append order.  The slot
        # count here is tiny (residual support), so a Python walk beats
        # another segmented-cumsum setup.
        prior = np.empty(cap.size)
        cap_fill = cap.copy()
        base = self._pair_cap_tot.ravel()
        added: dict = {}
        last_new: dict = {}
        for k in range(cap.size):
            p = int(flat[k])
            a = added.get(p, 0.0)
            prior[k] = base[p] + a
            added[p] = a + float(cap[k])
            last_new[p] = k
        for p, k in last_new.items():
            cap_fill[k] += self.headroom * added[p]
        for p, a in added.items():
            base[p] += a * (1.0 + self.headroom)
        self._sm_stage = np.concatenate([self._sm_stage, stage])
        self._sm_src = np.concatenate([self._sm_src, src])
        self._sm_flat = np.concatenate([self._sm_flat, flat])
        self._sm_out_flat = np.concatenate(
            [self._sm_out_flat, stage * n + src])
        self._cap_sm = np.concatenate([self._cap_sm, cap_fill])
        self._prior_sm = np.concatenate([self._prior_sm, prior])
        if stage.size:
            starts = np.concatenate(
                ([0], np.flatnonzero(np.diff(stage)) + 1))
            self._stg_start = np.concatenate(
                [self._stg_start, n_old_slots + starts])
            self._stg_ids = np.concatenate([self._stg_ids, stage[starts]])
        self._stg_full = self._stg_ids.size == self._perms2d.shape[0]
        if self.aware:
            self._sm_paircap = np.concatenate(
                [self._sm_paircap, self.caps_eff[src, dst]])
        return np.concatenate([take_sm, cap])

    # -- the delta path ------------------------------------------------------

    def update(self, t_new: np.ndarray, *,
               max_residual_fraction: float = 0.25,
               max_stage_drift: float = 2.0,
               quality_ratchet: float = 1.10
               ) -> Tuple[Optional[StageBlock], dict]:
        """Re-derive a stage list for ``t_new`` from the carried structure.

        Returns ``(block, stats)``.  ``block`` is None when a ratchet
        tripped (stats["tripped"] names which); the state is then invalid
        and the caller must resynthesize cold.  ``stats`` always carries
        ``residual_fraction`` and, on success, ``n_stages`` and
        ``quality`` (total window length over the exact lower bound).
        """
        with self._lock:
            return self._update_locked(
                np.asarray(t_new, dtype=np.float64),
                max_residual_fraction, max_stage_drift, quality_ratchet)

    def _update_locked(self, t_new, max_residual_fraction, max_stage_drift,
                       quality_ratchet):
        if self.invalid:
            raise RuntimeError(
                "DecompositionState tripped its ratchet; build a fresh one "
                "from a cold synthesis")
        n = self.n
        if t_new.shape != (n, n):
            raise ValueError(f"expected ({n}, {n}) matrix, got {t_new.shape}")
        stats: dict = {"mode": "incremental"}
        total = float(t_new.sum())

        # 1. Refill, entirely in the stage-major domain: each slot takes
        # clip(t_pair - prior, 0, cap) against its precomputed water-fill
        # thresholds -- one flat gather plus in-place elementwise ops.
        nslots = self._sm_src.size
        if nslots:
            # The takes never escape (emission scatters them into a fresh
            # block), so reuse one scratch buffer across updates.
            take_sm = self._take_buf
            if take_sm is None or take_sm.size != nslots:
                take_sm = np.empty(nslots)
                self._take_buf = take_sm
            np.take(t_new.reshape(-1), self._sm_flat, out=take_sm)
            take_sm -= self._prior_sm
            np.maximum(take_sm, 0.0, out=take_sm)
            np.minimum(take_sm, self._cap_sm, out=take_sm)
        else:
            take_sm = np.zeros(0)

        # 2. Residual: what the slots could not absorb, in closed form --
        # the water-fill delivers exactly min(t_pair, pair capacity), so
        # no per-slot reduction is needed.  Entries below the cutoff are
        # float fuzz (and far inside the validator's conservation
        # tolerance); dropping them keeps the residual support sparse.
        residual = np.maximum(t_new - self._pair_cap_tot, 0.0)
        byte_line = max_line_sum(t_new)  # shared: cutoff + quality lower
        cutoff = 1e-10 * max(byte_line, 1e-300)
        if float(residual.max(initial=0.0)) <= cutoff:
            # Fully absorbed (the steady case) -- skip the masking pass.
            res_total = 0.0
        else:
            residual[residual <= cutoff] = 0.0
            res_total = float(residual.sum())
        res_frac = res_total / total if total > 0 else 0.0
        stats["residual_fraction"] = res_frac
        if res_frac > max_residual_fraction:
            self.invalid = True
            stats["tripped"] = "residual"
            return None, stats

        if res_total > 0.0:
            fresh = self._decompose_residual(residual)
            stats["residual_stages"] = len(fresh)
            if fresh:
                # Structural change (rare on a drifting trajectory: the
                # slot headroom absorbs in-place drift): extend the flat
                # index in place -- no rebuild, no dense pass.  Appended
                # stages sit at the end of the store and execute last.
                take_sm = self._append_live(fresh, take_sm)
                nslots = take_sm.size

        # 3. Emit + ratchet audit: per-stage maxima via one flat reduceat
        # -- no dense (S, n) pass on the trajectory hot path.
        S = self._perms2d.shape[0]
        if self._stg_full and nslots:
            sizes_all = np.maximum.reduceat(take_sm, self._stg_start)
        else:
            sizes_all = np.zeros(S)
            if nslots:
                sizes_all[self._stg_ids] = np.maximum.reduceat(
                    take_sm, self._stg_start)
        if not self.aware:
            key_all = sizes_all
        elif self._stg_full and nslots:
            key_all = np.maximum.reduceat(
                take_sm / self._sm_paircap, self._stg_start)
        else:
            key_all = np.zeros(S)
            if nslots:
                key_all[self._stg_ids] = np.maximum.reduceat(
                    take_sm / self._sm_paircap, self._stg_start)
        live = sizes_all > 0.0
        n_live = int(live.sum())
        stats["n_stages"] = n_live
        bound = n * n - 2 * n + 2
        if n_live > max_stage_drift * bound:
            self.invalid = True
            stats["tripped"] = "stages"
            return None, stats
        # Quality: an exact decomposition's windows sum to the max line sum
        # (bytes, or seconds in the aware time domain) -- the Theorem 1
        # completion-time numerator.  Chained repairs may drift above it.
        lower = max_line_sum(t_new / self.caps_eff) if self.aware \
            else byte_line
        all_live = n_live == S
        q_sum = float(key_all.sum() if all_live else key_all[live].sum())
        quality = q_sum / lower if lower > 0 else 1.0
        stats["quality"] = quality
        if quality > quality_ratchet:
            self.invalid = True
            stats["tripped"] = "quality"
            return None, stats

        # Emission keeps the stored stage order: it is the cold
        # decomposition's ascending execution order, and per-step drift
        # perturbs sizes only locally, so re-sorting every update would
        # cost an (S, n) gather for a negligible pipeline-overlap gain
        # (the quality ratchet audits the window sum either way).
        # Appended residual slivers execute last.
        if all_live and bool(take_sm.all()):
            # Steady state -- every carried stage and slot refilled.  The
            # store IS the emission: zero-copy perms, and only the sent
            # scatter allocates (through the precomputed flat index: one
            # 1-D fancy store instead of a 2-D advanced-index resolve).
            out_sent = np.zeros(S * n)
            out_sent[self._sm_out_flat] = take_sm
            out_sent.shape = (S, n)
            out_perms = self._perms2d
            out_sizes = sizes_all
        else:
            idx = np.flatnonzero(live)
            row = np.full(S, -1, dtype=np.int64)
            row[idx] = np.arange(idx.size)
            live_slot = take_sm > 0.0
            out_sent = np.zeros((idx.size, n))
            out_sent[row[self._sm_stage[live_slot]],
                     self._sm_src[live_slot]] = take_sm[live_slot]
            out_perms = self._perms2d[idx]
            if not live_slot.all():
                # A carried slot that refilled to zero is idle this step:
                # mask its perm entry so the emitted stage stays tight.
                dead = ~live_slot
                dr = row[self._sm_stage[dead]]
                keep = dr >= 0
                out_perms[dr[keep], self._sm_src[dead][keep]] = -1
            out_sizes = sizes_all[idx]
        block = StageBlock(
            perms=out_perms,
            sizes=out_sizes,
            sent=out_sent,
            slots=out_sent.copy() if self.aware else None)
        self.updates += 1
        return block, stats

    def _decompose_residual(self, residual: np.ndarray) -> List[Stage]:
        """Fresh stages for the unabsorbed delta, warm-seeded matching.

        Capacity-aware states decompose in the time domain (matching the
        cold flash_ca path) and convert weights back to byte ``sent``
        entries; the per-slot capacity recorded in the state is the byte
        count, so refills stay in the byte domain either way.
        """
        n = self.n
        work_base = residual / self.caps_eff if self.aware else residual
        total = max_line_sum(work_base)
        if total <= 0:
            return []
        eps = total * _EPS_REL
        work = work_base + pad_to_doubly_balanced(work_base)
        realm = work_base.copy()
        seed: List[List[int]] = []
        stages = _incremental_stages(work, realm, n, eps, "repair",
                                     pref_rank=self._rank,
                                     init_match=self._res_seed,
                                     seed_out=seed)
        self._res_seed = seed[0] if seed else None
        stages = _coalesce(stages)
        out: List[Stage] = []
        for s in stages:
            if self.aware:
                s = _stage_to_bytes(s, self.caps_eff, n)
                if s is None:
                    continue
            elif not any(v > 0.0 for v in s.sent):
                continue  # padding-only stage: nothing to carry forward
            out.append(s)
        return out


def _greedy_drain(real: np.ndarray, stages: List[Stage], eps: float) -> None:
    """Fallback for pathological float residue: one stage per remaining entry."""
    n = real.shape[0]
    idx = np.argwhere(real > eps)
    for i, j in idx:
        perm = [-1] * n
        sent = [0.0] * n
        perm[int(i)] = int(j)
        sent[int(i)] = float(real[i, j])
        stages.append(Stage(perm=tuple(perm), size=float(real[i, j]),
                            sent=tuple(sent)))
        real[i, j] = 0.0
