"""Closed-form bounds from the paper's section 4.4 (Theorems 1-3).

These are the analytical oracles the property tests check the simulator and
the Birkhoff scheduler against.
"""

from __future__ import annotations

import numpy as np

from .birkhoff import max_line_sum
from .traffic import ClusterSpec, Workload, server_reduce

__all__ = [
    "t_optimal",
    "t_flash_worst_case",
    "gap_bound",
]


def t_optimal(w: Workload) -> float:
    """Theorem 1: infinite intra-bandwidth lower bound.

    t_opt = max(max_i sum_j T_ij, max_j sum_i T_ij) / (m * B2)
    """
    t, _ = server_reduce(w.matrix, w.cluster.m_gpus)
    return max_line_sum(t) / (w.cluster.m_gpus * w.cluster.b_inter)


def t_flash_worst_case(w: Workload) -> float:
    """Theorem 2: sum of worst-case phase times.

    t_FLASH <= t_opt                                   (inter, Birkhoff)
             + max_i sum_j T_ij / (m * B1)             (load balance head)
             + max_ij T_ij / B1                        (intra traffic S_i)
             + max_ij T_ij / (m * B1)                  (redistribute tail)

    Uses the paper's assumptions: full-mesh intra fabric of per-link
    bandwidth B1, one NIC of bandwidth B2 per GPU, S_i <= max_j T_ij.
    """
    c = w.cluster
    t, _ = server_reduce(w.matrix, c.m_gpus)
    m, b1, b2 = c.m_gpus, c.b_intra, c.b_inter
    t0 = t.sum(axis=1).max(initial=0.0) / (m * b1)
    t1 = t.max(initial=0.0) / b1
    t2 = max_line_sum(t) / (m * b2)
    t3 = t.max(initial=0.0) / (m * b1)
    return t0 + t1 + t2 + t3


def gap_bound(cluster: ClusterSpec) -> float:
    """Theorem 3: t_FLASH / t_opt <= 1 + (m + 2) * B2 / B1."""
    return 1.0 + (cluster.m_gpus + 2) * cluster.b_inter / cluster.b_intra


def check_workload_assumption(w: Workload) -> bool:
    """Paper's S_i <= max_j T_ij assumption (section 4.4)."""
    t, s = server_reduce(w.matrix, w.cluster.m_gpus)
    if t.size == 0:
        return True
    return bool(np.all(s <= t.max(axis=1) + 1e-9 * max(t.max(), 1.0)))
