from .checkpoint import (
    available_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["available_steps", "latest_step", "restore_checkpoint",
           "save_checkpoint"]
