"""Atomic, mesh-independent checkpointing (fault tolerance + elasticity).

Format: one directory per step --
    step_000123/
      manifest.json       (tree structure, leaf shapes/dtypes, step)
      leaves_000.npz ...  (host-gathered leaf arrays, chunked by size)
      _COMMITTED          (sentinel written last; torn saves are ignored)

Leaves are saved *unsharded* (host-gathered), so a checkpoint written on a
(2,16,16) mesh restores onto any other mesh -- this is the elastic-restart
story: on resize, restore with the new shardings and continue.  For
1000+-node deployments the same layout maps onto a parallel filesystem with
per-host shard files; the single-process writer here is the degenerate case
(noted in DESIGN.md).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "available_steps"]

_SENTINEL = "_COMMITTED"
_CHUNK_BYTES = 1 << 30


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def save_checkpoint(root: str, step: int, tree: Any,
                    keep_last: Optional[int] = 3) -> str:
    """Host-gather ``tree`` and atomically persist it under ``root``."""
    os.makedirs(root, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    np_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_save_")
    committed = False
    try:
        manifest = {
            "step": step,
            "treedef": _treedef_repr(tree),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in np_leaves],
            "files": [],
        }
        buf, size, fidx = [], 0, 0
        for i, arr in enumerate(np_leaves):
            # npz cannot round-trip ml_dtypes (bf16 etc.); store raw bytes,
            # shape/dtype live in the manifest
            raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            buf.append((f"leaf_{i}", raw))
            size += arr.nbytes
            if size >= _CHUNK_BYTES or i == len(np_leaves) - 1:
                fname = f"leaves_{fidx:03d}.npz"
                np.savez(os.path.join(tmp, fname), **dict(buf))
                manifest["files"].append(fname)
                buf, size, fidx = [], 0, fidx + 1
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _SENTINEL), "w") as f:
            f.write("ok")
        final = _step_dir(root, step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        committed = True
    finally:
        # try/finally instead of a broad `except: cleanup; raise`: the
        # original exception (KeyboardInterrupt and SystemExit included)
        # propagates untouched, and the staging dir is removed on every
        # non-committed exit path.
        if not committed:
            shutil.rmtree(tmp, ignore_errors=True)
    if keep_last is not None:
        _gc(root, keep_last)
    return _step_dir(root, step)


def _treedef_repr(tree) -> str:
    return str(jax.tree.structure(tree))


def _gc(root: str, keep_last: int) -> None:
    steps = available_steps(root)
    for s in steps[:-keep_last]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


def available_steps(root: str):
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and os.path.exists(
                os.path.join(root, name, _SENTINEL)):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = available_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(root: str, target: Any, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``target``.

    ``shardings``: optional matching pytree of jax.sharding.Sharding -- pass
    the *new* mesh's shardings to reshard elastically on restore.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: dict = {}
    for fname in manifest["files"]:
        with np.load(os.path.join(d, fname)) as z:
            arrays.update({k: z[k] for k in z.files})
    import ml_dtypes  # noqa: F401 -- registers bf16 etc. with numpy

    leaves = []
    for i, meta in enumerate(manifest["leaves"]):
        raw = arrays[f"leaf_{i}"]
        dtype = np.dtype(meta["dtype"])
        leaves.append(
            np.frombuffer(raw.tobytes(), dtype=dtype).reshape(meta["shape"]))
    treedef = jax.tree.structure(target)
    tree = treedef.unflatten(leaves)
    t_leaves = jax.tree.leaves(target)
    for a, t in zip(leaves, t_leaves):
        if tuple(a.shape) != tuple(t.shape):
            raise ValueError(
                f"checkpoint leaf shape {a.shape} != target {t.shape}")
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(
            lambda a, t: jax.numpy.asarray(a, dtype=t.dtype), tree, target)
    return tree, step
