"""The plan-serving daemon: FLASH synthesis as a long-running service.

Every entry point into the scheduler used to be a one-shot function call;
``PlanServer`` turns it into a shared, concurrent service that owns one
warm-start ``PlanCache`` and amortizes synthesis across every MoE job
(serving replicas, training steps, benchmarks) that asks for a plan.

The request path is split so the common case never waits on a queue:

  * **Synchronous fast path** (caller's thread): fingerprint the traffic,
    look it up in the cache.  A live (non-TTL-expired) hit resolves the
    ticket immediately with the cached plan -- whose compiled
    ``ExecutableSchedule`` is already attached, because workers compile
    before inserting -- so a hit costs one hash plus one locked dict
    probe, microseconds next to any synthesis.
  * **Tiered queue + worker pool** (misses): workers drain the
    ``TieredQueue`` in priority order.  Requests for a fingerprint
    already being synthesized coalesce onto the in-flight computation
    (no thundering herd).  A miss is answered by the *best available*
    route: family near-miss -> ``try_repair_plan`` warm repair; cold ->
    ``synthesize_bounded`` under the server's latency budget.  Both
    degraded routes answer immediately and schedule a BACKGROUND
    **upgrade** job that re-synthesizes the exact plan and swaps it into
    the cache -- later hits serve the exact plan, and ``upgrades`` in the
    telemetry tallies every swap.
  * **Prewarming**: the ``DriftPredictor`` extrapolates each family's
    traffic trajectory one step ahead; predicted fingerprints are
    synthesized at BACKGROUND priority before any client requests them.

**Fabric events** (serving/events.py) make topology change a first-class
scenario instead of an implicit cache wipe: ``apply_fabric_event`` swaps
the server's active ``Topology``, walks the cache's family index and
re-repairs every affected plan family against the new pair capacities at
BACKGROUND priority (``"rerepair"`` jobs), and keeps serving throughout
-- requests carrying a pre-event fabric are re-homed onto the live one
(``stale_topology`` counter), and a post-event miss warm-repairs from
the old fabric's family head (``try_repair_plan(topology_change=True)``)
rather than synthesizing cold.  Workers that die on an unexpected
exception fail their in-flight ticket, clean up, and respawn in place
(``worker_deaths`` counter), so a crash never leaves a queue slot dead.

Lifecycle: ``start()``/``stop()`` or use as a context manager;
``drain()`` waits for the queue and background work to settle (tests and
benchmarks use it to observe the post-upgrade steady state);
``telemetry_snapshot()`` exports the full JSON metrics view (telemetry +
cache stats + queue depths).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Union

from ..analysis.locks import make_lock
from ..core.plan import (
    Plan,
    PlanCache,
    cluster_family_key,
    traffic_fingerprint,
)
from ..core.schedulers import RepairConfig, Scheduler, get_scheduler
from ..core.topology import Topology
from ..core.traffic import Workload
from .events import FabricEvent, FabricMonitor
from .policy import DriftPredictor, TTLPolicy
from .queue import (
    AdmissionError,
    PlanRequest,
    PlanTicket,
    ServerClosed,
    TieredQueue,
    Tier,
)
from .telemetry import Telemetry

__all__ = ["PlanAnswer", "PlanServer"]


@dataclasses.dataclass(frozen=True)
class PlanAnswer:
    """One served plan plus its provenance.

    ``source`` is the route that produced the answer: ``"hit"`` (cache,
    including coalesced waiters), ``"warm"`` (repaired from a same-family
    plan), ``"cold"`` (synthesized now).  ``exact`` is False while the
    plan is a degraded answer (warm repair or over-budget bounded
    synthesis) awaiting its background upgrade.
    """

    plan: Plan
    source: str
    exact: bool
    latency_s: float
    request_id: int
    tier: Tier


class PlanServer:
    """Long-running, concurrent plan-serving daemon (module docstring).

    Args:
      cache: the PlanCache to own; default ``PlanCache(capacity=1024,
        warm_start=True)``.  Warm start matters: it is what turns family
        near-misses into repairs instead of cold syntheses.
      workers: queue-draining threads.  They serve interactive misses and,
        when idle, the BACKGROUND upgrade/prewarm tier.
      queue: the TieredQueue (constructed with the server's shed hook when
        omitted).
      ttl: entry lifetime -- seconds, a ``TTLPolicy``, or None (never
        expire).  Expired hits are served as misses and evicted.
      prewarm: predict-ahead synthesis of each family's next fingerprint.
      synth_budget_seconds: per-request synthesis latency budget handed to
        ``Scheduler.synthesize_bounded`` on the cold path; None = no
        budget (always exact).
      telemetry: shared Telemetry instance (constructed when omitted).
      repair_config: warm-repair knobs (``RepairConfig``) handed to
        ``try_repair_plan`` on the miss path -- the cold-fallback
        thresholds (residual fraction, stage drift, quality ratchet) and
        the incremental/one-shot engine switch.  None uses the
        scheduler's defaults.  Every repair attempt's residual fraction
        lands in the telemetry ``repair`` histogram.
      topology: the fabric this server believes is live.  Optional -- a
        server that never sees a fabric event does not need one.  Set it
        (or call ``attach_monitor``) to enable re-homing of requests that
        still carry a pre-event ``Topology`` and the event-driven
        re-repair walk in ``apply_fabric_event``.
    """

    def __init__(self, cache: Optional[PlanCache] = None, *,
                 workers: int = 2,
                 queue: Optional[TieredQueue] = None,
                 ttl: Union[None, float, TTLPolicy] = None,
                 prewarm: bool = True,
                 synth_budget_seconds: Optional[float] = None,
                 telemetry: Optional[Telemetry] = None,
                 predictor: Optional[DriftPredictor] = None,
                 repair_config: Optional[RepairConfig] = None,
                 topology: Optional[Topology] = None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.cache = cache if cache is not None else PlanCache(
            capacity=1024, warm_start=True)
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.queue = queue if queue is not None else TieredQueue()
        if self.queue._on_shed is None:
            self.queue._on_shed = self._on_shed
        self.ttl = (ttl if isinstance(ttl, TTLPolicy)
                    else TTLPolicy(ttl_seconds=ttl))
        self.prewarm = prewarm
        self.synth_budget_seconds = synth_budget_seconds
        self.repair_config = repair_config
        self.predictor = (predictor if predictor is not None
                          else DriftPredictor())
        self._n_workers = workers
        self._threads: List[threading.Thread] = []
        self._lock = make_lock("PlanServer._lock")
        self._inflight: Dict[str, List[PlanRequest]] = {}
        self._background_keys: set = set()  # queued upgrade/prewarm keys
        self._inexact: set = set()          # cached keys awaiting upgrade
        self._prewarmed: Dict[str, None] = {}  # keys inserted by prewarm
        self._busy = 0  # requests popped from the queue, not yet finished
        self._running = False
        self._closed = False
        self._active_topo = topology
        self._fabric_version = 0
        # new-fabric family key -> old-fabric family key: lets a
        # post-event miss warm-repair from the pre-event family head
        # before any rerepair job has landed.  Insertion-ordered, bounded.
        self._family_alias: Dict[str, str] = {}
        # thread ident -> the request that thread is serving; consulted by
        # _worker_main when the worker dies so the ticket can be failed.
        self._dying: Dict[int, PlanRequest] = {}

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "PlanServer":
        with self._lock:
            if self._running:
                return self
            if self._closed:
                raise ServerClosed("server was stopped; build a new one")
            self._running = True
        for i in range(self._n_workers):
            t = threading.Thread(target=self._worker_main,
                                 name=f"plan-server-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout: float = 10.0) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.queue.close()  # fails queued tickets, wakes idle workers
        for t in self._threads:
            t.join(timeout)
        self._threads.clear()
        with self._lock:
            self._running = False

    def __enter__(self) -> "PlanServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until no queued, in-flight or background work remains.

        Returns False on timeout.  Used to observe the settled state --
        every pending upgrade applied, every prewarm inserted."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                busy = (self._busy > 0 or bool(self._inflight)
                        or bool(self._background_keys))
            if not busy and self.queue.depth() == 0:
                return True
            time.sleep(0.002)
        return False

    # -- client API --------------------------------------------------------

    def submit(self, w: Workload, algorithm: str = "flash",
               tier: Tier = Tier.INTERACTIVE) -> PlanTicket:
        """Request a plan; returns a ticket (resolved already on a hit)."""
        if self._closed or not self._running:
            raise ServerClosed(
                "PlanServer is not running (use `with PlanServer(...)`"
                " or call start())")
        t_start = time.perf_counter()
        self.telemetry.count("requests")
        w = self._rehome(w)
        self.predictor.observe(w, algorithm)
        key = traffic_fingerprint(w, algorithm)
        ticket = PlanTicket()
        plan = self._lookup_live(key, counted=True)
        if plan is not None:
            self._resolve_hit(ticket, plan, key, t_start, tier, w, algorithm)
            return ticket
        req = PlanRequest(workload=w, algorithm=algorithm, tier=tier,
                          kind="plan", key=key, ticket=ticket,
                          t_start=t_start)
        self.queue.put(req)  # raises AdmissionError when saturated
        self.telemetry.observe_queue_depth(self.queue.depth())
        return ticket

    def request(self, w: Workload, algorithm: str = "flash",
                tier: Tier = Tier.INTERACTIVE,
                timeout: Optional[float] = 60.0) -> PlanAnswer:
        """Synchronous ``submit``: block until the answer (or raise)."""
        return self.submit(w, algorithm, tier).result(timeout)

    def telemetry_snapshot(self) -> Dict:
        """Full JSON-compatible metrics view (DESIGN.md section 2)."""
        snap = self.telemetry.snapshot()
        snap["cache"] = self.cache.stats()
        snap["queue"]["depths"] = self.queue.depths()
        cfg = self.repair_config
        if cfg is not None:
            snap["repair"]["config"] = dataclasses.asdict(cfg)
        with self._lock:
            snap["pending_upgrades"] = len(self._inexact)
            if self._active_topo is not None:
                snap["fabric"]["topology"] = self._active_topo.fingerprint()
        return snap

    def audit(self) -> Dict:
        """Run the workload-independent plan verifier over the live cache.

        Walks every family head (``cache.family_heads()``) through
        ``analysis.planlint``: incast-freedom, self-traffic, slot
        feasibility, stage ordering, topology consistency, and
        family-index agreement -- the FAST structural guarantees, checked
        on the plans this daemon is actually serving rather than on a
        workload-coupled ``validate`` at synthesis time.  Returns the
        planlint report (``{"plans", "clean", "issues": [...]}``); the
        ``audits``/``audit_issues`` counters land in telemetry so a soak
        or an operator snapshot shows at a glance whether a degraded
        route ever cached a structurally bad plan.
        """
        from ..analysis import planlint

        report = planlint.audit_cache(self.cache)
        self.telemetry.count("audits")
        if report["issues"]:
            self.telemetry.count("audit_issues", len(report["issues"]))
        return report

    # -- fabric events -----------------------------------------------------

    def attach_monitor(self, monitor: FabricMonitor) -> "PlanServer":
        """Adopt ``monitor``'s fabric as active and subscribe to its
        events; every later ``inject`` flows into ``apply_fabric_event``
        (strictly version-ordered -- the monitor notifies under its
        lock).

        The monitor state is snapshotted *before* taking the server
        lock: ``inject`` acquires FabricMonitor._lock then (via this
        subscription) PlanServer._lock, so reading the monitor while
        holding the server lock would acquire the same two locks in the
        opposite order -- a deadlock window the lock-order analysis
        flags as a cycle.  An event injected between the snapshot and
        the subscribe is not lost: the next delivered event carries the
        authoritative post-event topology explicitly."""
        version, topo = monitor.snapshot()
        with self._lock:
            self._active_topo = topo
            self._fabric_version = version
        monitor.subscribe(self.apply_fabric_event)
        return self

    def apply_fabric_event(self, event: FabricEvent,
                           topology: Optional[Topology] = None) -> int:
        """Swap the active fabric and re-repair every affected family.

        The serving answer to a NIC degrading or dying is *bounded
        slowdown*, not a stall: the cache is never wiped.  Each family
        the DriftPredictor tracks on the outgoing fabric gets (a) a
        BACKGROUND ``"rerepair"`` job that warm-repairs its head plan
        against the new pair capacities, and (b) a family alias so a
        client miss that arrives before the job lands still repairs from
        the old head synchronously instead of synthesizing cold.

        ``topology`` overrides the post-event fabric (used when the
        caller already constructed it); otherwise ``event.apply`` derives
        it from the current one.  Events at or below the last applied
        version are ignored (a late-delivered duplicate must not re-swap
        a fabric that has since moved on).  Returns the number of
        families scheduled for re-repair.
        """
        with self._lock:
            if event.version and event.version <= self._fabric_version:
                stale = True
                old = new = None
            else:
                stale = False
                old = self._active_topo
                new = topology
                if new is None:
                    if old is None:
                        raise ValueError(
                            "no active topology: construct with"
                            " PlanServer(topology=...), call"
                            " attach_monitor(), or pass topology=")
                    new = event.apply(old)
                self._active_topo = new
                self._fabric_version = (event.version
                                        or self._fabric_version + 1)
            version = self._fabric_version
        if stale:
            self.telemetry.count("fabric_events_stale")
            return 0
        self.telemetry.count("fabric_events")
        self.telemetry.observe_fabric_event(version, event.describe())
        return self._rerepair_families(old, new)

    def _rehome(self, w: Workload) -> Workload:
        """Move a request riding a stale fabric onto the active one.

        Clients built before a fabric event keep submitting workloads
        whose ``Topology`` predates it; planning against that fabric
        would produce schedules the real network can no longer honor.
        Only same-shape fabrics are re-homed -- a genuinely different
        cluster is the client's business, not staleness."""
        active = self._active_topo
        if active is None:
            return w
        topo = w.topo
        if topo is active or topo.fingerprint() == active.fingerprint():
            return w
        if (topo.n_servers, topo.m_gpus) != (active.n_servers,
                                             active.m_gpus):
            return w
        self.telemetry.count("stale_topology")
        return Workload(w.cluster, w.matrix, active)

    def _rerepair_families(self, old: Optional[Topology],
                           new: Topology) -> int:
        """Schedule one BACKGROUND rerepair per family planned on ``old``.

        The PlanCache family index knows the *plans* (``family_heads``);
        the DriftPredictor knows the *traffic* each family last saw.
        Joining them gives the work list: re-plan the last observed
        matrix of every family whose head rode the outgoing fabric."""
        if old is None:
            return 0
        old_fp = old.fingerprint()
        heads = {family: plan for family, plan in self.cache.family_heads()
                 if plan.topo.fingerprint() == old_fp}
        scheduled = 0
        for family, w_last, algo in self.predictor.snapshot():
            prev = heads.get(family)
            if prev is None or w_last.topo.fingerprint() != old_fp:
                continue
            w_new = Workload(w_last.cluster, w_last.matrix, new)
            with self._lock:
                self._family_alias[cluster_family_key(w_new, algo)] = family
                while len(self._family_alias) > 256:
                    self._family_alias.pop(next(iter(self._family_alias)))
            self._schedule_background(
                "rerepair", w_new, algo,
                traffic_fingerprint(w_new, algo), stale_plan=prev)
            scheduled += 1
        self.predictor.rehome(old_fp, new)
        return scheduled

    # -- fast-path helpers -------------------------------------------------

    def _lookup_live(self, key: str, counted: bool) -> Optional[Plan]:
        """Cache probe with TTL: an expired entry is evicted and reported
        as a miss.  ``counted`` selects the hit/miss-counting ``lookup``
        (client fast path) vs the silent ``peek`` (worker re-check of a
        miss that was already counted)."""
        if self.ttl.expired(key):
            self.cache.evict(key)
            self.ttl.forget(key)
            with self._lock:
                self._inexact.discard(key)
            self.telemetry.count("expired")
        return self.cache.lookup(key) if counted else self.cache.peek(key)

    def _resolve_hit(self, ticket: PlanTicket, plan: Plan, key: str,
                     t_start: float, tier: Tier, w: Workload,
                     algorithm: str) -> None:
        with self._lock:
            exact = key not in self._inexact
            was_prewarmed = self._prewarmed.pop(key, False) is None
        self.telemetry.count("hits")
        if was_prewarmed:
            self.telemetry.count("prewarm_hits")
        if not exact:
            # The cached answer is still a degraded plan (its upgrade was
            # shed or is queued behind other work): make sure an upgrade
            # is in flight again.
            self._schedule_background("upgrade", w, algorithm, key,
                                      stale_plan=plan)
        latency = time.perf_counter() - t_start
        self.telemetry.observe_latency(tier.name, latency)
        ticket.resolve(PlanAnswer(plan=plan, source="hit", exact=exact,
                                  latency_s=latency,
                                  request_id=-1, tier=tier))

    # -- worker side -------------------------------------------------------

    def _worker_main(self) -> None:
        """Thread target: run ``_worker_loop`` and survive its death.

        The loop's inner ``except Exception`` backstop already keeps
        ordinary synthesis failures from killing a worker, but anything
        that escapes it (a raising telemetry hook, ``KeyboardInterrupt``,
        a bug in the loop itself) used to take the thread down and leave
        its queue slot dead forever.  Now the dying worker fails the
        ticket it was holding (first-write-wins on ``PlanTicket`` makes
        the blind ``fail`` safe), releases its in-flight registration so
        coalesced waiters are not stranded, counts ``worker_deaths``, and
        respawns in place -- same thread, fresh loop."""
        ident = threading.get_ident()
        while True:
            try:
                self._worker_loop()
                return  # clean shutdown
            except BaseException as exc:
                req = self._dying.pop(ident, None)
                if req is not None:
                    if req.fail(exc):
                        self.telemetry.count("errors")
                    with self._lock:
                        waiters = self._inflight.get(req.key)
                        # Only yank the registration this request owns; a
                        # coalesced waiter's list belongs to another
                        # (live) worker.
                        if waiters and waiters[0] is req:
                            del self._inflight[req.key]
                        else:
                            waiters = None
                        if req.kind != "plan":
                            self._background_keys.discard(req.key)
                    for r in waiters or ():
                        if r is not req and r.fail(exc):
                            self.telemetry.count("errors")
                self.telemetry.count("worker_deaths")
                with self._lock:
                    if self._closed:
                        return

    def _worker_loop(self) -> None:
        ident = threading.get_ident()
        while True:
            req = self.queue.get(timeout=0.1)
            if req is None:
                if self._closed:
                    return
                # Idle housekeeping: age out expired entries in bites.
                for key in self.ttl.sweep(self.cache, limit=32):
                    self.ttl.forget(key)
                    with self._lock:
                        self._inexact.discard(key)
                    self.telemetry.count("expired")
                continue
            self._dying[ident] = req
            with self._lock:
                self._busy += 1
            try:
                if req.kind == "plan":
                    self._serve(req)
                elif req.kind == "upgrade":
                    self._upgrade(req)
                elif req.kind == "rerepair":
                    self._rerepair_job(req)
                else:
                    self._prewarm_job(req)
            except Exception as exc:  # backstop: never kill a worker
                # "errors" only when a client ticket actually failed --
                # counting ticketless background failures there would
                # break the requests == sum(outcomes) conservation law.
                if req.fail(exc):
                    self.telemetry.count("errors")
                else:
                    self.telemetry.count("background_errors")
            finally:
                with self._lock:
                    self._busy -= 1
                    if req.kind != "plan":
                        self._background_keys.discard(req.key)
            self._dying.pop(ident, None)  # settled without dying

    def _scheduler(self, algorithm: str) -> Scheduler:
        # get_scheduler builds a fresh stateless instance; cheap enough
        # that memoizing it here would only add another shared-state lock.
        return get_scheduler(algorithm)

    def _serve(self, req: PlanRequest) -> None:
        key = req.key
        with self._lock:
            waiters = self._inflight.get(key)
            if waiters is not None:
                # Same fingerprint already being synthesized: ride it.
                waiters.append(req)
                self.telemetry.count("coalesced")
                return
            self._inflight[key] = [req]
        plan: Optional[Plan] = None
        source, exact = "hit", True
        err: Optional[BaseException] = None
        try:
            plan = self._lookup_live(key, counted=False)
            if plan is None:
                plan, source, exact = self._synthesize_best(req)
        except BaseException as e:
            err = e
        finally:
            with self._lock:
                waiters = self._inflight.pop(key)
        if err is not None or plan is None:
            err = err if err is not None else RuntimeError(
                "plan synthesis produced no plan")
            for r in waiters:
                if r.fail(err):
                    self.telemetry.count("errors")
            if not isinstance(err, Exception):
                # Genuinely fatal (KeyboardInterrupt & co): the waiters
                # are settled, now let the worker die -- and respawn.
                raise err
            return
        for i, r in enumerate(waiters):
            self._answer(r, plan, source if i == 0 else "hit",
                         exact)

    def _synthesize_best(self, req: PlanRequest):
        """The miss path: best available answer now, upgrade later."""
        scheduler = self._scheduler(req.algorithm)
        w, key = req.workload, req.key
        plan, source, exact = None, "cold", True
        family = cluster_family_key(w, req.algorithm)
        prev = self.cache.peek_family(family)
        topology_change = False
        if prev is not None and \
                prev.topo.fingerprint() != w.topo.fingerprint():
            prev = None  # same family key, different fabric: unusable
        if prev is None:
            prev = self._alias_head(family, w)
            topology_change = prev is not None
        if prev is not None and hasattr(scheduler, "try_repair_plan") and \
                prev.cluster == w.cluster:
            repair_stats: Dict = {}
            plan = scheduler.try_repair_plan(
                prev, w, fingerprint=key, config=self.repair_config,
                stats=repair_stats, topology_change=topology_change)
            if "residual_fraction" in repair_stats:
                self.telemetry.observe_repair_residual(
                    repair_stats["residual_fraction"])
            if plan is not None:
                source, exact = "warm", False
                if topology_change:
                    self.telemetry.count("rerepaired")
            else:
                self.telemetry.count("repair_tripped")
        if plan is None:
            plan, exact = scheduler.synthesize_bounded(
                w, self.synth_budget_seconds, fingerprint=key)
            if not exact:
                self.telemetry.count("degraded")
        self.telemetry.observe_synthesis(plan.synth_seconds)
        self._insert(key, plan, exact=exact)
        plan.compile()  # answers carry a ready ExecutableSchedule
        if not exact:
            self._schedule_background("upgrade", w, req.algorithm, key,
                                      stale_plan=plan)
        if self.prewarm:
            for pw in self.predictor.predict(w, req.algorithm):
                pkey = traffic_fingerprint(pw, req.algorithm)
                if self.cache.peek(pkey) is None:
                    self._schedule_background("prewarm", pw, req.algorithm,
                                              pkey)
        return plan, source, exact

    def _answer(self, req: PlanRequest, plan: Plan, source: str,
                exact: bool) -> None:
        self.telemetry.count({"hit": "hits"}.get(source, source))
        # t_start is stamped at PlanRequest construction: a request
        # without one is a bug, and reading the attribute directly makes
        # it a loud AttributeError instead of a silently-recorded ~0s
        # latency (the old getattr fallback compared perf_counter to
        # itself).
        latency = time.perf_counter() - req.t_start
        self.telemetry.observe_latency(req.tier.name, latency)
        if req.ticket is not None:
            req.ticket.resolve(PlanAnswer(
                plan=plan, source=source, exact=exact, latency_s=latency,
                request_id=req.request_id, tier=req.tier))

    def _insert(self, key: str, plan: Plan, exact: bool) -> None:
        self.cache.insert(key, plan)
        self.ttl.note_insert(key)
        with self._lock:
            if exact:
                self._inexact.discard(key)
            else:
                self._inexact.add(key)

    # -- background jobs ---------------------------------------------------

    def _schedule_background(self, kind: str, w: Workload, algorithm: str,
                             key: str,
                             stale_plan: Optional[Plan] = None) -> None:
        with self._lock:
            if key in self._background_keys:
                return
            self._background_keys.add(key)
        req = PlanRequest(workload=w, algorithm=algorithm,
                          tier=Tier.BACKGROUND, kind=kind, key=key,
                          stale_plan=stale_plan)
        try:
            self.queue.put(req)
        except (AdmissionError, ServerClosed):
            with self._lock:
                self._background_keys.discard(key)

    def _alias_head(self, family: str, w: Workload) -> Optional[Plan]:
        """Cross-fabric warm seed for a post-event miss.

        Right after a fabric event the new-fabric family has no members
        yet; the alias recorded by ``_rerepair_families`` points back at
        the pre-event family whose head is still a better starting point
        than cold synthesis."""
        with self._lock:
            old_family = self._family_alias.get(family)
        if old_family is None:
            return None
        prev = self.cache.peek_family(old_family)
        if prev is None or prev.cluster != w.cluster or \
                (prev.topo.n_servers, prev.topo.m_gpus) != (
                    w.topo.n_servers, w.topo.m_gpus):
            return None
        return prev

    def _rerepair_job(self, req: PlanRequest) -> None:
        """Re-plan one family's last traffic on the post-event fabric.

        Warm path: ``try_repair_plan(topology_change=True)`` keeps the
        old head's permutation structure and re-water-fills it against
        the new pair capacities (the quality ratchet is relaxed to
        ``TOPOLOGY_CHANGE_QUALITY_RATCHET`` -- the old structure is
        necessarily a bit off the new fabric's optimum).  Cold fallback
        only if repair trips.  The result is inserted inexact so the
        normal upgrade machinery converges it to the exact plan."""
        if self._lookup_live(req.key, counted=False) is not None:
            return  # a client miss already re-planned this family
        scheduler = self._scheduler(req.algorithm)
        prev, w = req.stale_plan, req.workload
        plan: Optional[Plan] = None
        if prev is not None and hasattr(scheduler, "try_repair_plan") and \
                prev.cluster == w.cluster:
            repair_stats: Dict = {}
            plan = scheduler.try_repair_plan(
                prev, w, fingerprint=req.key, config=self.repair_config,
                stats=repair_stats, topology_change=True)
            if "residual_fraction" in repair_stats:
                self.telemetry.observe_repair_residual(
                    repair_stats["residual_fraction"])
        exact = False
        if plan is not None:
            self.telemetry.count("rerepaired")
        else:
            plan, exact = scheduler.synthesize_bounded(
                w, self.synth_budget_seconds, fingerprint=req.key)
            self.telemetry.count("rerepair_cold")
        self.telemetry.observe_synthesis(plan.synth_seconds)
        plan.compile()
        self._insert(req.key, plan, exact=exact)
        if not exact:
            # This key is still registered in _background_keys (released
            # only after the dispatch returns); drop it first or the
            # chained upgrade would be deduplicated away.
            with self._lock:
                self._background_keys.discard(req.key)
            self._schedule_background("upgrade", w, req.algorithm,
                                      req.key, stale_plan=plan)

    def _upgrade(self, req: PlanRequest) -> None:
        """Replace a degraded cache entry with the exact plan."""
        scheduler = self._scheduler(req.algorithm)
        plan = scheduler.synthesize(req.workload, fingerprint=req.key)
        self.telemetry.observe_synthesis(plan.synth_seconds)
        plan.compile()
        self._insert(req.key, plan, exact=True)
        self.telemetry.count("upgrades")

    def _prewarm_job(self, req: PlanRequest) -> None:
        """Synthesize a predicted fingerprint ahead of demand."""
        if self._lookup_live(req.key, counted=False) is not None:
            return  # a real request beat the prediction to it
        scheduler = self._scheduler(req.algorithm)
        plan = scheduler.synthesize(req.workload, fingerprint=req.key)
        self.telemetry.observe_synthesis(plan.synth_seconds)
        plan.compile()
        self._insert(req.key, plan, exact=True)
        with self._lock:
            self._prewarmed[req.key] = None
            while len(self._prewarmed) > 1024:
                self._prewarmed.pop(next(iter(self._prewarmed)))
        self.telemetry.count("prewarmed")

    # -- queue hook --------------------------------------------------------

    def _on_shed(self, req: PlanRequest, reason: str) -> None:
        if req.kind == "plan":
            self.telemetry.count(
                "rejected" if reason == "rejected" else "shed")
        else:
            self.telemetry.count("background_shed")
            with self._lock:
                self._background_keys.discard(req.key)
