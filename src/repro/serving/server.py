"""The plan-serving daemon: FLASH synthesis as a long-running service.

Every entry point into the scheduler used to be a one-shot function call;
``PlanServer`` turns it into a shared, concurrent service that owns one
warm-start ``PlanCache`` and amortizes synthesis across every MoE job
(serving replicas, training steps, benchmarks) that asks for a plan.

The request path is split so the common case never waits on a queue:

  * **Synchronous fast path** (caller's thread): fingerprint the traffic,
    look it up in the cache.  A live (non-TTL-expired) hit resolves the
    ticket immediately with the cached plan -- whose compiled
    ``ExecutableSchedule`` is already attached, because workers compile
    before inserting -- so a hit costs one hash plus one locked dict
    probe, microseconds next to any synthesis.
  * **Tiered queue + worker pool** (misses): workers drain the
    ``TieredQueue`` in priority order.  Requests for a fingerprint
    already being synthesized coalesce onto the in-flight computation
    (no thundering herd).  A miss is answered by the *best available*
    route: family near-miss -> ``try_repair_plan`` warm repair; cold ->
    ``synthesize_bounded`` under the server's latency budget.  Both
    degraded routes answer immediately and schedule a BACKGROUND
    **upgrade** job that re-synthesizes the exact plan and swaps it into
    the cache -- later hits serve the exact plan, and ``upgrades`` in the
    telemetry tallies every swap.
  * **Prewarming**: the ``DriftPredictor`` extrapolates each family's
    traffic trajectory one step ahead; predicted fingerprints are
    synthesized at BACKGROUND priority before any client requests them.

Lifecycle: ``start()``/``stop()`` or use as a context manager;
``drain()`` waits for the queue and background work to settle (tests and
benchmarks use it to observe the post-upgrade steady state);
``telemetry_snapshot()`` exports the full JSON metrics view (telemetry +
cache stats + queue depths).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Union

from ..core.plan import (
    Plan,
    PlanCache,
    cluster_family_key,
    traffic_fingerprint,
)
from ..core.schedulers import RepairConfig, Scheduler, get_scheduler
from ..core.traffic import Workload
from .policy import DriftPredictor, TTLPolicy
from .queue import (
    AdmissionError,
    PlanRequest,
    PlanTicket,
    ServerClosed,
    TieredQueue,
    Tier,
)
from .telemetry import Telemetry

__all__ = ["PlanAnswer", "PlanServer"]


@dataclasses.dataclass(frozen=True)
class PlanAnswer:
    """One served plan plus its provenance.

    ``source`` is the route that produced the answer: ``"hit"`` (cache,
    including coalesced waiters), ``"warm"`` (repaired from a same-family
    plan), ``"cold"`` (synthesized now).  ``exact`` is False while the
    plan is a degraded answer (warm repair or over-budget bounded
    synthesis) awaiting its background upgrade.
    """

    plan: Plan
    source: str
    exact: bool
    latency_s: float
    request_id: int
    tier: Tier


class PlanServer:
    """Long-running, concurrent plan-serving daemon (module docstring).

    Args:
      cache: the PlanCache to own; default ``PlanCache(capacity=1024,
        warm_start=True)``.  Warm start matters: it is what turns family
        near-misses into repairs instead of cold syntheses.
      workers: queue-draining threads.  They serve interactive misses and,
        when idle, the BACKGROUND upgrade/prewarm tier.
      queue: the TieredQueue (constructed with the server's shed hook when
        omitted).
      ttl: entry lifetime -- seconds, a ``TTLPolicy``, or None (never
        expire).  Expired hits are served as misses and evicted.
      prewarm: predict-ahead synthesis of each family's next fingerprint.
      synth_budget_seconds: per-request synthesis latency budget handed to
        ``Scheduler.synthesize_bounded`` on the cold path; None = no
        budget (always exact).
      telemetry: shared Telemetry instance (constructed when omitted).
      repair_config: warm-repair knobs (``RepairConfig``) handed to
        ``try_repair_plan`` on the miss path -- the cold-fallback
        thresholds (residual fraction, stage drift, quality ratchet) and
        the incremental/one-shot engine switch.  None uses the
        scheduler's defaults.  Every repair attempt's residual fraction
        lands in the telemetry ``repair`` histogram.
    """

    def __init__(self, cache: Optional[PlanCache] = None, *,
                 workers: int = 2,
                 queue: Optional[TieredQueue] = None,
                 ttl: Union[None, float, TTLPolicy] = None,
                 prewarm: bool = True,
                 synth_budget_seconds: Optional[float] = None,
                 telemetry: Optional[Telemetry] = None,
                 predictor: Optional[DriftPredictor] = None,
                 repair_config: Optional[RepairConfig] = None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.cache = cache if cache is not None else PlanCache(
            capacity=1024, warm_start=True)
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.queue = queue if queue is not None else TieredQueue()
        if self.queue._on_shed is None:
            self.queue._on_shed = self._on_shed
        self.ttl = (ttl if isinstance(ttl, TTLPolicy)
                    else TTLPolicy(ttl_seconds=ttl))
        self.prewarm = prewarm
        self.synth_budget_seconds = synth_budget_seconds
        self.repair_config = repair_config
        self.predictor = (predictor if predictor is not None
                          else DriftPredictor())
        self._n_workers = workers
        self._threads: List[threading.Thread] = []
        self._lock = threading.Lock()
        self._inflight: Dict[str, List[PlanRequest]] = {}
        self._background_keys: set = set()  # queued upgrade/prewarm keys
        self._inexact: set = set()          # cached keys awaiting upgrade
        self._prewarmed: Dict[str, None] = {}  # keys inserted by prewarm
        self._busy = 0  # requests popped from the queue, not yet finished
        self._running = False
        self._closed = False

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "PlanServer":
        with self._lock:
            if self._running:
                return self
            if self._closed:
                raise ServerClosed("server was stopped; build a new one")
            self._running = True
        for i in range(self._n_workers):
            t = threading.Thread(target=self._worker_loop,
                                 name=f"plan-server-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout: float = 10.0) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.queue.close()  # fails queued tickets, wakes idle workers
        for t in self._threads:
            t.join(timeout)
        self._threads.clear()
        with self._lock:
            self._running = False

    def __enter__(self) -> "PlanServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until no queued, in-flight or background work remains.

        Returns False on timeout.  Used to observe the settled state --
        every pending upgrade applied, every prewarm inserted."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                busy = (self._busy > 0 or bool(self._inflight)
                        or bool(self._background_keys))
            if not busy and self.queue.depth() == 0:
                return True
            time.sleep(0.002)
        return False

    # -- client API --------------------------------------------------------

    def submit(self, w: Workload, algorithm: str = "flash",
               tier: Tier = Tier.INTERACTIVE) -> PlanTicket:
        """Request a plan; returns a ticket (resolved already on a hit)."""
        if self._closed or not self._running:
            raise ServerClosed(
                "PlanServer is not running (use `with PlanServer(...)`"
                " or call start())")
        t_start = time.perf_counter()
        self.telemetry.count("requests")
        self.predictor.observe(w, algorithm)
        key = traffic_fingerprint(w, algorithm)
        ticket = PlanTicket()
        plan = self._lookup_live(key, counted=True)
        if plan is not None:
            self._resolve_hit(ticket, plan, key, t_start, tier, w, algorithm)
            return ticket
        req = PlanRequest(workload=w, algorithm=algorithm, tier=tier,
                          kind="plan", key=key, ticket=ticket)
        req.t_start = t_start
        self.queue.put(req)  # raises AdmissionError when saturated
        self.telemetry.observe_queue_depth(self.queue.depth())
        return ticket

    def request(self, w: Workload, algorithm: str = "flash",
                tier: Tier = Tier.INTERACTIVE,
                timeout: Optional[float] = 60.0) -> PlanAnswer:
        """Synchronous ``submit``: block until the answer (or raise)."""
        return self.submit(w, algorithm, tier).result(timeout)

    def telemetry_snapshot(self) -> Dict:
        """Full JSON-compatible metrics view (DESIGN.md section 2)."""
        snap = self.telemetry.snapshot()
        snap["cache"] = self.cache.stats()
        snap["queue"]["depths"] = self.queue.depths()
        cfg = self.repair_config
        if cfg is not None:
            snap["repair"]["config"] = dataclasses.asdict(cfg)
        with self._lock:
            snap["pending_upgrades"] = len(self._inexact)
        return snap

    # -- fast-path helpers -------------------------------------------------

    def _lookup_live(self, key: str, counted: bool) -> Optional[Plan]:
        """Cache probe with TTL: an expired entry is evicted and reported
        as a miss.  ``counted`` selects the hit/miss-counting ``lookup``
        (client fast path) vs the silent ``peek`` (worker re-check of a
        miss that was already counted)."""
        if self.ttl.expired(key):
            self.cache.evict(key)
            self.ttl.forget(key)
            with self._lock:
                self._inexact.discard(key)
            self.telemetry.count("expired")
        return self.cache.lookup(key) if counted else self.cache.peek(key)

    def _resolve_hit(self, ticket: PlanTicket, plan: Plan, key: str,
                     t_start: float, tier: Tier, w: Workload,
                     algorithm: str) -> None:
        with self._lock:
            exact = key not in self._inexact
            was_prewarmed = self._prewarmed.pop(key, False) is None
        self.telemetry.count("hits")
        if was_prewarmed:
            self.telemetry.count("prewarm_hits")
        if not exact:
            # The cached answer is still a degraded plan (its upgrade was
            # shed or is queued behind other work): make sure an upgrade
            # is in flight again.
            self._schedule_background("upgrade", w, algorithm, key,
                                      stale_plan=plan)
        latency = time.perf_counter() - t_start
        self.telemetry.observe_latency(tier.name, latency)
        ticket.resolve(PlanAnswer(plan=plan, source="hit", exact=exact,
                                  latency_s=latency,
                                  request_id=-1, tier=tier))

    # -- worker side -------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            req = self.queue.get(timeout=0.1)
            if req is None:
                if self._closed:
                    return
                # Idle housekeeping: age out expired entries in bites.
                for key in self.ttl.sweep(self.cache, limit=32):
                    self.ttl.forget(key)
                    with self._lock:
                        self._inexact.discard(key)
                    self.telemetry.count("expired")
                continue
            with self._lock:
                self._busy += 1
            try:
                if req.kind == "plan":
                    self._serve(req)
                elif req.kind == "upgrade":
                    self._upgrade(req)
                else:
                    self._prewarm_job(req)
            except Exception as exc:  # backstop: never kill a worker
                req.fail(exc)
                self.telemetry.count("errors")
            finally:
                with self._lock:
                    self._busy -= 1
                    if req.kind != "plan":
                        self._background_keys.discard(req.key)

    def _scheduler(self, algorithm: str) -> Scheduler:
        # get_scheduler builds a fresh stateless instance; cheap enough
        # that memoizing it here would only add another shared-state lock.
        return get_scheduler(algorithm)

    def _serve(self, req: PlanRequest) -> None:
        key = req.key
        with self._lock:
            waiters = self._inflight.get(key)
            if waiters is not None:
                # Same fingerprint already being synthesized: ride it.
                waiters.append(req)
                self.telemetry.count("coalesced")
                return
            self._inflight[key] = [req]
        plan: Optional[Plan] = None
        source, exact = "hit", True
        err: Optional[BaseException] = None
        try:
            plan = self._lookup_live(key, counted=False)
            if plan is None:
                plan, source, exact = self._synthesize_best(req)
        except Exception as e:
            err = e
        finally:
            with self._lock:
                waiters = self._inflight.pop(key)
        if err is not None or plan is None:
            err = err if err is not None else RuntimeError(
                "plan synthesis produced no plan")
            self.telemetry.count("errors", len(waiters))
            for r in waiters:
                r.fail(err)
            return
        for i, r in enumerate(waiters):
            self._answer(r, plan, source if i == 0 else "hit",
                         exact)

    def _synthesize_best(self, req: PlanRequest):
        """The miss path: best available answer now, upgrade later."""
        scheduler = self._scheduler(req.algorithm)
        w, key = req.workload, req.key
        plan, source, exact = None, "cold", True
        prev = self.cache.peek_family(
            cluster_family_key(w, req.algorithm))
        if prev is not None and hasattr(scheduler, "try_repair_plan") and \
                prev.cluster == w.cluster and \
                prev.topo.fingerprint() == w.topo.fingerprint():
            repair_stats: Dict = {}
            plan = scheduler.try_repair_plan(
                prev, w, fingerprint=key, config=self.repair_config,
                stats=repair_stats)
            if "residual_fraction" in repair_stats:
                self.telemetry.observe_repair_residual(
                    repair_stats["residual_fraction"])
            if plan is not None:
                source, exact = "warm", False
            else:
                self.telemetry.count("repair_tripped")
        if plan is None:
            plan, exact = scheduler.synthesize_bounded(
                w, self.synth_budget_seconds, fingerprint=key)
            if not exact:
                self.telemetry.count("degraded")
        self.telemetry.observe_synthesis(plan.synth_seconds)
        self._insert(key, plan, exact=exact)
        plan.compile()  # answers carry a ready ExecutableSchedule
        if not exact:
            self._schedule_background("upgrade", w, req.algorithm, key,
                                      stale_plan=plan)
        if self.prewarm:
            for pw in self.predictor.predict(w, req.algorithm):
                pkey = traffic_fingerprint(pw, req.algorithm)
                if self.cache.peek(pkey) is None:
                    self._schedule_background("prewarm", pw, req.algorithm,
                                              pkey)
        return plan, source, exact

    def _answer(self, req: PlanRequest, plan: Plan, source: str,
                exact: bool) -> None:
        self.telemetry.count({"hit": "hits"}.get(source, source))
        latency = time.perf_counter() - getattr(req, "t_start",
                                                time.perf_counter())
        self.telemetry.observe_latency(req.tier.name, latency)
        if req.ticket is not None:
            req.ticket.resolve(PlanAnswer(
                plan=plan, source=source, exact=exact, latency_s=latency,
                request_id=req.request_id, tier=req.tier))

    def _insert(self, key: str, plan: Plan, exact: bool) -> None:
        self.cache.insert(key, plan)
        self.ttl.note_insert(key)
        with self._lock:
            if exact:
                self._inexact.discard(key)
            else:
                self._inexact.add(key)

    # -- background jobs ---------------------------------------------------

    def _schedule_background(self, kind: str, w: Workload, algorithm: str,
                             key: str,
                             stale_plan: Optional[Plan] = None) -> None:
        with self._lock:
            if key in self._background_keys:
                return
            self._background_keys.add(key)
        req = PlanRequest(workload=w, algorithm=algorithm,
                          tier=Tier.BACKGROUND, kind=kind, key=key,
                          stale_plan=stale_plan)
        try:
            self.queue.put(req)
        except (AdmissionError, ServerClosed):
            with self._lock:
                self._background_keys.discard(key)

    def _upgrade(self, req: PlanRequest) -> None:
        """Replace a degraded cache entry with the exact plan."""
        scheduler = self._scheduler(req.algorithm)
        plan = scheduler.synthesize(req.workload, fingerprint=req.key)
        self.telemetry.observe_synthesis(plan.synth_seconds)
        plan.compile()
        self._insert(req.key, plan, exact=True)
        self.telemetry.count("upgrades")

    def _prewarm_job(self, req: PlanRequest) -> None:
        """Synthesize a predicted fingerprint ahead of demand."""
        if self._lookup_live(req.key, counted=False) is not None:
            return  # a real request beat the prediction to it
        scheduler = self._scheduler(req.algorithm)
        plan = scheduler.synthesize(req.workload, fingerprint=req.key)
        self.telemetry.observe_synthesis(plan.synth_seconds)
        plan.compile()
        self._insert(req.key, plan, exact=True)
        with self._lock:
            self._prewarmed[req.key] = None
            while len(self._prewarmed) > 1024:
                self._prewarmed.pop(next(iter(self._prewarmed)))
        self.telemetry.count("prewarmed")

    # -- queue hook --------------------------------------------------------

    def _on_shed(self, req: PlanRequest, reason: str) -> None:
        if req.kind == "plan":
            self.telemetry.count(
                "rejected" if reason == "rejected" else "shed")
        else:
            self.telemetry.count("background_shed")
            with self._lock:
                self._background_keys.discard(req.key)
