"""The plan-serving daemon: FLASH synthesis as a concurrent service.

This package turns the one-shot scheduler pipeline (Scheduler -> Plan ->
compiled executor) into a long-running daemon that many MoE jobs share:

  * ``server``    -- ``PlanServer``: the daemon (fast path, worker pool,
                     background upgrades, prewarming, fabric-event
                     re-repair, worker respawn).
  * ``client``    -- ``PlanClient``: a job's handle; retry with backoff,
                     deadline, inline fallback.
  * ``events``    -- ``FabricEvent``/``FabricMonitor``: topology change
                     as a versioned event stream.
  * ``queue``     -- priority tiers, admission control, staleness shedding.
  * ``policy``    -- TTL eviction and the drift predictor.
  * ``telemetry`` -- counters, latency percentiles, synthesis histograms.

See DESIGN.md section 2 ("The serving layer") for the architecture and
``examples/plan_server_demo.py`` for a runnable tour.
"""

from .client import PlanClient
from .events import FabricEvent, FabricMonitor
from .policy import DriftPredictor, TTLPolicy
from .queue import (
    AdmissionError,
    PlanRequest,
    PlanTicket,
    ServerClosed,
    TieredQueue,
    Tier,
    DEFAULT_STALE_AFTER,
)
from .server import PlanAnswer, PlanServer
from .telemetry import LatencyReservoir, Telemetry

__all__ = [
    "PlanServer",
    "PlanAnswer",
    "PlanClient",
    "Tier",
    "TieredQueue",
    "PlanRequest",
    "PlanTicket",
    "AdmissionError",
    "ServerClosed",
    "DEFAULT_STALE_AFTER",
    "FabricEvent",
    "FabricMonitor",
    "TTLPolicy",
    "DriftPredictor",
    "Telemetry",
    "LatencyReservoir",
]
