"""Fabric events: topology change as a first-class serving scenario.

Real two-tier fabrics shift *under* the traffic -- NICs fail, links run
degraded, servers recover -- and before this module a fabric change was
only handled implicitly: a new topology fingerprint meant every warm plan
family went cold at once, so a single NIC failure turned into a wall of
cold syntheses exactly when the fabric had the least capacity to spare.

``FabricEvent`` names the change (degrade / fail / recover, NIC- or
server-scoped, optionally direction-split for asymmetric up/down rates)
and ``FabricMonitor`` serializes events into a monotonically versioned
stream: it owns the authoritative current ``Topology``, applies each
injected event through the scenario constructors
(``degrade_nic``/``fail_nic``/``degrade_server``/``recover_nic``/...),
and notifies subscribers -- above all ``PlanServer.apply_fabric_event``,
which swaps its active fabric and re-repairs every affected plan family
against the new pair capacities instead of evicting them (see
DESIGN.md, "Fault tolerance and fabric events").

Versioning makes delivery idempotent and reorder-safe: each event carries
the monotone version stamped at injection, and a consumer simply ignores
any event at or below the version it has already applied.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..analysis.locks import make_lock
from ..core.topology import Topology

__all__ = [
    "FabricEvent",
    "FabricMonitor",
]

_KINDS = ("degrade", "fail", "recover")
_DIRECTIONS = ("both", "up", "down")


@dataclasses.dataclass(frozen=True)
class FabricEvent:
    """One observed fabric change.

    Attributes:
      kind: ``"degrade"`` (a link running slow), ``"fail"`` (degrade to
        zero) or ``"recover"`` (back to the pre-degradation rate).
      server: the affected server index.
      nic: the affected NIC (rail) index, or None for a server-scoped
        event (every NIC of the server).
      factor: for ``degrade``, the fraction of nominal speed in [0, 1];
        ignored for ``fail`` (0) and ``recover``.
      direction: which plane the event hits -- ``"both"`` (default),
        ``"up"`` (transmit only) or ``"down"`` (receive only), for
        asymmetric up/down degradation.  Recovery always restores both
        planes.
      version: monotone sequence number, stamped by the ``FabricMonitor``
        at injection (0 = unstamped).  Consumers apply events in version
        order and drop anything at or below their last applied version.
    """

    kind: str
    server: int
    nic: Optional[int] = None
    factor: float = 1.0
    direction: str = "both"
    version: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if self.direction not in _DIRECTIONS:
            raise ValueError(f"direction must be one of {_DIRECTIONS}, "
                             f"got {self.direction!r}")
        if self.kind == "degrade" and not 0.0 <= self.factor <= 1.0:
            raise ValueError(
                f"degrade factor must be in [0, 1], got {self.factor}")

    def apply(self, topo: Topology) -> Topology:
        """The topology after this event (pure; ``topo`` is unchanged)."""
        if self.kind == "recover":
            if self.nic is None:
                return topo.recover_server(self.server)
            return topo.recover_nic(self.server, self.nic)
        factor = 0.0 if self.kind == "fail" else self.factor
        if self.nic is None:
            return topo.degrade_server(self.server, factor, self.direction)
        return topo.degrade_nic(self.server, self.nic, factor,
                                self.direction)

    def describe(self) -> str:
        scope = (f"server {self.server}" if self.nic is None
                 else f"nic {self.server}.{self.nic}")
        extra = f" x{self.factor:g}" if self.kind == "degrade" else ""
        plane = "" if self.direction == "both" else f" [{self.direction}]"
        return f"v{self.version} {self.kind} {scope}{extra}{plane}"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class FabricMonitor:
    """Serializes fabric events and owns the authoritative live topology.

    In production the inject() calls would be fed by a health prober
    (NIC counters, link-flap interrupts); here injection is explicit so
    examples, benchmarks and tests can script failure timelines.

    Subscribers receive ``(event, new_topology)`` strictly in version
    order -- notification happens under the monitor lock, so no
    subscriber can observe version k+1 before k.  Subscriber exceptions
    propagate to the injector: a fabric event a consumer failed to apply
    is an operational error the caller must see, not swallow.
    """

    def __init__(self, topology: Topology):
        self._lock = make_lock("FabricMonitor._lock")
        self._topology = topology
        self._version = 0
        self._subscribers: List[Callable[[FabricEvent, Topology], None]] = []
        self._history: List[FabricEvent] = []

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def current(self) -> Topology:
        """The live topology after every injected event."""
        with self._lock:
            return self._topology

    def snapshot(self) -> Tuple[int, Topology]:
        """``(version, topology)`` from one critical section -- the two
        reads cannot tear across a concurrent ``inject``.  Consumers
        adopting the fabric (``PlanServer.attach_monitor``) use this so
        they never hold their own lock while reading the monitor (the
        monitor notifies *them* under its lock; nesting the other way
        would close a lock-order cycle)."""
        with self._lock:
            return self._version, self._topology

    def history(self) -> List[FabricEvent]:
        with self._lock:
            return list(self._history)

    def subscribe(self, fn: Callable[[FabricEvent, Topology], None],
                  ) -> None:
        """Register a consumer; it is NOT replayed past events (read
        ``current()`` at attach time instead, like PlanServer does)."""
        with self._lock:
            self._subscribers.append(fn)

    def inject(self, kind: str, server: int, nic: Optional[int] = None, *,
               factor: float = 1.0,
               direction: str = "both") -> FabricEvent:
        """Apply one fabric change: stamp the next version, advance the
        live topology, notify subscribers.  Returns the stamped event."""
        with self._lock:
            event = FabricEvent(kind=kind, server=server, nic=nic,
                                factor=factor, direction=direction,
                                version=self._version + 1)
            new_topo = event.apply(self._topology)
            self._version = event.version
            self._topology = new_topo
            self._history.append(event)
            subscribers = list(self._subscribers)
            for fn in subscribers:
                fn(event, new_topo)
        return event
