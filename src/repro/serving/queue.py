"""Priority-tiered plan-request queue with admission control.

The plan-serving daemon multiplexes many concurrent MoE jobs over a small
worker pool, so the queue -- not the synthesizer -- is where overload
policy lives:

  * **Tiers** -- ``INTERACTIVE`` (a serving replica blocked on its next
    dispatch schedule) drains before ``BATCH`` (training jobs that can
    ride one stale plan for an extra step), which drains before
    ``BACKGROUND`` (the daemon's own upgrade/prewarm work).  FIFO within
    a tier.
  * **Bounded depth** -- the queue never grows past ``max_depth``.  An
    arriving request first sheds stale queued work; if the queue is still
    full it preempts the newest request of a *strictly lower-priority*
    tier, and otherwise is rejected outright (``AdmissionError``) -- a
    full queue of equal-or-higher-priority work means the daemon is
    saturated and the client should fall back to inline synthesis rather
    than pile on.
  * **Per-tier staleness** -- a request older than its tier's
    ``stale_after`` horizon is shed instead of served: an interactive
    client has long since timed out, and synthesizing for it anyway would
    burn worker time current requests need.  Shed and preempted requests
    fail their ticket with ``AdmissionError`` so no waiter blocks forever.

Every mutation happens under one lock; ``get`` blocks on a condition
variable, so worker threads idle without spinning.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, Mapping, Optional, Union

from ..analysis.locks import make_condition, make_lock
from ..core.plan import Plan
from ..core.traffic import Workload

__all__ = [
    "Tier",
    "AdmissionError",
    "ServerClosed",
    "PlanTicket",
    "PlanRequest",
    "TieredQueue",
    "DEFAULT_STALE_AFTER",
]


class Tier(enum.IntEnum):
    """Request priority; lower value drains first."""

    INTERACTIVE = 0
    BATCH = 1
    BACKGROUND = 2


class AdmissionError(RuntimeError):
    """The queue refused (or later shed) a request."""


class ServerClosed(RuntimeError):
    """The daemon is stopped; no request can be served."""


# Per-tier staleness horizons (seconds).  Interactive callers block on the
# answer and give up quickly; background upgrade/prewarm jobs stay useful
# for much longer.
DEFAULT_STALE_AFTER: Mapping[Tier, float] = {
    Tier.INTERACTIVE: 2.0,
    Tier.BATCH: 10.0,
    Tier.BACKGROUND: 60.0,
}

_req_ids = itertools.count()


class PlanTicket:
    """A waitable slot for one request's answer (a minimal future).

    ``result`` blocks until a worker (or the fast path) resolves the
    ticket; failures -- shed, rejected, server stopped, synthesis error --
    re-raise in the waiting thread.

    Resolution is first-write-wins: ``resolve``/``fail`` return whether
    this call settled the ticket, and later calls are no-ops.  The worker
    respawn path relies on this -- a dying worker's cleanup can blindly
    fail its last request without clobbering an answer that already
    reached the client (and without double-counting in telemetry).
    """

    def __init__(self):
        self._event = threading.Event()
        self._lock = make_lock("PlanTicket._lock")
        self._answer = None
        self._exc: Optional[BaseException] = None

    def resolve(self, answer) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._answer = answer
            self._event.set()
            return True

    def fail(self, exc: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._exc = exc
            self._event.set()
            return True

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("plan request not answered in time")
        if self._exc is not None:
            raise self._exc
        return self._answer


@dataclasses.dataclass(eq=False)
class PlanRequest:
    """One unit of daemon work.

    ``kind`` distinguishes client-facing plan requests from the daemon's
    own background jobs: ``"plan"`` (a client waits on ``ticket``),
    ``"upgrade"`` (replace a warm-repaired cache entry with the exact
    plan), ``"prewarm"`` (synthesize a predicted fingerprint ahead of
    demand) and ``"rerepair"`` (re-repair a plan family across a fabric
    event; see serving/events.py).  Background kinds carry no ticket.
    """

    workload: Workload
    algorithm: str
    tier: Tier = Tier.INTERACTIVE
    kind: str = "plan"
    key: str = ""  # traffic fingerprint, filled by the server
    created: float = 0.0  # queue clock timestamp, stamped at put()
    # Latency clock origin (time.perf_counter domain), stamped at
    # construction so *every* request carries one -- the telemetry path
    # reads it unconditionally, and a missing stamp is a loud
    # AttributeError instead of a silently-recorded ~0s latency.
    t_start: float = dataclasses.field(
        default_factory=time.perf_counter)
    ticket: Optional[PlanTicket] = None
    # Upgrade jobs remember the plan they are replacing, so telemetry can
    # prove the exact plan actually displaced a warm-repaired one.
    stale_plan: Optional[Plan] = None
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_req_ids))

    def fail(self, exc: BaseException) -> bool:
        """Fail the waiter, if any; True when this call settled the
        ticket (first write), False for ticketless/already-settled
        requests."""
        if self.ticket is not None:
            return self.ticket.fail(exc)
        return False


def _normalize_stale(stale_after) -> Optional[Dict[Tier, float]]:
    if stale_after is None:
        return None
    if isinstance(stale_after, (int, float)):
        return {t: float(stale_after) for t in Tier}
    out = dict(DEFAULT_STALE_AFTER)
    out.update({Tier(k): float(v) for k, v in stale_after.items()})
    return out


class TieredQueue:
    """Bounded, tier-ordered request queue (see module docstring).

    Args:
      max_depth: total queued requests across all tiers.
      stale_after: staleness horizon -- per-tier mapping, one scalar for
        every tier, or None to disable shedding by age.  Defaults to
        ``DEFAULT_STALE_AFTER``.
      clock: monotonic time source (injectable for tests).
      on_shed: callback ``(request, reason)`` invoked after a request is
        shed/preempted/rejected, with reason in {"stale", "preempted",
        "rejected"} -- the server's telemetry hook.
    """

    def __init__(self, max_depth: int = 256,
                 stale_after: Union[None, float, Mapping] =
                 DEFAULT_STALE_AFTER,
                 clock: Callable[[], float] = time.monotonic,
                 on_shed: Optional[Callable] = None):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = max_depth
        self.stale_after = _normalize_stale(stale_after)
        self._clock = clock
        self._on_shed = on_shed
        self._lock = make_lock("TieredQueue._lock")
        self._not_empty = make_condition("TieredQueue._not_empty",
                                         self._lock)
        self._tiers: Dict[Tier, Deque[PlanRequest]] = {
            t: deque() for t in Tier}
        self._count = 0
        self._closed = False

    # -- internals (lock held) --------------------------------------------

    def _shed(self, req: PlanRequest, reason: str) -> None:
        req.fail(AdmissionError(
            f"request {req.request_id} ({req.kind}, tier "
            f"{req.tier.name}) {reason}"))
        if self._on_shed is not None:
            self._on_shed(req, reason)

    def _is_stale(self, req: PlanRequest, now: float) -> bool:
        if self.stale_after is None:
            return False
        return (now - req.created) > self.stale_after[req.tier]

    def _shed_stale_locked(self) -> int:
        """Drop every queued request older than its tier's horizon."""
        if self.stale_after is None:
            return 0
        now = self._clock()
        dropped = 0
        for tier, q in self._tiers.items():
            keep: Deque[PlanRequest] = deque()
            while q:
                req = q.popleft()
                if self._is_stale(req, now):
                    self._shed(req, "stale")
                    dropped += 1
                else:
                    keep.append(req)
            self._tiers[tier] = keep
        self._count -= dropped
        return dropped

    # -- public API -------------------------------------------------------

    def put(self, req: PlanRequest) -> None:
        """Admit a request, or raise ``AdmissionError``.

        Admission control under pressure, in order: shed stale queued
        requests; preempt the newest strictly-lower-priority queued
        request; reject the arrival.
        """
        with self._lock:
            if self._closed:
                raise ServerClosed("queue is closed")
            req.created = self._clock()
            if self._count >= self.max_depth:
                self._shed_stale_locked()
            if self._count >= self.max_depth:
                victim = None
                for tier in sorted(Tier, reverse=True):
                    if tier > req.tier and self._tiers[tier]:
                        victim = self._tiers[tier].pop()  # newest first
                        break
                if victim is not None:
                    self._count -= 1
                    self._shed(victim, "preempted")
                else:
                    self._shed(req, "rejected")
                    raise AdmissionError(
                        f"queue full ({self.max_depth} requests) with no "
                        f"lower-priority work to shed; tier "
                        f"{req.tier.name} request rejected")
            self._tiers[req.tier].append(req)
            self._count += 1
            self._not_empty.notify()

    def get(self, timeout: Optional[float] = None
            ) -> Optional[PlanRequest]:
        """Pop the oldest request of the highest-priority nonempty tier.

        Stale requests encountered on the way out are shed (their waiters
        unblocked), never served.  Returns None on timeout or once the
        queue is closed and drained.
        """
        deadline = None if timeout is None else self._clock() + timeout
        with self._lock:
            while True:
                now = self._clock()
                for tier in Tier:
                    q = self._tiers[tier]
                    while q:
                        req = q.popleft()
                        self._count -= 1
                        if self._is_stale(req, now):
                            self._shed(req, "stale")
                            continue
                        return req
                if self._closed:
                    return None
                if deadline is not None:
                    remaining = deadline - now
                    if remaining <= 0:
                        return None
                    self._not_empty.wait(remaining)
                else:
                    self._not_empty.wait()

    def close(self) -> None:
        """Stop admitting; fail all queued requests; wake every getter."""
        with self._lock:
            self._closed = True
            for q in self._tiers.values():
                while q:
                    q.popleft().fail(ServerClosed("server stopped"))
            self._count = 0
            self._not_empty.notify_all()

    def depth(self) -> int:
        with self._lock:
            return self._count

    def depths(self) -> Dict[str, int]:
        with self._lock:
            return {t.name: len(q) for t, q in self._tiers.items()}
