"""Cache lifetime and prewarming policy for the plan-serving daemon.

Two policies compose with the PlanCache's LRU rather than replacing it:

  * ``TTLPolicy`` -- entries expire by *age*, not just by recency of use.
    LRU alone keeps a hot fingerprint alive forever, but in a serving
    daemon a months-old plan for a still-popular signature pins memory
    for traffic whose surrounding family has long since drifted; a TTL
    bounds staleness.  The server consults ``expired`` on every lookup
    (an expired hit is served as a miss and evicted) and may ``sweep``
    opportunistically.

  * ``DriftPredictor`` -- dynamic MoE traffic moves along a trajectory:
    iteration t+1's matrix is usually iteration t's plus a small routing
    shift.  The predictor keeps the last two distinct matrices per
    (cluster, topology, algorithm) family and linearly extrapolates the
    next one (``2 * last - prev``, clipped nonnegative, diagonal zeroed).
    The daemon synthesizes the prediction at BACKGROUND priority before
    any client asks: an exact guess becomes a fast-path cache hit, and
    even a near miss refreshes the family head so the next warm repair
    starts from a plan one drift step closer to the request.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..analysis.locks import make_lock
from ..core.plan import PlanCache, cluster_family_key
from ..core.traffic import Workload

__all__ = ["TTLPolicy", "DriftPredictor"]


class TTLPolicy:
    """Age out cache entries ``ttl_seconds`` after insertion.

    ``ttl_seconds=None`` disables expiry (every check returns False), so
    a server can always carry a policy object.  Thread-safe; the clock is
    injectable for tests.
    """

    def __init__(self, ttl_seconds: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        if ttl_seconds is not None and ttl_seconds <= 0:
            raise ValueError("ttl_seconds must be positive (or None)")
        self.ttl_seconds = ttl_seconds
        self._clock = clock
        self._lock = make_lock("TTLPolicy._lock")
        self._born: "OrderedDict[str, float]" = OrderedDict()

    def note_insert(self, key: str) -> None:
        with self._lock:
            self._born[key] = self._clock()
            self._born.move_to_end(key)

    def forget(self, key: str) -> None:
        with self._lock:
            self._born.pop(key, None)

    def expired(self, key: str) -> bool:
        if self.ttl_seconds is None:
            return False
        with self._lock:
            born = self._born.get(key)
            if born is None:
                return False  # not tracked (inserted before the policy)
            return (self._clock() - born) > self.ttl_seconds

    def sweep(self, cache: PlanCache, limit: Optional[int] = None
              ) -> List[str]:
        """Evict every expired entry from ``cache``; returns evicted keys.

        Insertion order makes the scan short: entries age in the order
        they were born, so the walk stops at the first live one.
        """
        if self.ttl_seconds is None:
            return []
        evicted: List[str] = []
        with self._lock:
            now = self._clock()
            for key, born in self._born.items():
                if (now - born) <= self.ttl_seconds:
                    break
                evicted.append(key)
                if limit is not None and len(evicted) >= limit:
                    break
            for key in evicted:
                del self._born[key]
        for key in evicted:
            cache.evict(key)
        return evicted


class DriftPredictor:
    """Extrapolate the likely-next traffic matrix per plan family.

    ``observe`` feeds the request stream in arrival order; ``predict``
    returns candidate Workloads worth synthesizing ahead of demand.  Only
    the last two *distinct* matrices per family are kept (exact repeats
    carry no drift signal), bounded to ``max_families`` LRU families so a
    daemon serving many fabrics cannot grow without bound.
    """

    def __init__(self, max_families: int = 64):
        if max_families < 1:
            raise ValueError("max_families must be >= 1")
        self.max_families = max_families
        self._lock = make_lock("DriftPredictor._lock")
        # family key -> (workload template, [prev_matrix, last_matrix],
        #                algorithm)
        self._families: "OrderedDict[str, Tuple[Workload, List[np.ndarray], str]]"  # noqa: E501
        self._families = OrderedDict()

    def observe(self, w: Workload, algorithm: str) -> None:
        family = cluster_family_key(w, algorithm)
        with self._lock:
            entry = self._families.get(family)
            if entry is None:
                self._families[family] = (w, [w.matrix], algorithm)
            else:
                history = entry[1]
                if not np.array_equal(history[-1], w.matrix):
                    history.append(w.matrix)
                    del history[:-2]  # keep (prev, last)
                self._families[family] = (w, history, algorithm)
            self._families.move_to_end(family)
            while len(self._families) > self.max_families:
                self._families.popitem(last=False)

    def predict(self, w: Workload, algorithm: str) -> List[Workload]:
        """Likely-next workloads for ``w``'s family (possibly empty).

        Linear extrapolation of the last drift step; requires two distinct
        observed matrices and a nonzero delta, and never predicts a matrix
        identical to the last observation (that one is already cached).
        """
        family = cluster_family_key(w, algorithm)
        with self._lock:
            entry = self._families.get(family)
            if entry is None or len(entry[1]) < 2:
                return []
            template, (prev, last), _ = entry
        nxt = np.maximum(2.0 * last - prev, 0.0)
        np.fill_diagonal(nxt, 0.0)
        if np.array_equal(nxt, last):
            return []
        return [Workload(template.cluster, nxt, template.topology)]

    def families(self) -> int:
        with self._lock:
            return len(self._families)

    def snapshot(self) -> List[Tuple[str, Workload, str]]:
        """Every tracked family's latest traffic, MRU last: ``(family
        key, workload carrying the last observed matrix, algorithm)``.

        The fabric-event re-repair walk consumes this -- the predictor is
        the one component that already knows, per family, *what traffic
        to re-plan for* on the new topology."""
        with self._lock:
            return [(family, Workload(w.cluster, history[-1], w.topology),
                     algo)
                    for family, (w, history, algo)
                    in self._families.items()]

    def rehome(self, old_fingerprint: str, topology) -> int:
        """Migrate families observed on a pre-event fabric to the new one.

        Keeps each family's drift history (prev/last matrices) across a
        fabric event, so prewarming keeps predicting through the event
        window instead of restarting cold under the new family keys.
        Returns the number of families migrated."""
        with self._lock:
            moved = 0
            for family in list(self._families.keys()):
                w, history, algo = self._families[family]
                t = w.topo
                if t.fingerprint() != old_fingerprint:
                    continue
                if (t.n_servers, t.m_gpus) != (topology.n_servers,
                                               topology.m_gpus):
                    continue
                new_w = Workload(w.cluster, w.matrix, topology)
                new_family = cluster_family_key(new_w, algo)
                self._families.pop(family)
                self._families[new_family] = (new_w, history, algo)
                moved += 1
            return moved
