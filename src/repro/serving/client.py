"""Client-side adapter for the plan-serving daemon.

``PlanClient`` is the piece launch scripts and training loops hold: it
pins the request policy (algorithm, tier, timeout) once, then exposes the
same verbs as the inline path -- ``get_plan``, ``simulate``,
``simulate_many`` -- so routing a job through the daemon is a one-line
swap.

Failure policy (the client's half of fault tolerance): a transient
daemon failure -- queue saturated (``AdmissionError``) or a per-attempt
timeout -- is retried with bounded exponential backoff, because during a
fabric-event window the daemon is busy re-repairing and a moment later
usually answers.  A ``ServerClosed`` is terminal and is never retried.
When the retries (or the overall ``deadline``) are exhausted, the client
falls back to inline synthesis by default: the daemon is an accelerator,
never a new single point of failure.  Fallback answers are tagged
``source="inline"`` and tallied in the client's own counters, alongside
``retries``.  The clock and sleep are injectable so tests drive the
backoff schedule without real waiting.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence

from ..core.plan import traffic_fingerprint
from ..core.schedulers import get_scheduler
from ..core.simulator import SimResult, execute_plan
from ..core.traffic import Workload
from .queue import AdmissionError, ServerClosed, Tier
from .server import PlanAnswer, PlanServer

__all__ = ["PlanClient"]


class PlanClient:
    """One job's handle on a shared ``PlanServer``.

    Args:
      server: the daemon to route plan requests through.
      algorithm: scheduler registry name used for every request.
      tier: queue priority for this client's requests.
      timeout: seconds to wait for an answer *per attempt* before the
        attempt counts as failed.
      inline_fallback: when False, exhausted retries raise instead of
        silently synthesizing locally (benchmarks that must measure only
        the daemon set this).
      max_retries: transient failures (AdmissionError, attempt timeout)
        retried this many times after the first attempt, with bounded
        exponential backoff (``backoff_base * 2**k``, capped at
        ``backoff_cap``).  0 restores fail-fast.
      deadline: overall wall-clock budget across all attempts and
        backoffs; None means only ``timeout``/``max_retries`` bound the
        wait.  Attempt timeouts and backoff sleeps are trimmed to the
        remaining budget.
      clock / sleep: injectable time sources (tests use a fake clock to
        verify the backoff schedule deterministically).
    """

    def __init__(self, server: PlanServer, *, algorithm: str = "flash",
                 tier: Tier = Tier.INTERACTIVE,
                 timeout: Optional[float] = 60.0,
                 inline_fallback: bool = True,
                 max_retries: int = 2,
                 backoff_base: float = 0.05,
                 backoff_cap: float = 1.0,
                 deadline: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if backoff_base < 0 or backoff_cap < 0:
            raise ValueError("backoff must be nonnegative")
        if deadline is not None and deadline <= 0:
            raise ValueError("deadline must be positive (or None)")
        self.server = server
        self.algorithm = algorithm
        self.tier = tier
        self.timeout = timeout
        self.inline_fallback = inline_fallback
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.deadline = deadline
        self._clock = clock
        self._sleep = sleep
        self.counters: Dict[str, int] = {
            "requests": 0, "hit": 0, "warm": 0, "cold": 0, "inline": 0,
            "coalesced": 0, "retries": 0, "lowered": 0}

    # -- retry plumbing ----------------------------------------------------

    def _remaining(self, start: float) -> Optional[float]:
        """Seconds left in the overall deadline (None = unbounded)."""
        if self.deadline is None:
            return None
        return self.deadline - (self._clock() - start)

    def _attempt_timeout(self, start: float) -> Optional[float]:
        remaining = self._remaining(start)
        if remaining is None:
            return self.timeout
        if self.timeout is None:
            return remaining
        return min(self.timeout, remaining)

    def _backoff(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based), exponential, capped."""
        return min(self.backoff_cap, self.backoff_base * (2.0 ** (attempt - 1)))

    def get_plan(self, w: Workload) -> PlanAnswer:
        """A served plan for ``w`` -- from the daemon (with retries), or
        inline fallback once retries/deadline are exhausted."""
        self.counters["requests"] += 1
        start = self._clock()
        attempt = 0
        answer: Optional[PlanAnswer] = None
        last_exc: Optional[Exception] = None
        while answer is None:
            remaining = self._remaining(start)
            if remaining is not None and remaining <= 0:
                break  # deadline spent before this attempt could start
            try:
                answer = self.server.request(
                    w, self.algorithm, self.tier,
                    timeout=self._attempt_timeout(start))
            except ServerClosed as exc:
                last_exc = exc
                break  # terminal: a stopped server will not come back
            except (AdmissionError, TimeoutError) as exc:
                last_exc = exc
                attempt += 1
                if attempt > self.max_retries:
                    break
                delay = self._backoff(attempt)
                remaining = self._remaining(start)
                if remaining is not None:
                    if remaining <= 0:
                        break
                    delay = min(delay, remaining)
                self.counters["retries"] += 1
                if delay > 0:
                    self._sleep(delay)
        if answer is None:
            if not self.inline_fallback:
                raise last_exc if last_exc is not None else TimeoutError(
                    "plan request deadline exhausted")
            answer = self._inline(w)
        self.counters[answer.source] = self.counters.get(answer.source,
                                                         0) + 1
        return answer

    def _inline(self, w: Workload) -> PlanAnswer:
        t0 = time.perf_counter()
        scheduler = get_scheduler(self.algorithm)
        key = traffic_fingerprint(w, self.algorithm)
        plan = scheduler.synthesize(w, fingerprint=key)
        plan.compile()
        return PlanAnswer(plan=plan, source="inline", exact=True,
                          latency_s=time.perf_counter() - t0,
                          request_id=-1, tier=self.tier)

    def get_device_schedule(self, w: Workload, *,
                            n_pods: Optional[int] = None):
        """A served plan *plus* its device lowering, as ``(answer, sched)``.

        The handoff that closes the serving loop: clients that execute the
        exchange on device (``comm.plan_exec.plan_all_to_all``) need the
        lowered stage tables, not just the Plan.  The lowering is memoized
        on the plan object itself, so a daemon cache hit hands back the
        already-lowered schedule for free; ``counters["lowered"]`` tallies
        only the requests that actually ran the lowering (cache misses).
        """
        from ..comm.plan_exec import is_lowered, lower_plan

        answer = self.get_plan(w)
        if not is_lowered(answer.plan, n_pods=n_pods):
            self.counters["lowered"] += 1
        return answer, lower_plan(answer.plan, n_pods=n_pods)

    def simulate(self, w: Workload) -> SimResult:
        """Inline-path-compatible simulate: plan via the daemon, then
        execute the workload against it."""
        return execute_plan(self.get_plan(w).plan, w)

    def simulate_many(self, workloads: Sequence[Workload]
                      ) -> List[SimResult]:
        """Trajectory simulate with client-side coalescing: one daemon
        request per *distinct* traffic fingerprint, not per workload.

        MoE drift trajectories revisit signatures (the paper's repeat
        mix); issuing a ticket per workload floods the queue with
        near-duplicate misses that the server repairs independently.
        Resolving each fingerprint once and re-executing the shared plan
        keeps the queue at the trajectory's distinct-matrix cardinality;
        ``counters["coalesced"]`` tallies the requests saved."""
        answers: Dict[str, PlanAnswer] = {}
        out: List[SimResult] = []
        for w in workloads:
            key = traffic_fingerprint(w, self.algorithm)
            answer = answers.get(key)
            if answer is None:
                answer = self.get_plan(w)
                answers[key] = answer
            else:
                self.counters["coalesced"] += 1
            out.append(execute_plan(answer.plan, w))
        return out
