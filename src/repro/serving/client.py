"""Client-side adapter for the plan-serving daemon.

``PlanClient`` is the piece launch scripts and training loops hold: it
pins the request policy (algorithm, tier, timeout) once, then exposes the
same verbs as the inline path -- ``get_plan``, ``simulate``,
``simulate_many`` -- so routing a job through the daemon is a one-line
swap.  When the daemon cannot answer (queue saturated, request shed or
timed out, server stopped), the client falls back to inline synthesis by
default: the daemon is an accelerator, never a new single point of
failure.  Fallback answers are tagged ``source="inline"`` and tallied in
the client's own counters.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from ..core.plan import traffic_fingerprint
from ..core.schedulers import get_scheduler
from ..core.simulator import SimResult, execute_plan
from ..core.traffic import Workload
from .queue import AdmissionError, ServerClosed, Tier
from .server import PlanAnswer, PlanServer

__all__ = ["PlanClient"]


class PlanClient:
    """One job's handle on a shared ``PlanServer``.

    Args:
      server: the daemon to route plan requests through.
      algorithm: scheduler registry name used for every request.
      tier: queue priority for this client's requests.
      timeout: seconds to wait for an answer before falling back.
      inline_fallback: when False, daemon failures raise instead of
        silently synthesizing locally (benchmarks that must measure only
        the daemon set this).
    """

    def __init__(self, server: PlanServer, *, algorithm: str = "flash",
                 tier: Tier = Tier.INTERACTIVE,
                 timeout: Optional[float] = 60.0,
                 inline_fallback: bool = True):
        self.server = server
        self.algorithm = algorithm
        self.tier = tier
        self.timeout = timeout
        self.inline_fallback = inline_fallback
        self.counters: Dict[str, int] = {
            "requests": 0, "hit": 0, "warm": 0, "cold": 0, "inline": 0,
            "coalesced": 0}

    def get_plan(self, w: Workload) -> PlanAnswer:
        """A served plan for ``w`` -- from the daemon, or inline fallback."""
        self.counters["requests"] += 1
        try:
            answer = self.server.request(w, self.algorithm, self.tier,
                                         timeout=self.timeout)
        except (AdmissionError, ServerClosed, TimeoutError):
            if not self.inline_fallback:
                raise
            answer = self._inline(w)
        self.counters[answer.source] = self.counters.get(answer.source,
                                                         0) + 1
        return answer

    def _inline(self, w: Workload) -> PlanAnswer:
        t0 = time.perf_counter()
        scheduler = get_scheduler(self.algorithm)
        key = traffic_fingerprint(w, self.algorithm)
        plan = scheduler.synthesize(w, fingerprint=key)
        plan.compile()
        return PlanAnswer(plan=plan, source="inline", exact=True,
                          latency_s=time.perf_counter() - t0,
                          request_id=-1, tier=self.tier)

    def simulate(self, w: Workload) -> SimResult:
        """Inline-path-compatible simulate: plan via the daemon, then
        execute the workload against it."""
        return execute_plan(self.get_plan(w).plan, w)

    def simulate_many(self, workloads: Sequence[Workload]
                      ) -> List[SimResult]:
        """Trajectory simulate with client-side coalescing: one daemon
        request per *distinct* traffic fingerprint, not per workload.

        MoE drift trajectories revisit signatures (the paper's repeat
        mix); issuing a ticket per workload floods the queue with
        near-duplicate misses that the server repairs independently.
        Resolving each fingerprint once and re-executing the shared plan
        keeps the queue at the trajectory's distinct-matrix cardinality;
        ``counters["coalesced"]`` tallies the requests saved."""
        answers: Dict[str, PlanAnswer] = {}
        out: List[SimResult] = []
        for w in workloads:
            key = traffic_fingerprint(w, self.algorithm)
            answer = answers.get(key)
            if answer is None:
                answer = self.get_plan(w)
                answers[key] = answer
            else:
                self.counters["coalesced"] += 1
            out.append(execute_plan(answer.plan, w))
        return out
