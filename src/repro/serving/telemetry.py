"""Serving telemetry: counters, latency percentiles, synthesis histograms.

One ``Telemetry`` instance is shared by every thread of a ``PlanServer``
(client fast paths, queue workers, the background synthesizer); a single
lock makes every update and the whole ``snapshot()`` atomic, so the
exported numbers are mutually consistent -- ``requests`` always equals the
sum of its outcome counters at the instant of the snapshot, never a torn
mid-update view.

The schema of ``snapshot()`` (JSON-compatible throughout; see DESIGN.md
section 2):

    {
      "counters":  {"requests": int, "hits": int, "warm": int, ...},
      "latency":   {tier_name: {"count", "p50_us", "p90_us", "p99_us",
                                "max_us"}},
      "synthesis": {"count": int, "seconds_sum": float,
                    "hist": {"<=1e-05s": int, "<=0.0001s": int, ...}},
      "repair":    {"count": int, "residual_sum": float,
                    "hist": {"<=0.01": int, ..., ">1": int}},
      "queue":     {"depth": int, "peak_depth": int},
      "fabric":    {"version": int, "events": int,
                    "last_event": str | None},
    }
"""

from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from ..analysis.locks import make_lock

__all__ = ["Telemetry", "LatencyReservoir"]


class LatencyReservoir:
    """Bounded sample buffer with percentile extraction.

    Keeps the most recent ``capacity`` samples (a ring): serving telemetry
    wants *recent* latency percentiles, and an unbounded list would grow
    without limit in a long-running daemon.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._buf: List[float] = []
        self._next = 0  # ring cursor once the buffer is full
        self.count = 0  # total ever observed
        self.max_value = 0.0

    def add(self, value: float) -> None:
        value = float(value)
        if len(self._buf) < self.capacity:
            self._buf.append(value)
        else:
            self._buf[self._next] = value
            self._next = (self._next + 1) % self.capacity
        self.count += 1
        if value > self.max_value:
            self.max_value = value

    def percentile(self, q: float) -> float:
        if not self._buf:
            return 0.0
        return float(np.percentile(np.asarray(self._buf), q))

    def summary_us(self) -> Dict[str, float]:
        """count + p50/p90/p99/max in microseconds (JSON-ready)."""
        if not self._buf:
            return {"count": self.count, "p50_us": 0.0, "p90_us": 0.0,
                    "p99_us": 0.0, "max_us": 0.0}
        arr = np.asarray(self._buf) * 1e6
        p50, p90, p99 = np.percentile(arr, [50, 90, 99])
        return {"count": self.count, "p50_us": float(p50),
                "p90_us": float(p90), "p99_us": float(p99),
                "max_us": self.max_value * 1e6}


# Log-decade bucket edges for synthesis wall time, in seconds: 10us is the
# paper's small-cluster synthesis scale, minutes the pathological ceiling.
_SYNTH_EDGES = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0)

# Residual-fraction edges for warm repair: how much of each miss's traffic
# fell outside the previous plan's permutations.  The tail bucket past the
# default 0.25 bail threshold counts repairs that tripped to cold, so the
# histogram shows directly whether a deployment's drift fits its
# RepairConfig.
_REPAIR_EDGES = (0.01, 0.05, 0.10, 0.25, 0.50, 1.0)


class Telemetry:
    """Thread-safe serving metrics with an atomic JSON snapshot."""

    def __init__(self, latency_capacity: int = 4096):
        self._lock = make_lock("Telemetry._lock")
        self._counters: Dict[str, int] = {}
        self._latency: Dict[str, LatencyReservoir] = {}
        self._latency_capacity = latency_capacity
        self._synth_hist = [0] * (len(_SYNTH_EDGES) + 1)
        self._synth_count = 0
        self._synth_sum = 0.0
        self._repair_hist = [0] * (len(_REPAIR_EDGES) + 1)
        self._repair_count = 0
        self._repair_sum = 0.0
        self._queue_depth = 0
        self._queue_peak = 0
        self._fabric_version = 0
        self._fabric_events = 0
        self._fabric_last: str = ""

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def get(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def observe_latency(self, tier_name: str, seconds: float) -> None:
        with self._lock:
            res = self._latency.get(tier_name)
            if res is None:
                res = self._latency[tier_name] = LatencyReservoir(
                    self._latency_capacity)
            res.add(seconds)

    def observe_synthesis(self, seconds: float) -> None:
        with self._lock:
            i = int(np.searchsorted(_SYNTH_EDGES, seconds))
            self._synth_hist[i] += 1
            self._synth_count += 1
            self._synth_sum += float(seconds)

    def observe_repair_residual(self, fraction: float) -> None:
        """Record one warm-repair attempt's residual fraction (the share
        of the new matrix that fell outside the previous plan's slots)."""
        with self._lock:
            i = int(np.searchsorted(_REPAIR_EDGES, fraction))
            self._repair_hist[i] += 1
            self._repair_count += 1
            self._repair_sum += float(fraction)

    def observe_fabric_event(self, version: int, description: str) -> None:
        """Record one applied fabric event (serving/events.py): the
        daemon's current fabric version plus a human-readable tail."""
        with self._lock:
            self._fabric_version = int(version)
            self._fabric_events += 1
            self._fabric_last = description

    def observe_queue_depth(self, depth: int) -> None:
        with self._lock:
            self._queue_depth = int(depth)
            if depth > self._queue_peak:
                self._queue_peak = int(depth)

    def latency_percentile(self, tier_name: str, q: float) -> float:
        with self._lock:
            res = self._latency.get(tier_name)
            return res.percentile(q) if res is not None else 0.0

    def snapshot(self) -> Dict:
        """One consistent, JSON-compatible view of everything."""
        with self._lock:
            hist = {}
            for i, count in enumerate(self._synth_hist):
                label = (f"<={_SYNTH_EDGES[i]:g}s"
                         if i < len(_SYNTH_EDGES)
                         else f">{_SYNTH_EDGES[-1]:g}s")
                hist[label] = count
            repair_hist = {}
            for i, count in enumerate(self._repair_hist):
                label = (f"<={_REPAIR_EDGES[i]:g}"
                         if i < len(_REPAIR_EDGES)
                         else f">{_REPAIR_EDGES[-1]:g}")
                repair_hist[label] = count
            return {
                "counters": dict(self._counters),
                "latency": {name: res.summary_us()
                            for name, res in self._latency.items()},
                "synthesis": {"count": self._synth_count,
                              "seconds_sum": self._synth_sum,
                              "hist": hist},
                "repair": {"count": self._repair_count,
                           "residual_sum": self._repair_sum,
                           "hist": repair_hist},
                "queue": {"depth": self._queue_depth,
                          "peak_depth": self._queue_peak},
                "fabric": {"version": self._fabric_version,
                           "events": self._fabric_events,
                           "last_event": self._fabric_last or None},
            }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
