"""Plan-driven device All-to-All: lower a synthesized Plan into shard_map.

This is the bridge between the two halves of the reproduction: the
host-side scheduler (``repro.core``: FLASH synthesis -> typed ``Plan`` ->
``ExecutableSchedule``) and the jit-integrated comm layer
(``comm.all_to_all``).  ``lower_plan`` turns a plan's Birkhoff permutation
stages into a static ``DeviceSchedule``; ``plan_all_to_all`` executes that
schedule inside ``shard_map`` and is registered as ``impl="plan"`` in the
one A2A registry, so ``resolve_all_to_all`` / ``models/moe.py`` /
``launch/serve.py`` pick it up with zero call-site changes.

Static-pattern constraint (why lowering exists at all): XLA compiles a
*static* communication pattern, so the dynamic plan cannot be interpreted
on device.  Instead the stage permutations are baked as Python constants
into the traced program -- one ``lax.ppermute`` over the slow axis per
lowered stage -- and the lowering is memoized on the ``Plan`` object per
pod count, exactly like ``Plan.compile`` memoizes per execution-topology
fingerprint.  A serving loop that hands out cached plans therefore hands
out their lowered schedules for free: a drifted MoE matrix re-lowers only
on a cache miss (see ``serving.client.PlanClient.get_device_schedule``).

Exactness: the device exchange moves the *capacity-padded* MoE buffer --
every (src pod, dst pod) pair owes exactly one equal-size block, so a
correct program delivers each ordered pair exactly once.  A plan's stages
schedule pairs in proportion to *bytes* (a pair can appear in many
capacity-aware stages, a zero-traffic pair in none), so the lowering takes
each pair's **first** occurrence as its transfer stage and then appends
rotation stages covering any pairs the plan never named (zero-traffic
pairs still carry their padding block).  The result is bit-identical to
``direct_all_to_all`` on every routed-token exchange while moving bulk
traffic in the plan's stage order -- the property the subprocess golden
tests in tests/test_comm.py pin down.

Phase mapping (mirrors ``flash_all_to_all``, which lowers the *uniform*
special case of the same schedule):

  load balance  -> the per-stage send blocks are packed destination-
                   contiguously (``kernels/a2a_pack``) and rail-aligned by
                   ONE intra-pod all_to_all over the fast axes -- the
                   plan's LoadBalancePhase, with the targets carried by
                   the packed stage order;
  merged xfer   -> one ``lax.ppermute`` over the slow axis per lowered
                   stage, each shipping a stage-sized contiguous buffer;
  redistribute  -> a no-op in the aligned layout; the received stage
                   buffers are scattered back to source-shard slots on
                   device (``a2a_unpack``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .all_to_all import _as_tuple, axis_sizes, register_all_to_all_impl

__all__ = ["DeviceSchedule", "lower_plan", "is_lowered", "plan_all_to_all"]

_MEMO_ATTR = "_device_sched"
_MEMO_CAP = 8  # serving loops see 1-2 pod counts per plan (Plan.compile's cap)


@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    """A plan lowered to static ppermute stages over ``n_pods`` pods.

    ``pairs[k]`` is stage ``k``'s ppermute permutation -- the live
    ``(src, dst)`` pod pairs, incast-free (a partial permutation; pods can
    idle).  ``dst_of[k][q]`` / ``src_of[k][q]`` are pod ``q``'s send
    target / receive source in stage ``k`` (-1 = idle), the tables the
    SPMD program gathers its own role from at trace time.  Stages
    ``< n_plan_stages`` came from the plan (first occurrence of each
    pair, plan order); the remaining ``n_fallback_stages`` are the
    coverage-completing rotations for pairs the plan never scheduled.
    """

    n_pods: int
    pairs: Tuple[Tuple[Tuple[int, int], ...], ...]
    dst_of: Tuple[Tuple[int, ...], ...]
    src_of: Tuple[Tuple[int, ...], ...]
    n_plan_stages: int
    n_fallback_stages: int
    plan_fingerprint: Optional[str]
    algorithm: str

    @property
    def n_stages(self) -> int:
        return len(self.pairs)


def _iter_perm_stages(plan):
    """Every inter-server permutation of ``plan`` in execution order.

    Delegates to ``Plan.iter_perm_stages`` (the core-side device-lowering
    view); the structural fallback keeps duck-typed plan stand-ins from
    tests working.
    """
    view = getattr(plan, "iter_perm_stages", None)
    if view is not None:
        yield from view()
        return
    from ..core.plan import PermutationBlock, PermutationStage

    for phase in plan.phases:
        if isinstance(phase, PermutationStage):
            yield phase.perm
        elif isinstance(phase, PermutationBlock):
            for row in phase.perms:
                yield tuple(int(j) for j in row)


def _as_plan(plan_or_schedule):
    """Accept a Plan or anything carrying one (ExecutableSchedule)."""
    inner = getattr(plan_or_schedule, "plan", None)
    return plan_or_schedule if inner is None else inner


def _stage_tables(n: int, stage_pairs):
    dst = [-1] * n
    src = [-1] * n
    for s, d in stage_pairs:
        dst[s] = d
        src[d] = s
    return tuple(dst), tuple(src)


def lower_plan(plan_or_schedule, n_pods: Optional[int] = None
               ) -> DeviceSchedule:
    """Lower a ``Plan`` / ``ExecutableSchedule`` to a ``DeviceSchedule``.

    Pure function of (plan stages, n_pods) -- deterministic per plan
    fingerprint -- and memoized on the plan object keyed by ``n_pods``,
    alongside the ``Plan.compile`` slot, so a ``PlanCache`` hit (or a
    daemon answer) carries the lowering with it.
    """
    plan = _as_plan(plan_or_schedule)
    n = int(plan.cluster.n_servers)
    p = n if n_pods is None else int(n_pods)
    if p != n:
        raise ValueError(
            f"mesh slow axis has {p} pods but the plan was synthesized "
            f"for {n} servers; re-plan on a matching ClusterSpec")
    memo = plan.__dict__.get(_MEMO_ATTR)
    if memo is None:
        memo = {}
        object.__setattr__(plan, _MEMO_ATTR, memo)
    sched = memo.get(p)
    if sched is not None:
        return sched

    delivered = set()
    stages = []
    for perm in _iter_perm_stages(plan):
        fresh = []
        for s, d in enumerate(perm[:p]):
            d = int(d)
            if d < 0 or d == s or (s, d) in delivered:
                continue  # idle slot / self traffic / already shipped
            delivered.add((s, d))
            fresh.append((s, d))
        if fresh:
            stages.append(tuple(fresh))
    n_plan_stages = len(stages)
    # Coverage completion: pairs the plan never scheduled (zero traffic in
    # the matrix) still owe their capacity-padding block.  Each shift's
    # residue is itself a partial permutation, so incast-freedom holds.
    for shift in range(1, p):
        missing = tuple((q, (q + shift) % p) for q in range(p)
                        if (q, (q + shift) % p) not in delivered)
        if missing:
            stages.append(missing)
    sched = DeviceSchedule(
        n_pods=p,
        pairs=tuple(stages),
        dst_of=tuple(_stage_tables(p, st)[0] for st in stages),
        src_of=tuple(_stage_tables(p, st)[1] for st in stages),
        n_plan_stages=n_plan_stages,
        n_fallback_stages=len(stages) - n_plan_stages,
        plan_fingerprint=plan.fingerprint,
        algorithm=plan.algorithm,
    )
    if len(memo) >= _MEMO_CAP:
        memo.clear()
    memo[p] = sched
    return sched


def is_lowered(plan_or_schedule, n_pods: Optional[int] = None) -> bool:
    """True when ``lower_plan`` for this pod count would be a memo hit."""
    plan = _as_plan(plan_or_schedule)
    p = int(plan.cluster.n_servers) if n_pods is None else int(n_pods)
    return p in plan.__dict__.get(_MEMO_ATTR, {})


def _default_interpret() -> bool:
    # Pallas interpret mode everywhere but real TPUs (CPU CI, tests).
    return jax.default_backend() != "tpu"


@register_all_to_all_impl("plan")
def plan_all_to_all(x: jax.Array, slow_axis: str, fast_axes,
                    *, plan=None, schedule=None, use_kernel: bool = True,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Execute a lowered plan as the two-tier All-to-All schedule.

    Same contract as every registry impl -- ``x`` is ``[n_shards, ...]``
    slow-major, the result row ``s`` is the chunk combined shard ``s``
    sent here, bit-identical to ``direct_all_to_all`` -- but the DCN
    stage order comes from the synthesized plan instead of the fixed
    rotations.  ``plan`` (or ``schedule``) must be supplied;
    ``resolve_all_to_all(..., plan=...)`` closes over it.

    ``use_kernel`` routes the on-device slot packing/unpacking through the
    ``kernels/a2a_pack`` Pallas pair (scalar-prefetch DMA gather/scatter);
    False falls back to jnp gather/scatter (identical bits, no Pallas --
    the stable denominator for CPU wall-clock benchmarks).
    """
    src = schedule if schedule is not None else plan
    if src is None:
        raise ValueError(
            'impl="plan" needs a synthesized plan: pass plan=/schedule= '
            "through resolve_all_to_all (or DistContext.plan)")
    fast = _as_tuple(fast_axes) if fast_axes else ()
    p = lax.axis_size(slow_axis)
    i = axis_sizes(fast) if fast else 1
    n, rest = x.shape[0], x.shape[1:]
    if n != p * i:
        raise ValueError(f"leading dim {n} != slow*fast = {p}*{i}")
    sched = lower_plan(src, n_pods=p)
    if interpret is None:
        interpret = _default_interpret()
    my_pod = lax.axis_index(slow_axis)

    # 2D row view for the pack/unpack kernels: pod q's block is the
    # contiguous run of rows [q*B, (q+1)*B).
    inner = 1
    for dim in rest[:-1]:
        inner *= dim
    d = rest[-1] if rest else 1
    block = i * inner                     # rows per pod block
    x2 = x.reshape(p * block, d)
    s = sched.n_stages

    # Slot packing: bundle this device's send block for every stage into
    # one destination-contiguous buffer (slot 0 = the intra-pod block).
    # Idle stages (dst -1) pack the local block again; it is never shipped
    # (the pod is absent from that stage's ppermute pairs).
    dst_tab = jnp.asarray(sched.dst_of, jnp.int32)       # (S, P)
    dst_idx = jnp.concatenate(
        [my_pod[None].astype(jnp.int32),
         jnp.take(dst_tab, my_pod, axis=1) if s else
         jnp.zeros((0,), jnp.int32)])
    dst_idx = jnp.where(dst_idx < 0, my_pod.astype(jnp.int32), dst_idx)
    if use_kernel:
        from ..kernels.a2a_pack.a2a_pack import a2a_pack, a2a_unpack

        send = a2a_pack(x2, dst_idx, block_rows=block, interpret=interpret)
    else:
        send = jnp.take(x2.reshape(p, block, d), dst_idx,
                        axis=0).reshape(-1, d)
    buf = send.reshape(s + 1, i, *rest) if rest else \
        send.reshape(s + 1, i)

    # Load balance: ONE intra-pod all_to_all rail-aligns every stage block
    # (the plan's LoadBalancePhase; redistribute is then a no-op).
    if fast:
        buf = lax.all_to_all(buf, fast, split_axis=1, concat_axis=1,
                             tiled=True)

    # Merged transfers: one ppermute per lowered stage, stage-sized
    # contiguous buffers, static (src, dst) pairs baked from the plan.
    recv = [buf[0]]
    for k in range(s):
        recv.append(lax.ppermute(buf[k + 1], slow_axis,
                                 list(sched.pairs[k])))
    stack = jnp.stack(recv)                              # (S+1, i, *rest)

    # Slot unpacking: scatter each received stage block to its source
    # pod's output slot; non-receiving stages land in a trash block that
    # the final slice drops.  Coverage completion guarantees every real
    # output block is written exactly once.
    src_tab = jnp.asarray(sched.src_of, jnp.int32)       # (S, P)
    src_idx = jnp.concatenate(
        [my_pod[None].astype(jnp.int32),
         jnp.take(src_tab, my_pod, axis=1) if s else
         jnp.zeros((0,), jnp.int32)])
    src_idx = jnp.where(src_idx < 0, jnp.int32(p), src_idx)
    stack2 = stack.reshape((s + 1) * block, d)
    if use_kernel:
        out2 = a2a_unpack(stack2, src_idx, n_out_blocks=p + 1,
                          block_rows=block, interpret=interpret)
    else:
        out2 = jnp.zeros(((p + 1) * block, d), x.dtype)
        out2 = out2.reshape(p + 1, block, d).at[src_idx].set(
            stack2.reshape(s + 1, block, d)).reshape(-1, d)
    return out2[: p * block].reshape(n, *rest)
