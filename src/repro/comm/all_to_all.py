"""jit-integrated All-to-All collectives: FLASH two-tier schedule on TPU.

All functions here are meant to be called *inside* ``shard_map`` over a mesh
whose axes include one *slow* axis (inter-pod DCN, the paper's inter-server
network) and one or more *fast* axes (intra-pod ICI, the paper's NVLink/xGMI).

Semantics contract: every variant computes exactly

    out[src_shard] = chunk that shard ``src_shard`` addressed to this device

for ``x`` of shape ``[n_shards, ...]`` with the combined shard index ordered
slow-axis-major -- i.e. all variants are bit-identical to
``direct_all_to_all`` and interchangeable under a config flag.

TPU adaptation of the paper (see DESIGN.md section 3): XLA compiles a static
communication pattern, so the jit-integrated FLASH schedule is the
Birkhoff decomposition of the *balanced* post-load-balance matrix -- the
P-1 cyclic rotations sigma_k(p) = (p+k) mod P, each lowered to one
``collective_permute`` over the slow axis (a permutation collective is
incast-free by construction; equal static chunk sizes make it
straggler-free).  The three paper phases map to:

  load balance  -> intra-pod ``all_to_all`` aligning each chunk's carrier
                   with its final destination index ("rail" alignment)
  merged xfer   -> one ``ppermute`` per rotation over the slow axis; the
                   per-(pod pair) buffer is a single contiguous block
  redistribute  -> becomes a no-op in the aligned layout (the intra A2A ran
                   *before* the DCN hop); the MSCCL-style baseline
                   ``hierarchical_all_to_all`` runs it *after* instead

The genuinely dynamic-traffic form of FLASH (arbitrary skewed matrices, true
Hopcroft-Karp BvN) lives in ``repro.core`` and drives the host-side runtime
and the benchmarks.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Sequence, Tuple, Union

from .. import jax_compat  # noqa: F401  (installs shims on older jax)

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "direct_all_to_all",
    "flash_all_to_all",
    "hierarchical_all_to_all",
    "ALL_TO_ALL_IMPLS",
    "register_all_to_all_impl",
    "available_all_to_all_impls",
    "resolve_all_to_all",
    "axis_sizes",
]

AxisNames = Union[str, Tuple[str, ...]]

# name -> fn(x, slow_axis, fast_axes); the single registry through which
# model code, launch/ and benchmarks select jit-integrated A2A schedules.
ALL_TO_ALL_IMPLS: dict = {}


def register_all_to_all_impl(name: str):
    """Decorator: register a two-tier all_to_all implementation."""

    def deco(fn):
        ALL_TO_ALL_IMPLS[name] = fn
        return fn

    return deco


def available_all_to_all_impls() -> list:
    _ensure_extra_impls()
    return sorted(ALL_TO_ALL_IMPLS)


def _as_tuple(axes: AxisNames) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def axis_sizes(axes: AxisNames) -> int:
    """Product of mesh-axis sizes (valid inside shard_map)."""
    total = 1
    for a in _as_tuple(axes):
        total *= lax.axis_size(a)
    return total


@register_all_to_all_impl("direct")
def direct_all_to_all(x: jax.Array, slow_axis: str,
                      fast_axes: AxisNames) -> jax.Array:
    """Single flat all_to_all over the combined (slow, fast...) axis.

    This is the RCCL/NCCL-default analogue: one collective, every pair of
    shards exchanging its chunk point-to-point, with cross-pod chunks riding
    DCN as many small flows.  Combined shard index is slow-major, matching
    mesh axis order ("pod", "data", ...).
    """
    axes = (slow_axis, *(_as_tuple(fast_axes)))
    return lax.all_to_all(x, axes, split_axis=0, concat_axis=0, tiled=True)


def intra_all_to_all(x: jax.Array, fast_axes: AxisNames) -> jax.Array:
    """all_to_all restricted to the fast (intra-pod) axes."""
    return lax.all_to_all(
        x, _as_tuple(fast_axes), split_axis=0, concat_axis=0, tiled=True)


@register_all_to_all_impl("flash")
def flash_all_to_all(x: jax.Array, slow_axis: str,
                     fast_axes: AxisNames) -> jax.Array:
    """FLASH two-tier All-to-All: balance over ICI first, then one
    contiguous peer-to-peer DCN transfer per Birkhoff rotation.

    Args:
      x: [n_shards, ...] where n_shards = size(slow) * size(fast); row
        ``d`` is the chunk this device sends to combined shard ``d``
        (slow-major order).
      slow_axis: the inter-pod mesh axis name.
      fast_axes: intra-pod mesh axis name(s).

    Returns:
      [n_shards, ...]: row ``s`` is the chunk combined shard ``s`` sent here.
    """
    fast = _as_tuple(fast_axes)
    p = lax.axis_size(slow_axis)
    i = axis_sizes(fast)
    n, rest = x.shape[0], x.shape[1:]
    if n != p * i:
        raise ValueError(f"leading dim {n} != slow*fast = {p}*{i}")
    my_pod = lax.axis_index(slow_axis)

    x4 = x.reshape(p, i, *rest)  # [dst_pod, dst_fast, ...]
    out = jnp.zeros_like(x4)
    for shift in range(p):
        dst_pod = lax.rem(my_pod + shift, p)
        # Chunk of everything this device owes pod ``dst_pod``:
        blk = lax.dynamic_index_in_dim(x4, dst_pod, axis=0, keepdims=False)
        # Phase 1 -- load balance / rail alignment (intra-pod all_to_all):
        # after this, local device ``i`` carries the block destined to
        # *fast index i* of the destination pod, gathered from all local
        # sources: blk_aligned[k] = chunk (local src k -> dst (dst_pod, i)).
        blk_aligned = intra_all_to_all(blk, fast)
        if shift == 0:
            recv = blk_aligned  # purely intra-pod: overlapped with stage 1
            src_pod = my_pod
        else:
            # Phase 2 -- merged transfer: one contiguous buffer to the rail
            # peer (same fast index) in the destination pod.  Rotation
            # ``shift`` is one stage of the balanced Birkhoff schedule.
            perm = [(q, (q + shift) % p) for q in range(p)]
            recv = lax.ppermute(blk_aligned, slow_axis, perm)
            src_pod = lax.rem(my_pod - shift + p, p)
        # Phase 3 -- redistribute: no-op (alignment happened pre-DCN).
        out = lax.dynamic_update_index_in_dim(out, recv, src_pod, axis=0)
    return out.reshape(n, *rest)


@register_all_to_all_impl("hierarchical")
def hierarchical_all_to_all(x: jax.Array, slow_axis: str,
                            fast_axes: AxisNames) -> jax.Array:
    """MSCCL-style baseline: DCN transfer first, intra redistribute after.

    Same rotations over the slow axis, but each device ships its *own,
    unbalanced* per-destination block across DCN and the receiving pod then
    redistributes over ICI (gather-then-send of the paper's section 6.1
    MSCCL description, phases reversed relative to FLASH).  Byte counts on
    each tier match FLASH; only the phase order (and hence what can be
    overlapped / pooled) differs.
    """
    fast = _as_tuple(fast_axes)
    p = lax.axis_size(slow_axis)
    i = axis_sizes(fast)
    n, rest = x.shape[0], x.shape[1:]
    if n != p * i:
        raise ValueError(f"leading dim {n} != slow*fast = {p}*{i}")
    my_pod = lax.axis_index(slow_axis)

    x4 = x.reshape(p, i, *rest)
    out = jnp.zeros_like(x4)
    for shift in range(p):
        dst_pod = lax.rem(my_pod + shift, p)
        blk = lax.dynamic_index_in_dim(x4, dst_pod, axis=0, keepdims=False)
        if shift == 0:
            recv = blk
            src_pod = my_pod
        else:
            perm = [(q, (q + shift) % p) for q in range(p)]
            recv = lax.ppermute(blk, slow_axis, perm)
            src_pod = lax.rem(my_pod - shift + p, p)
        # Redistribute *after* the DCN hop (the un-balanced order).
        recv = intra_all_to_all(recv, fast)
        out = lax.dynamic_update_index_in_dim(out, recv, src_pod, axis=0)
    return out.reshape(n, *rest)


def fast_only_all_to_all(x: jax.Array, slow_axis: str,
                         fast_axes: AxisNames) -> jax.Array:
    """Degenerate case: EP axis entirely inside one pod (no slow traffic)."""
    del slow_axis
    return intra_all_to_all(x, _as_tuple(fast_axes))


def rotation_all_to_all(x: jax.Array, axis: str) -> jax.Array:
    """All-to-all over one axis as P-1 ppermute rotations.

    Semantically identical to ``lax.all_to_all(x, axis, 0, 0, tiled=True)``
    (rows = per-destination chunks) but lowered as the balanced Birkhoff
    rotation schedule -- one permutation collective per stage.  This is the
    FLASH-native form for a slow-axis-only exchange (mixtral: EP over
    ``pod``), and also works around an XLA SPMD crash ("Invalid binary
    instruction opcode copy") when all_to_all targets a single manual axis
    inside a partial-manual shard_map.
    """
    p = lax.axis_size(axis)
    my = lax.axis_index(axis)
    n, rest = x.shape[0], x.shape[1:]
    if n != p:
        raise ValueError(f"leading dim {n} != axis size {p}")
    out = jnp.zeros_like(x)
    for shift in range(p):
        dst = lax.rem(my + shift, p)
        blk = lax.dynamic_index_in_dim(x, dst, axis=0, keepdims=False)
        if shift == 0:
            recv, src = blk, my
        else:
            perm = [(q, (q + shift) % p) for q in range(p)]
            recv = lax.ppermute(blk, axis, perm)
            src = lax.rem(my - shift + p, p)
        out = lax.dynamic_update_index_in_dim(out, recv, src, axis=0)
    return out


def _ensure_extra_impls() -> None:
    """Import-on-demand registrations (plan_exec imports this module, so
    it cannot be imported at module scope without a cycle)."""
    if "plan" not in ALL_TO_ALL_IMPLS:
        from . import plan_exec  # noqa: F401  (registers impl="plan")


def all_to_all_by_name(name: str):
    _ensure_extra_impls()
    try:
        return ALL_TO_ALL_IMPLS[name]
    except KeyError:
        raise ValueError(
            f"unknown all_to_all impl {name!r}; pick from "
            f"{sorted(ALL_TO_ALL_IMPLS)}")


def resolve_all_to_all(
    dist=None,
    *,
    slow_axis: Optional[str] = None,
    ep_axes: Optional[Sequence[str]] = None,
    impl: str = "flash",
    topology=None,
    plan=None,
) -> Optional[Callable[[jax.Array], jax.Array]]:
    """Select the jit-integrated A2A schedule for an EP-axis layout.

    The single dispatch point for model code, ``launch/`` and benchmarks
    (previously hand-rolled inside ``models/moe.py``).  Pass either a
    ``DistContext``-like object (attributes ``slow_axis``, ``ep_axes``,
    ``a2a_impl``, optionally ``plan``) or the raw keyword form.

    Selection:
      * EP spans the slow axis plus fast axes -> the registered two-tier
        impl ``impl`` (flash | direct | hierarchical | plan | ...).
      * EP is exactly the slow axis -> the FLASH rotation schedule (every
        DCN link carries one contiguous chunk per stage, incast-free by
        construction), or the plan-driven stage schedule when
        ``impl="plan"``.
      * EP is fast-only -> a plain intra all_to_all over ICI.
      * No EP axes -> None (no exchange needed).

    ``impl="auto"`` resolves from what the caller knows: with a
    synthesized ``plan`` (or ``ExecutableSchedule``) supplied, auto picks
    ``"plan"`` -- the schedule already encodes the traffic *and* the
    fabric.  Otherwise it resolves from the fabric alone: on a
    heterogeneous or oversubscribed ``Topology`` (core/topology.py) the
    FLASH schedule's load-balance phase aligns per-rail shares with real
    link capacities, so auto picks ``flash``; on a homogeneous
    full-bisection fabric (or with no topology information) auto picks
    ``direct`` -- one fused collective, no balancing needed when every
    link is equal.

    ``impl="plan"`` (explicit or via auto) closes the returned callable
    over ``plan``; the per-fingerprint lowering happens in
    ``comm.plan_exec`` at trace time.

    Returns a unary ``buf -> buf`` callable, or None.
    """
    if dist is not None:
        slow_axis = dist.slow_axis
        ep_axes = dist.ep_axes
        impl = dist.a2a_impl
        topology = getattr(dist, "topology", topology)
        plan = getattr(dist, "plan", plan)
    if impl == "auto":
        if plan is not None:
            impl = "plan"
        else:
            hetero = topology is not None and not topology.is_homogeneous
            impl = "flash" if hetero else "direct"
    # Fail fast on unknown impl names on every path, including the
    # rotation/ICI-only ones that do not dispatch through the registry.
    two_tier = all_to_all_by_name(impl)
    if impl == "plan":
        if plan is None:
            raise ValueError(
                'impl="plan" needs a synthesized plan/schedule: pass '
                "plan= (or set DistContext.plan)")
        two_tier = partial(two_tier, plan=plan)
    ep = tuple(ep_axes or ())
    if not ep:
        return None
    if slow_axis in ep and len(ep) > 1:
        fast = tuple(a for a in ep if a != slow_axis)
        return partial(two_tier, slow_axis=slow_axis, fast_axes=fast)
    if ep == (slow_axis,):
        if impl == "plan":
            # slow-axis-only EP still follows the plan's stage order.
            return partial(two_tier, slow_axis=slow_axis, fast_axes=())
        return partial(rotation_all_to_all, axis=slow_axis)
    return partial(intra_all_to_all, fast_axes=ep)
