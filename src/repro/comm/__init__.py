"""jit-integrated collectives: FLASH all-to-all + gradient-sync variants."""

from .all_to_all import (
    ALL_TO_ALL_IMPLS,
    all_to_all_by_name,
    direct_all_to_all,
    flash_all_to_all,
    hierarchical_all_to_all,
    intra_all_to_all,
    rotation_all_to_all,
)
from .collectives import ef_compressed_psum, psum_bf16, tree_ef_state

__all__ = [
    "ALL_TO_ALL_IMPLS",
    "all_to_all_by_name",
    "direct_all_to_all",
    "flash_all_to_all",
    "hierarchical_all_to_all",
    "intra_all_to_all",
    "rotation_all_to_all",
    "ef_compressed_psum",
    "psum_bf16",
    "tree_ef_state",
]
