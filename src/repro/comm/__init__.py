"""jit-integrated collectives: FLASH all-to-all + gradient-sync variants.

Implementation selection goes through one registry
(``register_all_to_all_impl`` / ``resolve_all_to_all``) shared by model
code, ``launch/`` and the benchmarks; see DESIGN.md section 4.
"""

from .all_to_all import (
    ALL_TO_ALL_IMPLS,
    all_to_all_by_name,
    available_all_to_all_impls,
    direct_all_to_all,
    flash_all_to_all,
    hierarchical_all_to_all,
    intra_all_to_all,
    register_all_to_all_impl,
    resolve_all_to_all,
    rotation_all_to_all,
)
from .collectives import ef_compressed_psum, psum_bf16, tree_ef_state
from .plan_exec import DeviceSchedule, is_lowered, lower_plan, \
    plan_all_to_all

__all__ = [
    "DeviceSchedule",
    "is_lowered",
    "lower_plan",
    "plan_all_to_all",
    "ALL_TO_ALL_IMPLS",
    "all_to_all_by_name",
    "available_all_to_all_impls",
    "register_all_to_all_impl",
    "resolve_all_to_all",
    "direct_all_to_all",
    "flash_all_to_all",
    "hierarchical_all_to_all",
    "intra_all_to_all",
    "rotation_all_to_all",
    "ef_compressed_psum",
    "psum_bf16",
    "tree_ef_state",
]
