"""Distributed-optimization collectives beyond the paper.

* ``ef_compressed_psum`` -- int8 error-feedback gradient summation for the
  slow (DCN) axis: quantize (grad + error carry) per-tensor to int8,
  all_gather the int8 payload over the slow axis (P-1 small messages instead
  of a full-precision all-reduce), de-quantize and sum locally, and keep the
  quantization residual as next step's carry.  Cuts DCN gradient bytes 4x
  versus f32 psum (2x vs bf16) at equal asymptotic convergence (error
  feedback makes the compression unbiased over time).

* ``psum_bf16`` -- cheap middle ground: cast-to-bf16 all-reduce.

These follow the paper's design principle ("keep the slow tier maximally
utilized, spend fast-tier/compute resources to shrink slow-tier bytes") even
though the paper itself only schedules All-to-All.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["ef_compressed_psum", "psum_bf16", "tree_ef_state"]


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compressed_psum(
    grad: jax.Array,
    axis_name: str,
    error: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 gradient sum over ``axis_name``.

    Call inside shard_map.  Returns (summed_grad, new_error).  The wire
    payload over the slow axis is int8 data + one f32 scale per tensor.
    """
    carry = grad if error is None else grad + error
    q, scale = _quantize_int8(carry)
    # all_gather keeps payload int8 on the wire (a low-precision psum would
    # be upcast by the reduction); local dequant-sum costs fast-tier flops.
    q_all = lax.all_gather(q, axis_name)                    # [P, ...] int8
    s_all = lax.all_gather(scale, axis_name)                # [P]
    deq = q_all.astype(grad.dtype) * s_all.reshape(
        (-1,) + (1,) * (q.ndim))
    total = deq.sum(axis=0)
    my = lax.axis_index(axis_name)
    new_error = carry - q.astype(grad.dtype) * s_all[my]
    return total, new_error


def psum_bf16(grad: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce in bf16 (half the DCN bytes of f32)."""
    return lax.psum(grad.astype(jnp.bfloat16), axis_name).astype(grad.dtype)


def tree_ef_state(grads) -> dict:
    """Zero-initialized error-feedback carry matching a grad pytree."""
    return jax.tree.map(jnp.zeros_like, grads)
