"""whisper-tiny [audio]: enc-dec, conv frontend (stub) [arXiv:2212.04356].

The conv mel-frontend is a STUB per the assignment -- ``input_specs()``
provides precomputed frame embeddings of shape [B, encoder_len, d_model].
Encoder-decoder: decode shapes use self-attn KV cache + cross-attn cache.
"""

from .registry import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab=51865,
        encdec=True,
        n_encoder_layers=4,
        encoder_len=1500,
        frontend="audio_stub",
        norm="layernorm",
        act="gelu",
        scan_layers=False,  # 4 layers: unrolled HLO is fine
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke",
        family="encdec",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=128,
        encdec=True,
        n_encoder_layers=2,
        encoder_len=32,
        frontend="audio_stub",
        norm="layernorm",
        act="gelu",
        scan_layers=False,
    )


register("whisper-tiny", full, smoke)
