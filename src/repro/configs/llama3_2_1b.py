"""llama3.2-1b [dense]: small llama3 [hf:meta-llama/Llama-3.2-1B]."""

from .registry import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=128256,
        head_dim=64,
        rope_theta=5e5,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        head_dim=8,
        tie_embeddings=True,
        scan_layers=False,
    )


register("llama3.2-1b", full, smoke)
