"""xlstm-125m [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0 per the assignment: xLSTM blocks carry their own up/down projections
(pf=2 mLSTM / pf=4/3 sLSTM style folded into the block), no separate FFN.
Recurrent state => ``long_500k`` applicable.  Block pattern follows the
7:1 mLSTM:sLSTM ratio of the paper, adapted to 12 layers.
"""

from .registry import ModelConfig, register

_PATTERN = ("m", "m", "m", "s", "m", "m", "m", "s", "m", "m", "m", "s")


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        block_pattern=_PATTERN,
        norm="layernorm",
        act="gelu",
        scan_layers=False,  # heterogeneous pattern: unrolled
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        family="ssm",
        n_layers=2,
        d_model=32,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab=128,
        block_pattern=("m", "s"),
        norm="layernorm",
        act="gelu",
        scan_layers=False,
    )


register("xlstm-125m", full, smoke)
