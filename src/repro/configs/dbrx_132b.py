"""dbrx-132b [moe]: 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

EP mapping: 16 experts shard exactly over the ``data``(16) axis -> the
dispatch All-to-All stays on intra-pod ICI (FLASH degenerates to its
merged-transfer step only; see DESIGN.md section 3).
"""

from .registry import ModelConfig, MoESpec, register


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        moe=MoESpec(num_experts=16, top_k=4),
        rope_theta=5e5,
        norm="layernorm",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        moe=MoESpec(num_experts=4, top_k=2),
        norm="layernorm",
        scan_layers=False,
    )


register("dbrx-132b", full, smoke)
