"""granite-3-2b [dense]: GQA [hf:ibm-granite/granite-3.0-2b-base]."""

from .registry import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=49155,
        rope_theta=1e4,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=128,
        tie_embeddings=True,
        scan_layers=False,
    )


register("granite-3-2b", full, smoke)
