"""internvl2-1b [vlm]: InternViT + InternLM2 backbone [arXiv:2404.16821].

The transformer BACKBONE only; the vision frontend is a STUB per the
assignment -- ``input_specs()`` feeds precomputed patch embeddings which
occupy the first ``frontend_len`` positions of the sequence.
"""

from .registry import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151655,
        frontend="vision_stub",
        frontend_len=256,
        rope_theta=1e6,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        frontend="vision_stub",
        frontend_len=8,
        tie_embeddings=True,
        scan_layers=False,
    )


register("internvl2-1b", full, smoke)
