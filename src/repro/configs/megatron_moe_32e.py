"""megatron-moe-32e: the paper's OWN evaluation workload (section 6.2).

Megatron-LM MoE with 32 experts (one per 'GPU' in the paper's 4x8 testbed;
here: EP over pod(2) x data(16) = 32 shards -> dispatch/combine maximally
cross DCN).  This is the primary arch for validating the end-to-end FLASH
integration (Fig 14) and the capacity-pooling perf work.
"""

from .registry import ModelConfig, MoESpec, register


def full() -> ModelConfig:
    return ModelConfig(
        name="megatron-moe-32e",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=50304,
        moe=MoESpec(num_experts=32, top_k=2),
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="megatron-moe-32e-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        moe=MoESpec(num_experts=4, top_k=2),
        scan_layers=False,
    )


register("megatron-moe-32e", full, smoke)
