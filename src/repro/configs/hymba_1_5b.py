"""hymba-1.5b [hybrid]: parallel attn + mamba heads [arXiv:2411.13676].

Each block runs attention heads and Mamba (SSM, state=16) heads in
parallel on the same input and fuses their (normalized) outputs.  Most
layers use sliding-window attention; three use full attention (per the
paper).  SSM state + SWA cache => ``long_500k`` applicable.
"""

from .registry import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab=32001,
        head_dim=64,
        ssm_state=16,
        swa_window=1024,
        full_attn_layers=(0, 15, 31),
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke",
        family="hybrid",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=128,
        head_dim=16,
        ssm_state=4,
        swa_window=16,
        full_attn_layers=(0,),
        scan_layers=False,
    )


register("hymba-1.5b", full, smoke)
