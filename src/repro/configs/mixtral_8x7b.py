"""mixtral-8x7b [moe]: 8 experts top-2, SWA [arXiv:2401.04088].

EP mapping: 8 experts over ``pod``(2) x part of ICI -> dispatch/combine
cross DCN; this is the paper-representative FLASH cell (DESIGN.md section 3).
Sliding-window attention (w=4096) makes ``long_500k`` applicable.
"""

from .registry import ModelConfig, MoESpec, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        moe=MoESpec(num_experts=8, top_k=2),
        swa_window=4096,
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        moe=MoESpec(num_experts=4, top_k=2),
        swa_window=16,
        scan_layers=False,
    )


register("mixtral-8x7b", full, smoke)
