"""Assigned input-shape set (LM-family: seq_len x global_batch).

``train_*`` shapes lower ``train_step``; ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a KV cache of ``seq_len``);
``prefill_*`` lowers a forward pass producing the cache.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from .registry import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "applicable_shapes", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """Return a human-readable skip reason, or None if the cell runs.

    Per assignment: ``long_500k`` needs sub-quadratic attention -- skipped
    for pure full-attention archs; encoder-only archs would skip decode
    shapes (none assigned here are encoder-only).
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("pure full-attention arch: 512k-context decode requires "
                "sub-quadratic attention (assignment-directed skip)")
    return None


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    return tuple(s for s in SHAPES.values() if skip_reason(cfg, s) is None)
