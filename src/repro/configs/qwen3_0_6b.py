"""qwen3-0.6b [dense]: qk_norm, GQA [hf:Qwen/Qwen3-0.6B]."""

from .registry import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=3072,
        vocab=151936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=128,
        head_dim=16,
        qk_norm=True,
        tie_embeddings=True,
        scan_layers=False,
    )


register("qwen3-0.6b", full, smoke)
