"""Arch registry: ``--arch <id>`` surface for every assigned architecture."""

from .registry import (
    ModelConfig,
    MoESpec,
    get_config,
    list_archs,
    register,
    smoke_config,
)
from .shapes import SHAPES, ShapeSpec, applicable_shapes, skip_reason

__all__ = [
    "ModelConfig",
    "MoESpec",
    "get_config",
    "list_archs",
    "register",
    "smoke_config",
    "SHAPES",
    "ShapeSpec",
    "applicable_shapes",
    "skip_reason",
]
