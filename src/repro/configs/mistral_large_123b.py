"""mistral-large-123b [dense]: 88L GQA dense transformer
[hf:mistralai/Mistral-Large-Instruct-2407]."""

from .registry import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=28672,
        vocab=32768,
        head_dim=128,
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        head_dim=8,
        scan_layers=False,
    )


register("mistral-large-123b", full, smoke)
