"""Architecture config system.

Every assigned architecture is a frozen ``ModelConfig``; ``register`` /
``get_config`` give the launcher its ``--arch <id>`` surface.  Each arch
module also provides a ``smoke`` reduced config (same family, tiny sizes)
used by per-arch CPU smoke tests; the full config is exercised only through
the dry-run (ShapeDtypeStruct lowering, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "MoESpec",
    "ModelConfig",
    "register",
    "get_config",
    "list_archs",
    "smoke_config",
]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    capacity_factor: float = 2.0  # per (src shard, expert) padding factor
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None           # default d_model // n_heads
    moe: Optional[MoESpec] = None
    qk_norm: bool = False
    swa_window: Optional[int] = None          # sliding-window size (tokens)
    full_attn_layers: Tuple[int, ...] = ()    # layers overriding SWA -> full
    ssm_state: Optional[int] = None
    block_pattern: Optional[Tuple[str, ...]] = None  # xlstm: ("m","s",...)
    frontend: Optional[str] = None            # "vision_stub" | "audio_stub"
    frontend_len: int = 0                     # prefix positions fed by stub
    encdec: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500                   # whisper 30 s of frames
    norm: str = "rmsnorm"                     # rmsnorm | layernorm
    act: str = "silu"                         # silu (SwiGLU) | gelu
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # distribution / execution knobs (overridable per run)
    a2a_impl: str = "flash"                   # flash | direct | hierarchical
    remat: bool = True
    scan_layers: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # beyond-paper perf knobs (see EXPERIMENTS.md §Perf)
    seq_shard_activations: bool = False       # SP residual stream
    quantized_dispatch: bool = False          # int8 MoE a2a over DCN
    bf16_ce: bool = False                     # CE loss without f32 logits
    pure_dp: bool = False                     # no TP: replicate weights,
                                              # batch over every mesh axis
    fsdp: bool = False                        # ZeRO-3: shard params/moments
                                              # over the DP axes too
    remat_group: int = 0                      # two-level remat: outer scan
                                              # over groups of this many
                                              # layers (0 = flat remat)
    microbatches: int = 1                     # grad-accumulation chunks

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode with O(1)-per-token state at 500k context?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.swa_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.act == "silu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = attn
        if self.is_moe:
            per_layer += self.moe.num_experts * mlp + d * self.moe.num_experts
        elif self.family == "ssm":
            per_layer = _xlstm_block_params(self)
        elif self.family == "hybrid":
            per_layer = attn + _mamba_head_params(self) + mlp
        else:
            per_layer += mlp
        total = self.n_layers * per_layer + v * d  # embed
        if not self.tie_embeddings:
            total += v * d  # lm head
        if self.encdec:
            total += self.n_encoder_layers * (attn + mlp)  # encoder stack
            total += self.n_layers * attn                  # cross attention
        return total

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        mlp = 3 * d * f if self.act == "silu" else 2 * d * f
        dense = self.n_params() - self.n_layers * self.moe.num_experts * mlp
        return dense + self.n_layers * self.moe.top_k * mlp


def _xlstm_block_params(cfg: ModelConfig) -> int:
    # qkv + gates + out proj + up/down proj (pf=2 mLSTM block)
    d = cfg.d_model
    return 8 * d * d


def _mamba_head_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    n = cfg.ssm_state or 16
    d_in = 2 * d
    return 2 * d * d_in + d_in * (2 * n + 2) + d_in * d


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, **overrides) -> ModelConfig:
    _ensure_loaded()
    try:
        cfg = _REGISTRY[name]()
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; known: {list_archs()}")
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def smoke_config(name: str, **overrides) -> ModelConfig:
    _ensure_loaded()
    cfg = _SMOKE[name]()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    from . import (  # noqa: F401
        dbrx_132b,
        granite_3_2b,
        hymba_1_5b,
        internvl2_1b,
        llama3_2_1b,
        megatron_moe_32e,
        mistral_large_123b,
        mixtral_8x7b,
        qwen3_0_6b,
        whisper_tiny,
        xlstm_125m,
    )
    _LOADED = True
